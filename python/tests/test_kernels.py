"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle, swept over
shapes and weight distributions with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref, vnge

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def random_symmetric(n: int, seed: int, density: float = 0.3) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.0, 2.0, size=(n, n)) * (rng.uniform(size=(n, n)) < density)
    w = np.triu(a, k=1)
    w = w + w.T
    return w.astype(np.float32)


SIZES = st.sampled_from([2, 3, 4, 8, 16, 31, 64, 128])


@given(n=SIZES, seed=st.integers(0, 10_000))
def test_qstats_matches_ref(n, seed):
    w = random_symmetric(n, seed)
    rows, sq_part = vnge.q_stats_tiled(jnp.asarray(w))
    rows_ref, sq_ref = ref.q_stats_ref(jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(rows), np.asarray(rows_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(jnp.sum(sq_part)), float(sq_ref), rtol=1e-5, atol=1e-5)


@given(n=SIZES, seed=st.integers(0, 10_000))
def test_matvec_matches_ref(n, seed):
    w = random_symmetric(n, seed)
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=n).astype(np.float32)
    y = vnge.matvec_tiled(jnp.asarray(w), jnp.asarray(x))
    y_ref = ref.matvec_ref(jnp.asarray(w), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


@given(n=st.sampled_from([1, 2, 5, 17, 64]), seed=st.integers(0, 10_000))
def test_entropy_reduce_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    lam = rng.uniform(0.0, 1.0, size=n).astype(np.float32)
    lam[rng.uniform(size=n) < 0.3] = 0.0  # exercise the 0·ln0 mask
    got = float(vnge.entropy_reduce(jnp.asarray(lam)))
    want = float(ref.entropy_ref(jnp.asarray(lam)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_qstats_zero_matrix():
    w = jnp.zeros((8, 8), jnp.float32)
    rows, sq = vnge.q_stats_tiled(w)
    assert float(jnp.sum(rows)) == 0.0
    assert float(jnp.sum(sq)) == 0.0


def test_matvec_identity_like():
    n = 16
    w = jnp.eye(n, dtype=jnp.float32)  # not a graph, but checks the kernel math
    x = jnp.arange(n, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(vnge.matvec_tiled(w, x)), np.asarray(x), rtol=1e-6)


@pytest.mark.parametrize("n", [8, 64, 128])
def test_tile_divides(n):
    t = vnge._tile(n)
    assert n % t == 0 and 1 <= t <= vnge.TILE


def test_tile_odd_sizes():
    # t starts at min(TILE, n); halves until it divides n
    assert vnge._tile(31) == 31       # 31 divides itself
    assert vnge._tile(192) == 64      # 128 ∤ 192, halve once: 64 | 192
    assert 96 % vnge._tile(96) == 0


def test_kernels_jittable():
    # kernels must lower inside jit (the artifact path requirement)
    w = jnp.asarray(random_symmetric(16, 0))
    f = jax.jit(lambda w: vnge.q_stats_tiled(w)[0])
    np.testing.assert_allclose(
        np.asarray(f(w)), np.asarray(ref.q_stats_ref(w)[0]), rtol=1e-5
    )
