"""L2 model correctness: the kernel-backed graphs vs the dense-eigensolver
oracle, plus AOT lowering smoke (the exact path `make artifacts` exercises)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from compile import aot, model
from compile.kernels import ref


def er_graph(n: int, p: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = (rng.uniform(size=(n, n)) < p).astype(np.float32)
    w = np.triu(a, k=1)
    return (w + w.T).astype(np.float32)


@given(seed=st.integers(0, 500))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_q_stats_matches_oracle(seed):
    w = jnp.asarray(er_graph(64, 0.1, seed))
    (q,) = model.q_stats(w)
    q_ref = ref.quadratic_q_ref(w)
    np.testing.assert_allclose(float(q), float(q_ref), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,p", [(32, 0.2), (64, 0.1), (128, 0.05)])
def test_hhat_matches_eig_oracle(n, p):
    w = jnp.asarray(er_graph(n, p, 7))
    (hhat,) = model.hhat_dense(w)
    want = float(ref.hhat_ref(w))
    # f32 fixed-iteration power iteration vs f32 eigh oracle
    np.testing.assert_allclose(float(hhat), want, rtol=2e-3, atol=2e-3)


def test_hhat_empty_graph_zero():
    w = jnp.zeros((32, 32), jnp.float32)
    (hhat,) = model.hhat_dense(w)
    assert float(hhat) == 0.0


def test_jsdist_identical_zero():
    w = jnp.asarray(er_graph(64, 0.1, 3))
    (d,) = model.jsdist_dense(w, w)
    assert abs(float(d)) < 1e-3


def test_jsdist_matches_oracle():
    wa = jnp.asarray(er_graph(64, 0.10, 11))
    wb = jnp.asarray(er_graph(64, 0.14, 12))
    (d,) = model.jsdist_dense(wa, wb)
    want = float(ref.jsdist_ref(wa, wb))
    np.testing.assert_allclose(float(d), want, rtol=5e-2, atol=5e-3)


def test_jsdist_symmetry():
    wa = jnp.asarray(er_graph(64, 0.1, 21))
    wb = jnp.asarray(er_graph(64, 0.12, 22))
    (d1,) = model.jsdist_dense(wa, wb)
    (d2,) = model.jsdist_dense(wb, wa)
    np.testing.assert_allclose(float(d1), float(d2), rtol=1e-5, atol=1e-6)


def test_entry_points_table():
    assert set(model.ENTRY_POINTS) == {"q_stats", "hhat_dense", "jsdist_dense"}
    for _, (fn, arity) in model.ENTRY_POINTS.items():
        assert callable(fn) and arity in (1, 2)


@pytest.mark.parametrize("name", sorted(model.ENTRY_POINTS))
def test_aot_lowering_produces_hlo_text(name):
    fn, arity = model.ENTRY_POINTS[name]
    text = aot.lower_entry(name, fn, arity, 64)
    assert "HloModule" in text
    assert len(text) > 200


def test_aot_lowered_computation_is_executable():
    # compile+run the lowered module through XLA — the compiled-artifact
    # numerics check on the Python side (the Rust runtime_integration tests
    # exercise the HLO-text file path itself).
    fn, _arity = model.ENTRY_POINTS["q_stats"]
    w = er_graph(64, 0.1, 5)
    compiled = jax.jit(fn).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    (q,) = compiled(jnp.asarray(w))
    q_ref = float(ref.quadratic_q_ref(jnp.asarray(w)))
    np.testing.assert_allclose(float(q), q_ref, rtol=1e-4)


def test_aot_hlo_text_mentions_entry_shapes():
    # the HLO text must pin the lowered shapes (f32[64,64] inputs)
    text = aot.lower_entry("q_stats", *model.ENTRY_POINTS["q_stats"], 64)
    assert "f32[64,64]" in text
