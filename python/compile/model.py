"""L2 — JAX compute graphs for the FINGER dense path, calling the L1 Pallas
kernels. These are the functions `aot.py` lowers to HLO text; the Rust
runtime executes the lowered modules, so nothing here runs at request time.

Entry points (all take/return f32; shapes fixed at lowering):
  q_stats(w)            -> Q scalar                       (Lemma 1)
  hhat_dense(w)         -> Ĥ scalar                       (Eq. 1)
  jsdist_dense(wa, wb)  -> JSdist(G, G′) scalar           (Algorithm 1)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import vnge as kernels

# Power-iteration steps baked into the artifact (static for AOT; 128 steps
# converges λ_max to ~1e-6 relative on the graph families used here).
POWER_ITERS = 128


def _q_from_stats(rows, sq_partials):
    total = jnp.sum(rows)
    c = jnp.where(total > 0, 1.0 / total, 0.0)
    sumsq_w = jnp.sum(sq_partials)  # Σ_ij W² = 2 Σ_{(i,j)∈E} w²
    q = 1.0 - c * c * (jnp.sum(rows * rows) + sumsq_w)
    return jnp.where(total > 0, q, 0.0), rows, c


def q_stats(w):
    """Quadratic proxy Q of the graph with weight matrix w."""
    q, _, _ = _q_from_stats(*kernels.q_stats_tiled(w))
    return (q,)


def _lambda_max(w, rows, c):
    """λ_max(L_N) by fixed-iteration power iteration; L_N·x computed with the
    L1 mat-vec kernel: c·(s∘x − W·x)."""
    n = w.shape[0]

    def ln_matvec(x):
        return c * (rows * x - kernels.matvec_tiled(w, x))

    # deterministic, non-degenerate start (not in the Laplacian kernel)
    x0 = jnp.sin(jnp.arange(n, dtype=w.dtype) * 12.9898 + 0.5) + 1.5

    def norm(x):
        nm = jnp.sqrt(jnp.sum(x * x))
        return jnp.where(nm > 0, x / nm, x)

    def body(_, x):
        return norm(ln_matvec(x))

    x = jax.lax.fori_loop(0, POWER_ITERS, body, norm(x0))
    lam = jnp.dot(x, ln_matvec(x))
    return jnp.maximum(lam, 0.0)


def _hhat(w):
    q, rows, c = _q_from_stats(*kernels.q_stats_tiled(w))
    lam = _lambda_max(w, rows, c)
    return jnp.where(lam > 1e-12, jnp.maximum(-q * jnp.log(lam), 0.0), 0.0)


def hhat_dense(w):
    """FINGER-Ĥ (Eq. 1) on a dense weight matrix."""
    return (_hhat(w),)


def jsdist_dense(wa, wb):
    """FINGER-JSdist (Fast), Algorithm 1, on two dense weight matrices."""
    h_avg = _hhat((wa + wb) * 0.5)
    div = h_avg - 0.5 * (_hhat(wa) + _hhat(wb))
    return (jnp.sqrt(jnp.maximum(div, 0.0)),)


ENTRY_POINTS = {
    # name -> (fn, arity)
    "q_stats": (q_stats, 1),
    "hhat_dense": (hhat_dense, 1),
    "jsdist_dense": (jsdist_dense, 2),
}
