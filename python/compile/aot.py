"""AOT lowering: every L2 entry point × every artifact size → HLO **text**
(+ manifest) under artifacts/.

HLO text (not the serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out ../artifacts [--sizes 64,128,256]
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_SIZES = (64, 128, 256)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str, fn, arity: int, n: int) -> str:
    spec = jax.ShapeDtypeStruct((n, n), jax.numpy.float32)
    lowered = jax.jit(fn).lower(*([spec] * arity))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in DEFAULT_SIZES))
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = ["# name n arity path — written by compile/aot.py"]
    for name, (fn, arity) in model.ENTRY_POINTS.items():
        for n in sizes:
            text = lower_entry(name, fn, arity, n)
            fname = f"{name}_n{n}.hlo.txt"
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            manifest_lines.append(f"{name} {n} {arity} {fname}")
            print(f"lowered {name} n={n} arity={arity} -> {fname} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines) - 1} artifacts")


if __name__ == "__main__":
    main()
