"""L1 — Pallas kernels for the FINGER dense compute path.

Two kernels cover the hot spots of the L2 graphs:

* ``q_stats_tiled``   — fused per-row-block reduction producing the row sums
  (nodal strengths s_i) and the per-block Σ W² partials that the quadratic
  proxy Q (Lemma 1) needs. One pass over W, VPU-bound.
* ``matvec_tiled``    — blocked dense mat-vec y = W·x, the inner step of the
  power iteration for λ_max (FINGER-Ĥ).

TPU-shaped tiling (DESIGN.md §5): W is consumed in (TILE, n) row slabs via
BlockSpec so each grid step's working set fits VMEM; on real TPU the row-slab
matvec feeds the MXU with 128-aligned tiles. Kernels are lowered with
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic custom-calls —
so the artifact path runs them as plain fused HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-slab height. 128 matches the TPU lane width; callers pass n that is a
# multiple of TILE or TILE is clamped to n.
TILE = 128


def _tile(n: int) -> int:
    """Largest tile ≤ TILE that divides n (n is a power of two in artifacts)."""
    t = min(TILE, n)
    while n % t != 0:
        t //= 2
    return max(t, 1)


def _qstats_kernel(w_ref, rows_ref, sq_ref):
    blk = w_ref[...]                       # (T, n) row slab in VMEM
    rows_ref[...] = jnp.sum(blk, axis=1)   # nodal strengths of this slab
    sq_ref[...] = jnp.sum(blk * blk).reshape((1,))


def q_stats_tiled(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Return (row_sums (n,), sumsq_partials (n/T,)) for symmetric W."""
    n = w.shape[0]
    t = _tile(n)
    grid = (n // t,)
    return pl.pallas_call(
        _qstats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((t, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((n // t,), w.dtype),
        ],
        interpret=True,
    )(w)


def _matvec_kernel(w_ref, x_ref, y_ref):
    # (T, n) @ (n,) -> (T,), MXU-bound on real TPU
    y_ref[...] = w_ref[...] @ x_ref[...]


def matvec_tiled(w: jax.Array, x: jax.Array) -> jax.Array:
    """Blocked dense mat-vec y = W·x."""
    n = w.shape[0]
    t = _tile(n)
    grid = (n // t,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), w.dtype),
        interpret=True,
    )(w, x)


def _entropy_kernel(lam_ref, out_ref):
    lam = lam_ref[...]
    safe = jnp.where(lam > 1e-12, lam, 1.0)  # 0·ln0 := 0
    out_ref[...] = jnp.sum(jnp.where(lam > 1e-12, -lam * jnp.log(safe), 0.0)).reshape((1,))


def entropy_reduce(lam: jax.Array) -> jax.Array:
    """−Σ λ ln λ over an eigenvalue vector (single-block reduction kernel)."""
    n = lam.shape[0]
    return pl.pallas_call(
        _entropy_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), lam.dtype),
        interpret=True,
    )(lam)[0]
