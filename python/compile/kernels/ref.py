"""Pure-jnp oracles for the L1 kernels and L2 graphs — the build-time
correctness signal (pytest compares kernels and models against these)."""

from __future__ import annotations

import jax.numpy as jnp


def q_stats_ref(w):
    """(row_sums, total Σ W²) of a symmetric weight matrix."""
    return jnp.sum(w, axis=1), jnp.sum(w * w)


def matvec_ref(w, x):
    return w @ x


def entropy_ref(lam):
    safe = jnp.where(lam > 1e-12, lam, 1.0)
    return jnp.sum(jnp.where(lam > 1e-12, -lam * jnp.log(safe), 0.0))


def quadratic_q_ref(w):
    """Q = 1 − c²(Σ s² + Σ_ij W²), the Lemma-1 proxy (note Σ_ij W² counts each
    undirected edge twice, matching 2Σ_{(i,j)∈E} w²)."""
    s = jnp.sum(w, axis=1)
    total = jnp.sum(s)
    c = jnp.where(total > 0, 1.0 / total, 0.0)
    return jnp.where(total > 0, 1.0 - c * c * (jnp.sum(s * s) + jnp.sum(w * w)), 0.0)


def lambda_max_ref(w):
    """λ_max of L_N by dense eigendecomposition (float64-capable oracle)."""
    s = jnp.sum(w, axis=1)
    lap = jnp.diag(s) - w
    total = jnp.sum(s)
    ln = jnp.where(total > 0, lap / total, lap)
    return jnp.linalg.eigvalsh(ln)[-1]


def hhat_ref(w):
    """FINGER-Ĥ = −Q ln λ_max via the dense eigensolver oracle."""
    q = quadratic_q_ref(w)
    lam = lambda_max_ref(w)
    return jnp.where(lam > 1e-12, jnp.maximum(-q * jnp.log(lam), 0.0), 0.0)


def jsdist_ref(wa, wb):
    """FINGER-JSdist (Fast) with the oracle Ĥ."""
    h_avg = hhat_ref((wa + wb) / 2.0)
    div = h_avg - 0.5 * (hhat_ref(wa) + hhat_ref(wb))
    return jnp.sqrt(jnp.maximum(div, 0.0))
