//! End-to-end observability accounting, in a process of its own.
//!
//! The metrics registry is process-global, so this binary holds exactly one
//! test: unlike the monotone assertions the lib unit tests and
//! `net_integration` must settle for, here every recorded value comes from
//! the single load run below and the cross-layer invariants can be asserted
//! *exactly* — most importantly that the per-shard event slots in the JSON
//! snapshot sum to the service's authoritative submitted-event count.

use finger::net::{run_load, NetConfig, NetServer, TrafficConfig, Wire};
use finger::obs::ObsConfig;
use finger::service::{ServiceConfig, TenantWorkloadConfig};
use std::time::Duration;

/// Pull `"key": value` out of the one-pair-per-line snapshot (the same
/// contract the CI awk/grep scrape relies on).
fn metric_u64(text: &str, key: &str) -> Option<u64> {
    let needle = format!("\"{key}\":");
    for line in text.lines() {
        if let Some(rest) = line.trim().strip_prefix(needle.as_str()) {
            return rest.trim().trim_end_matches(',').trim().parse().ok();
        }
    }
    None
}

#[test]
fn snapshot_shard_events_sum_to_service_submitted() {
    let snap_path = std::env::temp_dir()
        .join(format!("finger_obs_integration_{}.json", std::process::id()));
    let net_cfg = NetConfig {
        addr: "127.0.0.1:0".to_string(),
        obs: ObsConfig {
            snapshot_path: Some(snap_path.display().to_string()),
            interval_ms: 50,
            slow_n: 16,
            sample_every: 1,
        },
        ..Default::default()
    };
    let server = NetServer::bind(ServiceConfig { shards: 3, ..Default::default() }, net_cfg)
        .expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    let server = std::thread::spawn(move || server.run());

    let load = run_load(&TrafficConfig {
        addr,
        wire: Wire::Text,
        client_timeout: Some(Duration::from_secs(30)),
        connections: 3,
        workload: TenantWorkloadConfig {
            sessions: 6,
            windows: 4,
            events_per_window: 10,
            nodes_per_session: 16,
            presets: Vec::new(),
            seed: 0xA11CE,
        },
        query_sessions: true,
        shutdown_after: true,
        live_stats: false,
        check_metrics: true,
    })
    .expect("load run");
    let service_report = server.join().expect("server thread").expect("server run");

    // the load driver verified METRICS key parity across both wires
    assert!(load.metrics_keys.expect("parity check ran") > 0);
    assert!(load.events_sent > 0);
    assert_eq!(service_report.dropped_events, 0);
    assert_eq!(service_report.total_events, load.events_sent);

    // the server wrote a final post-drain snapshot on shutdown
    let text = std::fs::read_to_string(&snap_path).expect("snapshot file exists");
    std::fs::remove_file(&snap_path).ok();

    // THE invariant: per-shard event slots sum exactly to the service's
    // submitted-event count (the submit sites bump both in lockstep)
    let mut shard_sum = 0u64;
    let mut shards_seen = 0usize;
    for i in 0..finger::obs::MAX_OBS_SHARDS {
        match metric_u64(&text, &format!("shard{i}_events")) {
            Some(v) => {
                shard_sum += v;
                shards_seen += 1;
            }
            None => break,
        }
    }
    assert_eq!(shards_seen, 3, "one slot per configured shard:\n{text}");
    assert_eq!(
        shard_sum,
        service_report.total_events as u64,
        "shard event slots must sum to the drained total:\n{text}"
    );
    assert_eq!(
        metric_u64(&text, "service_events_submitted"),
        Some(service_report.total_events as u64),
        "snapshot extras carry the authoritative submit count"
    );

    // event loops swept every connection before the final snapshot
    assert_eq!(metric_u64(&text, "net_connections"), Some(0), "{text}");
    // the scoring hot path recorded through the obs layer
    let windows: u64 = service_report.sessions.iter().map(|s| s.records.len() as u64).sum();
    assert!(metric_u64(&text, "score_windows").unwrap_or(0) >= windows);
    assert!(metric_u64(&text, "win_events_in").unwrap_or(0) >= load.events_sent as u64);
    // histograms and the span ring made it into the snapshot
    assert!(text.contains("\"score_latency_us\""), "{text}");
    assert!(text.contains("\"request_us\""), "{text}");
    assert!(text.contains("\"slow_spans\""), "{text}");
    assert!(text.contains("\"kind\""), "sampled spans present:\n{text}");
}
