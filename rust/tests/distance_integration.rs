//! Cross-method distance integration: the full registry on shared workloads,
//! metric sanity, and the paper's qualitative orderings.

use finger::assert_bits_eq;
use finger::coordinator::{all_methods, core_methods};
use finger::distance::*;
use finger::entropy::FingerState;
use finger::graph::{DeltaGraph, Graph, GraphSequence};
use finger::util::Pcg64;

fn perturbed(g: &Graph, edges_removed: usize) -> Graph {
    let mut out = g.clone();
    for (i, j, _) in g.edges().take(edges_removed) {
        out.remove_edge(i, j);
    }
    out
}

#[test]
fn all_methods_monotone_in_perturbation_size() {
    let mut rng = Pcg64::new(1);
    let g = finger::generators::erdos_renyi_avg_degree(200, 12.0, &mut rng);
    let small = perturbed(&g, 5);
    let big = perturbed(&g, 300);
    let seq_small = GraphSequence::from_snapshots(vec![g.clone(), small]);
    let seq_big = GraphSequence::from_snapshots(vec![g.clone(), big]);
    for m in all_methods() {
        let s = m.score_sequence(&seq_small)[0];
        let b = m.score_sequence(&seq_big)[0];
        assert!(
            b >= s - 1e-9,
            "{}: larger perturbation scored lower ({b} < {s})",
            m.name
        );
    }
}

#[test]
fn finger_detects_weight_change_support_methods_do_not() {
    // the genome experiment's discriminating property
    let mut rng = Pcg64::new(2);
    let mut g = finger::generators::erdos_renyi_avg_degree(150, 10.0, &mut rng);
    let edges: Vec<_> = g.edges().collect();
    for (k, (i, j, _)) in edges.iter().enumerate() {
        g.set_weight(*i, *j, 1.0 + (k % 5) as f64);
    }
    let mut reweighted = g.clone();
    for (i, j, w) in g.edges() {
        reweighted.set_weight(i, j, 10.0 / w); // drastic redistribution
    }
    assert!(jsdist_fast(&g, &reweighted) > 0.01);
    assert_bits_eq!(graph_edit_distance(&g, &reweighted), 0.0);
    assert!(veo_score(&g, &reweighted) < 1e-12);
    assert!(cosine_distance(&g, &reweighted) < 1e-12); // unweighted degrees equal
}

#[test]
fn incremental_jsdist_identity_on_ws_graphs() {
    // Algorithm 2 must equal the batch H̃-based JS distance exactly; note it
    // is NOT expected to match the Ĥ-based Algorithm 1 value (different
    // surrogate entropies — differences of close numbers diverge).
    let mut rng = Pcg64::new(3);
    let g = finger::generators::watts_strogatz(300, 20, 0.05, &mut rng);
    let mut d = DeltaGraph::new();
    for _ in 0..60 {
        let i = rng.below(300) as u32;
        let j = (i + 1 + rng.below(299) as u32) % 300;
        if i != j {
            d.add(i, j, 1.0);
        }
    }
    let d = d.coalesced();
    let next = finger::graph::ops::compose(&g, &d);
    let batch = finger::distance::jsdist_with(&g, &next, finger::entropy::finger_htilde);
    let fast = jsdist_fast(&g, &next);
    let mut state = FingerState::new(g);
    let inc = jsdist_incremental(&mut state, &d);
    assert!((inc - batch).abs() < 1e-9, "inc={inc} batch={batch}");
    assert!(fast.is_finite() && inc >= 0.0);
}

#[test]
fn deltacon_and_rmd_consistent() {
    let mut rng = Pcg64::new(4);
    let a = finger::generators::barabasi_albert(100, 3, &mut rng);
    let b = perturbed(&a, 40);
    let o = DeltaConOpts::default();
    let sim = deltacon_similarity(&a, &b, &o);
    let rmd = rmd_distance(&a, &b, &o);
    assert!((rmd - (1.0 / sim - 1.0)).abs() < 1e-9);
    assert!(sim > 0.0 && sim < 1.0);
}

#[test]
fn registry_scores_weighted_hic_sequence() {
    let cfg = finger::datasets::HicConfig { dim: 60, band: 8, ..Default::default() };
    let seq = finger::datasets::hic_sequence(&cfg);
    for m in core_methods() {
        let scores = m.score_sequence(&seq);
        assert_eq!(scores.len(), seq.len() - 1, "{}", m.name);
        assert!(scores.iter().all(|s| s.is_finite()), "{}", m.name);
    }
}

#[test]
fn lambda_distance_stable_under_node_relabel_shift() {
    // spectra are permutation-invariant; relabeled graph has distance ~0
    let mut rng = Pcg64::new(5);
    let g = finger::generators::erdos_renyi(80, 0.1, &mut rng);
    let mut perm: Vec<u32> = (0..80).collect();
    rng.shuffle(&mut perm);
    let mut relabeled = Graph::new(80);
    for (i, j, w) in g.edges() {
        relabeled.set_weight(perm[i as usize], perm[j as usize], w);
    }
    assert!(lambda_distance(&g, &relabeled, 6, LambdaMatrix::Laplacian) < 1e-6);
    assert!(lambda_distance(&g, &relabeled, 6, LambdaMatrix::Adjacency) < 1e-6);
    // the VNGE itself is label-invariant (spectral) ...
    // power iteration stops at 1e-8 Rayleigh stagnation, and the permuted
    // CSR takes a different convergence path — equality only to ~tol
    let h1 = finger::entropy::finger_hhat(&g);
    let h2 = finger::entropy::finger_hhat(&relabeled);
    assert!((h1 - h2).abs() < 1e-6, "{h1} vs {h2}");
    // ... but the JS *distance* uses node correspondence (averaged graph),
    // so a permuted copy is legitimately at positive distance.
    assert!(jsdist_fast(&g, &relabeled) > 0.0);
}

#[test]
fn exact_js_upper_bounds_hold() {
    // JSdiv ≤ ln 2 ⇒ JSdist ≤ √ln2 for density matrices
    let mut rng = Pcg64::new(6);
    for _ in 0..5 {
        let a = finger::generators::erdos_renyi(50, 0.1, &mut rng);
        let b = finger::generators::erdos_renyi(50, 0.3, &mut rng);
        let d = jsdist_exact(&a, &b);
        assert!(d <= (2f64.ln()).sqrt() + 0.15, "d={d}"); // slack: graph JS uses avg graph, not avg density
    }
}
