//! Property-based tests over the paper's invariants, using the in-tree
//! mini-proptest harness (seeded, size-ramped, reproducible failures).

use finger::entropy::{exact_vnge, finger_hhat, finger_htilde, quadratic_q, FingerState};
use finger::graph::{DeltaGraph, Graph};
use finger::util::proptest::{check, run, Config};
use finger::util::Pcg64;

/// Strategy: random weighted graph with size-scaled node count.
fn arb_graph(rng: &mut Pcg64, size: usize) -> Graph {
    let n = (size + 3).min(120);
    let p = rng.uniform(0.02, 0.3);
    let mut g = finger::generators::erdos_renyi(n, p, rng);
    // random positive weights on a subset
    let edges: Vec<_> = g.edges().collect();
    for (i, j, _) in edges {
        if rng.bernoulli(0.5) {
            g.set_weight(i, j, rng.uniform(0.1, 5.0));
        }
    }
    g
}

/// Strategy: (graph, delta) pair with mixed add/remove/perturb operations.
fn arb_graph_delta(rng: &mut Pcg64, size: usize) -> (Graph, DeltaGraph) {
    let g = arb_graph(rng, size);
    let n = g.num_nodes() as u32;
    let mut d = DeltaGraph::new();
    let ops = rng.range(1, size.max(2));
    for _ in 0..ops {
        let i = rng.below(n as usize) as u32;
        let j = (i + 1 + rng.below(n as usize - 1) as u32) % n;
        if i == j {
            continue;
        }
        match rng.below(4) {
            0 => {
                d.add(i, j, rng.uniform(0.1, 3.0));
            }
            1 => {
                d.add(i, j, -g.weight(i.min(j), i.max(j)));
            }
            2 => {
                d.add(i, j, rng.uniform(-1.0, 1.0));
            }
            _ => {
                d.grow_nodes(1);
            }
        }
    }
    (g, d.coalesced())
}

#[test]
fn prop_entropy_ordering() {
    check(arb_graph, |g| {
        let h = exact_vnge(g);
        let hhat = finger_hhat(g);
        let htil = finger_htilde(g);
        if htil > hhat + 1e-9 {
            return Err(format!("H̃={htil} > Ĥ={hhat}"));
        }
        if hhat > h + 1e-6 {
            return Err(format!("Ĥ={hhat} > H={h}"));
        }
        if h > ((g.num_nodes().max(2) - 1) as f64).ln() + 1e-9 {
            return Err(format!("H={h} exceeds ln(n-1)"));
        }
        Ok(())
    });
}

#[test]
fn prop_q_in_unit_interval_and_matches_eigen() {
    check(arb_graph, |g| {
        if g.num_edges() == 0 {
            return Ok(()); // density matrix undefined; Q := 0 by convention
        }
        let q = quadratic_q(g);
        if !(-1e-12..=1.0 + 1e-12).contains(&q) {
            return Err(format!("Q={q} outside [0,1]"));
        }
        let eigs = finger::linalg::SymMatrix::laplacian_normalized(g).eigenvalues();
        let purity: f64 = eigs.iter().map(|l| l * l).sum();
        if (q - (1.0 - purity)).abs() > 1e-8 {
            return Err(format!("Q={q} vs 1-purity={}", 1.0 - purity));
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_q_equals_scratch() {
    run(&Config { cases: 80, ..Default::default() }, arb_graph_delta, |(g, d)| {
        let mut state = FingerState::new(g.clone());
        state.apply(d);
        let composed = finger::graph::ops::compose(g, d);
        let q_scratch = quadratic_q(&composed);
        if (state.q() - q_scratch).abs() > 1e-8 {
            return Err(format!("Q drift: {} vs {q_scratch}", state.q()));
        }
        if (state.htilde() - finger_htilde(&composed)).abs() > 1e-8 {
            return Err(format!("H̃ drift: {} vs {}", state.htilde(), finger_htilde(&composed)));
        }
        state.graph().check_invariants()?;
        Ok(())
    });
}

#[test]
fn prop_jsdist_metric_axioms() {
    check(
        |rng: &mut Pcg64, size: usize| (arb_graph(rng, size), arb_graph(rng, size)),
        |(a, b)| {
            let dab = finger::distance::jsdist_fast(a, b);
            let dba = finger::distance::jsdist_fast(b, a);
            if (dab - dba).abs() > 1e-9 {
                return Err(format!("asymmetric: {dab} vs {dba}"));
            }
            if dab < 0.0 {
                return Err(format!("negative distance {dab}"));
            }
            // √ of an ~1e-16 rounding residue in the divergence is ~1e-8
            let daa = finger::distance::jsdist_fast(a, a);
            if daa > 1e-6 {
                return Err(format!("d(a,a)={daa}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_veo_in_unit_interval() {
    check(
        |rng: &mut Pcg64, size: usize| (arb_graph(rng, size), arb_graph(rng, size)),
        |(a, b)| {
            let v = finger::distance::veo_score(a, b);
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("VEO={v}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_delta_diff_apply_roundtrip() {
    check(
        |rng: &mut Pcg64, size: usize| (arb_graph(rng, size), arb_graph(rng, size + 1)),
        |(a, b)| {
            // growing direction (|b| ≥ |a|) and shrinking direction (the
            // diff target has fewer nodes — regression: this used to index
            // the smaller graph's adjacency out of bounds and panic)
            for (from, to) in [(a, b), (b, a)] {
                let d = DeltaGraph::diff(from, to);
                let rebuilt = finger::graph::ops::compose(from, &d);
                if rebuilt.num_edges() != to.num_edges() {
                    return Err(format!(
                        "edge count {} vs {}",
                        rebuilt.num_edges(),
                        to.num_edges()
                    ));
                }
                for (i, j, w) in to.edges() {
                    if (rebuilt.weight(i, j) - w).abs() > 1e-9 {
                        return Err(format!("weight mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Hot-path equivalence: scratch-reusing scoring (in-place batcher +
// `Scratch`-threaded Algorithm 2) must be bit-for-bit identical to the
// per-call-allocating path on arbitrary raw (uncoalesced, duplicate-bearing)
// deltas, under both s_max policies, across interleaved sessions that share
// one Scratch but nothing else.
// ---------------------------------------------------------------------------

/// Strategy helper: raw window deltas with guaranteed duplicate entries and
/// occasional node growth (NOT coalesced — exercises the fallback path).
fn raw_windows(rng: &mut Pcg64, g: &finger::graph::Graph, windows: usize) -> Vec<DeltaGraph> {
    let n = g.num_nodes() as u32;
    let mut out = Vec::new();
    for _ in 0..windows {
        let mut d = DeltaGraph::new();
        for _ in 0..rng.range(1, 8) {
            let i = rng.below(n as usize) as u32;
            let mut j = rng.below(n as usize) as u32;
            if i == j {
                j = (j + 1) % n;
            }
            match rng.below(4) {
                0 => {
                    d.add(i, j, rng.uniform(0.1, 2.0));
                }
                1 => {
                    // over-delete then re-add: a duplicate pair whose clamp
                    // semantics only work through the coalesced view
                    d.add(i, j, -g.weight(i.min(j), i.max(j)) - rng.uniform(0.0, 1.0));
                    d.add(j, i, rng.uniform(0.1, 0.8));
                }
                2 => {
                    d.add(i, j, rng.uniform(-1.0, 1.0));
                }
                _ => {
                    d.grow_nodes(1);
                }
            }
        }
        out.push(d);
    }
    out
}

#[test]
fn prop_scratch_scoring_bit_identical_across_interleaved_sessions() {
    use finger::distance::jsdist_incremental;
    use finger::entropy::{Scratch, SmaxPolicy};
    use finger::prop_assert;
    use finger::stream::event::events_from_deltas;
    use finger::stream::{AnomalyDetector, ResyncPolicy, WindowBatcher, WindowScorer};

    run(
        &Config { cases: 40, ..Default::default() },
        |rng: &mut Pcg64, size: usize| {
            let g1 = arb_graph(rng, size);
            let g2 = arb_graph(rng, size);
            let w1 = raw_windows(rng, &g1, 5);
            let w2 = raw_windows(rng, &g2, 5);
            (g1, g2, w1, w2)
        },
        |(g1, g2, w1, w2)| {
            for policy in [SmaxPolicy::Exact, SmaxPolicy::PaperFaithful] {
                // scratch path: one shared Scratch, two interleaved states
                let mut shared = Scratch::default();
                let mut scr1 = FingerState::with_policy(g1.clone(), policy);
                let mut scr2 = FingerState::with_policy(g2.clone(), policy);
                // reference path: per-call-allocating preview/apply
                let mut ref1 = FingerState::with_policy(g1.clone(), policy);
                let mut ref2 = FingerState::with_policy(g2.clone(), policy);
                for k in 0..w1.len().max(w2.len()) {
                    for (d, scr, rf) in
                        [(w1.get(k), &mut scr1, &mut ref1), (w2.get(k), &mut scr2, &mut ref2)]
                    {
                        let Some(d) = d else { continue };
                        let p_ref = rf.preview(d);
                        let p_scr = scr.preview_with(d, &mut shared);
                        prop_assert!(
                            p_ref.q.to_bits() == p_scr.q.to_bits()
                                && p_ref.s_total.to_bits() == p_scr.s_total.to_bits()
                                && p_ref.s_max.to_bits() == p_scr.s_max.to_bits(),
                            "{policy:?} window {k}: preview diverged"
                        );
                        rf.apply_previewed(d, p_ref);
                        scr.apply_previewed_with(d, p_scr, &mut shared);
                        prop_assert!(
                            rf.q().to_bits() == scr.q().to_bits()
                                && rf.s_max().to_bits() == scr.s_max().to_bits()
                                && rf.htilde().to_bits() == scr.htilde().to_bits(),
                            "{policy:?} window {k}: committed state diverged"
                        );
                    }
                }
                // in-place batcher + scratch scorer (the service hot path)
                // vs DeltaGraph::coalesced + allocating jsdist_incremental
                // (the pre-refactor window loop) over the same event stream
                let mut batcher = WindowBatcher::new();
                let mut scorer = WindowScorer::new(
                    FingerState::with_policy(g1.clone(), policy),
                    AnomalyDetector::new(3.0, 8),
                    ResyncPolicy::disabled(),
                );
                let mut reference = FingerState::with_policy(g1.clone(), policy);
                let mut scored = Vec::new();
                for ev in events_from_deltas(w1) {
                    if let Some((delta, n)) = batcher.push_ref(ev) {
                        prop_assert!(delta.is_sorted_unique(), "batcher window not normal form");
                        scored.push(scorer.score(delta, n).jsdist);
                    }
                }
                for (k, d) in w1.iter().enumerate() {
                    let js = jsdist_incremental(&mut reference, &d.coalesced());
                    prop_assert!(
                        js.to_bits() == scored[k].to_bits(),
                        "{policy:?} window {k}: jsdist {js} vs {}",
                        scored[k]
                    );
                }
                prop_assert!(
                    reference.htilde().to_bits() == scorer.state().htilde().to_bits(),
                    "{policy:?}: final H̃ diverged"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_power_iteration_within_anderson_morley() {
    check(arb_graph, |g| {
        if g.total_weight() <= 0.0 {
            return Ok(());
        }
        let lam = finger::linalg::power_iteration(
            &finger::graph::Csr::from_graph(g),
            &finger::linalg::PowerOpts::default(),
        );
        let bound = 2.0 * g.s_max() / g.total_weight();
        if lam > bound + 1e-9 {
            return Err(format!("λ={lam} > 2c·s_max={bound}"));
        }
        if lam > 1.0 + 1e-9 {
            return Err(format!("λ={lam} > 1"));
        }
        Ok(())
    });
}

#[test]
fn prop_graph_invariants_after_random_mutation() {
    run(&Config { cases: 60, ..Default::default() }, arb_graph_delta, |(g, d)| {
        let mut g = g.clone();
        d.apply_to(&mut g);
        g.check_invariants()?;
        let (s2, w2) = g.q_moments();
        if s2 < 0.0 || w2 < 0.0 {
            return Err("negative moments".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Wire-codec properties: any Command / Reply must survive encode → decode
// under both codecs (the binary wire exactly; the text wire up to its
// documented kv erasure of the snapshot shape), including %XX-escaped
// session ids, extreme-but-finite dw values, and max-size BATCH headers.
// ---------------------------------------------------------------------------

use finger::net::{BinaryCodec, Codec, Command, CommandRead, Reply, TextCodec, MAX_BATCH};
use finger::service::SessionSnapshot;
use finger::stream::StreamEvent;

/// Strategy: session ids spanning every encoding hazard — spaces, `%`,
/// slashes, UTF-8 multibyte. Non-empty: an empty id has no text-wire
/// representation (its `%XX` encoding is the empty token), which is a
/// documented limit of the line protocol, not of the command core.
fn arb_session_id(rng: &mut Pcg64, size: usize) -> String {
    let alphabet = [
        "a", "B", "7", ".", "_", "-", " ", "%", "/", ":", "é", "念", "\t", "\\",
    ];
    let len = 1 + rng.below(size.max(1));
    let mut id = String::new();
    for _ in 0..len {
        id.push_str(alphabet[rng.below(alphabet.len())]);
    }
    id
}

/// Strategy: wire-legal events with extreme-but-finite weights.
fn arb_wire_event(rng: &mut Pcg64, _size: usize) -> StreamEvent {
    match rng.below(3) {
        0 => {
            let i = rng.below((1 << 24) - 1) as u32;
            let mut j = rng.below((1 << 24) - 1) as u32;
            if i == j {
                j = (j + 1) % ((1 << 24) - 1);
            }
            // extreme magnitudes, subnormals and exact negatives included —
            // everything finite must survive the wire bit-for-bit
            let dw = match rng.below(6) {
                0 => rng.uniform(-1.0, 1.0),
                1 => 1e308,
                2 => -1e308,
                3 => f64::MIN_POSITIVE,
                4 => -f64::MIN_POSITIVE / 2.0, // subnormal
                _ => -0.0,
            };
            StreamEvent::EdgeDelta { i, j, dw }
        }
        1 => StreamEvent::GrowNodes { count: rng.below(1 << 24) },
        _ => StreamEvent::Tick,
    }
}

fn arb_command(rng: &mut Pcg64, size: usize) -> Command {
    let id = arb_session_id(rng, size);
    match rng.below(9) {
        0 => {
            let nodes = rng.below((1 << 24) + 1);
            let epoch = rng.bernoulli(0.5).then(|| rng.below(1 << 30) as u64);
            Command::Open { id, nodes, epoch }
        }
        1 => {
            let ev = arb_wire_event(rng, size);
            let seq = rng.bernoulli(0.5).then(|| rng.below(1 << 30) as u64);
            Command::Event { id, ev, seq }
        }
        2 => {
            let n = rng.below(size.max(1) + 1);
            let events = (0..n).map(|_| arb_wire_event(rng, size)).collect();
            let seq = rng.bernoulli(0.5).then(|| rng.below(1 << 30) as u64);
            Command::Batch { id, events, seq }
        }
        3 => Command::Query { id },
        4 => Command::Close { id },
        5 => Command::Stats,
        6 => Command::Quit,
        7 => {
            // names/specs stay in the wire grammar (no whitespace) so the
            // encode→decode roundtrip is exact
            const NAMES: [&str; 4] = ["wal.append", "wal.fsync", "snap.rename", "net.read"];
            const SPECS: [&str; 5] = ["off", "once", "at=3", "every=7", "after=2"];
            Command::Fault {
                name: NAMES[rng.below(NAMES.len())].to_string(),
                spec: SPECS[rng.below(SPECS.len())].to_string(),
            }
        }
        _ => Command::Shutdown,
    }
}

fn arb_snapshot(rng: &mut Pcg64, size: usize) -> SessionSnapshot {
    SessionSnapshot {
        // ids never travel in replies; decoders leave them empty
        id: String::new(),
        windows: rng.below(size + 1),
        events: rng.below(1 << 30),
        last_jsdist: if rng.bernoulli(0.5) { Some(rng.uniform(0.0, 1.0)) } else { None },
        last_anomalous: rng.bernoulli(0.3),
        htilde: rng.uniform(-10.0, 10.0),
        nodes: rng.below(1 << 24),
        edges: rng.below(1 << 24),
        anomalies: rng.below(64),
        pending_events: rng.below(1 << 20),
    }
}

fn arb_reply(rng: &mut Pcg64, size: usize) -> Reply {
    match rng.below(4) {
        0 => Reply::Ok,
        1 => {
            // non-empty: the text wire writes an empty kv set as a bare
            // `OK`, which decodes as Reply::Ok (same meaning, other shape)
            let n = 1 + rng.below(size.clamp(1, 8));
            let pairs = (0..n)
                .map(|k| (format!("k{k}"), format!("{}", rng.uniform(-1e6, 1e6))))
                .collect();
            Reply::OkKv(pairs)
        }
        2 => Reply::Snapshot(arb_snapshot(rng, size)),
        // free text, but never with leading/trailing whitespace — the text
        // wire trims the reason (documented), so such reasons can't roundtrip
        _ => Reply::Err(format!("reason-{}/{}", rng.below(1000), rng.below(1000))),
    }
}

/// Encode a command with `codec`, decode it back, and compare.
fn roundtrip_command(codec: &mut dyn Codec, cmd: &Command) -> Result<(), String> {
    let mut buf = Vec::new();
    codec.write_command(&mut buf, cmd).map_err(|e| format!("encode: {e}"))?;
    let mut cursor = std::io::Cursor::new(buf);
    match codec.read_command(&mut cursor, &|| false).map_err(|e| format!("decode: {e}"))? {
        CommandRead::Cmd(back) if back == *cmd => Ok(()),
        other => Err(format!("{} decoded {other:?}", codec.wire())),
    }
}

#[test]
fn prop_commands_roundtrip_under_both_codecs() {
    run(&Config { cases: 200, ..Default::default() }, arb_command, |cmd| {
        roundtrip_command(&mut TextCodec::new(), cmd)?;
        roundtrip_command(&mut BinaryCodec::new(), cmd)
    });
}

#[test]
fn prop_replies_roundtrip_under_both_codecs() {
    run(&Config { cases: 200, ..Default::default() }, arb_reply, |reply| {
        // binary: exact, including the snapshot shape and every f64 bit
        let mut buf = Vec::new();
        let mut bin = BinaryCodec::new();
        bin.write_reply(&mut buf, reply).map_err(|e| format!("bin encode: {e}"))?;
        let back = bin
            .read_reply(&mut std::io::Cursor::new(buf))
            .map_err(|e| format!("bin decode: {e}"))?
            .ok_or("bin decode: eof")?;
        if back != *reply {
            return Err(format!("binary: {back:?} != {reply:?}"));
        }
        // text: the snapshot shape is erased to kv (documented), but the
        // decoded content — every float bit included — must survive
        let mut buf = Vec::new();
        let mut text = TextCodec::new();
        text.write_reply(&mut buf, reply).map_err(|e| format!("text encode: {e}"))?;
        let back = text
            .read_reply(&mut std::io::Cursor::new(buf))
            .map_err(|e| format!("text decode: {e}"))?
            .ok_or("text decode: eof")?;
        match (reply, &back) {
            (Reply::Snapshot(snap), _) => {
                let got = back
                    .clone()
                    .into_snapshot("")
                    .ok_or_else(|| format!("text: snapshot kv unreadable: {back:?}"))?;
                if got != *snap {
                    return Err(format!("text snapshot: {got:?} != {snap:?}"));
                }
                match (got.last_jsdist, snap.last_jsdist) {
                    (Some(a), Some(b)) if a.to_bits() != b.to_bits() => {
                        return Err(format!("jsdist bits {a} != {b}"));
                    }
                    _ => {}
                }
                if got.htilde.to_bits() != snap.htilde.to_bits() {
                    return Err("htilde bits drifted".into());
                }
            }
            (expected, got) if got != expected => {
                return Err(format!("text: {got:?} != {expected:?}"));
            }
            _ => {}
        }
        Ok(())
    });
}

#[test]
fn write_batch_is_byte_identical_to_write_command() {
    // the client's borrowing hot path and the typed-command path must
    // produce the same bytes under both codecs
    fn check_codec(codec: &mut dyn Codec) {
        let events = vec![
            StreamEvent::EdgeDelta { i: 0, j: 1, dw: -1.5e300 },
            StreamEvent::GrowNodes { count: 3 },
            StreamEvent::Tick,
        ];
        let id = "tenant/1 %x";
        let cmd = Command::Batch { id: id.to_string(), events: events.clone(), seq: None };
        let mut via_command = Vec::new();
        codec.write_command(&mut via_command, &cmd).unwrap();
        let mut via_batch = Vec::new();
        codec.write_batch(&mut via_batch, id, &events).unwrap();
        assert_eq!(via_command, via_batch, "{} wire", codec.wire());
    }
    check_codec(&mut TextCodec::new());
    check_codec(&mut BinaryCodec::new());
}

#[test]
fn max_size_batch_header_roundtrips_under_both_codecs() {
    // not a property (one deterministic worst case): a BATCH at exactly
    // MAX_BATCH events survives both wires; one past it is refused by both
    let events: Vec<StreamEvent> = (0..MAX_BATCH)
        .map(|k| {
            let i = (k % ((1 << 20) - 1)) as u32;
            StreamEvent::EdgeDelta { i, j: i + 1, dw: (k as f64).mul_add(1e-9, 0.5) }
        })
        .collect();
    let cmd = Command::Batch { id: "max".to_string(), events, seq: None };
    roundtrip_command(&mut TextCodec::new(), &cmd).expect("text at MAX_BATCH");
    roundtrip_command(&mut BinaryCodec::new(), &cmd).expect("binary at MAX_BATCH");

    // text: an over-cap header is a recoverable Malformed read
    let over = format!("BATCH max {}\n", MAX_BATCH + 1);
    match TextCodec::new()
        .read_command(&mut std::io::Cursor::new(over.into_bytes()), &|| false)
        .expect("io")
    {
        CommandRead::Malformed(reason) => {
            assert!(reason.contains("exceeds maximum"), "{reason:?}")
        }
        other => panic!("over-cap text header: {other:?}"),
    }
}
