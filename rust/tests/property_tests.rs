//! Property-based tests over the paper's invariants, using the in-tree
//! mini-proptest harness (seeded, size-ramped, reproducible failures).

use finger::entropy::{exact_vnge, finger_hhat, finger_htilde, quadratic_q, FingerState};
use finger::graph::{DeltaGraph, Graph};
use finger::util::proptest::{check, run, Config};
use finger::util::Pcg64;

/// Strategy: random weighted graph with size-scaled node count.
fn arb_graph(rng: &mut Pcg64, size: usize) -> Graph {
    let n = (size + 3).min(120);
    let p = rng.uniform(0.02, 0.3);
    let mut g = finger::generators::erdos_renyi(n, p, rng);
    // random positive weights on a subset
    let edges: Vec<_> = g.edges().collect();
    for (i, j, _) in edges {
        if rng.bernoulli(0.5) {
            g.set_weight(i, j, rng.uniform(0.1, 5.0));
        }
    }
    g
}

/// Strategy: (graph, delta) pair with mixed add/remove/perturb operations.
fn arb_graph_delta(rng: &mut Pcg64, size: usize) -> (Graph, DeltaGraph) {
    let g = arb_graph(rng, size);
    let n = g.num_nodes() as u32;
    let mut d = DeltaGraph::new();
    let ops = rng.range(1, size.max(2));
    for _ in 0..ops {
        let i = rng.below(n as usize) as u32;
        let j = (i + 1 + rng.below(n as usize - 1) as u32) % n;
        if i == j {
            continue;
        }
        match rng.below(4) {
            0 => {
                d.add(i, j, rng.uniform(0.1, 3.0));
            }
            1 => {
                d.add(i, j, -g.weight(i.min(j), i.max(j)));
            }
            2 => {
                d.add(i, j, rng.uniform(-1.0, 1.0));
            }
            _ => {
                d.grow_nodes(1);
            }
        }
    }
    (g, d.coalesced())
}

#[test]
fn prop_entropy_ordering() {
    check(arb_graph, |g| {
        let h = exact_vnge(g);
        let hhat = finger_hhat(g);
        let htil = finger_htilde(g);
        if htil > hhat + 1e-9 {
            return Err(format!("H̃={htil} > Ĥ={hhat}"));
        }
        if hhat > h + 1e-6 {
            return Err(format!("Ĥ={hhat} > H={h}"));
        }
        if h > ((g.num_nodes().max(2) - 1) as f64).ln() + 1e-9 {
            return Err(format!("H={h} exceeds ln(n-1)"));
        }
        Ok(())
    });
}

#[test]
fn prop_q_in_unit_interval_and_matches_eigen() {
    check(arb_graph, |g| {
        if g.num_edges() == 0 {
            return Ok(()); // density matrix undefined; Q := 0 by convention
        }
        let q = quadratic_q(g);
        if !(-1e-12..=1.0 + 1e-12).contains(&q) {
            return Err(format!("Q={q} outside [0,1]"));
        }
        let eigs = finger::linalg::SymMatrix::laplacian_normalized(g).eigenvalues();
        let purity: f64 = eigs.iter().map(|l| l * l).sum();
        if (q - (1.0 - purity)).abs() > 1e-8 {
            return Err(format!("Q={q} vs 1-purity={}", 1.0 - purity));
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_q_equals_scratch() {
    run(&Config { cases: 80, ..Default::default() }, arb_graph_delta, |(g, d)| {
        let mut state = FingerState::new(g.clone());
        state.apply(d);
        let composed = finger::graph::ops::compose(g, d);
        let q_scratch = quadratic_q(&composed);
        if (state.q() - q_scratch).abs() > 1e-8 {
            return Err(format!("Q drift: {} vs {q_scratch}", state.q()));
        }
        if (state.htilde() - finger_htilde(&composed)).abs() > 1e-8 {
            return Err(format!("H̃ drift: {} vs {}", state.htilde(), finger_htilde(&composed)));
        }
        state.graph().check_invariants()?;
        Ok(())
    });
}

#[test]
fn prop_jsdist_metric_axioms() {
    check(
        |rng: &mut Pcg64, size: usize| (arb_graph(rng, size), arb_graph(rng, size)),
        |(a, b)| {
            let dab = finger::distance::jsdist_fast(a, b);
            let dba = finger::distance::jsdist_fast(b, a);
            if (dab - dba).abs() > 1e-9 {
                return Err(format!("asymmetric: {dab} vs {dba}"));
            }
            if dab < 0.0 {
                return Err(format!("negative distance {dab}"));
            }
            // √ of an ~1e-16 rounding residue in the divergence is ~1e-8
            let daa = finger::distance::jsdist_fast(a, a);
            if daa > 1e-6 {
                return Err(format!("d(a,a)={daa}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_veo_in_unit_interval() {
    check(
        |rng: &mut Pcg64, size: usize| (arb_graph(rng, size), arb_graph(rng, size)),
        |(a, b)| {
            let v = finger::distance::veo_score(a, b);
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("VEO={v}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_delta_diff_apply_roundtrip() {
    check(
        |rng: &mut Pcg64, size: usize| (arb_graph(rng, size), arb_graph(rng, size + 1)),
        |(a, b)| {
            let d = DeltaGraph::diff(a, b);
            let rebuilt = finger::graph::ops::compose(a, &d);
            if rebuilt.num_edges() != b.num_edges() {
                return Err(format!(
                    "edge count {} vs {}",
                    rebuilt.num_edges(),
                    b.num_edges()
                ));
            }
            for (i, j, w) in b.edges() {
                if (rebuilt.weight(i, j) - w).abs() > 1e-9 {
                    return Err(format!("weight mismatch at ({i},{j})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_power_iteration_within_anderson_morley() {
    check(arb_graph, |g| {
        if g.total_weight() <= 0.0 {
            return Ok(());
        }
        let lam = finger::linalg::power_iteration(
            &finger::graph::Csr::from_graph(g),
            &finger::linalg::PowerOpts::default(),
        );
        let bound = 2.0 * g.s_max() / g.total_weight();
        if lam > bound + 1e-9 {
            return Err(format!("λ={lam} > 2c·s_max={bound}"));
        }
        if lam > 1.0 + 1e-9 {
            return Err(format!("λ={lam} > 1"));
        }
        Ok(())
    });
}

#[test]
fn prop_graph_invariants_after_random_mutation() {
    run(&Config { cases: 60, ..Default::default() }, arb_graph_delta, |(g, d)| {
        let mut g = g.clone();
        d.apply_to(&mut g);
        g.check_invariants()?;
        let (s2, w2) = g.q_moments();
        if s2 < 0.0 || w2 < 0.0 {
            return Err("negative moments".into());
        }
        Ok(())
    });
}
