//! End-to-end coverage for `finger lint`: golden diagnostics over seeded
//! fixture files (one per rule, linted under virtual paths so the
//! directory-scoped rules apply), a repo-wide lexer/model self-check, a
//! lexer robustness property, and the gating invariant itself — the full
//! repo lints clean under the checked-in baseline.

use finger::lint::{self, FileModel};
use finger::util::{proptest, Pcg64};

const FL001_SRC: &str = include_str!("fixtures/lint/fl001.rs");
const FL002_SRC: &str = include_str!("fixtures/lint/fl002.rs");
const FL003_SRC: &str = include_str!("fixtures/lint/fl003.rs");
const FL004_SRC: &str = include_str!("fixtures/lint/fl004.rs");
const FL005_SRC: &str = include_str!("fixtures/lint/fl005.rs");
const FL006_SRC: &str = include_str!("fixtures/lint/fl006.rs");
const FL007_SRC: &str = include_str!("fixtures/lint/fl007.rs");

/// Lint a fixture under a virtual path; returns (diagnostics, waived count).
fn lint_fixture(virtual_path: &str, src: &str) -> (Vec<lint::Diagnostic>, usize) {
    let (diags, waived) = lint::lint_source(virtual_path, src.to_string());
    assert!(
        diags.iter().all(|d| d.rule != "FL000"),
        "fixture must lex cleanly with well-formed waivers: {diags:?}"
    );
    (diags, waived)
}

fn rule_lines(diags: &[lint::Diagnostic]) -> Vec<(&str, u32)> {
    diags.iter().map(|d| (d.rule.as_str(), d.line)).collect()
}

fn message_at(diags: &[lint::Diagnostic], line: u32) -> &str {
    &diags
        .iter()
        .find(|d| d.line == line)
        .unwrap_or_else(|| panic!("no diagnostic at line {line}: {diags:?}"))
        .message
}

#[test]
fn fl001_golden_panic_sites_on_request_path() {
    let (diags, waived) = lint_fixture("rust/src/service/fixture.rs", FL001_SRC);
    let expect = vec![
        ("FL001", 6),  // .unwrap()
        ("FL001", 7),  // .expect()
        ("FL001", 9),  // panic!
        ("FL001", 11), // indexing
        ("FL001", 18), // todo!
    ];
    assert_eq!(rule_lines(&diags), expect);
    assert_eq!(waived, 1, "the second shards[0] carries a bounds waiver");
    assert!(message_at(&diags, 6).contains("propagate an error"));
    assert!(message_at(&diags, 9).contains("return an error"));
    assert!(message_at(&diags, 11).contains(".get(..)"));
}

#[test]
fn fl001_same_source_outside_the_zone_is_quiet() {
    let (diags, _) = lint_fixture("rust/src/graph/fixture.rs", FL001_SRC);
    assert!(diags.is_empty(), "zone rule must not fire under rust/src/graph/: {diags:?}");
}

#[test]
fn fl002_golden_allocations_in_hot_region() {
    let (diags, waived) = lint_fixture("rust/src/entropy/fixture.rs", FL002_SRC);
    let expect = vec![
        ("FL002", 10), // .to_vec()
        ("FL002", 11), // format!
        ("FL002", 12), // Vec::new
    ];
    assert_eq!(rule_lines(&diags), expect);
    assert_eq!(waived, 1, "Vec::with_capacity carries a one-time-growth waiver");
    assert!(message_at(&diags, 10).contains("allocating call"));
    assert!(message_at(&diags, 11).contains("allocating macro"));
    assert!(message_at(&diags, 12).contains("allocating constructor"));
}

#[test]
fn fl003_golden_float_equality() {
    let (diags, waived) = lint_fixture("rust/src/distance/fixture.rs", FL003_SRC);
    let expect = vec![
        ("FL003", 9), // a == weight()
        ("FL003", 9), // b != 0.125
        ("FL003", 26), // assert_eq!(weight(), 2.5)
    ];
    assert_eq!(rule_lines(&diags), expect);
    assert_eq!(waived, 1, "the exact-zero assert_ne! carries a sentinel waiver");
    assert!(message_at(&diags, 9).contains("bit-exactness"));
    assert!(message_at(&diags, 26).contains("assert_bits_eq!"));
}

#[test]
fn fl004_golden_unbounded_channel() {
    let (diags, waived) = lint_fixture("rust/src/service/fixture.rs", FL004_SRC);
    assert_eq!(rule_lines(&diags), vec![("FL004", 8)]);
    assert_eq!(waived, 1, "the reply channel carries a rendezvous waiver");
    assert!(message_at(&diags, 8).contains("sync_channel"));
}

#[test]
fn fl005_golden_lock_unwrap() {
    let (diags, waived) = lint_fixture("rust/src/runtime/fixture.rs", FL005_SRC);
    assert_eq!(rule_lines(&diags), vec![("FL005", 8)]);
    assert_eq!(waived, 0);
    assert!(message_at(&diags, 8).contains("poisoning policy"));
}

#[test]
fn fl006_golden_blocking_io_in_event_loop_region() {
    let (diags, waived) = lint_fixture("rust/src/net/server.rs", FL006_SRC);
    let expect = vec![
        ("FL006", 14), // .read_line()
        ("FL006", 16), // .read_exact()
    ];
    assert_eq!(rule_lines(&diags), expect);
    assert_eq!(waived, 1, "the teardown read_to_end carries a waiver");
    assert!(message_at(&diags, 14).contains("stalls every connection"));
}

#[test]
fn fl007_golden_raw_sleep_in_service_net_code() {
    let (diags, waived) = lint_fixture("rust/src/net/server.rs", FL007_SRC);
    let expect = vec![
        ("FL007", 9),  // thread::sleep(..)
        ("FL007", 10), // std::thread::sleep(..)
    ];
    assert_eq!(rule_lines(&diags), expect);
    assert_eq!(waived, 1, "the startup-settle sleep carries a waiver");
    assert!(message_at(&diags, 9).contains("net::backoff"));
}

#[test]
fn fl007_backoff_seam_and_out_of_zone_paths_are_quiet() {
    // the backoff module is the one sanctioned home for the raw call
    let (d, _) = lint_fixture("rust/src/net/backoff.rs", FL007_SRC);
    assert!(d.is_empty(), "backoff.rs is the sleep seam: {d:?}");
    let (d, _) = lint_fixture("rust/src/util/timer.rs", FL007_SRC);
    assert!(d.is_empty(), "zone rule must not fire outside service//net/: {d:?}");
}

#[test]
fn panic_zone_rules_skip_test_files() {
    // the same seeded sources under rust/tests/ report nothing for the
    // whole-file-exempt rules (FL003 still applies to test files)
    let (d1, _) = lint_fixture("rust/tests/fixture.rs", FL001_SRC);
    assert!(d1.is_empty(), "{d1:?}");
    let (d4, _) = lint_fixture("rust/tests/fixture.rs", FL004_SRC);
    assert!(d4.is_empty(), "{d4:?}");
    let (d5, _) = lint_fixture("rust/tests/fixture.rs", FL005_SRC);
    assert!(d5.is_empty(), "{d5:?}");
    let (d3, _) = lint_fixture("rust/tests/fixture.rs", FL003_SRC);
    assert!(!d3.is_empty(), "FL003 must still fire in test files");
}

#[test]
fn lexer_and_model_handle_every_repo_source() {
    // every scanned .rs file must tokenize and model-build without error —
    // the lint can only gate CI if it can read the whole codebase
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = lint::collect_files(root).expect("walk scan roots");
    assert!(files.len() > 50, "expected a real scan, got {} files", files.len());
    for path in &files {
        let src = std::fs::read_to_string(path).expect("read source");
        let label = path.to_string_lossy().into_owned();
        let model = FileModel::build(&label, src)
            .unwrap_or_else(|e| panic!("{}: lexer/model failed: {e}", path.display()));
        assert!(
            model.malformed.is_empty(),
            "{}: malformed waiver: {:?}",
            path.display(),
            model.malformed
        );
    }
}

#[test]
fn repo_lints_clean_under_checked_in_baseline() {
    // the gating invariant: `finger lint --deny` passes on this tree, and
    // every baseline entry still matches a real finding (shrink-only)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint::run(&lint::LintOptions::new(root)).expect("lint run");
    assert!(
        report.clean(),
        "repo must lint clean:\n{}",
        report
            .diagnostics
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        report.stale_baseline.is_empty(),
        "stale baseline entries: {:?}",
        report.stale_baseline
    );
    assert!(report.files > 50);
}

#[test]
fn lexer_never_panics_on_arbitrary_input() {
    // robustness property: any byte soup either tokenizes or reports a
    // structured LexError — the lint must never crash on weird sources
    proptest::check(
        |rng: &mut Pcg64, size: usize| {
            let n = rng.below(size.max(1) * 8 + 1);
            let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            String::from_utf8_lossy(&bytes).into_owned()
        },
        |src| {
            let _ = lint::lexer::lex(src);
            Ok(())
        },
    );
}

#[test]
fn lexer_never_panics_on_rusty_fragments() {
    // denser coverage of the tricky lexemes: quotes, escapes, raw strings,
    // lifetimes, nested comments — assembled randomly
    const PIECES: &[&str] = &[
        "\"", "'", "\\", "r#\"", "\"#", "//", "/*", "*/", "'a", "b'x'", "0.5", "1e9", "ident",
        "::", "<", ">", "\n", "{", "}", "0x1f", "'\\n'", "r\"", "#", "!", "µ",
    ];
    proptest::check(
        |rng: &mut Pcg64, size: usize| {
            let n = rng.below(size + 1) + 1;
            (0..n).map(|_| PIECES[rng.below(PIECES.len())]).collect::<String>()
        },
        |src| {
            let _ = lint::lexer::lex(src);
            Ok(())
        },
    );
}

#[test]
fn baseline_roundtrip_through_render() {
    let diags = vec![lint::Diagnostic {
        rule: "FL001".to_string(),
        path: "rust/src/service/x.rs".to_string(),
        line: 3,
        col: 7,
        message: "boom".to_string(),
    }];
    let rendered = lint::render_as_baseline(&diags);
    let parsed = lint::Baseline::parse(&rendered).expect("rendered baseline parses");
    assert_eq!(parsed.entries.len(), 1);
    assert!(parsed.find("FL001", "rust/src/service/x.rs", 3).is_some());
    assert!(parsed.find("FL001", "rust/src/service/x.rs", 4).is_none());
}
