//! Cross-module entropy integration: exact eigensolver ↔ FINGER
//! approximations ↔ incremental state, on realistic generator workloads.

use finger::entropy::{
    entropy_from_eigenvalues, exact_vnge, finger_hhat, finger_htilde, quadratic_q, FingerState,
};
use finger::graph::{DeltaGraph, Graph, GraphSequence};
use finger::linalg::SymMatrix;
use finger::util::Pcg64;

#[test]
fn ordering_holds_across_models_and_densities() {
    let mut rng = Pcg64::new(100);
    let graphs: Vec<Graph> = vec![
        finger::generators::erdos_renyi_avg_degree(150, 8.0, &mut rng),
        finger::generators::erdos_renyi_avg_degree(150, 40.0, &mut rng),
        finger::generators::barabasi_albert(150, 3, &mut rng),
        finger::generators::watts_strogatz(150, 10, 0.05, &mut rng),
        finger::generators::watts_strogatz(150, 10, 0.8, &mut rng),
        finger::generators::complete(40, 2.5),
        finger::generators::star(100),
        finger::generators::ring(120),
    ];
    for (k, g) in graphs.iter().enumerate() {
        let h = exact_vnge(g);
        let hhat = finger_hhat(g);
        let htil = finger_htilde(g);
        assert!(htil <= hhat + 1e-9, "graph {k}: H̃={htil} > Ĥ={hhat}");
        assert!(hhat <= h + 1e-6, "graph {k}: Ĥ={hhat} > H={h}");
        assert!(h <= ((g.num_nodes() - 1) as f64).ln() + 1e-9, "graph {k}: H > ln(n-1)");
    }
}

#[test]
fn scaled_error_decays_for_er_and_grows_for_ba() {
    // Corollary 2 validation at test scale (the fig2 bench does it bigger)
    let sae = |g: &Graph| (exact_vnge(g) - finger_hhat(g)) / (g.num_nodes() as f64).ln();
    let mut rng = Pcg64::new(5);
    let er_small = finger::generators::erdos_renyi_avg_degree(150, 20.0, &mut rng);
    let er_large = finger::generators::erdos_renyi_avg_degree(900, 20.0, &mut rng);
    assert!(
        sae(&er_large) < sae(&er_small),
        "ER SAE must decay: {} vs {}",
        sae(&er_large),
        sae(&er_small)
    );
}

#[test]
fn incremental_state_tracks_sequence_exactly() {
    // drive a FingerState through a 60-step mixed stream and compare with
    // from-scratch H̃ at every step
    let mut rng = Pcg64::new(7);
    let g0 = finger::generators::erdos_renyi(120, 0.05, &mut rng);
    let mut state = FingerState::new(g0.clone());
    let mut reference = g0;
    for step in 0..60 {
        let mut d = DeltaGraph::new();
        for _ in 0..8 {
            let i = rng.below(120) as u32;
            let j = (i + 1 + rng.below(119) as u32) % 120;
            if i == j {
                continue;
            }
            match rng.below(3) {
                0 => {
                    d.add(i, j, rng.uniform(0.1, 2.0));
                }
                1 => {
                    let w = reference.weight(i.min(j), i.max(j));
                    if w > 0.0 {
                        d.add(i, j, -w);
                    }
                }
                _ => {
                    d.add(i, j, rng.uniform(-0.3, 0.3));
                }
            }
        }
        let d = d.coalesced();
        state.apply(&d);
        d.apply_to(&mut reference);
        let fresh = finger_htilde(&reference);
        assert!(
            (state.htilde() - fresh).abs() < 1e-8,
            "step {step}: {} vs {fresh}",
            state.htilde()
        );
        let q_fresh = quadratic_q(&reference);
        assert!((state.q() - q_fresh).abs() < 1e-8, "step {step} Q drift");
    }
}

#[test]
fn q_is_one_minus_purity_on_every_model() {
    let mut rng = Pcg64::new(9);
    for g in [
        finger::generators::barabasi_albert(80, 2, &mut rng),
        finger::generators::watts_strogatz(80, 6, 0.2, &mut rng),
    ] {
        let eigs = SymMatrix::laplacian_normalized(&g).eigenvalues();
        let purity: f64 = eigs.iter().map(|l| l * l).sum();
        assert!((quadratic_q(&g) - (1.0 - purity)).abs() < 1e-9);
        // and exact H reproduces entropy_from_eigenvalues
        assert!((exact_vnge(&g) - entropy_from_eigenvalues(&eigs)).abs() < 1e-12);
    }
}

#[test]
fn complete_graph_anchor_values() {
    // Theorem 1 equality case across sizes, both entropy and bounds
    for n in [5usize, 20, 60] {
        let g = finger::generators::complete(n, 1.0);
        let h = exact_vnge(&g);
        assert!((h - ((n - 1) as f64).ln()).abs() < 1e-8);
        // Ĥ on complete graphs: λ_max = 1/(n−1), Q = 1 − 1/(n−1)
        let hhat = finger_hhat(&g);
        let expected = (1.0 - 1.0 / (n as f64 - 1.0)) * ((n as f64) - 1.0).ln();
        assert!((hhat - expected).abs() < 1e-6, "n={n}: {hhat} vs {expected}");
    }
}

#[test]
fn disconnected_graphs_sum_structure() {
    // entropy of disjoint union is well-defined and FINGER stays ordered
    let mut g = Graph::new(60);
    for base in [0u32, 20, 40] {
        for i in 0..19 {
            g.set_weight(base + i, base + i + 1, 1.0);
        }
    }
    assert_eq!(g.connected_components(), 3);
    let h = exact_vnge(&g);
    let hhat = finger_hhat(&g);
    let htil = finger_htilde(&g);
    assert!(htil <= hhat + 1e-9 && hhat <= h + 1e-6);
}

#[test]
fn sequence_entropies_stable_under_materialization() {
    // computing over GraphSequence::from_deltas equals direct composition
    let mut rng = Pcg64::new(21);
    let g0 = finger::generators::erdos_renyi(60, 0.08, &mut rng);
    let mut deltas = Vec::new();
    for _ in 0..10 {
        let mut d = DeltaGraph::new();
        let i = rng.below(60) as u32;
        let j = (i + 7) % 60;
        if i != j {
            d.add(i, j, 1.0);
        }
        deltas.push(d);
    }
    let seq = GraphSequence::from_deltas(g0.clone(), &deltas);
    let mut g = g0;
    for (t, d) in deltas.iter().enumerate() {
        d.apply_to(&mut g);
        assert!((finger_hhat(seq.get(t + 1)) - finger_hhat(&g)).abs() < 1e-12);
    }
}
