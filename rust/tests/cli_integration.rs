//! CLI + config integration: exercise the binary's argument surface through
//! the library-level entry points, plus config file parsing end to end.

use finger::cli::{Args, Config};

fn toks(s: &str) -> Vec<String> {
    s.split_whitespace().map(|t| t.to_string()).collect()
}

#[test]
fn full_cli_surface_parses() {
    let a = Args::parse(&toks(
        "wiki --dataset en --scale 2.5 --series",
    ));
    assert_eq!(a.subcommand.as_deref(), Some("wiki"));
    assert_eq!(a.get("dataset"), Some("en"));
    assert!((a.get_parsed("scale", 0.0f64) - 2.5).abs() < 1e-12);
    assert!(a.flag("series"));
}

#[test]
fn sweep_args() {
    let a = Args::parse(&toks("sweep --kind fig1-ws --n 1200 --trials 5"));
    assert_eq!(a.get("kind"), Some("fig1-ws"));
    assert_eq!(a.get_parsed("n", 0usize), 1200);
    assert_eq!(a.get_parsed("trials", 0usize), 5);
}

#[test]
fn config_round_trip_through_file() {
    let dir = std::env::temp_dir().join("finger_cli_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "[wiki]\nmonths = 36\n[stream]\ncapacity = 32\nanomaly_sigma = 2.0\n",
    )
    .unwrap();
    let c = Config::load(&path).unwrap();
    assert_eq!(c.get_or("wiki.months", 0usize), 36);
    assert_eq!(c.get_or("stream.capacity", 0usize), 32);
    assert!((c.get_or("stream.anomaly_sigma", 0.0f64) - 2.0).abs() < 1e-12);
    std::fs::remove_file(path).ok();
}

#[test]
fn graph_file_workflow() {
    // save a graph, reload it, and compute entropies — the `finger entropy
    // file.edges` path without spawning a process
    let dir = std::env::temp_dir().join("finger_cli_it2");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.edges");
    let mut rng = finger::util::Pcg64::new(5);
    let g = finger::generators::erdos_renyi(80, 0.1, &mut rng);
    finger::graph::io::save_graph(&g, &path).unwrap();
    let loaded = finger::graph::io::load_graph(&path).unwrap();
    assert!((finger::entropy::finger_hhat(&g) - finger::entropy::finger_hhat(&loaded)).abs() < 1e-12);
    std::fs::remove_file(path).ok();
}

#[test]
fn delta_stream_file_workflow() {
    let dir = std::env::temp_dir().join("finger_cli_it3");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("deltas.txt");
    std::fs::write(&path, "0 0 1 1.0\n0 1 2 1.0\n1 0 1 -1.0\n").unwrap();
    let f = std::fs::File::open(&path).unwrap();
    let deltas = finger::graph::io::read_delta_stream(f).unwrap();
    assert_eq!(deltas.len(), 2);
    assert_eq!(deltas[0].num_changes(), 2);
    let events = finger::stream::event::events_from_deltas(&deltas);
    let res = finger::stream::Pipeline::new(
        finger::graph::Graph::new(3),
        finger::stream::PipelineConfig::default(),
    )
    .run(events);
    assert_eq!(res.records.len(), 2);
    assert_eq!(res.records[1].edges, 1); // edge (0,1) deleted again
    std::fs::remove_file(path).ok();
}
