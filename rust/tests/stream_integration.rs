//! Streaming pipeline integration: realistic workloads, backpressure,
//! checkpoint/restore mid-stream, and failure injection (malformed events
//! are dropped at parse, self-loops ignored, empty windows are fine).

use finger::datasets::{wiki_stream, WikiConfig};
use finger::stream::checkpoint;
use finger::stream::event::{events_from_deltas, StreamEvent};
use finger::stream::{Pipeline, PipelineConfig};
use finger::entropy::FingerState;
use finger::util::Pcg64;

#[test]
fn wiki_workload_end_to_end() {
    let cfg = WikiConfig {
        months: 18,
        initial_nodes: 150,
        growth_per_month: 40,
        burst_months: 2,
        burst_factor: 10.0,
        ..Default::default()
    };
    let stream = wiki_stream(&cfg);
    let events = events_from_deltas(&stream.deltas);
    let total = events.len();
    let res = Pipeline::new(stream.initial.clone(), PipelineConfig::default()).run(events);
    assert_eq!(res.records.len(), 17);
    assert_eq!(res.total_events, total);
    // node growth visible in the records
    assert!(res.records.last().unwrap().nodes > stream.initial.num_nodes());
    // bursts produce the largest JS scores
    let mut scored: Vec<(usize, f64)> =
        res.records.iter().map(|r| (r.window + 1, r.jsdist)).collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let top_months: Vec<usize> = scored.iter().take(4).map(|(m, _)| *m).collect();
    let hits = stream.burst_months.iter().filter(|m| top_months.contains(m)).count();
    assert!(hits >= 1, "bursts {:?} not among top windows {top_months:?}", stream.burst_months);
}

#[test]
fn pipeline_result_independent_of_channel_capacity() {
    let cfg = WikiConfig { months: 8, initial_nodes: 80, growth_per_month: 20, ..Default::default() };
    let stream = wiki_stream(&cfg);
    let mut baseline: Option<Vec<f64>> = None;
    for cap in [1usize, 4, 256] {
        let events = events_from_deltas(&stream.deltas);
        let res = Pipeline::new(
            stream.initial.clone(),
            PipelineConfig { channel_capacity: cap, ..Default::default() },
        )
        .run(events);
        let scores: Vec<f64> = res.records.iter().map(|r| r.jsdist).collect();
        match &baseline {
            None => baseline = Some(scores),
            Some(b) => {
                assert_eq!(b.len(), scores.len());
                for (x, y) in b.iter().zip(&scores) {
                    assert!((x - y).abs() < 1e-12, "capacity {cap} changed scores");
                }
            }
        }
    }
}

#[test]
fn checkpoint_mid_stream_resume_equivalence() {
    let mut rng = Pcg64::new(11);
    let g = finger::generators::erdos_renyi(60, 0.1, &mut rng);
    let mut deltas = Vec::new();
    for _ in 0..12 {
        let mut d = finger::graph::DeltaGraph::new();
        for _ in 0..6 {
            let i = rng.below(60) as u32;
            let j = (i + 1 + rng.below(59) as u32) % 60;
            if i != j {
                d.add(i, j, rng.uniform(-0.5, 1.0));
            }
        }
        deltas.push(d.coalesced());
    }
    // uninterrupted
    let mut full = FingerState::new(g.clone());
    for d in &deltas {
        full.apply(d);
    }
    // interrupted at step 6 with checkpoint
    let mut part = FingerState::new(g);
    for d in &deltas[..6] {
        part.apply(d);
    }
    let path = std::env::temp_dir().join("finger_stream_it.ckpt");
    checkpoint::save(&part, &path).unwrap();
    let mut resumed = checkpoint::load(&path).unwrap();
    for d in &deltas[6..] {
        resumed.apply(d);
    }
    assert!((full.htilde() - resumed.htilde()).abs() < 1e-10);
    assert!((full.q() - resumed.q()).abs() < 1e-10);
    std::fs::remove_file(path).ok();
}

#[test]
fn malformed_event_lines_are_rejected_not_crashing() {
    for bad in ["e 1", "e a b c", "n", "q 1 2 3", "e 1 1 nanx"] {
        assert!(StreamEvent::parse(bad).is_none(), "{bad:?} should not parse");
    }
}

#[test]
fn burst_flagged_online_with_default_sigma() {
    // deterministic burst detection through the full pipeline
    let g = finger::generators::erdos_renyi(200, 0.05, &mut Pcg64::new(21));
    let mut deltas = Vec::new();
    let mut rng = Pcg64::new(22);
    for t in 0..40 {
        let mut d = finger::graph::DeltaGraph::new();
        let k = if t == 30 { 600 } else { 4 };
        for _ in 0..k {
            let i = rng.below(200) as u32;
            let j = (i + 1 + rng.below(199) as u32) % 200;
            if i != j {
                d.add(i, j, 1.0);
            }
        }
        deltas.push(d.coalesced());
    }
    let res = Pipeline::new(g, PipelineConfig::default()).run(events_from_deltas(&deltas));
    assert!(res.anomalies.contains(&30), "{:?}", res.anomalies);
    // steady-state windows mostly unflagged
    assert!(res.anomalies.len() <= 5, "{:?}", res.anomalies);
}

#[test]
fn throughput_is_reported_positive() {
    let g = finger::generators::erdos_renyi(100, 0.1, &mut Pcg64::new(31));
    let events: Vec<StreamEvent> = (0..500)
        .flat_map(|k: u32| {
            let mut v = vec![StreamEvent::EdgeDelta {
                i: k % 100,
                j: (k * 7 + 1) % 100,
                dw: 0.5,
            }];
            if k % 25 == 24 {
                v.push(StreamEvent::Tick);
            }
            v
        })
        .collect();
    let res = Pipeline::new(g, PipelineConfig::default()).run(events);
    assert!(res.throughput > 1000.0, "throughput={}", res.throughput);
    assert!(res.p99_latency >= res.p50_latency);
}

#[test]
fn pipeline_scores_bit_identical_to_allocating_algorithm2_loop() {
    // The pipeline's scorer runs the scratch-reusing hot path (in-place
    // batcher + `entropy::Scratch`); its scores must be bit-for-bit what the
    // per-call-allocating `jsdist_incremental` produces over the same
    // windows — the pre-refactor reference semantics.
    let cfg =
        WikiConfig { months: 14, initial_nodes: 120, growth_per_month: 30, ..Default::default() };
    let stream = wiki_stream(&cfg);
    let events = events_from_deltas(&stream.deltas);
    let res = Pipeline::new(stream.initial.clone(), PipelineConfig::default()).run(events);

    let mut state = FingerState::new(stream.initial.clone());
    let mut batcher = finger::stream::WindowBatcher::new();
    let mut reference = Vec::new();
    for d in &stream.deltas {
        for ev in events_from_deltas(std::slice::from_ref(d)) {
            if let Some((delta, _)) = batcher.push(ev) {
                reference.push(finger::distance::jsdist_incremental(&mut state, &delta));
            }
        }
    }
    assert_eq!(res.records.len(), reference.len());
    for (r, js) in res.records.iter().zip(&reference) {
        assert_eq!(
            r.jsdist.to_bits(),
            js.to_bits(),
            "window {}: {} vs {js}",
            r.window,
            r.jsdist
        );
    }
    assert_eq!(res.records.last().unwrap().htilde.to_bits(), state.htilde().to_bits());
}
