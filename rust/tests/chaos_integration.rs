//! Chaos suite: deterministic fault injection against the real stack.
//!
//! Every test arms `finger::fault` failpoints (WAL appends, snapshot
//! renames, socket reads/writes, shard submits) against live services and
//! asserts the robustness contract from `docs/ROBUSTNESS.md`: `fail_stop`
//! refuses writes until an epoch cut restores the log, `degrade` keeps
//! scoring bit-identically while flagging `durability=degraded`, recovery
//! of a fault-torn WAL always yields a valid prefix, the retry client
//! delivers exactly once across connection kills, and parked writes shed
//! with `ERR retry-after`.
//!
//! The whole file is gated on the `fault-inject` feature — the default
//! build compiles it to an empty harness:
//! `cargo test --features fault-inject --test chaos_integration`.

#![cfg(feature = "fault-inject")]

use finger::durability::{DurabilityConfig, FsyncPolicy, OnError};
use finger::fault::{self, Failpoint, FaultSpec};
use finger::graph::Graph;
use finger::net::{
    Command, NetClient, NetConfig, NetServer, Reply, RetryClient, RetryPolicy, Wire,
};
use finger::service::{ScoringService, ServiceConfig, ServiceReport, SessionSnapshot};
use finger::stream::StreamEvent;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

const NODES: usize = 16;

/// The failpoint registry is process-global, so chaos tests must not
/// overlap: each takes this lock and gets a clean (all-off) registry on
/// entry and on exit, panic included.
static FAULTS: Mutex<()> = Mutex::new(());

struct FaultGuard {
    _serial: MutexGuard<'static, ()>,
}

impl FaultGuard {
    fn hold() -> Self {
        let serial = FAULTS.lock().unwrap_or_else(|e| e.into_inner());
        disarm_all();
        FaultGuard { _serial: serial }
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        disarm_all();
    }
}

fn disarm_all() {
    for fp in Failpoint::ALL {
        fault::set(fp, FaultSpec::Off);
    }
}

/// Deterministic tick-terminated window `w`: positive weights, no
/// self-loops, indices well inside `NODES` — identical over the wire and
/// in process.
fn window(w: usize) -> Vec<StreamEvent> {
    let mut evs = Vec::with_capacity(7);
    for k in 0..6u32 {
        let i = ((w as u32) * 5 + k * 3) % 10;
        let j = i + 1 + (k % 4);
        let dw = 0.2 + f64::from((k + w as u32) % 5) * 0.3;
        evs.push(StreamEvent::EdgeDelta { i, j, dw });
    }
    evs.push(StreamEvent::Tick);
    evs
}

fn temp_root(tag: &str) -> PathBuf {
    let root =
        std::env::temp_dir().join(format!("finger_chaos_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).expect("create chaos test root");
    root
}

fn durable_cfg(dir: &Path, on_error: OnError) -> ServiceConfig {
    let mut dur = DurabilityConfig::new(dir);
    dur.fsync = FsyncPolicy::Always;
    dur.on_error = on_error;
    ServiceConfig { shards: 1, durability: Some(dur), ..Default::default() }
}

fn spawn_server(
    service_cfg: ServiceConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<ServiceReport>>) {
    let net_cfg = NetConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() };
    spawn_server_with(service_cfg, net_cfg)
}

fn spawn_server_with(
    service_cfg: ServiceConfig,
    net_cfg: NetConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<ServiceReport>>) {
    let server = NetServer::bind(service_cfg, net_cfg).expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    (addr, std::thread::spawn(move || server.run()))
}

fn assert_bits_equal(got: &SessionSnapshot, want: &SessionSnapshot, label: &str) {
    assert_eq!(got.windows, want.windows, "{label}: window count");
    assert_eq!(got.events, want.events, "{label}: event count");
    assert_eq!(got.pending_events, want.pending_events, "{label}: pending events");
    assert_eq!(got.nodes, want.nodes, "{label}: nodes");
    assert_eq!(got.edges, want.edges, "{label}: edges");
    assert_eq!(got.anomalies, want.anomalies, "{label}: anomaly count");
    assert_eq!(
        got.htilde.to_bits(),
        want.htilde.to_bits(),
        "{label}: H̃ {} vs {}",
        got.htilde,
        want.htilde
    );
    match (got.last_jsdist, want.last_jsdist) {
        (Some(a), Some(b)) => {
            assert_eq!(a.to_bits(), b.to_bits(), "{label}: jsdist {a} vs {b}")
        }
        (None, None) => {}
        (a, b) => panic!("{label}: jsdist presence mismatch: {a:?} vs {b:?}"),
    }
}

#[test]
fn fault_verb_arms_and_reports_over_the_wire() {
    let _guard = FaultGuard::hold();
    assert!(fault::compiled_in(), "this suite only builds with fault-inject");

    let (addr, server) = spawn_server(ServiceConfig { shards: 1, ..Default::default() });
    let mut client = NetClient::connect(addr.as_str()).expect("connect");

    // arming echoes the failpoint and its normalized spec, and lands in the
    // process-global registry this test shares with the server
    let reply = client
        .roundtrip(&Command::Fault { name: "wal.append".to_string(), spec: "every=3".to_string() })
        .expect("FAULT round-trip");
    assert_eq!(reply.get("fault"), Some("wal.append"), "{reply:?}");
    assert_eq!(reply.get("spec"), Some("every=3"), "{reply:?}");
    assert_eq!(fault::spec_of(Failpoint::WalAppend), FaultSpec::Every(3));

    // unknown name and malformed spec are distinct, connection-preserving ERRs
    for (name, spec, want) in [
        ("wal.nope", "once", "unknown-failpoint"),
        ("wal.append", "at=0", "bad-fault-spec"),
        ("wal.append", "sometimes", "bad-fault-spec"),
    ] {
        match client
            .roundtrip(&Command::Fault { name: name.to_string(), spec: spec.to_string() })
            .expect("connection must survive a bad FAULT")
        {
            Reply::Err(reason) => assert!(reason.contains(want), "{name} {spec}: {reason:?}"),
            ok => panic!("{name} {spec}: should ERR, got {ok:?}"),
        }
    }
    // a bad FAULT must not have disturbed the armed schedule
    assert_eq!(fault::spec_of(Failpoint::WalAppend), FaultSpec::Every(3));

    // disarming over the wire
    client
        .roundtrip(&Command::Fault { name: "wal.append".to_string(), spec: "off".to_string() })
        .expect("disarm");
    assert_eq!(fault::spec_of(Failpoint::WalAppend), FaultSpec::Off);

    client.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
}

#[test]
fn wal_fault_under_fail_stop_refuses_writes_until_epoch_cut() {
    let _guard = FaultGuard::hold();
    let root = temp_root("failstop");
    let (addr, server) = spawn_server(durable_cfg(&root, OnError::FailStop));
    let mut client = NetClient::connect(addr.as_str()).expect("connect");

    client.open("s", NODES).expect("open");
    client.send_batch("s", &window(0)).expect("healthy batch");

    fault::set(Failpoint::WalAppend, FaultSpec::Once);
    client.send_batch("s", &window(1)).expect("batch is acked before the WAL latch lands");
    // QUERY rides the same shard FIFO, so once it answers the faulted append
    // has been processed and the fail-stop latch is set
    client.query("s").expect("settle query").expect("live session");

    let stats = client.roundtrip(&Command::Stats).expect("stats");
    assert_eq!(stats.get("durability"), Some("failed"), "{stats:?}");

    // every mutating verb is refused; reads still work
    let err = client.send_batch("s", &window(2)).expect_err("write must be refused");
    assert!(err.to_string().contains("durability-failed"), "{err:#}");
    let err = client.send_event("s", &StreamEvent::Tick).expect_err("EV refused too");
    assert!(err.to_string().contains("durability-failed"), "{err:#}");
    client.query("s").expect("reads pass the gate").expect("live session");

    // an epoch cut rotates every shard onto a fresh log and clears the latch
    let (epoch, sessions) = client.epoch().expect("EPOCH restores the log");
    assert_eq!(epoch, 1);
    assert_eq!(sessions, 1);
    let stats = client.roundtrip(&Command::Stats).expect("stats after cut");
    assert_eq!(stats.get("durability"), Some("on"), "{stats:?}");
    client.send_batch("s", &window(2)).expect("writes resume after the cut");

    client.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn degrade_keeps_scoring_bit_identically_and_fails_epoch_cuts() {
    let _guard = FaultGuard::hold();
    let root = temp_root("degrade");
    let svc = ScoringService::start(durable_cfg(&root, OnError::Degrade));
    let reference = ScoringService::start(ServiceConfig { shards: 1, ..Default::default() });
    svc.open_session("t", Graph::new(NODES)).expect("open durable");
    reference.open_session("t", Graph::new(NODES)).expect("open reference");
    // settle the OPEN so the armed fault cannot land on its WAL record
    svc.query("t").expect("settle").expect("live session");

    for w in 0..5 {
        if w == 2 {
            fault::set(Failpoint::WalAppend, FaultSpec::Once);
        }
        svc.submit_batch("t", window(w)).expect("degraded service keeps accepting");
        reference.submit_batch("t", window(w)).expect("reference batch");
    }
    let got = svc.query("t").expect("query").expect("live session");
    let want = reference.query("t").expect("query").expect("live session");
    assert_bits_equal(&got, &want, "scores must not notice the dropped WAL");
    assert_eq!(svc.durability_status(), "degraded");

    // a WAL-less shard cannot take an epoch barrier — the cut must fail
    // loudly rather than commit a snapshot that promises durability
    let err = svc.snapshot_epoch().expect_err("degraded cut must fail");
    assert!(err.to_string().contains("no WAL writer"), "{err:#}");

    svc.finish();
    reference.finish();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn degraded_status_surfaces_in_stats_and_metrics_on_the_wire() {
    let _guard = FaultGuard::hold();
    let root = temp_root("degrade_wire");
    let (addr, server) = spawn_server(durable_cfg(&root, OnError::Degrade));
    let mut client = NetClient::connect(addr.as_str()).expect("connect");

    client.open("s", NODES).expect("open");
    client.send_batch("s", &window(0)).expect("healthy batch");
    let stats = client.roundtrip(&Command::Stats).expect("stats");
    assert_eq!(stats.get("durability"), Some("on"), "{stats:?}");

    // arm through the wire verb — the live-server path the chaos-smoke CI
    // job scripts — then trip it and settle
    client
        .roundtrip(&Command::Fault { name: "wal.append".to_string(), spec: "once".to_string() })
        .expect("arm over the wire");
    client.send_batch("s", &window(1)).expect("batch that trips the latch");
    client.query("s").expect("settle query").expect("live session");

    let stats = client.roundtrip(&Command::Stats).expect("stats");
    assert_eq!(stats.get("durability"), Some("degraded"), "{stats:?}");
    let metrics = client.metrics().expect("metrics");
    let get = |k: &str| -> u64 {
        metrics.pairs.iter().find(|(key, _)| key == k).map(|(_, v)| *v).expect(k)
    };
    assert_eq!(get("durability_degraded"), 1);
    assert_eq!(get("durability_failed"), 0);
    assert!(get("fault_injected") >= 1, "the armed failpoint fired");

    client.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn injected_wal_faults_always_recover_a_valid_prefix() {
    let _guard = FaultGuard::hold();
    const TOTAL: usize = 6;
    // exhaustive small matrix rather than sampling: every at=N position in
    // (and past) the run, plus the periodic and persistent shapes
    let mut schedules = vec![
        FaultSpec::Once,
        FaultSpec::Every(2),
        FaultSpec::Every(3),
        FaultSpec::After(2),
    ];
    schedules.extend((1..=TOTAL as u64 + 2).map(FaultSpec::At));
    for (k, spec) in schedules.into_iter().enumerate() {
        let root = temp_root(&format!("prefix{k}"));
        disarm_all();
        {
            let svc = ScoringService::start(durable_cfg(&root, OnError::FailStop));
            svc.open_session("t", Graph::new(NODES)).expect("open");
            // settle the OPEN record first: the schedule under test is about
            // window appends, and a session-less WAL recovers trivially
            svc.query("t").expect("settle").expect("live session");
            fault::set(Failpoint::WalAppend, spec);
            for w in 0..TOTAL {
                svc.submit_batch("t", window(w)).expect("submit under fault schedule");
            }
            svc.finish();
        }
        disarm_all();

        let recovered = ScoringService::recover(durable_cfg(&root, OnError::FailStop))
            .unwrap_or_else(|e| panic!("schedule {spec:?} must recover, got: {e:#}"));
        let snap = recovered
            .query("t")
            .expect("query recovered")
            .expect("the logged OPEN restores the session");
        assert!(
            snap.windows <= TOTAL,
            "schedule {spec:?} replayed {} windows > {TOTAL} submitted",
            snap.windows
        );
        assert_eq!(snap.pending_events, 0, "windows replay whole or not at all");

        // the recovered prefix must match an unfaulted run of that many
        // windows bit for bit
        let reference = ScoringService::start(ServiceConfig { shards: 1, ..Default::default() });
        reference.open_session("t", Graph::new(NODES)).expect("open reference");
        for w in 0..snap.windows {
            reference.submit_batch("t", window(w)).expect("reference batch");
        }
        let want = reference.query("t").expect("query").expect("live session");
        assert_bits_equal(&snap, &want, &format!("prefix under {spec:?}"));
        reference.finish();
        recovered.finish();
        std::fs::remove_dir_all(&root).ok();
    }
}

#[test]
fn retry_client_delivers_exactly_once_across_connection_faults() {
    let _guard = FaultGuard::hold();
    const TOTAL: usize = 6;

    // unfaulted reference run, in process
    let reference = ScoringService::start(ServiceConfig { shards: 1, ..Default::default() });
    reference.open_session("t", Graph::new(NODES)).expect("open reference");
    for w in 0..TOTAL {
        reference.submit_batch("t", window(w)).expect("reference batch");
    }
    let want = reference.query("t").expect("query").expect("live session");
    let want_report = reference.finish();

    let (addr, server) = spawn_server(ServiceConfig { shards: 1, ..Default::default() });
    let mut wires = 0usize;
    for wire in [Wire::Text, Wire::Binary] {
        wires += 1;
        let mut client = RetryClient::connect(
            addr.as_str(),
            wire,
            Some(Duration::from_secs(10)),
            RetryPolicy::default(),
        )
        .expect("retry connect");
        client.open("t", NODES).expect("reliable open");
        for w in 0..TOTAL {
            match w {
                // kill the connection before the server reads the request:
                // the write is lost pre-apply and must be resent
                2 => fault::set(Failpoint::NetRead, FaultSpec::Once),
                // kill the connection after apply, before the ack: the
                // resend must be recognized as a duplicate and discarded
                4 => fault::set(Failpoint::NetWrite, FaultSpec::Once),
                _ => {}
            }
            let accepted = client.send_batch("t", &window(w)).expect("reliable batch");
            assert_eq!(accepted, window(w).len(), "{wire}: window {w}");
        }
        let got = client.query("t").expect("query").expect("live session");
        assert_bits_equal(&got, &want, &format!("{wire}: exactly-once replay"));
        let errs = client.counts().clone();
        assert!(
            errs.retries >= 2,
            "{wire}: two injected kills must surface as retries: {errs:?}"
        );
        assert!(errs.total() >= 2, "{wire}: transport errors were recorded: {errs:?}");
        client.quit().expect("quit");
    }

    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    let report = server.join().expect("server thread").expect("server run");
    // the exactly-once core: retries and duplicate resends land ZERO extra
    // events — each wire's run applied exactly the reference event count
    assert_eq!(
        report.total_events,
        want_report.total_events * wires,
        "duplicate or lost events under connection faults"
    );
}

#[test]
fn parked_writes_shed_with_retry_after_and_retry_client_rides_it_out() {
    let _guard = FaultGuard::hold();
    let net_cfg = NetConfig {
        addr: "127.0.0.1:0".to_string(),
        event_threads: 1,
        shed_after_ms: 40,
        ..Default::default()
    };
    let (addr, server) = spawn_server_with(
        ServiceConfig { shards: 1, channel_capacity: 1, ..Default::default() },
        net_cfg,
    );
    let mut client = NetClient::connect(addr.as_str()).expect("connect");
    client.open("s", NODES).expect("open");
    client.query("s").expect("settle open").expect("live session");

    // injected backpressure on every submit: the parked command can never
    // drain, so the shed budget must fire
    fault::set(Failpoint::ShardSubmit, FaultSpec::Every(1));
    let err = client.send_event("s", &StreamEvent::Tick).expect_err("must shed");
    assert!(err.to_string().contains("retry-after 40"), "{err:#}");

    // the connection survives shedding, and writes resume once the
    // backpressure clears
    fault::set(Failpoint::ShardSubmit, FaultSpec::Off);
    client.send_event("s", &StreamEvent::Tick).expect("send after shed");

    // a RetryClient treats retry-after on a send as wait-and-resend (OPEN is
    // deliberately fail-fast, so open before arming): re-arm, clear the
    // fault from another thread a beat later, and the delivery completes
    let mut retry = RetryClient::connect(
        addr.as_str(),
        Wire::Text,
        Some(Duration::from_secs(10)),
        RetryPolicy::default(),
    )
    .expect("retry connect");
    retry.open("r", NODES).expect("reliable open");
    fault::set(Failpoint::ShardSubmit, FaultSpec::Every(1));
    let clearer = std::thread::spawn(|| {
        std::thread::sleep(Duration::from_millis(150));
        fault::set(Failpoint::ShardSubmit, FaultSpec::Off);
    });
    retry.send_batch("r", &window(0)).expect("delivery survives the shed window");
    clearer.join().expect("clearer thread");
    let errs = retry.counts().clone();
    assert!(
        errs.server_err.contains_key("retry-after"),
        "the shed replies were observed: {errs:?}"
    );
    retry.quit().expect("quit");

    client.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
}

#[test]
fn failed_epoch_cut_is_retryable_and_commits_cleanly() {
    let _guard = FaultGuard::hold();
    let root = temp_root("epoch_retry");
    let svc = ScoringService::start(durable_cfg(&root, OnError::FailStop));
    svc.open_session("t", Graph::new(NODES)).expect("open");
    svc.submit_batch("t", window(0)).expect("batch");
    svc.query("t").expect("settle").expect("live session");

    fault::set(Failpoint::SnapRename, FaultSpec::Once);
    let err = svc.snapshot_epoch().expect_err("injected rename fails the cut");
    assert!(err.to_string().contains("injected fault: snap.rename"), "{err:#}");

    // same epoch number, clean staging: the retry commits
    let cut = svc.snapshot_epoch().expect("second cut succeeds");
    assert_eq!(cut.epoch, 1);
    assert_eq!(cut.sessions, 1);
    svc.finish();

    disarm_all();
    let recovered = ScoringService::recover(durable_cfg(&root, OnError::FailStop))
        .expect("recover from the retried epoch");
    let snap = recovered.query("t").expect("query").expect("restored session");
    assert_eq!(snap.windows, 1);
    assert_eq!(snap.pending_events, 0);
    recovered.finish();
    std::fs::remove_dir_all(&root).ok();
}
