//! Sharded scoring service integration: deterministic cross-shard routing,
//! no event loss under maximal backpressure (capacity-1 channels, many
//! sessions), per-session scoring equivalence with the offline Algorithm-2
//! loop, and checkpoint/restore round-trips through the service.

use finger::distance::jsdist_incremental;
use finger::entropy::FingerState;
use finger::graph::Graph;
use finger::service::{
    shard_of, workload, ScoringService, ServiceConfig, TenantWorkloadConfig,
};
use finger::stream::{event::events_from_deltas, StreamEvent};
use finger::util::Pcg64;

fn small_workload(sessions: usize, windows: usize) -> Vec<workload::TenantStream> {
    workload::tenant_streams(&TenantWorkloadConfig {
        sessions,
        windows,
        events_per_window: 12,
        nodes_per_session: 20,
        seed: 0x7E57,
        ..Default::default()
    })
}

#[test]
fn routing_is_deterministic_and_stable() {
    // shard_for must agree with the free function, be stable across service
    // instances, and be independent of submission order.
    let cfg = ServiceConfig { shards: 4, ..Default::default() };
    let a = ScoringService::start(cfg.clone());
    let b = ScoringService::start(cfg);
    for k in 0..64 {
        let id = format!("tenant-{k}");
        assert_eq!(a.shard_for(&id), shard_of(&id, 4));
        assert_eq!(a.shard_for(&id), b.shard_for(&id));
    }
    a.finish();
    b.finish();
}

#[test]
fn no_event_loss_under_capacity_one_channels() {
    // capacity-1 shard queues with many sessions and several producer
    // threads: constant backpressure, yet every event must arrive.
    let workload_data = small_workload(48, 6);
    let total = workload::workload_events(&workload_data);
    let cfg = ServiceConfig { shards: 3, channel_capacity: 1, ..Default::default() };
    let report = workload::drive(&cfg, &workload_data, 6, false).unwrap();
    assert_eq!(report.total_events, total);
    assert_eq!(report.dropped_events, 0);
    assert_eq!(report.sessions.len(), 48);
    let per_session: usize = report.sessions.iter().map(|s| s.events).sum();
    assert_eq!(per_session, total, "every submitted event reaches its session");
    for s in &report.sessions {
        assert_eq!(s.records.len(), 6, "{}: every tick closes a window", s.id);
    }
}

#[test]
fn batched_ingest_loses_nothing_either() {
    let workload_data = small_workload(32, 5);
    let total = workload::workload_events(&workload_data);
    let cfg = ServiceConfig { shards: 4, channel_capacity: 1, ..Default::default() };
    let report = workload::drive(&cfg, &workload_data, 4, true).unwrap();
    assert_eq!(report.total_events, total);
    assert_eq!(report.sessions.iter().map(|s| s.events).sum::<usize>(), total);
}

#[test]
fn per_session_scores_match_offline_loop() {
    // Whatever the interleaving across shards and producers, each session's
    // scores must equal the direct single-threaded Algorithm-2 loop.
    let workload_data = small_workload(12, 5);
    let cfg = ServiceConfig { shards: 3, ..Default::default() };
    let report = workload::drive(&cfg, &workload_data, 4, false).unwrap();
    for (id, initial, events) in &workload_data {
        let session = report.session(id).expect("session scored");
        // replay offline
        let mut state = FingerState::new(initial.clone());
        let mut batcher = finger::stream::WindowBatcher::new();
        let mut offline = Vec::new();
        for ev in events.iter().cloned() {
            if let Some((delta, _)) = batcher.push(ev) {
                offline.push(jsdist_incremental(&mut state, &delta));
            }
        }
        assert_eq!(session.records.len(), offline.len(), "{id}");
        for (r, js) in session.records.iter().zip(&offline) {
            assert!((r.jsdist - js).abs() < 1e-12, "{id} window {}", r.window);
        }
        assert!((session.htilde - state.htilde()).abs() < 1e-12, "{id}");
    }
}

#[test]
fn service_matches_single_stream_pipeline() {
    // one session through the service == the same stream through Pipeline
    let g = finger::generators::erdos_renyi(40, 0.1, &mut Pcg64::new(3));
    let mut deltas = Vec::new();
    let mut rng = Pcg64::new(4);
    for _ in 0..8 {
        let mut d = finger::graph::DeltaGraph::new();
        for _ in 0..5 {
            let i = rng.below(40) as u32;
            let j = (i + 1 + rng.below(39) as u32) % 40;
            if i != j {
                d.add(i, j, rng.uniform(0.1, 1.0));
            }
        }
        deltas.push(d.coalesced());
    }
    let events = events_from_deltas(&deltas);
    let pipeline_res = finger::stream::Pipeline::new(
        g.clone(),
        finger::stream::PipelineConfig::default(),
    )
    .run(events.clone());

    let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
    svc.open_session("solo", g).unwrap();
    svc.submit_all("solo", events).unwrap();
    let report = svc.finish();
    let session = report.session("solo").unwrap();
    assert_eq!(session.records.len(), pipeline_res.records.len());
    for (a, b) in session.records.iter().zip(&pipeline_res.records) {
        assert!((a.jsdist - b.jsdist).abs() < 1e-12);
        assert_eq!(a.events, b.events);
        assert_eq!(a.anomalous, b.anomalous);
    }
}

#[test]
fn checkpoint_restore_roundtrip_preserves_htilde_per_session() {
    let dir = std::env::temp_dir().join("finger_service_ckpt_it");
    std::fs::remove_dir_all(&dir).ok();
    let workload_data = small_workload(10, 4);
    let cfg = ServiceConfig {
        shards: 2,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    let first = workload::drive(&cfg, &workload_data, 2, true).unwrap();
    assert_eq!(first.sessions.len(), 10);

    // restore into a fresh service and finish immediately: states must match
    let svc = ScoringService::start(ServiceConfig { shards: 3, ..Default::default() });
    let restored = svc.restore_sessions(&dir).unwrap();
    assert_eq!(restored, 10);
    let resumed = svc.finish();
    assert_eq!(resumed.sessions.len(), 10);
    for s in &resumed.sessions {
        let orig = first.session(&s.id).expect("restored id matches checkpointed id");
        assert!(
            (s.htilde - orig.htilde).abs() < 1e-12,
            "{}: {} vs {}",
            s.id,
            s.htilde,
            orig.htilde
        );
        assert_eq!(s.nodes, orig.nodes);
        assert_eq!(s.edges, orig.edges);
    }

    // restore then continue == run uninterrupted (per session)
    let extra: Vec<StreamEvent> = vec![
        StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.7 },
        StreamEvent::EdgeDelta { i: 1, j: 2, dw: 0.3 },
        StreamEvent::Tick,
    ];
    let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
    svc.restore_sessions(&dir).unwrap();
    for (id, _, _) in &workload_data {
        svc.submit_all(id, extra.clone()).unwrap();
    }
    let continued = svc.finish();
    for (id, initial, events) in &workload_data {
        let mut state = FingerState::new(initial.clone());
        let mut batcher = finger::stream::WindowBatcher::new();
        for ev in events.iter().cloned().chain(extra.iter().cloned()) {
            if let Some((delta, _)) = batcher.push(ev) {
                jsdist_incremental(&mut state, &delta);
            }
        }
        let s = continued.session(id).unwrap();
        assert!(
            (s.htilde - state.htilde()).abs() < 1e-10,
            "{id}: {} vs {}",
            s.htilde,
            state.htilde()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn close_session_retires_state_but_keeps_the_books() {
    // close mid-run: the snapshot is final (trailing window flushed), the
    // shard state is freed, and the closed session's scored history still
    // reaches the end-of-run report — no event goes unaccounted.
    let workload_data = small_workload(8, 3);
    let svc = ScoringService::start(ServiceConfig { shards: 3, ..Default::default() });
    let mut submitted = 0usize;
    for (id, initial, events) in &workload_data {
        svc.open_session(id, initial.clone()).unwrap();
        submitted += svc.submit_all(id, events.iter().cloned()).unwrap();
    }
    // close half the sessions; FIFO ordering makes each close observe every
    // event submitted for its session above
    let (closed, kept) = workload_data.split_at(4);
    for (id, _, events) in closed {
        let snap = svc.close_session(id).unwrap().expect("session is live");
        assert_eq!(snap.id, *id);
        assert_eq!(snap.events, events.len());
        assert_eq!(snap.pending_events, 0, "{id}: close flushes the open window");
        // retired: reads and re-closes both miss now
        assert_eq!(svc.query(id).unwrap(), None, "{id}");
        assert_eq!(svc.close_session(id).unwrap(), None, "{id}");
    }
    assert_eq!(svc.close_session("never-opened").unwrap(), None);
    for (id, _, _) in kept {
        assert!(svc.query(id).unwrap().is_some(), "{id} must still be live");
    }
    let report = svc.finish();
    assert_eq!(report.sessions.len(), 8, "closed sessions still report");
    assert_eq!(report.total_events, submitted);
    for (id, _, events) in &workload_data {
        assert_eq!(report.session(id).unwrap().events, events.len(), "{id}");
    }
}

#[test]
fn close_then_reopen_starts_fresh() {
    let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
    svc.open_session("a", Graph::new(4)).unwrap();
    svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
    svc.submit("a", StreamEvent::Tick).unwrap();
    let first = svc.close_session("a").unwrap().expect("live");
    assert_eq!(first.windows, 1);
    // a reopened id is a brand-new session, not a resurrection
    svc.open_session("a", Graph::new(4)).unwrap();
    let snap = svc.query("a").unwrap().expect("reopened");
    assert_eq!(snap.windows, 0);
    assert_eq!(snap.events, 0);
    let report = svc.finish();
    // two distinct lifetimes of "a" are both accounted for
    assert_eq!(report.sessions.iter().filter(|s| s.id == "a").count(), 2);
}

#[test]
fn growing_sessions_route_and_score() {
    // sessions that grow their node set mid-stream (GrowNodes) work through
    // the service exactly as through a direct state
    let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
    svc.open_session("grow", Graph::new(2)).unwrap();
    svc.submit("grow", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
    svc.submit("grow", StreamEvent::Tick).unwrap();
    svc.submit("grow", StreamEvent::GrowNodes { count: 3 }).unwrap();
    svc.submit("grow", StreamEvent::EdgeDelta { i: 3, j: 4, dw: 2.0 }).unwrap();
    svc.submit("grow", StreamEvent::Tick).unwrap();
    let report = svc.finish();
    let s = report.session("grow").unwrap();
    assert_eq!(s.nodes, 5);
    assert_eq!(s.edges, 2);
    assert_eq!(s.records.len(), 2);
}

#[test]
fn per_session_anomalies_are_isolated() {
    // a burst in one session must not flag the others
    let quiet: Vec<StreamEvent> = (0..10)
        .flat_map(|_| {
            vec![StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.01 }, StreamEvent::Tick]
        })
        .collect();
    let mut noisy = quiet.clone();
    // burst in the final window
    noisy.pop();
    for k in 0..400u32 {
        noisy.push(StreamEvent::EdgeDelta { i: k % 20, j: (k * 3 + 1) % 20, dw: 1.0 });
    }
    noisy.push(StreamEvent::Tick);

    let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
    let base = finger::generators::erdos_renyi(20, 0.2, &mut Pcg64::new(17));
    svc.open_session("quiet", base.clone()).unwrap();
    svc.open_session("noisy", base).unwrap();
    svc.submit_all("quiet", quiet).unwrap();
    svc.submit_all("noisy", noisy).unwrap();
    let report = svc.finish();
    assert!(report.session("quiet").unwrap().anomalies.is_empty());
    assert!(
        report.session("noisy").unwrap().anomalies.contains(&9),
        "burst window flagged: {:?}",
        report.session("noisy").unwrap().anomalies
    );
}

#[test]
fn per_session_scores_bit_identical_to_allocating_loop() {
    // Stronger form of `per_session_scores_match_offline_loop`: the sharded
    // service scores through the allocation-free scratch path, and every
    // session's jsdist/htilde must equal the per-call-allocating
    // `jsdist_incremental` replay bit for bit (not just within tolerance).
    let workload_data = small_workload(10, 6);
    let cfg = ServiceConfig { shards: 4, ..Default::default() };
    let report = workload::drive(&cfg, &workload_data, 3, false).unwrap();
    for (id, initial, events) in &workload_data {
        let session = report.session(id).expect("session scored");
        let mut state = FingerState::new(initial.clone());
        let mut batcher = finger::stream::WindowBatcher::new();
        let mut offline = Vec::new();
        for ev in events.iter().cloned() {
            if let Some((delta, _)) = batcher.push(ev) {
                offline.push(jsdist_incremental(&mut state, &delta));
            }
        }
        assert_eq!(session.records.len(), offline.len(), "{id}");
        for (r, js) in session.records.iter().zip(&offline) {
            assert_eq!(r.jsdist.to_bits(), js.to_bits(), "{id} window {}", r.window);
        }
        assert_eq!(session.htilde.to_bits(), state.htilde().to_bits(), "{id}");
    }
}
