//! Durability failover integration: a `finger serve` process is SIGKILLed
//! mid-load (no drain, no flush — a real crash), restarted on the same
//! durability directory, and must answer queries bit-for-bit identical to a
//! reference run that was never interrupted. A second test truncates the
//! WAL tail at arbitrary byte offsets (torn final write) and asserts
//! recovery always yields a valid prefix instead of an error.

use finger::durability::{DurabilityConfig, FsyncPolicy};
use finger::graph::Graph;
use finger::net::{NetClient, Wire};
use finger::service::{ScoringService, ServiceConfig, SessionSnapshot};
use finger::stream::StreamEvent;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::time::Duration;

const NODES: usize = 16;
const SESSIONS: usize = 4;
const PRE_CRASH_WINDOWS: usize = 3;
const TOTAL_WINDOWS: usize = 5;

/// Deterministic tick-terminated window `w` of session `s` — identical on
/// the wire and in process, positive weights, no self-loops, indices < 16.
fn window(s: usize, w: usize) -> Vec<StreamEvent> {
    let mut evs = Vec::with_capacity(7);
    for k in 0..6u32 {
        let i = ((w as u32) * 5 + k * 3 + s as u32) % 10;
        let j = i + 1 + (k % 4);
        let dw = 0.2 + f64::from((k + w as u32) % 5) * 0.3;
        evs.push(StreamEvent::EdgeDelta { i, j, dw });
    }
    evs.push(StreamEvent::Tick);
    evs
}

fn session_ids() -> Vec<String> {
    (0..SESSIONS).map(|s| format!("tenant-{s}")).collect()
}

fn durable_cfg(dir: &Path) -> ServiceConfig {
    let mut dur = DurabilityConfig::new(dir);
    dur.fsync = FsyncPolicy::Always;
    ServiceConfig { shards: 2, durability: Some(dur), ..Default::default() }
}

struct ServerProc {
    child: std::process::Child,
    addr: String,
    startup_line: String,
}

/// Boot the real binary with durability on an ephemeral port and parse the
/// startup line (printed only after bind + recovery have finished).
fn spawn_serve(dir: &Path) -> ServerProc {
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_finger"))
        .args(["serve", "--addr", "127.0.0.1:0", "--shards", "2", "--threads", "1"])
        .arg("--durability-dir")
        .arg(dir)
        .args(["--fsync", "always"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn finger serve");
    let stdout = child.stdout.take().expect("child stdout");
    let mut lines = std::io::BufReader::new(stdout).lines();
    let startup_line = loop {
        let line = lines
            .next()
            .expect("server exited before printing its startup line")
            .expect("read startup line");
        if line.contains("listening on") {
            break line;
        }
    };
    let addr = startup_line
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .expect("address in startup line")
        .trim_end_matches([',', ';'])
        .to_string();
    // keep draining stdout so the child never blocks on a full pipe
    std::thread::spawn(move || for _ in lines {});
    ServerProc { child, addr, startup_line }
}

fn connect(addr: &str) -> NetClient {
    NetClient::connect_with(addr, Wire::Text, Some(Duration::from_secs(30)))
        .expect("connect to serve")
}

fn assert_bit_identical(got: &SessionSnapshot, want: &SessionSnapshot, id: &str) {
    assert_eq!(got.windows, want.windows, "{id}: window count");
    assert_eq!(got.events, want.events, "{id}: event count");
    assert_eq!(got.pending_events, 0, "{id}: ticks close every window");
    assert_eq!(got.nodes, want.nodes, "{id}: nodes");
    assert_eq!(got.edges, want.edges, "{id}: edges");
    assert_eq!(got.anomalies, want.anomalies, "{id}: anomaly count");
    assert_eq!(
        got.htilde.to_bits(),
        want.htilde.to_bits(),
        "{id}: H̃ {} vs {}",
        got.htilde,
        want.htilde
    );
    match (got.last_jsdist, want.last_jsdist) {
        (Some(a), Some(b)) => {
            assert_eq!(a.to_bits(), b.to_bits(), "{id}: jsdist {a} vs {b}")
        }
        (None, None) => {}
        (a, b) => panic!("{id}: jsdist presence mismatch: {a:?} vs {b:?}"),
    }
}

#[test]
fn kill9_mid_load_then_restart_is_bit_identical_to_uninterrupted_run() {
    let root =
        std::env::temp_dir().join(format!("finger_recovery_it_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).expect("create test root");
    let crashed_dir = root.join("crashed");
    let reference_dir = root.join("reference");
    let ids = session_ids();

    // Reference: the same durable load, in process, never interrupted. The
    // epoch cut lands at the same window boundary as the wire run's EPOCH,
    // so both runs canonicalize their live states at the same point.
    let reference = ScoringService::start(durable_cfg(&reference_dir));
    for id in &ids {
        reference.open_session(id, Graph::new(NODES)).expect("open reference session");
    }
    for w in 0..TOTAL_WINDOWS {
        for (s, id) in ids.iter().enumerate() {
            reference.submit_batch(id, window(s, w)).expect("reference batch");
        }
        if w == 1 {
            reference.snapshot_epoch().expect("reference epoch cut");
        }
    }
    let want: Vec<SessionSnapshot> = ids
        .iter()
        .map(|id| reference.query(id).expect("reference query").expect("live session"))
        .collect();
    reference.finish();

    // Crashed run, part 1: the real server over the wire, killed with
    // SIGKILL after the settle barrier (a QUERY round-trips through each
    // shard worker, so every submitted window is scored and — fsync=always —
    // WAL-appended to stable storage before the kill lands).
    let mut srv = spawn_serve(&crashed_dir);
    {
        let mut client = connect(&srv.addr);
        for id in &ids {
            client.open(id, NODES).expect("open session over the wire");
        }
        for w in 0..PRE_CRASH_WINDOWS {
            for (s, id) in ids.iter().enumerate() {
                client.send_batch(id, &window(s, w)).expect("wire batch");
            }
            if w == 1 {
                let (epoch, sessions) = client.epoch().expect("EPOCH verb");
                assert_eq!(epoch, 1, "first online cut");
                assert_eq!(sessions, SESSIONS, "cut covers every session");
            }
        }
        for id in &ids {
            client.query(id).expect("settle query").expect("live session");
        }
    }
    srv.child.kill().expect("SIGKILL the server");
    let _ = srv.child.wait();

    // Part 2: restart on the same directory — recovery must restore the
    // epoch snapshot, replay the WAL tail, and keep scoring as if the crash
    // never happened.
    let mut srv2 = spawn_serve(&crashed_dir);
    assert!(
        srv2.startup_line.contains(&format!("restored {SESSIONS} sessions")),
        "startup line must report recovery: {}",
        srv2.startup_line
    );
    let mut client = connect(&srv2.addr);
    for w in PRE_CRASH_WINDOWS..TOTAL_WINDOWS {
        for (s, id) in ids.iter().enumerate() {
            client.send_batch(id, &window(s, w)).expect("post-recovery batch");
        }
    }
    for (s, id) in ids.iter().enumerate() {
        let got = client.query(id).expect("query recovered").expect("recovered session");
        assert_bit_identical(&got, &want[s], id);
    }
    client.shutdown_server().expect("graceful shutdown");
    let _ = srv2.child.wait();
    std::fs::remove_dir_all(&root).ok();
}

fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).expect("create copy dir");
    for entry in std::fs::read_dir(src).expect("read src dir") {
        let entry = entry.expect("dir entry");
        let to = dst.join(entry.file_name());
        if entry.file_type().expect("file type").is_dir() {
            copy_dir(&entry.path(), &to);
        } else {
            std::fs::copy(entry.path(), &to).expect("copy file");
        }
    }
}

#[test]
fn truncated_wal_tail_always_recovers_a_valid_prefix() {
    let root =
        std::env::temp_dir().join(format!("finger_recovery_trunc_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    std::fs::create_dir_all(&root).expect("create test root");
    let src = root.join("src");

    // One durable single-shard session, crashed without any drain or cut.
    let mut cfg = durable_cfg(&src);
    cfg.shards = 1;
    let svc = ScoringService::start(cfg);
    svc.open_session("t", Graph::new(NODES)).expect("open");
    for w in 0..6 {
        svc.submit_batch("t", window(0, w)).expect("batch");
    }
    let full = svc.query("t").expect("settle query").expect("live session");
    assert_eq!(full.windows, 6);
    std::mem::forget(svc); // simulated kill -9: workers leak, nothing flushes

    let wal_dir = src.join("wal");
    let mut segments: Vec<PathBuf> = std::fs::read_dir(&wal_dir)
        .expect("wal dir exists")
        .map(|e| e.expect("dir entry").path())
        .collect();
    segments.sort();
    let last = segments.last().expect("one WAL segment").clone();
    let bytes = std::fs::read(&last).expect("read segment");
    assert!(!bytes.is_empty(), "segment holds the session's records");

    // Cut the tail at a spread of offsets (including 0, 1, mid-record cuts
    // and the full length): recovery must never error, must never score
    // more than the uninterrupted run, and at full length must match it
    // bit for bit.
    let step = (bytes.len() / 10).max(1);
    let mut cuts: Vec<usize> = (0..bytes.len()).step_by(step).collect();
    cuts.extend([1, bytes.len().saturating_sub(1), bytes.len()]);
    for (k, cut) in cuts.into_iter().enumerate() {
        let dst = root.join(format!("cut-{k}"));
        copy_dir(&src, &dst);
        let torn = dst.join("wal").join(last.file_name().expect("segment name"));
        let prefix = bytes.get(..cut).expect("cut within segment").to_vec();
        std::fs::write(&torn, prefix).expect("write torn segment");

        let mut cfg = durable_cfg(&dst);
        cfg.shards = 1;
        let recovered = ScoringService::recover(cfg)
            .unwrap_or_else(|e| panic!("cut at {cut}B must recover, got: {e}"));
        match recovered.query("t").expect("query recovered") {
            Some(snap) => {
                assert!(
                    snap.windows <= full.windows,
                    "cut at {cut}B replayed {} windows > full {}",
                    snap.windows,
                    full.windows
                );
                assert_eq!(snap.pending_events, 0, "windows replay whole or not at all");
                if cut == bytes.len() {
                    assert_bit_identical(&snap, &full, "untorn tail");
                }
            }
            None => assert!(
                cut < bytes.len(),
                "full-length copy must restore the session"
            ),
        }
        recovered.finish();
        std::fs::remove_dir_all(&dst).ok();
    }
    std::fs::remove_dir_all(&root).ok();
}
