//! XLA runtime integration: load the AOT artifacts, execute the L2 graphs
//! via PJRT and cross-check numerics against the native Rust path.
//!
//! These tests require `make artifacts`; they SKIP (pass trivially with a
//! note) when artifacts/ is absent so `cargo test` works pre-build.

use finger::entropy::{finger_hhat, quadratic_q};
use finger::runtime::{Runtime, XlaEntropy};
use finger::util::Pcg64;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_covers_all_entry_points() {
    let Some(rt) = runtime() else { return };
    for name in ["q_stats", "hhat_dense", "jsdist_dense"] {
        let sizes = rt.manifest().sizes(name);
        assert!(!sizes.is_empty(), "no artifacts for {name}");
        assert!(sizes.contains(&64), "{name} missing n=64");
    }
}

#[test]
fn q_offload_matches_native() {
    let Some(rt) = runtime() else { return };
    let xe = XlaEntropy::new(&rt);
    let mut rng = Pcg64::new(1);
    for &n in &[20usize, 63, 64, 120] {
        let g = finger::generators::erdos_renyi_avg_degree(n, 8.0, &mut rng);
        let native = quadratic_q(&g);
        let xla = xe.q(&g).expect("offload q");
        assert!((native - xla).abs() < 1e-4, "n={n}: {native} vs {xla}");
    }
}

#[test]
fn hhat_offload_matches_native() {
    let Some(rt) = runtime() else { return };
    let xe = XlaEntropy::new(&rt);
    let mut rng = Pcg64::new(2);
    for &n in &[30usize, 100, 250] {
        let g = finger::generators::erdos_renyi_avg_degree(n, 10.0, &mut rng);
        let native = finger_hhat(&g);
        let xla = xe.hhat(&g).expect("offload hhat");
        assert!(
            (native - xla).abs() < 5e-3 * (1.0 + native),
            "n={n}: {native} vs {xla}"
        );
    }
}

#[test]
fn jsdist_offload_matches_native() {
    let Some(rt) = runtime() else { return };
    let xe = XlaEntropy::new(&rt);
    let mut rng = Pcg64::new(3);
    let a = finger::generators::erdos_renyi_avg_degree(100, 10.0, &mut rng);
    let mut b = a.clone();
    let edges: Vec<_> = a.edges().take(40).collect();
    for (i, j, _) in edges {
        b.remove_edge(i, j);
    }
    let native = finger::distance::jsdist_fast(&a, &b);
    let xla = xe.jsdist(&a, &b).expect("offload jsdist");
    assert!((native - xla).abs() < 2e-2, "{native} vs {xla}");
}

#[test]
fn executor_caches_compiles() {
    let Some(rt) = runtime() else { return };
    let xe = XlaEntropy::new(&rt);
    let mut rng = Pcg64::new(4);
    let g = finger::generators::erdos_renyi(50, 0.1, &mut rng);
    let before = rt.cached_count();
    let _ = xe.q(&g).unwrap();
    let after_first = rt.cached_count();
    let _ = xe.q(&g).unwrap();
    let after_second = rt.cached_count();
    assert_eq!(after_first, before + 1);
    assert_eq!(after_second, after_first, "second call must hit the cache");
}

#[test]
fn oversize_graph_rejected_cleanly() {
    let Some(rt) = runtime() else { return };
    let xe = XlaEntropy::new(&rt);
    let biggest = *rt.manifest().sizes("q_stats").last().unwrap();
    let g = finger::graph::Graph::new(biggest + 1);
    assert!(xe.q(&g).is_err());
}
