//! Loopback integration tests for the TCP front end: wire scores must match
//! the in-process pipeline bit-for-bit, malformed lines must be isolated to
//! one `ERR`, and a graceful shutdown must account for every event sent.

use finger::graph::Graph;
use finger::net::{run_load, NetClient, NetConfig, NetServer, TrafficConfig};
use finger::net::{traffic, Response};
use finger::service::workload::{tenant_streams, TenantStream};
use finger::service::{
    ScoringService, ServiceConfig, ServiceReport, TenantPreset, TenantWorkloadConfig,
};
use finger::stream::StreamEvent;

/// Boot a server on an ephemeral loopback port; returns its address and the
/// thread that will yield the final `ServiceReport` after shutdown.
fn spawn_server(
    service_cfg: ServiceConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<ServiceReport>>) {
    let net_cfg = NetConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() };
    let server = NetServer::bind(service_cfg, net_cfg).expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    (addr, std::thread::spawn(move || server.run()))
}

fn small_workload() -> Vec<TenantStream> {
    tenant_streams(&TenantWorkloadConfig {
        sessions: 6,
        windows: 4,
        events_per_window: 12,
        nodes_per_session: 24,
        presets: vec![TenantPreset::Synthetic, TenantPreset::Wiki],
        seed: 0x7E57_0BEE,
    })
}

/// Mirror of the load driver's per-tenant replay, through the in-process
/// API: open an empty graph, seed it with the initial edges as window 0,
/// then submit each tick-delimited window as one batch.
fn run_in_process(streams: &[TenantStream], shards: usize) -> ServiceReport {
    let svc = ScoringService::start(ServiceConfig { shards, ..Default::default() });
    for (id, initial, events) in streams {
        svc.open_session(id, Graph::new(initial.num_nodes())).unwrap();
        let seed: Vec<StreamEvent> = initial
            .edges()
            .map(|(i, j, w)| StreamEvent::EdgeDelta { i, j, dw: w })
            .chain(std::iter::once(StreamEvent::Tick))
            .collect();
        svc.submit_batch(id, seed).unwrap();
        for win in events.split_inclusive(|e| matches!(e, StreamEvent::Tick)) {
            svc.submit_batch(id, win.to_vec()).unwrap();
        }
    }
    svc.finish()
}

#[test]
fn concurrent_wire_sessions_match_in_process_scores_bit_for_bit() {
    let streams = small_workload();
    let reference = run_in_process(&streams, 3);

    let (addr, server) = spawn_server(ServiceConfig { shards: 3, ..Default::default() });
    let report = traffic::replay(&addr, 3, true, &streams).expect("load run");
    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    let service_report = server.join().expect("server thread").expect("server run");

    assert_eq!(report.sessions, streams.len());
    assert_eq!(report.snapshots.len(), streams.len());
    for snap in &report.snapshots {
        let reference_session =
            reference.session(&snap.id).expect("session in reference run");
        assert_eq!(snap.windows, reference_session.records.len(), "{}", snap.id);
        assert_eq!(snap.events, reference_session.events, "{}", snap.id);
        let wire_js = snap.last_jsdist.expect("scored at least one window");
        let reference_js = reference_session.records.last().unwrap().jsdist;
        assert_eq!(
            wire_js.to_bits(),
            reference_js.to_bits(),
            "{}: wire jsdist {wire_js} != in-process {reference_js}",
            snap.id
        );
        assert_eq!(
            snap.htilde.to_bits(),
            reference_session.htilde.to_bits(),
            "{}: wire H̃ {} != in-process {}",
            snap.id,
            snap.htilde,
            reference_session.htilde
        );
        assert_eq!(
            snap.anomalies,
            reference_session.anomalies.len(),
            "{}: anomaly flags must replay identically",
            snap.id
        );
    }
    // the drained server saw exactly what the clients acknowledged
    assert_eq!(service_report.total_events, report.events_sent);
    assert_eq!(service_report.total_events, reference.total_events);
    assert_eq!(service_report.dropped_events, 0);
}

#[test]
fn malformed_lines_err_without_killing_connection_or_server() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });
    let mut client = NetClient::connect(addr.as_str()).expect("connect");

    for bad in [
        "GARBAGE 1 2\n",
        "OPEN onlyid\n",
        "EV s e 1 1 0.5\n",      // self-loop
        "EV s e 1 2 NaN\n",      // poisonous delta
        "EV s e 1 2 inf\n",
        "BATCH s nope\n",
        "QUERY bad%zz\n",        // malformed id encoding
        "STATS andmore\n",
    ] {
        match client.roundtrip_raw(bad).expect("connection must survive") {
            Response::Err(reason) => assert!(!reason.is_empty(), "{bad:?}"),
            ok => panic!("{bad:?} should ERR, got {ok:?}"),
        }
    }

    // a batch with one bad body line is consumed fully, rejected atomically,
    // and the stream stays line-synchronized
    client.open("s", 4).expect("open after errors");
    let batch = "BATCH s 3\ne 0 1 1.0\ne 2 2 1.0\nt\n";
    match client.roundtrip_raw(batch).expect("batch round-trip") {
        Response::Err(reason) => {
            assert!(reason.contains("batch line 2"), "got {reason:?}")
        }
        ok => panic!("bad batch should ERR, got {ok:?}"),
    }
    // rejected batch left no partial state behind
    let snap = client.query("s").expect("query").expect("session exists");
    assert_eq!(snap.events, 0);
    assert_eq!(snap.pending_events, 0);

    // the same connection still works end to end
    client
        .send_batch(
            "s",
            &[
                StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                StreamEvent::EdgeDelta { i: 1, j: 2, dw: 2.0 },
                StreamEvent::Tick,
            ],
        )
        .expect("good batch after bad one");
    let snap = client.query("s").expect("query").expect("session exists");
    assert_eq!(snap.windows, 1);
    assert_eq!(snap.edges, 2);
    assert!(snap.last_jsdist.is_some());

    // a second client is unaffected by the first one's garbage
    let mut other = NetClient::connect(addr.as_str()).expect("second connect");
    let stats = other.stats().expect("stats");
    assert_eq!(stats.shards, 2);
    assert_eq!(stats.depths.len(), 2);
    assert_eq!(stats.submitted, 3);
    other.quit().expect("quit");

    client.shutdown_server().expect("shutdown");
    let report = server.join().expect("server thread").expect("server run");
    assert_eq!(report.total_events, 3, "only the good batch was counted");
    assert_eq!(report.sessions.len(), 1);
}

#[test]
fn shutdown_drains_and_accounts_for_every_event_sent() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });

    let mut sent = 0usize;
    let mut clients: Vec<NetClient> = (0..2)
        .map(|_| NetClient::connect(addr.as_str()).expect("connect"))
        .collect();
    for (c, client) in clients.iter_mut().enumerate() {
        let id = format!("tenant-{c}");
        client.open(&id, 8).expect("open");
        for w in 0..3u32 {
            let mut events: Vec<StreamEvent> = (0..5u32)
                .map(|k| StreamEvent::EdgeDelta {
                    i: (w + k) % 8,
                    j: (w + k + 1) % 8,
                    dw: 0.5 + k as f64,
                })
                .collect();
            events.push(StreamEvent::Tick);
            sent += client.send_batch(&id, &events).expect("batch");
        }
        // one single-event submit exercises the EV verb too
        client.send_event(&id, &StreamEvent::Tick).expect("event");
        sent += 1;
    }
    for client in clients {
        client.quit().expect("quit");
    }

    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    let report = server.join().expect("server thread").expect("server run");
    assert_eq!(report.total_events, sent);
    assert_eq!(report.dropped_events, 0);
    assert_eq!(report.sessions.len(), 2);
    assert_eq!(report.total_windows(), 8, "3 batched + 1 bare-tick window per tenant");
    for session in &report.sessions {
        assert_eq!(session.events, sent / 2);
    }
}

#[test]
fn run_load_presets_round_trip_over_the_wire() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 4, ..Default::default() });
    let report = run_load(&TrafficConfig {
        addr,
        connections: 4,
        workload: TenantWorkloadConfig {
            sessions: 4,
            windows: 3,
            events_per_window: 8,
            nodes_per_session: 24,
            presets: vec![
                TenantPreset::Synthetic,
                TenantPreset::Wiki,
                TenantPreset::Dos,
                TenantPreset::HiC,
            ],
            seed: 11,
        },
        query_sessions: true,
        shutdown_after: true,
    })
    .expect("load");
    let service_report = server.join().expect("server thread").expect("server run");

    assert_eq!(report.sessions, 4);
    assert!(report.windows > 0, "every preset must score windows");
    assert_eq!(service_report.total_events, report.events_sent);
    // snapshots are sorted by session id, hence alphabetical preset order
    for (preset, snap) in
        ["dos", "hic", "synthetic", "wiki"].iter().zip(&report.snapshots)
    {
        assert!(snap.id.starts_with(preset), "{}", snap.id);
        assert!(snap.windows >= 2, "{}: too few windows", snap.id);
        assert!(snap.htilde.is_finite());
    }
    assert!(report.events_per_sec > 0.0);
}
