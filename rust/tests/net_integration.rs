//! Loopback integration tests for the TCP front end: wire scores must match
//! the in-process pipeline bit-for-bit on *both* codecs, the text wire must
//! be byte-identical to the pre-redesign protocol (raw `nc`-style fixtures),
//! malformed frames must be isolated to one `Err`, `CLOSE` must retire
//! sessions on both wires, a graceful shutdown must account for every event
//! sent, and slow-loris senders (byte-dribbled and half-frame-stalled) must
//! neither break their own connection nor delay anyone else's.

use finger::graph::Graph;
use finger::net::codec;
use finger::net::traffic;
use finger::net::{
    run_load, Codec, Command, NetClient, NetConfig, NetServer, Reply, TrafficConfig,
    Wire, WireMode,
};
use finger::service::workload::{tenant_streams, TenantStream};
use finger::service::{
    ScoringService, ServiceConfig, ServiceReport, TenantPreset, TenantWorkloadConfig,
};
use finger::stream::StreamEvent;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// Boot a server on an ephemeral loopback port; returns its address and the
/// thread that will yield the final `ServiceReport` after shutdown.
fn spawn_server(
    service_cfg: ServiceConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<ServiceReport>>) {
    let net_cfg = NetConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() };
    spawn_server_with(service_cfg, net_cfg)
}

fn spawn_server_with(
    service_cfg: ServiceConfig,
    net_cfg: NetConfig,
) -> (String, std::thread::JoinHandle<anyhow::Result<ServiceReport>>) {
    let server = NetServer::bind(service_cfg, net_cfg).expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    (addr, std::thread::spawn(move || server.run()))
}

fn small_workload() -> Vec<TenantStream> {
    tenant_streams(&TenantWorkloadConfig {
        sessions: 6,
        windows: 4,
        events_per_window: 12,
        nodes_per_session: 24,
        presets: vec![TenantPreset::Synthetic, TenantPreset::Wiki],
        seed: 0x7E57_0BEE,
    })
}

/// Mirror of the load driver's per-tenant replay, through the in-process
/// API: open an empty graph, seed it with the initial edges as window 0,
/// then submit each tick-delimited window as one batch.
fn run_in_process(streams: &[TenantStream], shards: usize) -> ServiceReport {
    let svc = ScoringService::start(ServiceConfig { shards, ..Default::default() });
    for (id, initial, events) in streams {
        svc.open_session(id, Graph::new(initial.num_nodes())).unwrap();
        let seed: Vec<StreamEvent> = initial
            .edges()
            .map(|(i, j, w)| StreamEvent::EdgeDelta { i, j, dw: w })
            .chain(std::iter::once(StreamEvent::Tick))
            .collect();
        svc.submit_batch(id, seed).unwrap();
        for win in events.split_inclusive(|e| matches!(e, StreamEvent::Tick)) {
            svc.submit_batch(id, win.to_vec()).unwrap();
        }
    }
    svc.finish()
}

/// Assert one wire replay's snapshots match the in-process reference run
/// bit for bit.
fn assert_matches_reference(
    report: &traffic::TrafficReport,
    reference: &ServiceReport,
    label: &str,
) {
    for snap in &report.snapshots {
        let reference_session =
            reference.session(&snap.id).expect("session in reference run");
        assert_eq!(snap.windows, reference_session.records.len(), "{label}: {}", snap.id);
        assert_eq!(snap.events, reference_session.events, "{label}: {}", snap.id);
        let wire_js = snap.last_jsdist.expect("scored at least one window");
        let reference_js = reference_session.records.last().unwrap().jsdist;
        assert_eq!(
            wire_js.to_bits(),
            reference_js.to_bits(),
            "{label}: {}: wire jsdist {wire_js} != in-process {reference_js}",
            snap.id
        );
        assert_eq!(
            snap.htilde.to_bits(),
            reference_session.htilde.to_bits(),
            "{label}: {}: wire H̃ {} != in-process {}",
            snap.id,
            snap.htilde,
            reference_session.htilde
        );
        assert_eq!(
            snap.anomalies,
            reference_session.anomalies.len(),
            "{label}: {}: anomaly flags must replay identically",
            snap.id
        );
    }
}

#[test]
fn both_wires_match_in_process_scores_bit_for_bit() {
    let streams = small_workload();
    let reference = run_in_process(&streams, 3);

    // one server, both wires (codec negotiated per connection): the text
    // replay runs first, then OPEN resets every session for the binary one
    let (addr, server) = spawn_server(ServiceConfig { shards: 3, ..Default::default() });
    let text = traffic::replay(&addr, 3, true, &streams, Wire::Text, None)
        .expect("text load run");
    let binary = traffic::replay(&addr, 3, true, &streams, Wire::Binary, None)
        .expect("binary load run");
    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    let service_report = server.join().expect("server thread").expect("server run");

    for report in [&text, &binary] {
        assert_eq!(report.sessions, streams.len());
        assert_eq!(report.snapshots.len(), streams.len());
        assert_eq!(report.events_sent, text.events_sent, "same stream, same count");
    }
    assert_matches_reference(&text, &reference, "text");
    assert_matches_reference(&binary, &reference, "binary");
    // ...and against each other, snapshot by snapshot
    for (t, b) in text.snapshots.iter().zip(&binary.snapshots) {
        assert_eq!(t.id, b.id);
        assert_eq!(t.htilde.to_bits(), b.htilde.to_bits(), "{}", t.id);
        assert_eq!(
            t.last_jsdist.unwrap().to_bits(),
            b.last_jsdist.unwrap().to_bits(),
            "{}",
            t.id
        );
    }
    // the drained server saw exactly what the clients acknowledged
    assert_eq!(service_report.total_events, text.events_sent + binary.events_sent);
    assert_eq!(service_report.dropped_events, 0);
}

/// The redesigned server must speak the v1 line protocol with zero wire
/// format changes: raw `nc`-style bytes in, exact reply lines out.
#[test]
fn raw_text_fixture_is_byte_identical_to_v1() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });
    let stream = TcpStream::connect(addr.as_str()).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    writer
        .write_all(
            b"OPEN demo 4\n\
              EV demo e 0 1 1.0\n\
              BATCH demo 2\n\
              e 1 2 2.0\n\
              t\n\
              STATS\n\
              GARBAGE\n\
              QUERY nosuch\n\
              QUIT\n",
        )
        .expect("send fixture");
    let mut lines = Vec::new();
    for _ in 0..7 {
        let mut line = String::new();
        reader.read_line(&mut line).expect("read reply line");
        lines.push(line);
    }
    assert_eq!(lines[0], "OK\n", "OPEN");
    assert_eq!(lines[1], "OK\n", "EV");
    assert_eq!(lines[2], "OK accepted=2\n", "BATCH");
    // depths are timing-dependent (events may still be in flight); the
    // layout and the monotonic counters are not
    assert!(
        lines[3].starts_with("OK shards=2 depths=") && lines[3].contains(" submitted=3"),
        "STATS: {:?}",
        lines[3]
    );
    assert!(
        lines[3].contains(" uptime_ms=") && lines[3].contains(" connections="),
        "STATS carries liveness keys: {:?}",
        lines[3]
    );
    assert_eq!(lines[4], "ERR unknown verb `GARBAGE`\n");
    assert_eq!(lines[5], "ERR unknown-session\n", "QUERY miss");
    assert_eq!(lines[6], "OK\n", "QUIT");

    // QUERY kv layout (values vary, key order must not)
    let mut client = NetClient::connect(addr.as_str()).expect("connect 2");
    let reply = client.roundtrip_raw(b"QUERY demo\n").expect("query");
    match reply {
        Reply::OkKv(pairs) => {
            let keys: Vec<&str> = pairs.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(
                keys,
                [
                    "windows", "events", "htilde", "nodes", "edges", "anomalies",
                    "pending", "anomalous", "jsdist"
                ]
            );
        }
        other => panic!("QUERY should reply kv, got {other:?}"),
    }
    client.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
}

#[test]
fn malformed_frames_err_without_killing_connection_or_server() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });
    let mut client = NetClient::connect(addr.as_str()).expect("connect");

    for bad in [
        "GARBAGE 1 2\n",
        "OPEN onlyid\n",
        "EV s e 1 1 0.5\n",      // self-loop
        "EV s e 1 2 NaN\n",      // poisonous delta
        "EV s e 1 2 inf\n",
        "BATCH s nope\n",
        "QUERY bad%zz\n",        // malformed id encoding
        "CLOSE bad%zz\n",
        "STATS andmore\n",
    ] {
        match client.roundtrip_raw(bad.as_bytes()).expect("connection must survive") {
            Reply::Err(reason) => assert!(!reason.is_empty(), "{bad:?}"),
            ok => panic!("{bad:?} should ERR, got {ok:?}"),
        }
    }

    // a batch with one bad body line is consumed fully, rejected atomically,
    // and the stream stays line-synchronized
    client.open("s", 4).expect("open after errors");
    let batch = "BATCH s 3\ne 0 1 1.0\ne 2 2 1.0\nt\n";
    match client.roundtrip_raw(batch.as_bytes()).expect("batch round-trip") {
        Reply::Err(reason) => {
            assert!(reason.contains("batch line 2"), "got {reason:?}")
        }
        ok => panic!("bad batch should ERR, got {ok:?}"),
    }
    // rejected batch left no partial state behind
    let snap = client.query("s").expect("query").expect("session exists");
    assert_eq!(snap.events, 0);
    assert_eq!(snap.pending_events, 0);

    // the same connection still works end to end
    client
        .send_batch(
            "s",
            &[
                StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                StreamEvent::EdgeDelta { i: 1, j: 2, dw: 2.0 },
                StreamEvent::Tick,
            ],
        )
        .expect("good batch after bad one");
    let snap = client.query("s").expect("query").expect("session exists");
    assert_eq!(snap.windows, 1);
    assert_eq!(snap.edges, 2);
    assert!(snap.last_jsdist.is_some());

    // a second client is unaffected by the first one's garbage
    let mut other = NetClient::connect(addr.as_str()).expect("second connect");
    let stats = other.stats().expect("stats");
    assert_eq!(stats.shards, 2);
    assert_eq!(stats.depths.len(), 2);
    assert_eq!(stats.submitted, 3);
    other.quit().expect("quit");

    client.shutdown_server().expect("shutdown");
    let report = server.join().expect("server thread").expect("server run");
    assert_eq!(report.total_events, 3, "only the good batch was counted");
    assert_eq!(report.sessions.len(), 1);
}

#[test]
fn close_retires_sessions_on_both_wires() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });
    for wire in [Wire::Text, Wire::Binary] {
        let id = format!("tenant-{wire}");
        let mut client =
            NetClient::connect_with(addr.as_str(), wire, None).expect("connect");
        client.open(&id, 8).expect("open");
        client
            .send_batch(
                &id,
                &[
                    StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                    StreamEvent::Tick,
                    // trailing partial window: CLOSE must flush it
                    StreamEvent::EdgeDelta { i: 1, j: 2, dw: 2.0 },
                ],
            )
            .expect("batch");
        let closed = client.close(&id).expect("close").expect("session was live");
        assert_eq!(closed.id, id);
        assert_eq!(closed.windows, 2, "{wire}: close flushes the open window");
        assert_eq!(closed.events, 3, "{wire}");
        assert_eq!(closed.edges, 2, "{wire}");
        assert_eq!(closed.pending_events, 0, "{wire}");
        // the session is gone on every path
        assert_eq!(client.close(&id).expect("second close"), None, "{wire}");
        assert_eq!(client.query(&id).expect("query"), None, "{wire}");
        client.quit().expect("quit");
    }
    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    let report = server.join().expect("server thread").expect("server run");
    // retired sessions still count in the final accounting
    assert_eq!(report.sessions.len(), 2);
    assert_eq!(report.total_events, 6);
}

#[test]
fn binary_and_text_clients_interleave_on_one_port() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });
    let mut text = NetClient::connect_with(addr.as_str(), Wire::Text, None).unwrap();
    let mut binary = NetClient::connect_with(addr.as_str(), Wire::Binary, None).unwrap();
    assert_eq!(text.wire(), Wire::Text);
    assert_eq!(binary.wire(), Wire::Binary);

    text.open("shared", 4).expect("text open");
    binary
        .send_batch(
            "shared",
            &[StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.5 }, StreamEvent::Tick],
        )
        .expect("binary batch");
    // the binary write is acknowledged, so the text query (same shard FIFO)
    // observes it
    let snap = text.query("shared").expect("text query").expect("session exists");
    assert_eq!(snap.windows, 1);
    assert_eq!(snap.events, 2);
    let snap_bin =
        binary.query("shared").expect("binary query").expect("session exists");
    assert_eq!(
        snap.htilde.to_bits(),
        snap_bin.htilde.to_bits(),
        "one session, one truth, two wires"
    );
    text.quit().expect("quit");
    binary.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
}

#[test]
fn wire_restriction_refuses_the_other_codec() {
    let net_cfg = NetConfig {
        addr: "127.0.0.1:0".to_string(),
        wire: WireMode::Only(Wire::Text),
        ..Default::default()
    };
    let (addr, server) =
        spawn_server_with(ServiceConfig { shards: 1, ..Default::default() }, net_cfg);
    // text works
    let mut text = NetClient::connect_with(addr.as_str(), Wire::Text, None).unwrap();
    text.open("a", 2).expect("text open on text-only server");
    // binary is refused with a binary Err frame, then the connection
    // closes. Read the refusal without sending a command first — the
    // server pushes it as soon as negotiation completes, and an unread
    // command at server close could RST away the buffered refusal.
    let mut binary = NetClient::connect_with(addr.as_str(), Wire::Binary, None).unwrap();
    match binary.roundtrip_raw(b"").expect("read refusal") {
        Reply::Err(reason) => assert!(reason.contains("disabled"), "{reason:?}"),
        other => panic!("expected refusal, got {other:?}"),
    }
    text.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
}

#[test]
fn client_timeout_surfaces_as_clean_error_on_both_wires() {
    for wire in [Wire::Text, Wire::Binary] {
        // a listener that accepts and never replies — a hung server
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let hold = std::thread::spawn(move || {
            // keep the connection open (unanswered) until the client gives up
            let (stream, _) = listener.accept().expect("accept");
            std::thread::sleep(std::time::Duration::from_millis(400));
            drop(stream);
        });
        let mut client = NetClient::connect_with(
            addr.as_str(),
            wire,
            Some(std::time::Duration::from_millis(50)),
        )
        .expect("connect");
        let err = client.query("x").expect_err("must time out");
        assert!(err.to_string().contains("timed out"), "{wire}: {err:#}");
        hold.join().expect("holder thread");
    }
}

#[test]
fn shutdown_drains_and_accounts_for_every_event_sent() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });

    let mut sent = 0usize;
    let mut clients: Vec<NetClient> = [Wire::Text, Wire::Binary]
        .iter()
        .map(|&w| NetClient::connect_with(addr.as_str(), w, None).expect("connect"))
        .collect();
    for (c, client) in clients.iter_mut().enumerate() {
        let id = format!("tenant-{c}");
        client.open(&id, 8).expect("open");
        for w in 0..3u32 {
            let mut events: Vec<StreamEvent> = (0..5u32)
                .map(|k| StreamEvent::EdgeDelta {
                    i: (w + k) % 8,
                    j: (w + k + 1) % 8,
                    dw: 0.5 + k as f64,
                })
                .collect();
            events.push(StreamEvent::Tick);
            sent += client.send_batch(&id, &events).expect("batch");
        }
        // one single-event submit exercises the EV command too
        client.send_event(&id, &StreamEvent::Tick).expect("event");
        sent += 1;
    }
    for client in clients {
        client.quit().expect("quit");
    }

    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    let report = server.join().expect("server thread").expect("server run");
    assert_eq!(report.total_events, sent);
    assert_eq!(report.dropped_events, 0);
    assert_eq!(report.sessions.len(), 2);
    assert_eq!(report.total_windows(), 8, "3 batched + 1 bare-tick window per tenant");
    for session in &report.sessions {
        assert_eq!(session.events, sent / 2);
    }
}

#[test]
fn run_load_presets_round_trip_over_the_wire() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 4, ..Default::default() });
    let report = run_load(&TrafficConfig {
        addr,
        wire: Wire::Binary,
        client_timeout: Some(std::time::Duration::from_secs(30)),
        connections: 4,
        workload: TenantWorkloadConfig {
            sessions: 4,
            windows: 3,
            events_per_window: 8,
            nodes_per_session: 24,
            presets: vec![
                TenantPreset::Synthetic,
                TenantPreset::Wiki,
                TenantPreset::Dos,
                TenantPreset::HiC,
            ],
            seed: 11,
        },
        query_sessions: true,
        shutdown_after: true,
        live_stats: false,
        check_metrics: true,
    })
    .expect("load");
    let service_report = server.join().expect("server thread").expect("server run");

    let keys = report.metrics_keys.expect("parity check ran");
    assert!(keys > 0, "METRICS must expose at least the counter registry");
    assert_eq!(report.sessions, 4);
    assert_eq!(report.wire, Wire::Binary);
    assert!(report.windows > 0, "every preset must score windows");
    assert_eq!(service_report.total_events, report.events_sent);
    // snapshots are sorted by session id, hence alphabetical preset order
    for (preset, snap) in
        ["dos", "hic", "synthetic", "wiki"].iter().zip(&report.snapshots)
    {
        assert!(snap.id.starts_with(preset), "{}", snap.id);
        assert!(snap.windows >= 2, "{}: too few windows", snap.id);
        assert!(snap.htilde.is_finite());
    }
    assert!(report.events_per_sec > 0.0);
}

/// A slow-loris sender (one byte per write, with pauses) must be served
/// correctly on both wires: partial frames park in the per-connection
/// buffer until they complete, and every reply still comes back in order.
#[test]
fn slow_loris_byte_dribble_completes_on_both_wires() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });

    // text wire: dribble a pipelined fixture one byte at a time
    {
        let stream = TcpStream::connect(addr.as_str()).expect("connect text");
        let mut writer = stream.try_clone().expect("clone");
        let mut reader = BufReader::new(stream);
        let bytes: &[u8] = b"OPEN loris 4\nEV loris e 0 1 1.0\nQUERY loris\nQUIT\n";
        for &b in bytes {
            writer.write_all(&[b]).expect("dribble byte");
            writer.flush().expect("flush");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let mut lines = Vec::new();
        for _ in 0..4 {
            let mut line = String::new();
            reader.read_line(&mut line).expect("read reply line");
            lines.push(line);
        }
        assert_eq!(lines[0], "OK\n", "OPEN");
        assert_eq!(lines[1], "OK\n", "EV");
        assert!(lines[2].starts_with("OK windows="), "QUERY: {:?}", lines[2]);
        assert_eq!(lines[3], "OK\n", "QUIT");
    }

    // binary wire: same discipline — preamble plus four frames, one byte
    // per write, replies read back through the codec
    {
        let stream = TcpStream::connect(addr.as_str()).expect("connect binary");
        let mut writer = stream.try_clone().expect("clone");
        let mut wire_codec = Wire::Binary.codec();
        let mut bytes = Vec::new();
        codec::write_binary_preamble(&mut bytes).expect("preamble");
        for cmd in [
            Command::Open { id: "loris-bin".to_string(), nodes: 4, epoch: None },
            Command::Event {
                id: "loris-bin".to_string(),
                ev: StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                seq: None,
            },
            Command::Query { id: "loris-bin".to_string() },
            Command::Quit,
        ] {
            wire_codec.write_command(&mut bytes, &cmd).expect("encode");
        }
        for &b in bytes.iter() {
            writer.write_all(&[b]).expect("dribble byte");
            writer.flush().expect("flush");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let mut reader = BufReader::new(stream);
        for (k, expect_snapshot) in [false, false, true, false].into_iter().enumerate() {
            let reply = wire_codec
                .read_reply(&mut reader)
                .expect("read reply")
                .expect("reply before EOF");
            match (expect_snapshot, reply) {
                (false, Reply::Ok) => {}
                (true, Reply::Snapshot(snap)) => {
                    assert_eq!(snap.id, "loris-bin");
                    assert_eq!(snap.events, 1, "the dribbled EV landed");
                }
                (want_snap, got) => panic!("reply {k}: want snapshot={want_snap}, got {got:?}"),
            }
        }
    }

    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
}

/// One stalled half-frame must not delay other connections multiplexed on
/// the same event-loop thread: the readiness-driven server parks the
/// partial frame in that connection's buffer and keeps serving everyone
/// else, and the parked bytes resume exactly where they stopped.
#[test]
fn stalled_half_frame_does_not_delay_other_connections() {
    let net_cfg = NetConfig {
        addr: "127.0.0.1:0".to_string(),
        event_threads: 1, // force both connections onto one loop thread
        ..Default::default()
    };
    let (addr, server) =
        spawn_server_with(ServiceConfig { shards: 2, ..Default::default() }, net_cfg);

    // connection A: a BATCH header promising two body lines, then silence
    let stalled = TcpStream::connect(addr.as_str()).expect("connect stalled");
    let mut stalled_writer = stalled.try_clone().expect("clone");
    stalled_writer
        .write_all(b"OPEN stall 4\nBATCH stall 2\ne 0 1 1.0")
        .expect("send half frame");
    stalled_writer.flush().expect("flush");
    let mut stalled_reader = BufReader::new(stalled);
    let mut line = String::new();
    stalled_reader.read_line(&mut line).expect("OPEN reply");
    assert_eq!(line, "OK\n", "OPEN for the stalled connection");

    // connection B on the same loop thread: round-trips must stay snappy
    // while A's half-frame sits parked
    let mut live = NetClient::connect(addr.as_str()).expect("connect live");
    live.open("live", 4).expect("open");
    let t0 = std::time::Instant::now();
    for _ in 0..20 {
        live.send_batch(
            "live",
            &[StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.5 }, StreamEvent::Tick],
        )
        .expect("batch while neighbor stalls");
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < std::time::Duration::from_secs(2),
        "20 round-trips took {elapsed:?} next to a stalled half-frame"
    );
    let snap = live.query("live").expect("query").expect("session exists");
    assert_eq!(snap.windows, 20);

    // A completes its frame: the batch lands atomically, in order
    stalled_writer.write_all(b"\nt\nQUIT\n").expect("finish frame");
    line.clear();
    stalled_reader.read_line(&mut line).expect("BATCH reply");
    assert_eq!(line, "OK accepted=2\n");
    line.clear();
    stalled_reader.read_line(&mut line).expect("QUIT reply");
    assert_eq!(line, "OK\n");
    live.quit().expect("quit");

    NetClient::connect(addr.as_str()).expect("connect").shutdown_server().expect("shutdown");
    let report = server.join().expect("server thread").expect("server run");
    assert_eq!(report.total_events, 42, "20 live batches of 2 plus the stalled batch of 2");
}

/// The METRICS verb: raw text fixture pins the one-line kv shape and the
/// registry's leading key, the binary opcode fixture pins the 0x09 frame,
/// and the typed reports must carry identical key lists on both wires.
#[test]
fn metrics_verb_reports_identically_on_both_wires() {
    let (addr, server) = spawn_server(ServiceConfig { shards: 2, ..Default::default() });
    // move some traffic first so the counters are provably live
    let mut client = NetClient::connect(addr.as_str()).expect("connect");
    client.open("m", 8).expect("open");
    client
        .send_batch(
            "m",
            &[
                StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                StreamEvent::EdgeDelta { i: 1, j: 2, dw: 0.5 },
                StreamEvent::Tick,
            ],
        )
        .expect("batch");
    // QUERY rides the shard FIFO, so once it answers the batch has been
    // batched and scored — the win_/score_ counters below are settled
    client.query("m").expect("query").expect("session exists");

    // raw text fixture: one OK kv line, registry keys first in declaration
    // order, server extras appended, histograms packed at the end
    {
        let stream = TcpStream::connect(addr.as_str()).expect("connect raw");
        let mut writer = stream.try_clone().expect("clone");
        let mut reader = BufReader::new(stream);
        writer.write_all(b"METRICS\nQUIT\n").expect("send fixture");
        let mut line = String::new();
        reader.read_line(&mut line).expect("read metrics line");
        assert!(
            line.starts_with("OK net_accepted="),
            "registry order pins the first key: {line:?}"
        );
        for key in [
            " net_wakeups=",
            " net_bytes_in=",
            " net_connections=",
            " svc_sessions=",
            " shard0_events=",
            " shard1_events=",
            " loop0_pollset=",
            " service_shards=2 ",
            " service_events_submitted=",
            " uptime_ms=",
            " shard0_depth=",
            " hist:score_latency_us=",
            " hist:request_us=",
            " hist:queue_wait_us=",
        ] {
            assert!(line.contains(key), "METRICS line missing {key:?}: {line:?}");
        }
        let mut quit = String::new();
        reader.read_line(&mut quit).expect("read quit reply");
        assert_eq!(quit, "OK\n");
    }

    // typed reports on both wires: identical key lists, same three hists
    let mut text = NetClient::connect_with(addr.as_str(), Wire::Text, None).expect("text");
    let mut binary =
        NetClient::connect_with(addr.as_str(), Wire::Binary, None).expect("binary");
    let rt = text.metrics().expect("text metrics");
    let rb = binary.metrics().expect("binary metrics");
    let keys = |r: &finger::obs::MetricsReport| -> Vec<String> {
        r.pairs
            .iter()
            .map(|(k, _)| k.clone())
            .chain(r.hists.iter().map(|h| format!("hist:{}", h.name)))
            .collect()
    };
    assert_eq!(keys(&rt), keys(&rb), "key parity across wires");
    assert_eq!(rt.hists.len(), 3);
    assert_eq!(rt.hists[0].name, "score_latency_us");
    assert_eq!(rt.hists[1].name, "request_us");
    assert_eq!(rt.hists[2].name, "queue_wait_us");
    // values: the registry is process-global (other tests in this binary
    // record concurrently), so global counters assert monotone; the
    // service-derived extras are this server's and assert exactly
    let get = |r: &finger::obs::MetricsReport, k: &str| -> u64 {
        r.pairs.iter().find(|(key, _)| key == k).map(|(_, v)| *v).expect(k)
    };
    assert_eq!(get(&rt, "service_shards"), 2);
    assert_eq!(get(&rt, "service_events_submitted"), 3);
    assert!(get(&rt, "net_accepted") >= 3);
    assert!(get(&rt, "win_events_in") >= 3);
    assert!(get(&rt, "score_windows") >= 1);
    assert!(rt.hists[1].count >= 1, "request_us saw our round-trips");

    // binary opcode fixture: METRICS is the single byte 0x09 on the wire
    match binary.roundtrip_raw(&[0x09]).expect("raw binary metrics") {
        Reply::Metrics(r) => assert!(!r.pairs.is_empty()),
        other => panic!("raw 0x09 should yield Reply::Metrics, got {other:?}"),
    }

    // the load driver's parity helper agrees end to end
    let n = traffic::check_metrics_parity(&addr, None).expect("parity");
    assert!(n > 0);

    text.quit().expect("quit text");
    binary.quit().expect("quit binary");
    client.shutdown_server().expect("shutdown");
    server.join().expect("server thread").expect("server run");
}
