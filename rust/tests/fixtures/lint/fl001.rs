//! FL001 fixture: panic sites on a request path. The golden test lints this
//! under a virtual `rust/src/service/` path so the zone rule applies; it is
//! never compiled (the `fixtures/` directory is skipped by the scanner).

pub fn handle(line: &str, shards: &[u32]) -> u32 {
    let id = line.split(' ').next().unwrap();
    let n: u32 = id.parse().expect("bad id");
    if shards.is_empty() {
        panic!("no shards");
    }
    let first = shards[0];
    // finger-lint: allow(FL001): emptiness checked above
    let also_first = shards[0];
    first + also_first + n
}

pub fn unfinished() {
    todo!("route the reply");
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let v = vec![1u32];
        assert_eq!(v[0], "7".parse::<u32>().unwrap());
    }
}
