//! FL004 fixture: unbounded channels where `sync_channel` would preserve
//! backpressure. Linted under a virtual `rust/src/service/` path; never
//! compiled.

use std::sync::mpsc::{channel, sync_channel};

pub fn wire_up() {
    let (tx, rx) = channel::<u32>();
    // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
    let (reply_tx, reply_rx) = channel::<u32>();
    let (bounded_tx, bounded_rx) = sync_channel::<u32>(16);
    drop((tx, rx, reply_tx, reply_rx, bounded_tx, bounded_rx));
}
