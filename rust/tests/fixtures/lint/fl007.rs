//! FL007 fixture: raw `thread::sleep` in service/net code hides a
//! wall-clock wait from shutdown signaling and fault schedules. Linted
//! under a virtual `rust/src/net/` path; never compiled.

use std::thread;
use std::time::Duration;

pub fn wait_for_peer() {
    thread::sleep(Duration::from_millis(50));
    std::thread::sleep(Duration::from_millis(5));
    // finger-lint: allow(FL007): one-shot startup settle before the loop owns the socket
    thread::sleep(Duration::from_millis(1));
}

pub fn polite_wait() {
    crate::net::backoff::sleep(Duration::from_millis(1));
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    #[test]
    fn sleeps_are_fine_in_tests() {
        std::thread::sleep(Duration::from_millis(1));
    }
}
