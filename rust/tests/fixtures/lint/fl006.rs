//! FL006 fixture: blocking I/O inside a `lint: event-loop` region stalls
//! every connection sharing the loop's thread. Linted under a virtual
//! `rust/src/net/` path; never compiled.

use std::io::{BufRead, Read};
use std::net::TcpStream;

pub fn accept_setup(s: &TcpStream) {
    s.set_read_timeout(None).ok();
}

// lint: event-loop
pub fn pump(r: &mut dyn BufRead, line: &mut String) {
    r.read_line(line).ok();
    let mut hdr = [0u8; 2];
    r.read_exact(&mut hdr).ok();
    // finger-lint: allow(FL006): runs once at loop teardown, sockets closed
    let _ = r.read_to_end(&mut Vec::new());
}
// lint: event-loop end

pub fn shutdown_drain(r: &mut dyn Read) {
    let mut rest = Vec::new();
    r.read_to_end(&mut rest).ok();
}
