//! FL003 fixture: float equality outside the bit-exactness helpers. Linted
//! under a virtual `rust/src/distance/` path; never compiled.

pub fn weight() -> f64 {
    2.5
}

pub fn raw_compares(a: f64, b: f64) -> bool {
    a == weight() && b != 0.125
}

pub fn bits_compare(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

pub fn int_compare(a: u32, b: u32) -> bool {
    a == b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_asserts() {
        assert_eq!(weight(), 2.5);
        // finger-lint: allow(FL003): exact zero sentinel
        assert_ne!(weight(), 0.0);
        assert_eq!(weight().to_bits(), 2.5f64.to_bits());
    }
}
