//! FL005 fixture: `.lock().unwrap()` hides the poisoning policy. Linted
//! under a virtual `rust/src/runtime/` path (outside the FL001 panic zone,
//! so only FL005 fires); never compiled.

use std::sync::Mutex;

pub fn counter(m: &Mutex<u64>) -> u64 {
    *m.lock().unwrap()
}

pub fn counter_with_context(m: &Mutex<u64>) -> u64 {
    *m.lock().expect("counter mutex poisoned")
}
