//! FL002 fixture: allocations inside a hot-path region marker. Linted under
//! a virtual `rust/src/entropy/` path; never compiled.

pub fn cold(input: &[f64]) -> Vec<f64> {
    input.to_vec()
}

// lint: hot-path
pub fn hot(input: &[f64], out: &mut Vec<f64>) -> usize {
    let copy = input.to_vec();
    let text = format!("{}", copy.len());
    let fresh: Vec<f64> = Vec::new();
    // finger-lint: allow(FL002): one-time growth, amortized to zero
    let grown: Vec<f64> = Vec::with_capacity(input.len());
    out.extend_from_slice(input);
    text.len() + fresh.capacity() + grown.capacity()
}
// lint: hot-path end

pub fn cold_again() -> String {
    "allocations are fine outside the region".to_string()
}
