//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline registry used to build this repo carries no general crate
//! closure, so the small API subset `finger` relies on is implemented here:
//! [`Error`] (message + context chain), [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait for
//! `Result` and `Option`. Like the real crate, `Error` deliberately does NOT
//! implement `std::error::Error`, which is what lets the blanket
//! `From<E: std::error::Error>` impl coexist with `?` conversions.

use std::fmt;

/// Error: a boxed cause (or plain message) plus a stack of context strings.
pub struct Error {
    /// Context messages, outermost last (pushed by [`Context`] adapters).
    context: Vec<String>,
    /// The root cause, if this error wraps a std error.
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
    /// The root message when constructed from a string (`anyhow!`/`bail!`).
    message: Option<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { context: Vec::new(), source: None, message: Some(message.to_string()) }
    }

    /// Construct from a std error, preserving it as the root cause.
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Self { context: Vec::new(), source: Some(Box::new(error)), message: None }
    }

    /// Push an outer context message (innermost cause stays last in `{:#}`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.context.push(context.to_string());
        self
    }

    fn root(&self) -> String {
        match (&self.message, &self.source) {
            (Some(m), _) => m.clone(),
            (None, Some(s)) => s.to_string(),
            (None, None) => "unknown error".to_string(),
        }
    }

    /// The chain outermost-first: contexts in reverse push order, then root.
    fn chain_strings(&self) -> Vec<String> {
        let mut out: Vec<String> = self.context.iter().rev().cloned().collect();
        out.push(self.root());
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        if f.alternate() {
            // `{:#}` — the full chain, anyhow's "error: cause: cause" style.
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        write!(f, "{}", chain[0])?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result` with a defaulted error type, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format args.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/finger")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_format() {
        let e: Result<()> = io_fail().context("reading config");
        let err = e.unwrap_err();
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        let brief = format!("{err}");
        assert_eq!(brief, "reading config");
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_work() {
        fn f(v: i32) -> Result<i32> {
            ensure!(v >= 0, "negative: {v}");
            if v == 3 {
                bail!("three is right out");
            }
            Ok(v)
        }
        assert!(f(-1).is_err());
        assert!(f(3).is_err());
        assert_eq!(f(2).unwrap(), 2);
        let e = anyhow!("n={}", 7);
        assert_eq!(format!("{e}"), "n=7");
    }
}
