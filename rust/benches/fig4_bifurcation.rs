//! Figure 4: bifurcation detection of cell reprogramming in the dynamic
//! Hi-C-like genomic sequence via TDS local minima.
//!
//! `cargo bench --bench fig4_bifurcation [-- --full | -- --quick]`
//! Paper shape: FINGER-JSdist is the only method whose TDS detects exactly
//! the ground-truth instant (measurement 6); support-only metrics lock onto
//! the decoy support-noise dip; spectral/affinity methods follow the hub
//! oscillation confounder.

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::bench::{bench_mode, BenchMode};
use finger::coordinator::experiments::run_bifurcation;
use finger::coordinator::report::bifurcation_table;
use finger::datasets::HicConfig;

fn main() {
    let mode = bench_mode();
    let dim = match mode {
        BenchMode::Quick => 120,
        BenchMode::Default => 240,
        BenchMode::Full => 720, // real data is 2894 1Mb bins
    };
    let cfg = HicConfig { dim, ..Default::default() };
    println!("=== Fig 4 — Hi-C-like bifurcation (dim={dim}, {mode:?}) ===\n");
    let rows = run_bifurcation(&cfg);
    println!("{}", bifurcation_table(&rows, cfg.bifurcation));
    let exact: Vec<&str> = rows.iter().filter(|r| r.correct).map(|r| r.method.as_str()).collect();
    let partial: Vec<&str> = rows
        .iter()
        .filter(|r| !r.correct && r.detected.contains(&cfg.bifurcation))
        .map(|r| r.method.as_str())
        .collect();
    println!("uniquely correct: {exact:?}");
    println!("detect 6 among extra minima: {partial:?}");
}
