//! Table 2 + Table S1 + Fig 3/S4: anomaly detection in evolving wiki-like
//! hyperlink networks — PCC/SRCC of each method against the VEO proxy plus
//! wall-clock scoring time per dataset.
//!
//! `cargo bench --bench table2_wikipedia [-- --full | -- --quick]`
//! Paper shape: FINGER-JS (Fast) best PCC and SRCC everywhere; Incremental
//! fastest with second-best correlation.

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::bench::{bench_mode, BenchMode};
use finger::coordinator::experiments::run_wiki;
use finger::coordinator::report::{series_dump, wiki_table};
use finger::datasets::WikiConfig;
use finger::util::fmt::Table;

fn main() {
    let mode = bench_mode();
    let scale = match mode {
        BenchMode::Quick => 0.4,
        BenchMode::Default => 1.0,
        BenchMode::Full => 6.0,
    };
    println!("=== Table 2 / S1 — synthetic wiki streams (scale={scale}, {mode:?}) ===\n");

    let mut summary = Table::new(&["dataset", "best PCC method", "PCC", "best SRCC", "fastest"]);
    for name in ["sen", "en", "fr", "ge"] {
        let cfg = WikiConfig::preset(name, scale);
        let run = run_wiki(name, &cfg);
        println!("{}", wiki_table(&run));
        let best_pcc =
            run.rows.iter().max_by(|a, b| a.pcc.partial_cmp(&b.pcc).unwrap()).unwrap();
        let best_srcc =
            run.rows.iter().max_by(|a, b| a.srcc.partial_cmp(&b.srcc).unwrap()).unwrap();
        let fastest = run
            .rows
            .iter()
            .min_by(|a, b| a.seconds.partial_cmp(&b.seconds).unwrap())
            .unwrap();
        summary.row(vec![
            name.to_string(),
            best_pcc.method.clone(),
            format!("{:+.4}", best_pcc.pcc),
            best_srcc.method.clone(),
            fastest.method.clone(),
        ]);
        if name == "en" {
            println!("--- Fig 3 analog: dissimilarity series (en) ---");
            println!("{}", series_dump(&run));
        }
    }
    println!("=== summary ===\n{}", summary.render());
}
