//! Figure 2 (+ S2, S3): scaled approximation error (SAE) and CTRR of Ĥ and
//! H̃ under varying graph size n for ER/BA/WS.
//!
//! `cargo bench --bench fig2_scaling [-- --full | -- --quick]`
//! Paper shape: SAE → 0 with n for ER/WS (balanced spectra, Corollaries 2–3);
//! SAE grows ~log n for BA; CTRR → ~100% for moderate n.

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::bench::{bench_mode, BenchMode};
use finger::coordinator::experiments::{fig2_size_sweep, mean_ctrr, sae_trend, GraphModel};
use finger::coordinator::report::approx_table;

fn main() {
    let mode = bench_mode();
    let (ns, trials): (Vec<usize>, usize) = match mode {
        BenchMode::Quick => (vec![100, 200, 400], 1),
        BenchMode::Default => (vec![200, 400, 800, 1400], 2),
        BenchMode::Full => (vec![500, 1000, 2000, 3000, 4000], 5),
    };
    println!("=== Fig 2 / S2 / S3 — ns={ns:?}, trials={trials} ({mode:?}) ===\n");

    for (model, d) in [(GraphModel::Er, 20.0), (GraphModel::Ba, 20.0), (GraphModel::Ws, 20.0)] {
        println!("--- {} (d̄={d}) ---", model.name());
        let rows = fig2_size_sweep(model, &ns, d, 0.1, trials, 0xF200);
        println!("{}", approx_table(&rows, "n"));
        let (first, last) = sae_trend(&rows);
        let (c_hat, c_til) = mean_ctrr(&rows);
        println!(
            "SAE(Ĥ) first→last: {first:.5} → {last:.5} ({})  |  mean CTRR: Ĥ {:.1}%  H̃ {:.1}%\n",
            if last < first { "decaying ✓" } else { "growing (expected for BA)" },
            100.0 * c_hat,
            100.0 * c_til
        );
    }

    println!("--- S2: WS at two more degrees ---");
    for d in [6.0, 10.0] {
        let rows = fig2_size_sweep(GraphModel::Ws, &ns, d, 0.1, trials, 0xF202);
        println!("WS d̄={d}\n{}", approx_table(&rows, "n"));
    }
}
