//! Figure 1 (+ S1): approximation error and CTRR of Ĥ/H̃ vs exact H under
//! varying average degree (ER, BA) and rewiring probability (WS).
//!
//! `cargo bench --bench fig1_approx_error [-- --full | -- --quick]`
//! Paper shape to reproduce: AE decays as d̄ grows or p_ws shrinks; CTRR of
//! both approximations ≥ 97%.

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::bench::{bench_mode, BenchMode};
use finger::coordinator::experiments::{fig1_degree_sweep, fig1_ws_sweep, GraphModel};
use finger::coordinator::report::approx_table;

fn main() {
    let mode = bench_mode();
    let (n, trials) = match mode {
        BenchMode::Quick => (300, 1),
        BenchMode::Default => (800, 3),
        BenchMode::Full => (2000, 10), // the paper's n and trial count
    };
    println!("=== Fig 1 — n={n}, trials={trials} ({mode:?}) ===\n");

    let degrees = [6.0, 10.0, 20.0, 50.0];
    println!("--- Fig 1(a): ER, varying average degree ---");
    println!("{}", approx_table(&fig1_degree_sweep(GraphModel::Er, n, &degrees, trials, 0xF161), "d̄"));

    println!("--- Fig 1(b): BA, varying average degree ---");
    println!("{}", approx_table(&fig1_degree_sweep(GraphModel::Ba, n, &degrees, trials, 0xF162), "d̄"));

    println!("--- Fig 1(c) + S1: WS, varying p_ws per average degree ---");
    let p_list = [0.01, 0.05, 0.1, 0.3, 0.6, 1.0];
    for d in [6.0, 10.0, 20.0, 50.0] {
        println!("WS d̄={d}");
        println!("{}", approx_table(&fig1_ws_sweep(n, d, &p_list, trials, 0xF163), "p_ws"));
    }
}
