//! §Perf: the FINGER scoring hot path — windows/s and allocations/window.
//!
//! `cargo bench --bench finger_hotpath [-- --full | -- --quick]`
//!
//! Measures one committed window score end to end (batcher coalesce →
//! Algorithm-2 preview ×2 → commit → anomaly decision) in two shapes:
//!
//! * **small-Δ streaming** — 10-edge windows against a large graph (the
//!   wiki/DoS per-session shape the service multiplexes by the thousand);
//! * **large-Δ monthly batch** — thousands-of-edges windows (the paper's
//!   monthly Wikipedia snapshots).
//!
//! Each shape is driven twice over identical event streams: the **scratch**
//! path (`WindowBatcher::push_ref` + `WindowScorer`'s reusable
//! `entropy::Scratch`) and the **baseline** path (owned `push` + the
//! per-call-allocating `jsdist_incremental`), asserting the scores are
//! bit-for-bit identical before reporting the throughput ratio.
//!
//! A counting global allocator measures allocations/window; in steady state
//! (fixed edge support, PaperFaithful s_max, resyncs off) the scratch scorer
//! loop must allocate **zero** — the bench asserts it, so a regression fails
//! CI's bench-smoke job.
//!
//! Results land in `BENCH_finger.json` (override with `FINGER_BENCH_JSON`);
//! see docs/PERF.md for how to read the trajectory.

#![allow(clippy::print_stdout)] // stdout is this target's interface
#![allow(unsafe_code)] // the counting GlobalAlloc needs raw alloc hooks

use finger::assert_bits_eq;
use finger::bench::{bench_mode, write_json_report, BenchMode, BenchRecord};
use finger::distance::jsdist_incremental;
use finger::entropy::{FingerState, SmaxPolicy};
use finger::graph::Graph;
use finger::stream::{
    AnomalyDetector, ResyncPolicy, StreamEvent, WindowBatcher, WindowScorer,
};
use finger::util::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Global allocator wrapper counting every alloc/realloc (not frees): the
/// steady-state scorer loop must not enter it at all.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// One window: `edges_per_window` edge events + the closing tick.
fn make_events(
    n: usize,
    windows: usize,
    edges_per_window: usize,
    seed: u64,
) -> Vec<StreamEvent> {
    let mut rng = Pcg64::new(seed);
    let mut evs = Vec::with_capacity(windows * (edges_per_window + 1));
    for _ in 0..windows {
        for _ in 0..edges_per_window {
            let i = rng.below(n) as u32;
            let j = (i + 1 + rng.below(n - 1) as u32) % n as u32;
            if i != j {
                evs.push(StreamEvent::EdgeDelta { i, j, dw: rng.uniform(0.1, 1.0) });
            }
        }
        evs.push(StreamEvent::Tick);
    }
    evs
}

/// Fold `score` bits into a running checksum so the two paths can be
/// asserted bit-for-bit equal without storing every window.
fn fold(acc: u64, score: f64) -> u64 {
    acc.rotate_left(7) ^ score.to_bits()
}

/// Scratch path: in-place batcher + scratch-reusing scorer (the service /
/// pipeline hot path). Returns (windows, seconds, score checksum).
fn run_scratch(initial: &Graph, events: &[StreamEvent]) -> (usize, f64, u64) {
    let mut batcher = WindowBatcher::new();
    let mut scorer = WindowScorer::new(
        FingerState::new(initial.clone()),
        AnomalyDetector::new(3.0, 24),
        ResyncPolicy::disabled(),
    );
    let mut checksum = 0u64;
    let t0 = Instant::now();
    for ev in events {
        if let Some((delta, n_events)) = batcher.push_ref(ev.clone()) {
            let rec = scorer.score(delta, n_events);
            checksum = fold(checksum, rec.jsdist);
        }
    }
    (scorer.windows(), t0.elapsed().as_secs_f64(), checksum)
}

/// Baseline path: owned batcher windows + per-call-allocating Algorithm 2 —
/// the pre-optimization per-window allocation pattern.
fn run_baseline(initial: &Graph, events: &[StreamEvent]) -> (usize, f64, u64) {
    let mut batcher = WindowBatcher::new();
    let mut state = FingerState::new(initial.clone());
    let mut detector = AnomalyDetector::new(3.0, 24);
    let mut windows = 0usize;
    let mut checksum = 0u64;
    let t0 = Instant::now();
    for ev in events {
        if let Some((delta, _n_events)) = batcher.push(ev.clone()) {
            let js = jsdist_incremental(&mut state, &delta);
            detector.observe(js);
            windows += 1;
            checksum = fold(checksum, js);
        }
    }
    (windows, t0.elapsed().as_secs_f64(), checksum)
}

/// Run one shape through both paths; returns (scratch windows/s, baseline
/// windows/s) and pushes the records.
fn bench_shape(
    label: &str,
    initial: &Graph,
    events: &[StreamEvent],
    records: &mut Vec<BenchRecord>,
) -> (f64, f64) {
    // warm both paths once (fills caches and scratch capacities), then time
    let _ = run_scratch(initial, events);
    let _ = run_baseline(initial, events);
    let (w_s, secs_s, sum_s) = run_scratch(initial, events);
    let (w_b, secs_b, sum_b) = run_baseline(initial, events);
    assert_eq!(w_s, w_b, "{label}: window counts diverged");
    assert_eq!(
        sum_s, sum_b,
        "{label}: scratch and baseline scores are not bit-identical"
    );
    let wps_scratch = w_s as f64 / secs_s.max(1e-12);
    let wps_baseline = w_b as f64 / secs_b.max(1e-12);
    println!(
        "{label:<28} {w_s} windows: scratch {wps_scratch:.3e} w/s, \
         baseline {wps_baseline:.3e} w/s ({:.2}x)",
        wps_scratch / wps_baseline
    );
    records.push(BenchRecord::metric(
        format!("finger_windows_per_sec_{label}"),
        wps_scratch,
        "windows_per_sec",
    ));
    records.push(BenchRecord::metric(
        format!("finger_windows_per_sec_{label}_baseline"),
        wps_baseline,
        "windows_per_sec",
    ));
    records.push(BenchRecord::metric(
        format!("finger_speedup_{label}"),
        wps_scratch / wps_baseline,
        "ratio",
    ));
    (wps_scratch, wps_baseline)
}

/// Steady-state allocation count: perturb-only windows over a fixed edge
/// support (no adjacency growth), PaperFaithful s_max (no multiset), resync
/// off. Measures allocator entries per window for the given driver.
fn allocs_per_window(
    g: &Graph,
    edges: &[(u32, u32, f64)],
    windows: usize,
    scratch_path: bool,
) -> f64 {
    let mut rng = Pcg64::new(0xA110C);
    let mut mk_events = |count: usize| {
        let mut evs = Vec::with_capacity(count * (edges.len().min(10) + 1));
        for _ in 0..count {
            for k in 0..10 {
                let (i, j, _) = edges[(rng.below(edges.len()) + k) % edges.len()];
                // tiny alternating perturbation: weight stays strictly positive
                let dw = if rng.bernoulli(0.5) { 1e-3 } else { -1e-3 };
                evs.push(StreamEvent::EdgeDelta { i, j, dw });
            }
            evs.push(StreamEvent::Tick);
        }
        evs
    };
    let warm = mk_events(64);
    let timed = mk_events(windows);
    let mut batcher = WindowBatcher::new();
    let state = FingerState::with_policy(g.clone(), SmaxPolicy::PaperFaithful);
    if scratch_path {
        let mut scorer =
            WindowScorer::new(state, AnomalyDetector::new(3.0, 24), ResyncPolicy::disabled());
        for ev in &warm {
            if let Some((delta, n)) = batcher.push_ref(ev.clone()) {
                scorer.score(delta, n);
            }
        }
        let before = alloc_calls();
        for ev in &timed {
            if let Some((delta, n)) = batcher.push_ref(ev.clone()) {
                scorer.score(delta, n);
            }
        }
        (alloc_calls() - before) as f64 / windows as f64
    } else {
        let mut state = state;
        let mut detector = AnomalyDetector::new(3.0, 24);
        for ev in &warm {
            if let Some((delta, _)) = batcher.push(ev.clone()) {
                detector.observe(jsdist_incremental(&mut state, &delta));
            }
        }
        let before = alloc_calls();
        for ev in &timed {
            if let Some((delta, _)) = batcher.push(ev.clone()) {
                detector.observe(jsdist_incremental(&mut state, &delta));
            }
        }
        (alloc_calls() - before) as f64 / windows as f64
    }
}

fn main() {
    let mode = bench_mode();
    let (n_small, windows_small) = match mode {
        BenchMode::Quick => (2_000, 400),
        BenchMode::Default => (20_000, 2_000),
        BenchMode::Full => (200_000, 5_000),
    };
    let (n_large, windows_large, edges_large) = match mode {
        BenchMode::Quick => (600, 12, 1_000),
        BenchMode::Default => (1_500, 24, 3_000),
        BenchMode::Full => (4_000, 36, 10_000),
    };
    println!("=== §Perf FINGER hot path ({mode:?}) ===\n");
    let mut records: Vec<BenchRecord> = Vec::new();

    let mut rng = Pcg64::new(0xF19E);

    // -- shape 1: small-Δ streaming windows over a big BA graph --
    let g_small = finger::generators::barabasi_albert(n_small, 5, &mut rng);
    let ev_small = make_events(n_small, windows_small, 10, 0xD311A);
    println!(
        "small-Δ streaming: BA n={} m={}, {windows_small} windows × 10 events",
        g_small.num_nodes(),
        g_small.num_edges()
    );
    let (wps, _) = bench_shape("small_delta", &g_small, &ev_small, &mut records);

    // -- shape 2: large-Δ monthly batches on a denser mid-size graph --
    let g_large = finger::generators::erdos_renyi_avg_degree(n_large, 16.0, &mut rng);
    let ev_large = make_events(n_large, windows_large, edges_large, 0xB47C);
    println!(
        "\nlarge-Δ monthly batch: ER n={} m={}, {windows_large} windows × {edges_large} events",
        g_large.num_nodes(),
        g_large.num_edges()
    );
    bench_shape("large_delta", &g_large, &ev_large, &mut records);

    // -- steady-state allocations/window (fixed support, perturb-only) --
    let support: Vec<(u32, u32, f64)> = g_small.edges().take(4_000).collect();
    let alloc_windows = match mode {
        BenchMode::Quick => 100,
        _ => 400,
    };
    let a_scratch = allocs_per_window(&g_small, &support, alloc_windows, true);
    let a_baseline = allocs_per_window(&g_small, &support, alloc_windows, false);
    println!(
        "\nsteady-state allocations/window: scratch {a_scratch:.2}, baseline {a_baseline:.2}"
    );
    records.push(BenchRecord::metric(
        "finger_allocs_per_window_steady",
        a_scratch,
        "allocs_per_window",
    ));
    records.push(BenchRecord::metric(
        "finger_allocs_per_window_steady_baseline",
        a_baseline,
        "allocs_per_window",
    ));
    assert_bits_eq!(
        a_scratch, 0.0,
        "scratch scorer loop allocated in steady state — hot-path regression"
    );

    println!("\nsmall-Δ scratch throughput: {wps:.3e} windows/s");
    let json_path =
        std::env::var("FINGER_BENCH_JSON").unwrap_or_else(|_| "BENCH_finger.json".to_string());
    match write_json_report(&json_path, "finger_hotpath", &records) {
        Ok(()) => println!("wrote {} records to {json_path}", records.len()),
        Err(e) => eprintln!("failed to write {json_path}: {e}"),
    }
}
