//! §Perf: layer-by-layer hot-path microbenchmarks.
//!
//! `cargo bench --bench perf_hotpath [-- --full | -- --quick]`
//!
//! L3 native: incremental update throughput (events/s), power iteration,
//! exact eigensolver, CSR mat-vec, streaming pipeline end-to-end, and the
//! sharded scoring service. Runtime: XLA offload latency (compile-cached
//! execute) and the native-vs-offload crossover ablation — skipped if
//! artifacts are missing.
//!
//! Every case is also written to `BENCH_service.json` (override the path
//! with `FINGER_BENCH_JSON`) so the perf trajectory is machine-readable
//! across PRs.

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::bench::{bench_mode, write_json_report, BenchMode, BenchRecord, BenchResult, Bencher};
use finger::entropy::FingerState;
use finger::graph::{Csr, DeltaGraph};
use finger::linalg::{power_iteration, PowerOpts, SymMatrix};
use finger::service::{workload, ServiceConfig, TenantWorkloadConfig};
use finger::stream::{event, Pipeline, PipelineConfig};
use finger::util::Pcg64;

fn show(records: &mut Vec<BenchRecord>, r: BenchResult) -> BenchResult {
    println!("{}", r.report());
    records.push(BenchRecord::from(&r));
    r
}

fn main() {
    let mode = bench_mode();
    let bencher = match mode {
        BenchMode::Quick => Bencher::quick(),
        _ => Bencher::default(),
    };
    let n = match mode {
        BenchMode::Quick => 2_000,
        BenchMode::Default => 20_000,
        BenchMode::Full => 200_000,
    };
    println!("=== §Perf hot paths (n={n}, {mode:?}) ===\n");
    let mut records: Vec<BenchRecord> = Vec::new();

    let mut rng = Pcg64::new(0xBE9C);
    let g = finger::generators::barabasi_albert(n, 5, &mut rng);
    let csr = Csr::from_graph(&g);
    println!("workload: BA n={} m={}", g.num_nodes(), g.num_edges());

    // -- L3: FINGER from-scratch --
    show(&mut records, bencher.run("finger_hhat (from scratch, O(n+m))", || {
        finger::entropy::finger_hhat(&g)
    }));
    show(&mut records, bencher.run("finger_htilde (from scratch, O(n+m))", || {
        finger::entropy::finger_htilde(&g)
    }));

    // -- L3: incremental update throughput --
    let mut state = FingerState::new(g.clone());
    let mut deltas = Vec::new();
    let mut drng = Pcg64::new(0xD311A);
    for _ in 0..1000 {
        let mut d = DeltaGraph::new();
        for _ in 0..10 {
            let i = drng.below(n) as u32;
            let j = (i + 1 + drng.below(n - 1) as u32) % n as u32;
            if i != j {
                d.add(i, j, drng.uniform(0.1, 1.0));
            }
        }
        deltas.push(d.coalesced());
    }
    let mut k = 0usize;
    let r = show(&mut records, bencher.run("FingerState::apply (10-edge ΔG)", || {
        state.apply(&deltas[k % deltas.len()]);
        k += 1;
    }));
    let inc_tput = 10.0 / r.mean_secs;
    println!("  → incremental throughput ≈ {inc_tput:.2e} edge-events/s");
    records.push(BenchRecord::metric("incremental_throughput", inc_tput, "edge_events_per_sec"));
    let mut state2 = FingerState::new(g.clone());
    let mut k2 = 0usize;
    show(&mut records, bencher.run("jsdist_incremental (Algorithm 2, 10-edge ΔG)", || {
        let d = &deltas[k2 % deltas.len()];
        k2 += 1;
        finger::distance::jsdist_incremental(&mut state2, d)
    }));

    // -- L3: spectral substrates --
    let x: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
    let mut y = vec![0.0; n];
    show(&mut records, bencher.run("CSR matvec_laplacian", || {
        csr.matvec_laplacian(&x, &mut y);
        y[0]
    }));
    show(&mut records, bencher.run("power_iteration λ_max", || {
        power_iteration(&csr, &PowerOpts::default())
    }));

    let n_eig = match mode {
        BenchMode::Quick => 200,
        BenchMode::Default => 600,
        BenchMode::Full => 2000,
    };
    let ge = finger::generators::erdos_renyi_avg_degree(n_eig, 20.0, &mut rng);
    show(&mut records, bencher.run(
        &format!("exact eigensolver (tred+tql, n={n_eig}) [the O(n³) baseline]"),
        || SymMatrix::laplacian_normalized(&ge).eigenvalues().len(),
    ));

    // -- L3: pipeline end-to-end --
    let wiki = finger::datasets::wiki_stream(&finger::datasets::WikiConfig {
        months: 24,
        initial_nodes: 1000,
        growth_per_month: 200,
        ..Default::default()
    });
    let events = event::events_from_deltas(&wiki.deltas);
    let n_events = events.len();
    let res = Pipeline::new(wiki.initial.clone(), PipelineConfig::default()).run(events);
    println!(
        "pipeline end-to-end: {} events in {:.3}s → {:.2e} events/s (p99 window latency {:.1}µs)",
        n_events, res.wall_secs, res.throughput, res.p99_latency * 1e6
    );
    records.push(BenchRecord::metric("pipeline_throughput", res.throughput, "events_per_sec"));
    records.push(BenchRecord::metric("pipeline_p99_latency", res.p99_latency, "secs"));

    // -- L3: sharded scoring service (small fixed workload; the full shard
    // sweep lives in benches/service_throughput.rs) --
    let svc_sessions = match mode {
        BenchMode::Quick => 64,
        _ => 256,
    };
    let svc_workload = workload::tenant_streams(&TenantWorkloadConfig {
        sessions: svc_sessions,
        windows: 8,
        events_per_window: 40,
        nodes_per_session: 48,
        ..Default::default()
    });
    let svc_cfg = ServiceConfig { shards: 4, ..Default::default() };
    let report = workload::drive(&svc_cfg, &svc_workload, 4, true).expect("drive service");
    println!(
        "service (4 shards, {svc_sessions} sessions): {} events in {:.3}s → {:.2e} events/s",
        report.total_events, report.wall_secs, report.throughput
    );
    records.push(BenchRecord::metric(
        "service_throughput_4shards",
        report.throughput,
        "events_per_sec",
    ));

    // -- L3: durability tax — the same workload with the per-shard WAL on
    // (default fsync policy). windows/s WAL-on should stay ≥ 0.8× WAL-off.
    let wal_dir =
        std::env::temp_dir().join(format!("finger_bench_wal_{}", std::process::id()));
    std::fs::remove_dir_all(&wal_dir).ok();
    let wal_cfg = ServiceConfig {
        shards: 4,
        durability: Some(finger::durability::DurabilityConfig::new(&wal_dir)),
        ..Default::default()
    };
    let wal_report = workload::drive(&wal_cfg, &svc_workload, 4, true).expect("drive WAL");
    std::fs::remove_dir_all(&wal_dir).ok();
    let secs_off = report.wall_secs.max(1e-9);
    let secs_on = wal_report.wall_secs.max(1e-9);
    let windows_off = report.total_windows() as f64 / secs_off;
    let windows_on = wal_report.total_windows() as f64 / secs_on;
    let wal_ratio = windows_on / windows_off.max(1e-9);
    println!(
        "service durability tax: {windows_off:.0} windows/s WAL-off vs \
         {windows_on:.0} windows/s WAL-on ({:.2}x)",
        wal_ratio
    );
    records.push(BenchRecord::metric(
        "service_windows_per_sec_wal_off",
        windows_off,
        "windows_per_sec",
    ));
    records.push(BenchRecord::metric(
        "service_windows_per_sec_wal_on",
        windows_on,
        "windows_per_sec",
    ));
    records.push(BenchRecord::metric(
        "service_wal_on_off_ratio",
        wal_ratio,
        "ratio_on_vs_off",
    ));

    // -- runtime: XLA offload (needs artifacts) --
    match finger::runtime::Runtime::load("artifacts") {
        Ok(rt) => {
            let xe = finger::runtime::XlaEntropy::new(&rt);
            for &gn in &[60usize, 120, 250] {
                let sg = finger::generators::erdos_renyi_avg_degree(gn, 12.0, &mut rng);
                let _ = xe.hhat(&sg); // warm the compile cache
                let rx = show(
                    &mut records,
                    bencher.run(&format!("XLA offload Ĥ (n={gn}, padded artifact)"), || {
                        xe.hhat(&sg).unwrap()
                    }),
                );
                let rn = show(
                    &mut records,
                    bencher.run(&format!("native Ĥ (n={gn})"), || {
                        finger::entropy::finger_hhat(&sg)
                    }),
                );
                println!(
                    "  → crossover: native is {:.1}× {} at n={gn}",
                    (rx.mean_secs / rn.mean_secs).max(rn.mean_secs / rx.mean_secs),
                    if rn.mean_secs < rx.mean_secs { "faster" } else { "slower" }
                );
            }
        }
        Err(e) => println!("(XLA offload skipped: {e})"),
    }

    let json_path =
        std::env::var("FINGER_BENCH_JSON").unwrap_or_else(|_| "BENCH_service.json".to_string());
    match write_json_report(&json_path, "perf_hotpath", &records) {
        Ok(()) => println!("\nwrote {} records to {json_path}", records.len()),
        Err(e) => eprintln!("failed to write {json_path}: {e}"),
    }
}
