//! §Perf: layer-by-layer hot-path microbenchmarks.
//!
//! `cargo bench --bench perf_hotpath [-- --full | -- --quick]`
//!
//! L3 native: incremental update throughput (events/s), power iteration,
//! exact eigensolver, CSR mat-vec, streaming pipeline end-to-end.
//! Runtime: XLA offload latency (compile-cached execute) and the
//! native-vs-offload crossover ablation — skipped if artifacts are missing.

use finger::bench::{bench_mode, BenchMode, Bencher};
use finger::entropy::FingerState;
use finger::graph::{Csr, DeltaGraph};
use finger::linalg::{power_iteration, PowerOpts, SymMatrix};
use finger::stream::{event, Pipeline, PipelineConfig};
use finger::util::Pcg64;

fn main() {
    let mode = bench_mode();
    let bencher = match mode {
        BenchMode::Quick => Bencher::quick(),
        _ => Bencher::default(),
    };
    let n = match mode {
        BenchMode::Quick => 2_000,
        BenchMode::Default => 20_000,
        BenchMode::Full => 200_000,
    };
    println!("=== §Perf hot paths (n={n}, {mode:?}) ===\n");

    let mut rng = Pcg64::new(0xBE9C);
    let g = finger::generators::barabasi_albert(n, 5, &mut rng);
    let csr = Csr::from_graph(&g);
    println!("workload: BA n={} m={}", g.num_nodes(), g.num_edges());

    // -- L3: FINGER from-scratch --
    println!("{}", bencher.run("finger_hhat (from scratch, O(n+m))", || {
        finger::entropy::finger_hhat(&g)
    }).report());
    println!("{}", bencher.run("finger_htilde (from scratch, O(n+m))", || {
        finger::entropy::finger_htilde(&g)
    }).report());

    // -- L3: incremental update throughput --
    let mut state = FingerState::new(g.clone());
    let mut deltas = Vec::new();
    let mut drng = Pcg64::new(0xD311A);
    for _ in 0..1000 {
        let mut d = DeltaGraph::new();
        for _ in 0..10 {
            let i = drng.below(n) as u32;
            let j = (i + 1 + drng.below(n - 1) as u32) % n as u32;
            if i != j {
                d.add(i, j, drng.uniform(0.1, 1.0));
            }
        }
        deltas.push(d.coalesced());
    }
    let mut k = 0usize;
    let r = bencher.run("FingerState::apply (10-edge ΔG)", || {
        state.apply(&deltas[k % deltas.len()]);
        k += 1;
    });
    println!("{}", r.report());
    println!(
        "  → incremental throughput ≈ {:.2e} edge-events/s",
        10.0 / r.mean_secs
    );
    let mut state2 = FingerState::new(g.clone());
    let mut k2 = 0usize;
    let r2 = bencher.run("jsdist_incremental (Algorithm 2, 10-edge ΔG)", || {
        let d = &deltas[k2 % deltas.len()];
        k2 += 1;
        finger::distance::jsdist_incremental(&mut state2, d)
    });
    println!("{}", r2.report());

    // -- L3: spectral substrates --
    let x: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
    let mut y = vec![0.0; n];
    println!("{}", bencher.run("CSR matvec_laplacian", || {
        csr.matvec_laplacian(&x, &mut y);
        y[0]
    }).report());
    println!("{}", bencher.run("power_iteration λ_max", || {
        power_iteration(&csr, &PowerOpts::default())
    }).report());

    let n_eig = match mode {
        BenchMode::Quick => 200,
        BenchMode::Default => 600,
        BenchMode::Full => 2000,
    };
    let ge = finger::generators::erdos_renyi_avg_degree(n_eig, 20.0, &mut rng);
    println!("{}", bencher.run(
        &format!("exact eigensolver (tred+tql, n={n_eig}) [the O(n³) baseline]"),
        || SymMatrix::laplacian_normalized(&ge).eigenvalues().len(),
    ).report());

    // -- L3: pipeline end-to-end --
    let wiki = finger::datasets::wiki_stream(&finger::datasets::WikiConfig {
        months: 24,
        initial_nodes: 1000,
        growth_per_month: 200,
        ..Default::default()
    });
    let events = event::events_from_deltas(&wiki.deltas);
    let n_events = events.len();
    let res = Pipeline::new(wiki.initial.clone(), PipelineConfig::default()).run(events);
    println!(
        "pipeline end-to-end: {} events in {:.3}s → {:.2e} events/s (p99 window latency {:.1}µs)",
        n_events, res.wall_secs, res.throughput, res.p99_latency * 1e6
    );

    // -- runtime: XLA offload (needs artifacts) --
    match finger::runtime::Runtime::load("artifacts") {
        Ok(rt) => {
            let xe = finger::runtime::XlaEntropy::new(&rt);
            for &gn in &[60usize, 120, 250] {
                let sg = finger::generators::erdos_renyi_avg_degree(gn, 12.0, &mut rng);
                let _ = xe.hhat(&sg); // warm the compile cache
                let rx = bencher.run(&format!("XLA offload Ĥ (n={gn}, padded artifact)"), || {
                    xe.hhat(&sg).unwrap()
                });
                println!("{}", rx.report());
                let rn = bencher.run(&format!("native Ĥ (n={gn})"), || {
                    finger::entropy::finger_hhat(&sg)
                });
                println!("{}", rn.report());
                println!(
                    "  → crossover: native is {:.1}× {} at n={gn}",
                    (rx.mean_secs / rn.mean_secs).max(rn.mean_secs / rx.mean_secs),
                    if rn.mean_secs < rx.mean_secs { "faster" } else { "slower" }
                );
            }
        }
        Err(e) => println!("(XLA offload skipped: {e})"),
    }
}
