//! Table 3 + Table S2: detection rate of synthesized DoS events in dynamic
//! AS router networks, X ∈ {1,3,5,10}% over randomized trials, top-2 ranking.
//!
//! `cargo bench --bench table3_dos [-- --full | -- --quick]`
//! Paper shape: FINGER-JS (Fast) dominates at every X; all methods converge
//! near X=10%; VEO/degree-distribution columns (S2) are not competitive.

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::bench::{bench_mode, BenchMode};
use finger::coordinator::experiments::run_dos;
use finger::coordinator::report::dos_table;
use finger::datasets::OregonConfig;

fn main() {
    let mode = bench_mode();
    let (nodes, trials) = match mode {
        BenchMode::Quick => (400, 8),
        BenchMode::Default => (1200, 25),
        BenchMode::Full => (5000, 100), // paper: 100 random instances
    };
    let cfg = OregonConfig { nodes, ..Default::default() };
    let xs = [0.01, 0.03, 0.05, 0.10];
    println!(
        "=== Table 3 / S2 — DoS detection (n={nodes}, trials={trials}, {mode:?}) ===\n"
    );
    let rows = run_dos(&cfg, &xs, trials, true, 0x7AB3);
    println!("{}", dos_table(&rows, &xs));

    let finger = &rows[0];
    println!(
        "FINGER-JS (Fast) rates: {:?}",
        finger.rates.iter().map(|r| format!("{:.0}%", r * 100.0)).collect::<Vec<_>>()
    );
}
