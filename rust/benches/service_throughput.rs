//! §Service: aggregate scoring throughput vs shard count.
//!
//! `cargo bench --bench service_throughput [-- --quick | -- --full]`
//!
//! Drives the same prebuilt multi-tenant workload (≥256 concurrent sessions
//! by default) through the sharded scoring service at increasing shard
//! counts and reports aggregate events/sec plus the speedup over the
//! 1-shard baseline. Scaling comes from shard workers scoring disjoint
//! session sets in parallel; expect ≥2× from 1→4 shards on a ≥4-core
//! machine. Results are written to `BENCH_service_throughput.json`.

#![allow(clippy::print_stdout)] // stdout is this target's interface

use finger::bench::{bench_mode, write_json_report, BenchMode, BenchRecord};
use finger::service::{workload, ServiceConfig, TenantWorkloadConfig};

fn main() {
    let mode = bench_mode();
    let (sessions, windows, events_per_window) = match mode {
        BenchMode::Quick => (64, 8, 40),
        BenchMode::Default => (256, 16, 60),
        BenchMode::Full => (1024, 24, 80),
    };
    let wl_cfg = TenantWorkloadConfig {
        sessions,
        windows,
        events_per_window,
        nodes_per_session: 64,
        ..Default::default()
    };
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "=== service throughput vs shards ({sessions} sessions × {windows} windows × \
         {events_per_window} events, {cores} cores, {mode:?}) ===\n"
    );
    let workload_data = workload::tenant_streams(&wl_cfg);
    let total = workload::workload_events(&workload_data);

    let shard_counts: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&s| s == 1 || s <= cores * 2).collect();
    let mut records = Vec::new();
    let mut baseline: Option<f64> = None;
    println!(
        "{:<8} {:>12} {:>12} {:>14} {:>10}",
        "shards", "events", "wall(s)", "events/s", "speedup"
    );
    for &shards in &shard_counts {
        let cfg = ServiceConfig { shards, ..Default::default() };
        let report = workload::drive(&cfg, &workload_data, 4, true).expect("drive workload");
        assert_eq!(report.total_events, total, "event loss at {shards} shards");
        let speedup = report.throughput / *baseline.get_or_insert(report.throughput);
        println!(
            "{:<8} {:>12} {:>12.3} {:>14.0} {:>9.2}x",
            shards, report.total_events, report.wall_secs, report.throughput, speedup
        );
        records.push(BenchRecord::metric(
            format!("service_throughput_shards_{shards}"),
            report.throughput,
            "events_per_sec",
        ));
        records.push(BenchRecord::metric(
            format!("service_speedup_shards_{shards}"),
            speedup,
            "ratio_vs_1_shard",
        ));
    }
    if cores < 4 {
        println!("\n(note: only {cores} cores available — shard scaling is capped by hardware)");
    }

    let json_path = std::env::var("FINGER_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_service_throughput.json".to_string());
    match write_json_report(&json_path, "service_throughput", &records) {
        Ok(()) => println!("\nwrote {} records to {json_path}", records.len()),
        Err(e) => eprintln!("failed to write {json_path}: {e}"),
    }
}
