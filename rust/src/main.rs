//! `finger` — CLI for the FINGER reproduction.
//!
//! Subcommands:
//!   entropy      compute H / Ĥ / H̃ of a generated or loaded graph
//!   jsdist       JS distance between two edge-list files
//!   stream       run the streaming pipeline over a delta-stream file or a
//!                generated wiki workload
//!   wiki         Table 2 / S1 experiment on synthetic wiki streams
//!   bifurcation  Fig 4 experiment on the Hi-C-like sequence
//!   dos          Table 3 / S2 experiment (DoS detection rates)
//!   sweep        Fig 1 / Fig 2 approximation sweeps
//!   serve-bench  drive a synthetic multi-tenant workload through the
//!                sharded scoring service across shard counts
//!   serve        put the scoring service on a TCP socket (line protocol,
//!                see docs/PROTOCOL.md); runs until a SHUTDOWN request
//!   epoch        ask a running `serve` to cut one durability epoch snapshot
//!   load         replay a multi-tenant workload (dataset presets included)
//!                against a running `serve` over N concurrent connections
//!   offload      cross-check the XLA artifact path against native Rust
//!   lint         run the first-party invariant lint (FL001–FL005) over the
//!                repo's own source, see docs/LINTS.md

#![allow(clippy::print_stdout)] // stdout is this target's interface

use anyhow::{bail, Context, Result};
use finger::bench::{self, BenchRecord};
use finger::cli::{Args, Config};
use finger::coordinator::experiments::{self, GraphModel};
use finger::coordinator::report;
use finger::datasets::{HicConfig, OregonConfig, WikiConfig};
use finger::durability::{DurabilityConfig, FsyncPolicy};
use finger::entropy::{exact_vnge, finger_hhat, finger_htilde};
use finger::graph::{io as gio, Graph};
use finger::net::{traffic, NetClient, NetConfig, NetServer, TrafficConfig, Wire, WireMode};
use finger::service::{workload, ServiceConfig, TenantPreset, TenantWorkloadConfig};
use finger::stream::{event, Pipeline, PipelineConfig};
use finger::util::Pcg64;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("entropy") => cmd_entropy(args),
        Some("jsdist") => cmd_jsdist(args),
        Some("stream") => cmd_stream(args),
        Some("wiki") => cmd_wiki(args),
        Some("bifurcation") => cmd_bifurcation(args),
        Some("dos") => cmd_dos(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve-bench") => cmd_serve_bench(args),
        Some("serve") => cmd_serve(args),
        Some("epoch") => cmd_epoch(args),
        Some("load") => cmd_load(args),
        Some("offload") => cmd_offload(args),
        Some("lint") => cmd_lint(args),
        Some(other) => bail!("unknown subcommand `{other}` (try --help)"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "finger — Fast Incremental von Neumann Graph Entropy (ICML 2019 reproduction)\n\
         \n\
         usage: finger <subcommand> [options]\n\
         \n\
         subcommands:\n\
           entropy     --model er|ba|ws --n N --degree D [--pws P] [--exact] | <edges-file>\n\
           jsdist      <a.edges> <b.edges> [--exact]\n\
           stream      [--file deltas.txt | --months M] [--capacity C]\n\
           wiki        [--dataset sen|en|fr|ge] [--scale S]\n\
           bifurcation [--dim N]\n\
           dos         [--nodes N] [--trials T] [--extended]\n\
           sweep       --kind fig1-er|fig1-ba|fig1-ws|fig2 [--n N] [--trials T]\n\
           serve-bench [--sessions N] [--shards 1,2,4] [--windows W] [--events E]\n\
                       [--nodes N] [--capacity C] [--producers P] [--seed S]\n\
                       [--config run.toml] [--per-event]\n\
           serve       [--addr 127.0.0.1:7341] [--shards N] [--capacity C]\n\
                       [--wire auto|text|binary] [--threads N] [--config run.toml]\n\
                       [--metrics-out snap.json] [--metrics-interval MS]\n\
                       [--durability-dir DIR] [--fsync always|every_ms[=N]|every_n[=N]]\n\
                       [--snapshot-interval MS]\n\
                       (config sections: [service], [net], [obs], [durability],\n\
                       [fault] — see docs/OBSERVABILITY.md, docs/DURABILITY.md\n\
                       and docs/ROBUSTNESS.md)\n\
           epoch       [--addr 127.0.0.1:7341] [--wire text|binary] [--config run.toml]\n\
                       (cut one online durability epoch on a running serve)\n\
           load        [--addr 127.0.0.1:7341] [--connections 1,2,4,8]\n\
                       [--wire text,binary] [--sessions N] [--windows W]\n\
                       [--events E] [--nodes N] [--timeout-ms T]\n\
                       [--presets wiki,dos,hic,synthetic] [--seed S]\n\
                       [--bench-out BENCH_net.json] [--config run.toml] [--shutdown]\n\
                       [--live-stats] [--check-metrics] [--retry]\n\
                       [--retry-attempts N]\n\
                       (reports events/s plus p50/p99 request latency; --retry\n\
                       drives exactly-once clients that survive faults)\n\
           offload     [--artifacts DIR]\n\
           lint        [--root DIR] [--baseline FILE] [--deny] [--write-baseline]\n\
                       [--config run.toml]   (config section: [lint])"
    );
}

fn cmd_lint(args: &Args) -> Result<()> {
    let config = match args.get("config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    let mut opts = finger::lint::LintOptions::from_config(&config);
    if let Some(root) = args.get("root") {
        opts.root = root.into();
    }
    if let Some(b) = args.get("baseline") {
        opts.baseline = Some(b.into());
    }
    opts.deny = opts.deny || args.flag("deny");
    let report = finger::lint::run(&opts)?;
    for d in &report.diagnostics {
        println!("{d}");
    }
    for stale in &report.stale_baseline {
        eprintln!("note: stale baseline entry (remove it): {stale}");
    }
    println!("{}", report.summary());
    if args.flag("write-baseline") {
        let path = opts.root.join("lint-baseline.txt");
        std::fs::write(&path, finger::lint::render_as_baseline(&report.diagnostics))
            .with_context(|| format!("write {}", path.display()))?;
        println!(
            "lint: wrote baseline with {} entries to {}",
            report.diagnostics.len(),
            path.display()
        );
        return Ok(());
    }
    if opts.deny && !report.clean() {
        bail!("lint failed with {} finding(s) (--deny)", report.diagnostics.len());
    }
    Ok(())
}

fn gen_graph(args: &Args) -> Result<Graph> {
    if let Some(path) = args.positional.first() {
        return gio::load_graph(path);
    }
    let n = args.get_parsed("n", 500usize);
    let degree = args.get_parsed("degree", 10.0f64);
    let p_ws = args.get_parsed("pws", 0.1f64);
    let seed = args.get_parsed("seed", 42u64);
    let mut rng = Pcg64::new(seed);
    let model = match args.get("model").unwrap_or("er") {
        "er" => GraphModel::Er,
        "ba" => GraphModel::Ba,
        "ws" => GraphModel::Ws,
        m => bail!("unknown model {m}"),
    };
    Ok(model.sample(n, degree, p_ws, &mut rng))
}

fn cmd_entropy(args: &Args) -> Result<()> {
    let g = gen_graph(args)?;
    println!("graph: n={} m={} S={:.4}", g.num_nodes(), g.num_edges(), g.total_weight());
    let (hhat, t1) = finger::util::timer::time_it(|| finger_hhat(&g));
    let (htil, t2) = finger::util::timer::time_it(|| finger_htilde(&g));
    println!("FINGER-Ĥ  = {hhat:.6}   ({})", finger::util::fmt::secs(t1));
    println!("FINGER-H̃ = {htil:.6}   ({})", finger::util::fmt::secs(t2));
    if args.flag("exact") {
        let (h, t0) = finger::util::timer::time_it(|| exact_vnge(&g));
        println!("exact H   = {h:.6}   ({})", finger::util::fmt::secs(t0));
        println!(
            "AE(Ĥ)={:.6} AE(H̃)={:.6} CTRR(Ĥ)={} CTRR(H̃)={}",
            h - hhat,
            h - htil,
            finger::util::fmt::pct(finger::util::timer::ctrr(t0, t1)),
            finger::util::fmt::pct(finger::util::timer::ctrr(t0, t2)),
        );
    }
    Ok(())
}

fn cmd_jsdist(args: &Args) -> Result<()> {
    let a = gio::load_graph(args.positional.first().context("need two edge-list files")?)?;
    let b = gio::load_graph(args.positional.get(1).context("need two edge-list files")?)?;
    let (fast, t) = finger::util::timer::time_it(|| finger::distance::jsdist_fast(&a, &b));
    println!("JSdist (FINGER fast) = {fast:.6}  ({})", finger::util::fmt::secs(t));
    if args.flag("exact") {
        let (ex, t) = finger::util::timer::time_it(|| finger::distance::jsdist_exact(&a, &b));
        println!("JSdist (exact)       = {ex:.6}  ({})", finger::util::fmt::secs(t));
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<()> {
    let cfg = PipelineConfig {
        channel_capacity: args.get_parsed("capacity", 64usize),
        ..Default::default()
    };
    let (initial, events) = if let Some(path) = args.get("file") {
        let f = std::fs::File::open(path)?;
        let deltas = gio::read_delta_stream(f)?;
        (Graph::new(0), event::events_from_deltas(&deltas))
    } else {
        let months = args.get_parsed("months", 24usize);
        let wiki =
            finger::datasets::wiki_stream(&WikiConfig { months, ..WikiConfig::default() });
        (wiki.initial, event::events_from_deltas(&wiki.deltas))
    };
    let res = Pipeline::new(initial, cfg).run(events);
    println!(
        "windows={} events={} wall={} throughput={:.0} ev/s p50={} p99={}",
        res.records.len(),
        res.total_events,
        finger::util::fmt::secs(res.wall_secs),
        res.throughput,
        finger::util::fmt::secs(res.p50_latency),
        finger::util::fmt::secs(res.p99_latency),
    );
    for r in &res.records {
        println!(
            "window={:<4} jsdist={:.6} H̃={:.4} n={} m={}{}",
            r.window,
            r.jsdist,
            r.htilde,
            r.nodes,
            r.edges,
            if r.anomalous { "  << ANOMALY" } else { "" }
        );
    }
    Ok(())
}

fn cmd_wiki(args: &Args) -> Result<()> {
    let dataset = args.get("dataset").unwrap_or("sen").to_string();
    let scale = args.get_parsed("scale", 1.0f64);
    let cfg = WikiConfig::preset(&dataset, scale);
    let run = experiments::run_wiki(&dataset, &cfg);
    println!("{}", report::wiki_table(&run));
    if args.flag("series") {
        println!("{}", report::series_dump(&run));
    }
    Ok(())
}

fn cmd_bifurcation(args: &Args) -> Result<()> {
    let cfg = HicConfig { dim: args.get_parsed("dim", 240usize), ..Default::default() };
    let rows = experiments::run_bifurcation(&cfg);
    println!("{}", report::bifurcation_table(&rows, cfg.bifurcation));
    Ok(())
}

fn cmd_dos(args: &Args) -> Result<()> {
    let cfg = OregonConfig { nodes: args.get_parsed("nodes", 2000usize), ..Default::default() };
    let trials = args.get_parsed("trials", 20usize);
    let xs = [0.01, 0.03, 0.05, 0.10];
    let rows = experiments::run_dos(&cfg, &xs, trials, args.flag("extended"), 7);
    println!("{}", report::dos_table(&rows, &xs));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let trials = args.get_parsed("trials", 3usize);
    let n = args.get_parsed("n", 800usize);
    match args.get("kind").unwrap_or("fig1-er") {
        "fig1-er" => {
            let rows = experiments::fig1_degree_sweep(
                GraphModel::Er,
                n,
                &[6.0, 10.0, 20.0, 50.0],
                trials,
                1,
            );
            println!("{}", report::approx_table(&rows, "d̄"));
        }
        "fig1-ba" => {
            let rows = experiments::fig1_degree_sweep(
                GraphModel::Ba,
                n,
                &[6.0, 10.0, 20.0, 50.0],
                trials,
                2,
            );
            println!("{}", report::approx_table(&rows, "d̄"));
        }
        "fig1-ws" => {
            let rows = experiments::fig1_ws_sweep(n, 20.0, &[0.01, 0.1, 0.3, 0.6, 1.0], trials, 3);
            println!("{}", report::approx_table(&rows, "p_ws"));
        }
        "fig2" => {
            for model in [GraphModel::Er, GraphModel::Ba, GraphModel::Ws] {
                let rows = experiments::fig2_size_sweep(
                    model,
                    &[200, 400, 800, n.max(1200)],
                    20.0,
                    0.1,
                    trials,
                    4,
                );
                println!("model={}\n{}", model.name(), report::approx_table(&rows, "n"));
            }
        }
        k => bail!("unknown sweep kind {k}"),
    }
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> Result<()> {
    let base = match args.get("config") {
        Some(path) => ServiceConfig::from_config(&Config::load(path)?),
        None => ServiceConfig::default(),
    };
    let wl_cfg = TenantWorkloadConfig {
        sessions: args.get_parsed("sessions", 256usize).max(1),
        windows: args.get_parsed("windows", 16usize).max(1),
        events_per_window: args.get_parsed("events", 60usize).max(1),
        nodes_per_session: args.get_parsed("nodes", 64usize).max(2),
        seed: args.get_parsed("seed", 0x5E55u64),
        ..Default::default()
    };
    let shard_counts = args.get_list("shards", &[1usize, 2, 4]);
    let capacity = args.get_parsed("capacity", base.channel_capacity);
    let producers = args.get_parsed("producers", 4usize).max(1);
    let batched = !args.flag("per-event");
    println!(
        "serve-bench: {} sessions × {} windows × {} events (n={} per session), \
         {} producers, {} ingest",
        wl_cfg.sessions,
        wl_cfg.windows,
        wl_cfg.events_per_window,
        wl_cfg.nodes_per_session,
        producers,
        if batched { "batched" } else { "per-event" },
    );
    let workload_data = workload::tenant_streams(&wl_cfg);
    let total = workload::workload_events(&workload_data);
    println!(
        "{:<8} {:>12} {:>12} {:>14} {:>10}",
        "shards", "events", "wall", "events/s", "speedup"
    );
    let mut baseline: Option<f64> = None;
    for &shards in &shard_counts {
        let cfg = ServiceConfig { shards, channel_capacity: capacity, ..base.clone() };
        let report = workload::drive(&cfg, &workload_data, producers, batched)?;
        assert_eq!(report.total_events, total, "event loss in serve-bench");
        let speedup = report.throughput / baseline.get_or_insert(report.throughput).max(1e-12);
        println!(
            "{:<8} {:>12} {:>12} {:>14.0} {:>9.2}x",
            shards,
            report.total_events,
            finger::util::fmt::secs(report.wall_secs),
            report.throughput,
            speedup,
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let config = match args.get("config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    let mut service_cfg = ServiceConfig::from_config(&config);
    service_cfg.shards = args.get_parsed("shards", service_cfg.shards).max(1);
    service_cfg.channel_capacity =
        args.get_parsed("capacity", service_cfg.channel_capacity).max(1);
    let mut net_cfg = NetConfig::from_config(&config);
    if let Some(addr) = args.get("addr") {
        net_cfg.addr = addr.to_string();
    }
    if let Some(raw) = args.get("wire") {
        net_cfg.wire = WireMode::parse(raw)
            .with_context(|| format!("unknown wire {raw:?} (want auto|text|binary)"))?;
    }
    net_cfg.event_threads = args.get_parsed("threads", net_cfg.event_threads).max(1);
    if let Some(path) = args.get("metrics-out") {
        net_cfg.obs.snapshot_path = Some(path.to_string());
    }
    net_cfg.obs.interval_ms =
        args.get_parsed("metrics-interval", net_cfg.obs.interval_ms).max(1);
    if let Some(dir) = args.get("durability-dir") {
        let mut dur = service_cfg
            .durability
            .take()
            .unwrap_or_else(|| DurabilityConfig::new(dir));
        dur.dir = dir.into();
        service_cfg.durability = Some(dur);
    }
    if let Some(dur) = service_cfg.durability.as_mut() {
        if let Some(raw) = args.get("fsync") {
            dur.fsync = FsyncPolicy::parse(raw).with_context(|| {
                format!("unknown fsync spec {raw:?} (want always|every_ms[=N]|every_n[=N])")
            })?;
        }
        dur.snapshot_interval_ms =
            args.get_parsed("snapshot-interval", dur.snapshot_interval_ms);
    }
    // arm any [fault] failpoint schedule before the server touches disk or
    // sockets, so recovery itself runs under the schedule; a feature-off
    // build refuses an armed section rather than silently ignoring it
    let armed = finger::fault::arm_from_config(&config).map_err(|e| anyhow::anyhow!(e))?;
    if !armed.is_empty() {
        println!("serve: fault injection armed: {}", armed.join(", "));
    }
    let wire_mode = net_cfg.wire;
    let event_threads = net_cfg.event_threads;
    let metrics_out = net_cfg.obs.snapshot_path.clone();
    let server = NetServer::bind(service_cfg.clone(), net_cfg)?;
    let restored_ckpt = server.restore_checkpoint_sessions()?;
    let rec = server.recovery().clone();
    println!(
        "serve: listening on {} ({} shards, capacity {}, wire {}, {} event threads, \
         restored {} sessions, replayed {} windows); send SHUTDOWN to stop",
        server.local_addr(),
        service_cfg.shards,
        service_cfg.channel_capacity,
        wire_mode.name(),
        event_threads,
        rec.restored_sessions + restored_ckpt,
        rec.replayed_windows,
    );
    if let Some(dur) = &service_cfg.durability {
        println!(
            "serve: durability on at {} (fsync {:?}, on_error {}{})",
            dur.dir.display(),
            dur.fsync,
            dur.on_error.spec(),
            match rec.epoch {
                Some(e) => format!(", recovered from epoch {e}"),
                None => String::new(),
            },
        );
    }
    if let Some(path) = &metrics_out {
        println!("serve: writing metrics snapshots to {path}");
    }
    let report = server.run()?;
    println!(
        "serve: drained — {} sessions, {} events ({} dropped), {} windows, \
         {} anomalies, {:.0} events/s over {}",
        report.sessions.len(),
        report.total_events,
        report.dropped_events,
        report.total_windows(),
        report.total_anomalies(),
        report.throughput,
        finger::util::fmt::secs(report.wall_secs),
    );
    Ok(())
}

fn cmd_epoch(args: &Args) -> Result<()> {
    let config = match args.get("config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    let mut net_cfg = NetConfig::from_config(&config);
    if let Some(addr) = args.get("addr") {
        net_cfg.addr = addr.to_string();
    }
    let wire = match args.get("wire") {
        None => net_cfg.wire.client_wire(),
        Some(raw) => Wire::parse(raw)
            .with_context(|| format!("unknown wire {raw:?} (want text|binary)"))?,
    };
    let mut client =
        NetClient::connect_with(net_cfg.addr.as_str(), wire, net_cfg.client_timeout())?;
    let (epoch, sessions) = client.epoch()?;
    println!("epoch: committed epoch {epoch} covering {sessions} session(s)");
    client.quit().ok();
    Ok(())
}

fn cmd_load(args: &Args) -> Result<()> {
    let config = match args.get("config") {
        Some(path) => Config::load(path)?,
        None => Config::default(),
    };
    let mut net_cfg = NetConfig::from_config(&config);
    if let Some(addr) = args.get("addr") {
        net_cfg.addr = addr.to_string();
    }
    let presets = match args.get("presets") {
        None => Vec::new(),
        Some(raw) => TenantPreset::parse_list(raw)
            .with_context(|| format!("unknown preset in {raw:?} (want synthetic|wiki|dos|hic)"))?,
    };
    let workload = TenantWorkloadConfig {
        sessions: args.get_parsed("sessions", 64usize).max(1),
        windows: args.get_parsed("windows", 8usize).max(1),
        events_per_window: args.get_parsed("events", 40usize).max(1),
        nodes_per_session: args.get_parsed("nodes", 48usize).max(2),
        presets,
        seed: args.get_parsed("seed", 0x5E55u64),
    };
    let connection_counts = args.get_list("connections", &[1usize, 2, 4, 8]);
    let wires: Vec<Wire> = match args.get("wire") {
        None => vec![net_cfg.wire.client_wire()],
        Some(raw) => raw
            .split(',')
            .map(|t| {
                Wire::parse(t.trim())
                    .with_context(|| format!("unknown wire {t:?} (want text|binary)"))
            })
            .collect::<Result<_>>()?,
    };
    let timeout_ms = args.get_parsed("timeout-ms", net_cfg.client_timeout_ms);
    let client_timeout =
        (timeout_ms > 0).then(|| std::time::Duration::from_millis(timeout_ms));
    let retry = args.flag("retry").then(|| finger::net::RetryPolicy {
        max_attempts: args.get_parsed("retry-attempts", 8u32).max(1),
        ..Default::default()
    });
    println!(
        "load: {} sessions ({} presets) × {} windows against {} — \
         connection sweep {:?} on {:?} wire(s)",
        workload.sessions,
        traffic::preset_summary(&workload),
        workload.windows,
        net_cfg.addr,
        connection_counts,
        wires.iter().map(|w| w.name()).collect::<Vec<_>>(),
    );
    println!(
        "{:<8} {:<12} {:>12} {:>12} {:>12} {:>14} {:>10} {:>10}",
        "wire", "connections", "events", "windows", "wall", "events/s", "p50(us)", "p99(us)"
    );
    let mut records = Vec::new();
    let mut total_windows = 0usize;
    for &wire in &wires {
        for &connections in &connection_counts {
            let report = traffic::run_load(&TrafficConfig {
                addr: net_cfg.addr.clone(),
                wire,
                client_timeout,
                connections,
                workload: workload.clone(),
                query_sessions: true,
                shutdown_after: false,
                live_stats: args.flag("live-stats"),
                check_metrics: args.flag("check-metrics"),
                retry,
            })?;
            total_windows += report.windows;
            println!(
                "{:<8} {:<12} {:>12} {:>12} {:>12} {:>14.0} {:>10} {:>10}",
                wire.name(),
                report.connections,
                report.events_sent,
                report.windows,
                finger::util::fmt::secs(report.wall_secs),
                report.events_per_sec,
                report.p50_us,
                report.p99_us,
            );
            // label records with the connection count that actually ran —
            // replay() clamps the request to the tenant count
            let conns = report.connections;
            if conns != connections {
                println!("  (requested {connections} connections, clamped to {conns})");
            }
            if let Some(n) = report.metrics_keys {
                println!("  (METRICS parity OK across wires: {n} keys)");
            }
            // per-kind error accounting: silent under a clean fail-fast run,
            // one line when anything was refused, reset or retried
            let errs = &report.errors;
            if errs.total() > 0 || errs.retries > 0 {
                let server: Vec<String> = errs
                    .server_err
                    .iter()
                    .map(|(code, n)| format!("{code}×{n}"))
                    .collect();
                println!(
                    "  errors: refused={} timeout={} reset={} other={} server=[{}] retries={}",
                    errs.connect_refused,
                    errs.read_timeout,
                    errs.reset,
                    errs.other_io,
                    server.join(","),
                    errs.retries,
                );
                records.push(BenchRecord::metric(
                    format!("net_errors_{}_conns_{conns}", wire.name()),
                    errs.total() as f64,
                    "errors",
                ));
                records.push(BenchRecord::metric(
                    format!("net_retries_{}_conns_{conns}", wire.name()),
                    errs.retries as f64,
                    "retries",
                ));
            }
            records.push(BenchRecord::metric(
                format!("net_throughput_{}_conns_{conns}", wire.name()),
                report.events_per_sec,
                "events_per_sec",
            ));
            records.push(BenchRecord::metric(
                format!("net_windows_{}_conns_{conns}", wire.name()),
                report.windows as f64,
                "windows",
            ));
            records.push(BenchRecord::metric(
                format!("net_p50_us_{}_conns_{conns}", wire.name()),
                report.p50_us as f64,
                "us",
            ));
            records.push(BenchRecord::metric(
                format!("net_p99_us_{}_conns_{conns}", wire.name()),
                report.p99_us as f64,
                "us",
            ));
        }
    }
    if args.flag("shutdown") {
        // speak a wire the sweep just used — a `serve --wire binary` server
        // refuses a text connection, and the records must still be written
        NetClient::connect_with(net_cfg.addr.as_str(), wires[0], client_timeout)?
            .shutdown_server()?;
        println!("load: sent SHUTDOWN to {}", net_cfg.addr);
    }
    let out = args.get("bench-out").unwrap_or("BENCH_net.json");
    bench::write_json_report(out, "net_load", &records)
        .with_context(|| format!("write {out}"))?;
    println!("load: wrote {} records to {out}", records.len());
    if total_windows == 0 {
        bail!("load drove zero windows — server scored nothing");
    }
    Ok(())
}

fn cmd_offload(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let rt = finger::runtime::Runtime::load(dir)?;
    println!("PJRT platform: {}", rt.platform());
    let x = finger::runtime::XlaEntropy::new(&rt);
    let mut rng = Pcg64::new(9);
    let g = finger::generators::erdos_renyi(60, 0.15, &mut rng);
    let q_native = finger::entropy::quadratic_q(&g);
    let q_xla = x.q(&g)?;
    let hhat_native = finger_hhat(&g);
    let hhat_xla = x.hhat(&g)?;
    println!("Q     native={q_native:.6} xla={q_xla:.6} |Δ|={:.2e}", (q_native - q_xla).abs());
    println!(
        "Ĥ     native={hhat_native:.6} xla={hhat_xla:.6} |Δ|={:.2e}",
        (hhat_native - hhat_xla).abs()
    );
    Ok(())
}
