//! Periodic JSON snapshot of the metrics registry, written by the server's
//! `finger-obs` thread so `finger load` runs and CI can scrape live
//! telemetry off disk (`BENCH_net.json`'s sibling, `OBS_net.json` in CI).
//!
//! The format is hand-rolled JSON (serde is not in the offline registry),
//! deliberately one `"key": value` pair per line so shell tooling can grep
//! and awk it — the CI net-smoke step sums the `shard<i>_events` lines and
//! checks them against `service_events_submitted`. Scrape examples live in
//! `docs/OBSERVABILITY.md`.

use super::span::snapshot_spans;
use crate::bench::json_escape;
use crate::util::stats::LatencySummary;
use std::io::Write as _;
use std::path::Path;

/// Knobs of the observability layer, read from the `[obs]` config section
/// (and `finger serve --metrics-interval/--metrics-out`).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Where the periodic JSON snapshot lands; `None` disables the writer.
    pub snapshot_path: Option<String>,
    /// Snapshot cadence in milliseconds.
    pub interval_ms: u64,
    /// Slow-request spans kept (ring capacity).
    pub slow_n: usize,
    /// Span sampling: look at every Nth request (1 = all, 0 = disabled).
    pub sample_every: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            snapshot_path: None,
            interval_ms: 1000,
            slow_n: super::span::DEFAULT_SLOW_N,
            sample_every: 1,
        }
    }
}

/// Write one snapshot: every registry pair plus the caller's `extra` pairs
/// (the server appends live service-derived values — `uptime_ms`,
/// `service_events_submitted`, per-shard depths), per-histogram summary
/// stats, sparse bucket arrays, and the slow-span ring. The file is
/// replaced atomically enough for scrapers (written to a `.tmp` sibling,
/// then renamed) so a reader never sees a torn snapshot.
pub fn write_snapshot(path: &Path, extra: &[(String, u64)]) -> std::io::Result<()> {
    let report = super::report(extra);
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        writeln!(f, "{{")?;
        writeln!(f, "  \"metrics\": {{")?;
        let n = report.pairs.len();
        for (k, (name, value)) in report.pairs.iter().enumerate() {
            let comma = if k + 1 < n { "," } else { "" };
            writeln!(f, "    \"{}\": {value}{comma}", json_escape(name))?;
        }
        writeln!(f, "  }},")?;
        writeln!(f, "  \"hists\": {{")?;
        let nh = report.hists.len();
        for (k, wh) in report.hists.iter().enumerate() {
            let comma = if k + 1 < nh { "," } else { "" };
            let s = LatencySummary::from_histogram(&wh.to_histogram());
            let buckets: Vec<String> =
                wh.buckets.iter().map(|(i, c)| format!("[{i},{c}]")).collect();
            writeln!(
                f,
                "    \"{}\": {{\"count\": {}, \"mean\": {:.1}, \"p50\": {}, \"p99\": {}, \
                 \"buckets\": [{}]}}{comma}",
                json_escape(&wh.name),
                s.count,
                s.mean,
                s.p50 as u64,
                s.p99 as u64,
                buckets.join(",")
            )?;
        }
        writeln!(f, "  }},")?;
        writeln!(f, "  \"slow_spans\": [")?;
        let spans = snapshot_spans();
        let ns = spans.len();
        for (k, s) in spans.iter().enumerate() {
            let comma = if k + 1 < ns { "," } else { "" };
            writeln!(
                f,
                "    {{\"kind\": \"{}\", \"id\": \"{}\", \"shard\": {}, \"queue_us\": {}, \
                 \"total_us\": {}}}{comma}",
                s.kind,
                json_escape(&s.id),
                s.shard,
                s.queue_us,
                s.total_us
            )?;
        }
        writeln!(f, "  ]")?;
        writeln!(f, "}}")?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_is_parseable_shape_and_greppable() {
        super::super::note_shards(2);
        super::super::shard_events_add(0, 3);
        super::super::score_window(250, false, 0);
        let path = std::env::temp_dir().join("finger_obs_snapshot_test.json");
        let extra = vec![
            ("uptime_ms".to_string(), 1234u64),
            ("service_events_submitted".to_string(), 3u64),
        ];
        write_snapshot(&path, &extra).expect("write snapshot");
        let text = std::fs::read_to_string(&path).expect("read back");
        // one pair per line: the CI awk/grep contract
        assert!(text.lines().any(|l| l.trim_start().starts_with("\"shard0_events\":")), "{text}");
        assert!(text.contains("\"service_events_submitted\": 3"));
        assert!(text.contains("\"uptime_ms\": 1234"));
        assert!(text.contains("\"score_latency_us\""));
        assert!(text.contains("\"slow_spans\""));
        // braces and brackets balance (cheap well-formedness check)
        let open = text.matches('{').count();
        let close = text.matches('}').count();
        assert_eq!(open, close);
        assert_eq!(text.matches('[').count(), text.matches(']').count());
        // no trailing comma before a closing brace/bracket
        for w in text.split_whitespace().collect::<Vec<_>>().windows(2) {
            if let [a, b] = w {
                assert!(
                    !(a.ends_with(',') && (b.starts_with('}') || b.starts_with(']'))),
                    "trailing comma before {b}"
                );
            }
        }
        std::fs::remove_file(&path).ok();
    }
}
