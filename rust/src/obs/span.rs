//! Sampled request spans: a fixed ring of the slowest N requests the server
//! has answered, each carrying the command kind, session id, shard, and a
//! queue-wait vs. service-time breakdown (monotonic-clock microseconds,
//! measured by the caller with `Instant`).
//!
//! Recording is sampled (`1/sample_every` requests, decided by one relaxed
//! `fetch_add`) and best-effort: the ring is a small pre-allocated `Vec`
//! under a `Mutex`, and a recorder that loses the lock race (poisoning)
//! simply drops the span — observability must never take a request down
//! with it, so there is no panic path here (FL001 covers this module).
//! Session ids are copied into a fixed inline buffer; ids longer than
//! [`SPAN_ID_BYTES`] are truncated for display.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Inline id-copy capacity (ids are ≤ 24 bytes in every workload preset;
/// longer ones truncate, they never allocate).
pub const SPAN_ID_BYTES: usize = 24;

/// Default ring capacity (`[obs] slow_n`).
pub const DEFAULT_SLOW_N: usize = 32;

/// What kind of request a span timed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Open,
    Batch,
    Query,
    Close,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Open => "open",
            SpanKind::Batch => "batch",
            SpanKind::Query => "query",
            SpanKind::Close => "close",
        }
    }
}

/// One recorded span, inline storage only.
#[derive(Debug, Clone, Copy)]
struct Span {
    kind: SpanKind,
    id: [u8; SPAN_ID_BYTES],
    id_len: u8,
    shard: u32,
    queue_us: u64,
    total_us: u64,
}

/// A span rendered for snapshots (owned strings are fine off the hot path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanSnapshot {
    pub kind: &'static str,
    pub id: String,
    pub shard: u32,
    /// Time parked on shard backpressure before the service accepted the
    /// command (0 for requests that never parked).
    pub queue_us: u64,
    /// Full round-trip: decode complete → reply queued.
    pub total_us: u64,
}

struct Ring {
    spans: Vec<Span>,
    cap: usize,
}

static RING: Mutex<Ring> = Mutex::new(Ring { spans: Vec::new(), cap: DEFAULT_SLOW_N });
/// Request counter driving the sampling decision.
static TICK: AtomicU64 = AtomicU64::new(0);
/// Record every Nth request (0 disables spans entirely).
static SAMPLE_EVERY: AtomicU64 = AtomicU64::new(1);

/// Configure the ring: keep the slowest `slow_n` spans, looking at every
/// `sample_every`-th request (`0` disables spans). Called by the server at
/// startup; safe to call again (the ring restarts empty).
pub fn init_spans(slow_n: usize, sample_every: u64) {
    SAMPLE_EVERY.store(sample_every, Ordering::Relaxed);
    if let Ok(mut r) = RING.lock() {
        r.cap = slow_n;
        r.spans = Vec::with_capacity(slow_n);
    }
}

// lint: hot-path
// The record path runs inside the event loop per request: one atomic for
// the sampling decision; only sampled requests touch the (short) lock, and
// the inline id copy never allocates.

/// Record one request span (sampled). `id` is copied inline, truncated to
/// [`SPAN_ID_BYTES`].
#[inline]
pub fn span_record(kind: SpanKind, id: &str, shard: usize, queue_us: u64, total_us: u64) {
    let every = SAMPLE_EVERY.load(Ordering::Relaxed);
    if every == 0 {
        return;
    }
    let tick = TICK.fetch_add(1, Ordering::Relaxed);
    if every > 1 && tick % every != 0 {
        return;
    }
    let mut buf = [0u8; SPAN_ID_BYTES];
    let mut len = 0u8;
    for (dst, src) in buf.iter_mut().zip(id.as_bytes()) {
        *dst = *src;
        len += 1;
    }
    let span = Span {
        kind,
        id: buf,
        id_len: len,
        shard: (shard.min(u32::MAX as usize)) as u32,
        queue_us,
        total_us,
    };
    // best-effort: a poisoned lock drops the span, never the request
    if let Ok(mut r) = RING.lock() {
        if r.spans.len() < r.cap {
            r.spans.push(span);
            return;
        }
        // full: replace the fastest kept span iff this one is slower
        let min = r
            .spans
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| s.total_us)
            .map(|(i, s)| (i, s.total_us));
        if let Some((i, fastest)) = min {
            if span.total_us > fastest {
                if let Some(slot) = r.spans.get_mut(i) {
                    *slot = span;
                }
            }
        }
    }
}

// lint: hot-path end

/// The kept spans, slowest first (allocates; snapshot/METRICS path only).
pub fn snapshot_spans() -> Vec<SpanSnapshot> {
    let mut out: Vec<SpanSnapshot> = Vec::new();
    if let Ok(r) = RING.lock() {
        out.reserve(r.spans.len());
        for s in r.spans.iter() {
            let id_bytes = s.id.get(..s.id_len as usize).unwrap_or(&[]);
            out.push(SpanSnapshot {
                kind: s.kind.name(),
                id: String::from_utf8_lossy(id_bytes).into_owned(),
                shard: s.shard,
                queue_us: s.queue_us,
                total_us: s.total_us,
            });
        }
    }
    out.sort_by(|a, b| b.total_us.cmp(&a.total_us));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The ring is process-global, so every assertion here runs under one
    /// lock-step test to avoid cross-test interference.
    #[test]
    fn ring_keeps_the_slowest_and_samples() {
        init_spans(3, 1);
        for (i, total) in [10u64, 500, 20, 900, 5, 30].iter().enumerate() {
            span_record(SpanKind::Batch, &format!("s{i}"), i, 1, *total);
        }
        let kept = snapshot_spans();
        assert_eq!(kept.len(), 3);
        let totals: Vec<u64> = kept.iter().map(|s| s.total_us).collect();
        assert_eq!(totals, vec![900, 500, 30], "slowest three, sorted desc");
        assert_eq!(kept.first().map(|s| s.kind), Some("batch"));

        // sample_every = 0 disables recording entirely
        init_spans(3, 0);
        span_record(SpanKind::Query, "x", 0, 0, 10_000);
        assert!(snapshot_spans().is_empty());

        // long ids truncate inline, never panic
        init_spans(2, 1);
        let long = "a".repeat(SPAN_ID_BYTES * 2);
        span_record(SpanKind::Open, &long, 7, 0, 42);
        let kept = snapshot_spans();
        assert_eq!(kept.first().map(|s| s.id.len()), Some(SPAN_ID_BYTES));
        assert_eq!(kept.first().map(|s| s.shard), Some(7));
    }
}
