//! Lock-free histogram recorder: the atomic mirror of
//! [`stats::Histogram`](crate::util::stats::Histogram), sharing its exact
//! bucket table ([`stats::bucket_index`](crate::util::stats::bucket_index),
//! 976 buckets, 1/16 relative error) so a snapshot transfers bucket counts
//! without re-bucketing.
//!
//! Recording is two relaxed `fetch_add`s on `static` storage — safe from any
//! thread, zero allocation, no lock. To keep concurrent recorders (shard
//! workers, event loops) from bouncing one cache line, the bucket table is
//! striped [`OBS_HIST_STRIPES`] ways: callers pass a stripe hint (their
//! shard or loop index) and snapshots fold the stripes back together.

use super::OBS_HIST_STRIPES;
use crate::util::stats::{self, HIST_BUCKETS};
use std::sync::atomic::{AtomicU64, Ordering};

/// One stripe: a full bucket table plus its sample count.
struct Stripe {
    counts: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
}

impl Stripe {
    const fn new() -> Self {
        const Z: AtomicU64 = AtomicU64::new(0);
        Stripe { counts: [Z; HIST_BUCKETS], count: AtomicU64::new(0) }
    }
}

/// A statically-constructible, lock-free, striped histogram recorder.
pub struct AtomicHistogram {
    stripes: [Stripe; OBS_HIST_STRIPES],
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    /// Const so recorders can live in `static`s (`static H: AtomicHistogram
    /// = AtomicHistogram::new();`).
    pub const fn new() -> Self {
        const S: Stripe = Stripe::new();
        AtomicHistogram { stripes: [S; OBS_HIST_STRIPES] }
    }

    // lint: hot-path
    // Record is called from scoring and event-loop hot regions: atomics
    // only, slot access via `get` (no panic path), no allocation.

    /// Record one sample on the caller's stripe (`stripe` folds modulo the
    /// stripe count — pass a shard or loop index).
    #[inline]
    pub fn record(&self, stripe: usize, v: u64) {
        if let Some(s) = self.stripes.get(stripe % OBS_HIST_STRIPES) {
            if let Some(c) = s.counts.get(stats::bucket_index(v)) {
                c.fetch_add(1, Ordering::Relaxed);
                s.count.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    // lint: hot-path end

    /// Total samples recorded across all stripes.
    pub fn count(&self) -> u64 {
        self.stripes.iter().map(|s| s.count.load(Ordering::Relaxed)).sum()
    }

    /// Fold the stripes into an owned [`stats::Histogram`] snapshot.
    /// Concurrent recording keeps running; the snapshot is a consistent
    /// *monotone* view (it may miss samples landing mid-walk, never
    /// invents any).
    pub fn snapshot(&self) -> stats::Histogram {
        let mut h = stats::Histogram::new();
        for s in &self.stripes {
            for (i, c) in s.counts.iter().enumerate() {
                let n = c.load(Ordering::Relaxed);
                if n > 0 {
                    h.add_count(i, n);
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_plain_histogram_across_stripes() {
        static H: AtomicHistogram = AtomicHistogram::new();
        let mut expect = stats::Histogram::new();
        for (i, v) in [0u64, 5, 16, 999, 54_321, 7, 7, 1 << 40].iter().enumerate() {
            H.record(i, *v); // spread over every stripe, folding included
            expect.record(*v);
        }
        assert_eq!(H.count(), expect.count());
        assert_eq!(H.snapshot(), expect);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        static H: AtomicHistogram = AtomicHistogram::new();
        const PER_THREAD: usize = 5_000;
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    for k in 0..PER_THREAD {
                        H.record(t, (k as u64 % 100) + 1);
                    }
                });
            }
        });
        assert_eq!(H.count(), 4 * PER_THREAD as u64);
        assert_eq!(H.snapshot().count(), 4 * PER_THREAD as u64);
    }

    #[test]
    fn snapshot_percentiles_bound_error_like_the_source() {
        let h = AtomicHistogram::new();
        for _ in 0..100 {
            h.record(0, 1_000);
        }
        let p99 = h.snapshot().percentile(99.0);
        assert!(p99 >= 1_000 && p99 - 1_000 <= 1_000 / 16, "p99={p99}");
    }
}
