//! First-party observability: a dependency-free, process-global metrics
//! registry in the style of `util/stats.rs`.
//!
//! The server built in PRs 2–7 was a black box while running — queue depths,
//! event-loop wakeups, backpressure parks and per-window scoring latency were
//! only visible post-mortem in `ServiceReport`/`TrafficReport`. This module
//! is the sensor layer the ROADMAP's scaling items read from: static atomic
//! [`Counter`]s and [`Gauge`]s, fixed per-shard / per-event-loop slot arrays,
//! striped lock-free [`AtomicHistogram`] recorders, and a sampled ring of the
//! slowest request [`span`]s.
//!
//! Design constraints, in order:
//!
//! * **Zero allocation at record time.** Every record function is a handful
//!   of relaxed atomic ops on `static` cells — callable from `// lint:
//!   hot-path` and `// lint: event-loop` regions (the recording code below is
//!   itself inside a `lint: hot-path` region, so FL002 enforces this), and
//!   the counting-allocator assert in `benches/finger_hotpath.rs` still sees
//!   0 allocations/window with scoring metrics live.
//! * **No panic paths.** `rust/src/obs/` is part of the FL001 panic-free
//!   zone: slot arrays are accessed via `get(i % LEN)` (out-of-range shards
//!   fold modulo the slot count, so totals stay exact), never by indexing.
//! * **Process-global.** Recorders are reached from the scoring hot path
//!   (`stream/window.rs`), which is constructed in places that know nothing
//!   about servers (benches, the in-process pipeline) — a registry handle
//!   can't be threaded through, so the registry is `static` and readers must
//!   treat values as monotone counters, not per-run deltas.
//!
//! Rendering (name → value pairs, histogram snapshots) allocates freely —
//! it runs on the `METRICS` request path and the snapshot writer thread,
//! never per event. The catalogue of every metric below is documented in
//! `docs/OBSERVABILITY.md`.

pub mod hist;
pub mod snapshot;
pub mod span;

pub use hist::AtomicHistogram;
pub use snapshot::{write_snapshot, ObsConfig};
pub use span::{
    init_spans, snapshot_spans, span_record, SpanKind, SpanSnapshot, DEFAULT_SLOW_N,
    SPAN_ID_BYTES,
};

use crate::util::stats;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Per-shard slot count. A service configured with more shards than this
/// folds the excess modulo [`MAX_OBS_SHARDS`] — per-slot attribution blurs
/// past 64 shards, but slot sums stay exactly equal to the true totals.
pub const MAX_OBS_SHARDS: usize = 64;

/// Per-event-loop slot count (the server clamps `event_threads` to 64, so
/// in practice this is never folded).
pub const MAX_OBS_LOOPS: usize = 64;

/// Stripe count for the histogram recorders: concurrent recorders spread
/// over stripes by shard/loop index so a hot path never bounces one cache
/// line across every worker.
pub const OBS_HIST_STRIPES: usize = 4;

/// Monotone event counters. Names on the wire/snapshot come from
/// [`Counter::name`]; the declaration order here is the stable render order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Connections accepted by the listener (lifetime total).
    NetAccepted,
    /// Event-loop `poll(2)` returns (readiness, waker byte, or tick).
    NetWakeups,
    /// Bytes read off client sockets.
    NetBytesIn,
    /// Bytes written to client sockets.
    NetBytesOut,
    /// Malformed or framing-broken requests answered with `ERR`.
    NetDecodeErrors,
    /// Commands parked on shard backpressure (`Pending`), withdrawing the
    /// connection's read interest.
    NetParks,
    /// Parked commands later accepted by their shard.
    NetResumes,
    /// Write queues crossing the high-water mark (decode suspended until
    /// the peer drains replies).
    NetWriteSuspensions,
    /// `try_submit*` rejections with a full shard queue.
    SvcWouldBlock,
    /// Events entering window batching (pre-coalesce).
    WinEventsIn,
    /// Edge deltas surviving coalescing (post-merge); the coalesce ratio is
    /// `win_coalesced / win_events_in`.
    WinCoalesced,
    /// Windows scored (Algorithm 2 runs).
    ScoreWindows,
    /// Windows flagged anomalous by the detector.
    ScoreAnomalies,
    /// Records appended to per-shard write-ahead logs.
    WalAppends,
    /// Bytes appended to per-shard write-ahead logs (framing included).
    WalBytes,
    /// `fsync` calls issued by WAL writers.
    WalFsyncs,
    /// Epoch snapshots committed (manifest renamed + `CURRENT` repointed).
    SnapshotEpochs,
    /// Failpoints that actually fired (armed schedule hit — `fault-inject`
    /// builds only; always 0 in production binaries).
    FaultInjected,
    /// Shards that dropped their WAL and entered degraded scoring
    /// (`[durability] on_error = degrade`).
    Degraded,
    /// Requests answered `ERR retry-after` because their shard queue stayed
    /// saturated past `[net] shed_after_ms`.
    ShedRequests,
    /// Reliable writes discarded as duplicates (`seq <= acked`).
    DupDiscards,
}

/// Every counter in stable render order.
pub const COUNTERS: &[Counter] = &[
    Counter::NetAccepted,
    Counter::NetWakeups,
    Counter::NetBytesIn,
    Counter::NetBytesOut,
    Counter::NetDecodeErrors,
    Counter::NetParks,
    Counter::NetResumes,
    Counter::NetWriteSuspensions,
    Counter::SvcWouldBlock,
    Counter::WinEventsIn,
    Counter::WinCoalesced,
    Counter::ScoreWindows,
    Counter::ScoreAnomalies,
    Counter::WalAppends,
    Counter::WalBytes,
    Counter::WalFsyncs,
    Counter::SnapshotEpochs,
    Counter::FaultInjected,
    Counter::Degraded,
    Counter::ShedRequests,
    Counter::DupDiscards,
];

/// Live-level gauges (incremented and decremented; rendered as `u64`, never
/// below zero).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Connections currently owned by the event loops.
    NetConnections,
    /// Sessions currently resident across all shards.
    SvcSessions,
}

/// Every gauge in stable render order.
pub const GAUGES: &[Gauge] = &[Gauge::NetConnections, Gauge::SvcSessions];

// lint: hot-path
// Record-time surface: pure relaxed atomics on statics. No allocation
// (FL002 checks this region), no indexing/unwrap (FL001 checks the module).

/// One zero-initialized cell per macro expansion — each `match` arm below
/// gets its own distinct `static`.
macro_rules! cell {
    () => {{
        static C: AtomicU64 = AtomicU64::new(0);
        &C
    }};
}

impl Counter {
    fn cell(self) -> &'static AtomicU64 {
        match self {
            Counter::NetAccepted => cell!(),
            Counter::NetWakeups => cell!(),
            Counter::NetBytesIn => cell!(),
            Counter::NetBytesOut => cell!(),
            Counter::NetDecodeErrors => cell!(),
            Counter::NetParks => cell!(),
            Counter::NetResumes => cell!(),
            Counter::NetWriteSuspensions => cell!(),
            Counter::SvcWouldBlock => cell!(),
            Counter::WinEventsIn => cell!(),
            Counter::WinCoalesced => cell!(),
            Counter::ScoreWindows => cell!(),
            Counter::ScoreAnomalies => cell!(),
            Counter::WalAppends => cell!(),
            Counter::WalBytes => cell!(),
            Counter::WalFsyncs => cell!(),
            Counter::SnapshotEpochs => cell!(),
            Counter::FaultInjected => cell!(),
            Counter::Degraded => cell!(),
            Counter::ShedRequests => cell!(),
            Counter::DupDiscards => cell!(),
        }
    }

    /// Add `n`; a relaxed `fetch_add` on a static cell.
    #[inline]
    pub fn add(self, n: u64) {
        self.cell().fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    #[inline]
    pub fn inc(self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(self) -> u64 {
        self.cell().load(Ordering::Relaxed)
    }

    /// The stable metric name (`docs/OBSERVABILITY.md` catalogues these).
    pub fn name(self) -> &'static str {
        match self {
            Counter::NetAccepted => "net_accepted",
            Counter::NetWakeups => "net_wakeups",
            Counter::NetBytesIn => "net_bytes_in",
            Counter::NetBytesOut => "net_bytes_out",
            Counter::NetDecodeErrors => "net_decode_errors",
            Counter::NetParks => "net_parks",
            Counter::NetResumes => "net_resumes",
            Counter::NetWriteSuspensions => "net_write_suspensions",
            Counter::SvcWouldBlock => "svc_would_block",
            Counter::WinEventsIn => "win_events_in",
            Counter::WinCoalesced => "win_coalesced",
            Counter::ScoreWindows => "score_windows",
            Counter::ScoreAnomalies => "score_anomalies",
            Counter::WalAppends => "wal_appends",
            Counter::WalBytes => "wal_bytes",
            Counter::WalFsyncs => "wal_fsyncs",
            Counter::SnapshotEpochs => "snapshot_epochs",
            Counter::FaultInjected => "fault_injected",
            Counter::Degraded => "degraded",
            Counter::ShedRequests => "shed_requests",
            Counter::DupDiscards => "dup_discards",
        }
    }
}

impl Gauge {
    fn cell(self) -> &'static AtomicU64 {
        match self {
            Gauge::NetConnections => cell!(),
            Gauge::SvcSessions => cell!(),
        }
    }

    /// Raise the level by one.
    #[inline]
    pub fn inc(self) {
        self.cell().fetch_add(1, Ordering::Relaxed);
    }

    /// Lower the level by one; saturates at zero instead of wrapping, so a
    /// spurious extra decrement (a bug, but an observability bug) can never
    /// render as `u64::MAX`.
    #[inline]
    pub fn dec(self) {
        let c = self.cell();
        let mut cur = c.load(Ordering::Relaxed);
        while cur > 0 {
            match c.compare_exchange_weak(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current level.
    pub fn get(self) -> u64 {
        self.cell().load(Ordering::Relaxed)
    }

    /// The stable metric name.
    pub fn name(self) -> &'static str {
        match self {
            Gauge::NetConnections => "net_connections",
            Gauge::SvcSessions => "svc_sessions",
        }
    }
}

const SLOT_ZERO: AtomicU64 = AtomicU64::new(0);

/// Events accepted per shard (incremented at the service's submit sites, so
/// the slots sum exactly to `ServiceReport.events_submitted`).
static SHARD_EVENTS: [AtomicU64; MAX_OBS_SHARDS] = [SLOT_ZERO; MAX_OBS_SHARDS];
/// Windows scored per shard.
static SHARD_WINDOWS: [AtomicU64; MAX_OBS_SHARDS] = [SLOT_ZERO; MAX_OBS_SHARDS];
/// `WouldBlock` rejections per shard (which queue is the hot one).
static SHARD_WOULD_BLOCK: [AtomicU64; MAX_OBS_SHARDS] = [SLOT_ZERO; MAX_OBS_SHARDS];
/// Poll-set size per event loop (connections + the waker), set each wakeup.
static LOOP_POLLSET: [AtomicU64; MAX_OBS_LOOPS] = [SLOT_ZERO; MAX_OBS_LOOPS];

/// How many shard slots are live (highest configured shard count seen).
static SHARD_COUNT: AtomicUsize = AtomicUsize::new(0);
/// How many event-loop slots are live.
static LOOP_COUNT: AtomicUsize = AtomicUsize::new(0);

#[inline]
fn slot_add(slots: &[AtomicU64; MAX_OBS_SHARDS], shard: usize, n: u64) {
    if let Some(c) = slots.get(shard % MAX_OBS_SHARDS) {
        c.fetch_add(n, Ordering::Relaxed);
    }
}

/// Record `n` events accepted onto `shard`.
#[inline]
pub fn shard_events_add(shard: usize, n: u64) {
    slot_add(&SHARD_EVENTS, shard, n);
}

/// Record one window scored on `shard`.
#[inline]
pub fn shard_window(shard: usize) {
    slot_add(&SHARD_WINDOWS, shard, 1);
}

/// Record one `WouldBlock` rejection from `shard` (also bumps the global
/// [`Counter::SvcWouldBlock`]).
#[inline]
pub fn shard_would_block(shard: usize) {
    slot_add(&SHARD_WOULD_BLOCK, shard, 1);
    Counter::SvcWouldBlock.inc();
}

/// Publish event loop `idx`'s current poll-set size.
#[inline]
pub fn set_loop_pollset(idx: usize, size: u64) {
    if let Some(c) = LOOP_POLLSET.get(idx % MAX_OBS_LOOPS) {
        c.store(size, Ordering::Relaxed);
    }
}

/// Histogram of window scoring latency (Algorithm 2, microseconds).
pub fn score_latency_us() -> &'static AtomicHistogram {
    static H: AtomicHistogram = AtomicHistogram::new();
    &H
}

/// Histogram of full request round-trips server-side (decode → reply
/// queued, microseconds), including any backpressure park.
pub fn request_us() -> &'static AtomicHistogram {
    static H: AtomicHistogram = AtomicHistogram::new();
    &H
}

/// Histogram of backpressure queue-wait (park → shard acceptance,
/// microseconds); empty while no command ever parks.
pub fn queue_wait_us() -> &'static AtomicHistogram {
    static H: AtomicHistogram = AtomicHistogram::new();
    &H
}

/// Record one scored window from the scoring hot path: latency into
/// [`score_latency_us`] (striped by `stripe`), the window counter, and the
/// anomaly counter when the detector fired.
#[inline]
pub fn score_window(latency_us: u64, anomalous: bool, stripe: usize) {
    score_latency_us().record(stripe, latency_us);
    Counter::ScoreWindows.inc();
    if anomalous {
        Counter::ScoreAnomalies.inc();
    }
}

// lint: hot-path end

/// Declare the number of live service shards (rendering shows this many
/// per-shard slots). Keeps the maximum it has seen.
pub fn note_shards(n: usize) {
    SHARD_COUNT.fetch_max(n.min(MAX_OBS_SHARDS), Ordering::Relaxed);
}

/// Declare the number of live event loops.
pub fn note_loops(n: usize) {
    LOOP_COUNT.fetch_max(n.min(MAX_OBS_LOOPS), Ordering::Relaxed);
}

/// The live per-shard event totals (one entry per noted shard). Their sum
/// equals `ServiceReport.events_submitted` for a single-service process.
pub fn shard_event_counts() -> Vec<u64> {
    let n = SHARD_COUNT.load(Ordering::Relaxed);
    SHARD_EVENTS.iter().take(n).map(|c| c.load(Ordering::Relaxed)).collect()
}

/// Everything the registry knows, as a typed report: the payload of the
/// `METRICS` wire verb (`Reply::Metrics`) and the core of the JSON
/// snapshot. Key order is deterministic: counters, gauges, then per-shard
/// and per-loop slots in index order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsReport {
    /// Flat `name → value` pairs (counters, gauges, slots, plus whatever
    /// server-derived pairs the builder appends, e.g. `uptime_ms`).
    pub pairs: Vec<(String, u64)>,
    /// Histograms in sparse encoded form.
    pub hists: Vec<WireHist>,
}

/// One histogram in the sparse form that travels on the wire and into
/// snapshots: `(bucket index, count)` pairs ascending by index, bucket
/// semantics shared with [`stats::bucket_index`].
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct WireHist {
    pub name: String,
    /// Total samples (sum of the bucket counts).
    pub count: u64,
    pub buckets: Vec<(u32, u64)>,
}

impl WireHist {
    /// Encode a dense histogram sparsely under `name`.
    pub fn from_histogram(name: &str, h: &stats::Histogram) -> Self {
        Self {
            name: name.to_string(),
            count: h.count(),
            buckets: h.nonzero_buckets().map(|(i, c)| (i as u32, c)).collect(),
        }
    }

    /// Reconstruct the dense histogram (exact: both sides index with
    /// [`stats::bucket_index`]).
    pub fn to_histogram(&self) -> stats::Histogram {
        let mut h = stats::Histogram::new();
        for &(i, c) in &self.buckets {
            h.add_count(i as usize, c);
        }
        h
    }
}

/// Render the whole registry. `extra` pairs (server-derived values such as
/// `uptime_ms` or `shards`) are appended after the registry's own, so the
/// registry portion of the key sequence is identical no matter who asks.
pub fn report(extra: &[(String, u64)]) -> MetricsReport {
    let mut pairs: Vec<(String, u64)> = Vec::new();
    for c in COUNTERS {
        pairs.push((c.name().to_string(), c.get()));
    }
    for g in GAUGES {
        pairs.push((g.name().to_string(), g.get()));
    }
    let shards = SHARD_COUNT.load(Ordering::Relaxed);
    for (i, (ev, (win, wb))) in SHARD_EVENTS
        .iter()
        .zip(SHARD_WINDOWS.iter().zip(SHARD_WOULD_BLOCK.iter()))
        .take(shards)
        .enumerate()
    {
        pairs.push((format!("shard{i}_events"), ev.load(Ordering::Relaxed)));
        pairs.push((format!("shard{i}_windows"), win.load(Ordering::Relaxed)));
        pairs.push((format!("shard{i}_would_block"), wb.load(Ordering::Relaxed)));
    }
    let loops = LOOP_COUNT.load(Ordering::Relaxed);
    for (i, c) in LOOP_POLLSET.iter().take(loops).enumerate() {
        pairs.push((format!("loop{i}_pollset"), c.load(Ordering::Relaxed)));
    }
    pairs.extend(extra.iter().cloned());
    let hists = vec![
        WireHist::from_histogram("score_latency_us", &score_latency_us().snapshot()),
        WireHist::from_histogram("request_us", &request_us().snapshot()),
        WireHist::from_histogram("queue_wait_us", &queue_wait_us().snapshot()),
    ];
    MetricsReport { pairs, hists }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and production code records into it
    // from other unit tests running concurrently in this binary, so the
    // assertions below are monotone (`>=`), never exact before/after.

    #[test]
    fn counters_accumulate_and_name_stably() {
        let before = Counter::NetAccepted.get();
        Counter::NetAccepted.inc();
        Counter::NetAccepted.add(2);
        assert!(Counter::NetAccepted.get() >= before + 3);
        assert_eq!(Counter::NetAccepted.name(), "net_accepted");
        assert_eq!(COUNTERS.len(), 21);
        // names are unique (each variant has its own cell and wire key)
        let mut names: Vec<&str> = COUNTERS.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), COUNTERS.len());
    }

    #[test]
    fn gauge_saturates_at_zero() {
        // NetConnections is only recorded by the event loops, which no lib
        // unit test runs — drain it, then go below zero on purpose
        for _ in 0..10_000 {
            Gauge::NetConnections.dec();
        }
        assert_eq!(Gauge::NetConnections.get(), 0, "dec must saturate, not wrap");
        Gauge::NetConnections.inc();
        assert!(Gauge::NetConnections.get() >= 1);
        Gauge::NetConnections.dec();
    }

    #[test]
    fn shard_slots_fold_modulo_capacity() {
        let base: u64 = shard_event_counts().iter().sum();
        note_shards(4);
        shard_events_add(1, 5);
        shard_events_add(1 + MAX_OBS_SHARDS, 7); // folds onto slot 1
        let sum: u64 = shard_event_counts().iter().sum();
        assert!(sum >= base + 12, "folded shard still lands in a live slot");
    }

    #[test]
    fn report_orders_registry_keys_deterministically() {
        note_shards(2);
        note_loops(1);
        let r1 = report(&[("uptime_ms".to_string(), 1)]);
        let r2 = report(&[("uptime_ms".to_string(), 2)]);
        let keys1: Vec<&String> = r1.pairs.iter().map(|(k, _)| k).collect();
        let keys2: Vec<&String> = r2.pairs.iter().map(|(k, _)| k).collect();
        assert_eq!(keys1, keys2);
        assert_eq!(keys1.first().map(|s| s.as_str()), Some("net_accepted"));
        assert!(keys1.iter().any(|k| *k == "shard1_windows"));
        assert!(keys1.iter().any(|k| *k == "loop0_pollset"));
        assert_eq!(keys1.last().map(|s| s.as_str()), Some("uptime_ms"));
        assert_eq!(r1.hists.len(), 3);
        assert_eq!(r1.hists.first().map(|h| h.name.as_str()), Some("score_latency_us"));
    }

    #[test]
    fn wire_hist_roundtrips_exactly() {
        let mut h = crate::util::stats::Histogram::new();
        for v in [0u64, 3, 17, 999, 1_000_000] {
            h.record(v);
        }
        let w = WireHist::from_histogram("t", &h);
        assert_eq!(w.count, 5);
        assert_eq!(w.to_histogram(), h);
    }

    #[test]
    fn score_window_feeds_counter_and_histogram() {
        let wins = Counter::ScoreWindows.get();
        let anom = Counter::ScoreAnomalies.get();
        let hist = score_latency_us().snapshot().count();
        score_window(120, true, 0);
        score_window(80, false, 3);
        assert!(Counter::ScoreWindows.get() >= wins + 2);
        assert!(Counter::ScoreAnomalies.get() >= anom + 1);
        assert!(score_latency_us().snapshot().count() >= hist + 2);
    }
}
