//! Shrink-only baseline I/O. The baseline file carries pre-existing
//! violations so the lint can land blocking; every entry names its rule,
//! site, and a written reason. CI checks the file only ever *shrinks*
//! relative to `main` — new code never gets baselined, it gets fixed or
//! carries an inline waiver.
//!
//! Format, one entry per line (`#` comments and blank lines ignored):
//!
//! ```text
//! FL001 rust/src/stream/pipeline.rs:113 worker join at pipeline finish is fail-fast by design
//! ```

use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct BaselineEntry {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub reason: String,
}

#[derive(Debug, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

fn rule_id_ok(id: &str) -> bool {
    let b = id.as_bytes();
    b.len() == 5 && b[0] == b'F' && b[1] == b'L' && b[2..].iter().all(u8::is_ascii_digit)
}

impl Baseline {
    pub fn parse(text: &str) -> Result<Baseline> {
        let mut entries = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (rule, rest) = line
                .split_once(char::is_whitespace)
                .with_context(|| format!("baseline line {lineno}: want `RULE path:line reason`"))?;
            if !rule_id_ok(rule) {
                bail!("baseline line {lineno}: malformed rule id `{rule}`");
            }
            let rest = rest.trim_start();
            let (site, reason) = rest
                .split_once(char::is_whitespace)
                .with_context(|| format!("baseline line {lineno}: entry needs a written reason"))?;
            let (path, site_line) = site
                .rsplit_once(':')
                .with_context(|| format!("baseline line {lineno}: site must be `path:line`"))?;
            let site_line: u32 = site_line
                .parse()
                .with_context(|| format!("baseline line {lineno}: bad line number in `{site}`"))?;
            let reason = reason.trim();
            if reason.is_empty() {
                bail!("baseline line {lineno}: entry needs a written reason");
            }
            entries.push(BaselineEntry {
                rule: rule.to_string(),
                path: path.to_string(),
                line: site_line,
                reason: reason.to_string(),
            });
        }
        Ok(Baseline { entries })
    }

    /// Load from a file; a missing file is an empty baseline.
    pub fn load(path: &Path) -> Result<Baseline> {
        match std::fs::read_to_string(path) {
            Ok(text) => {
                Self::parse(&text).with_context(|| format!("parse {}", path.display()))
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Baseline::default()),
            Err(e) => Err(e).with_context(|| format!("read {}", path.display())),
        }
    }

    /// Index of the entry covering a diagnostic, if any.
    pub fn find(&self, rule: &str, path: &str, line: u32) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| e.rule == rule && e.path == path && e.line == line)
    }

    pub fn render(&self) -> String {
        let mut out = String::from(
            "# finger lint baseline — shrink-only: entries may be removed (by fixing or\n\
             # inline-waiving the site), never added. Format: RULE path:line reason\n",
        );
        for e in &self.entries {
            out.push_str(&format!("{} {}:{} {}\n", e.rule, e.path, e.line, e.reason));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_entries_and_comments() {
        let text = "# header\n\
                    \n\
                    FL001 rust/src/net/x.rs:12 cold-start only\n\
                    FL003 rust/src/a.rs:3 exact sentinel\n";
        let b = Baseline::parse(text).unwrap();
        assert_eq!(b.entries.len(), 2);
        assert_eq!(b.find("FL001", "rust/src/net/x.rs", 12), Some(0));
        assert_eq!(b.find("FL001", "rust/src/net/x.rs", 13), None);
        assert_eq!(b.entries[1].reason, "exact sentinel");
    }

    #[test]
    fn rejects_entries_without_reason() {
        assert!(Baseline::parse("FL001 rust/src/net/x.rs:12\n").is_err());
        assert!(Baseline::parse("FL001 rust/src/net/x.rs:12   \n").is_err());
    }

    #[test]
    fn rejects_bad_rule_or_site() {
        assert!(Baseline::parse("FLX01 a.rs:1 reason\n").is_err());
        assert!(Baseline::parse("FL001 a.rs reason\n").is_err());
        assert!(Baseline::parse("FL001 a.rs:zz reason\n").is_err());
    }

    #[test]
    fn render_round_trips() {
        let text = "FL002 rust/src/entropy/x.rs:9 carried from before the hot marker\n";
        let b = Baseline::parse(text).unwrap();
        let again = Baseline::parse(&b.render()).unwrap();
        assert_eq!(again.entries.len(), 1);
        assert_eq!(again.entries[0].line, 9);
    }
}
