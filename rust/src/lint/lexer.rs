//! A small hand-rolled Rust lexer — just enough fidelity for the `finger
//! lint` rules: cooked/raw/byte strings, char-literal vs. lifetime
//! disambiguation, nested block comments, float vs. integer literals and
//! multi-character operators, with 1-based line/column tracking.
//!
//! The lexer is deliberately forgiving: it never panics on arbitrary input
//! (see the property test in `tests/lint_integration.rs`) and only reports an
//! error for constructs it cannot find the end of (unterminated strings,
//! char literals and block comments). Everything else — including invalid
//! Rust — tokenizes to *something*, which is all the rule engine needs.

/// Token classification. `Punct` covers operators and delimiters; multi-char
/// operators (`==`, `!=`, `::`, `->`, …) lex as a single token so rules can
/// match on exact operator text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    Ident,
    Lifetime,
    Int,
    Float,
    Str,
    Char,
    LineComment,
    BlockComment,
    Punct,
}

/// A token: byte span into the source plus the 1-based line/column where it
/// starts (columns count bytes, matching rustc's default for ASCII source).
#[derive(Debug, Clone, Copy)]
pub struct Token {
    pub kind: TokenKind,
    pub start: usize,
    pub end: usize,
    pub line: u32,
    pub col: u32,
}

impl Token {
    /// The token's text. Spans always fall on char boundaries by
    /// construction, but slice defensively anyway.
    pub fn text<'a>(&self, src: &'a str) -> &'a str {
        src.get(self.start..self.end).unwrap_or("")
    }
}

/// The only lexer failure mode: a construct with no terminator before EOF.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LexError {
    pub what: &'static str,
    pub line: u32,
}

impl std::fmt::Display for LexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unterminated {} starting on line {}", self.what, self.line)
    }
}

impl std::error::Error for LexError {}

const THREE_BYTE_OPS: &[&[u8]] = &[b"<<=", b">>=", b"..=", b"..."];
const TWO_BYTE_OPS: &[&[u8]] = &[
    b"::", b"->", b"=>", b"==", b"!=", b"<=", b">=", b"&&", b"||", b"<<", b">>", b"+=", b"-=",
    b"*=", b"/=", b"%=", b"^=", b"&=", b"|=", b"..",
];

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_' || c >= 0x80
}

fn is_ident_continue(c: u8) -> bool {
    is_ident_start(c) || c.is_ascii_digit()
}

/// True when the single char starting at `b[j]` is immediately followed by a
/// closing quote — i.e. `'x'` is a char literal, not the lifetime `'x`.
fn char_closes(b: &[u8], j: usize) -> bool {
    let c = match b.get(j) {
        Some(&c) => c,
        None => return false,
    };
    let len = if c < 0x80 {
        1
    } else if c < 0xE0 {
        2
    } else if c < 0xF0 {
        3
    } else {
        4
    };
    b.get(j + len) == Some(&b'\'')
}

/// True when `b[j..]` is `#`* followed by `"` — distinguishes the raw string
/// `r#"…"#` from the raw identifier `r#fn`.
fn raw_follows(b: &[u8], mut j: usize) -> bool {
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    col: u32,
    toks: Vec<Token>,
}

impl Lexer<'_> {
    fn peek(&self, k: usize) -> u8 {
        self.b.get(self.i + k).copied().unwrap_or(0)
    }

    fn bump(&mut self) {
        if let Some(&c) = self.b.get(self.i) {
            self.i += 1;
            if c == b'\n' {
                self.line += 1;
                self.col = 1;
            } else {
                self.col += 1;
            }
        }
    }

    fn push(&mut self, kind: TokenKind, start: usize, line: u32, col: u32) {
        self.toks.push(Token { kind, start, end: self.i, line, col });
    }

    fn block_comment(&mut self, start: usize, line: u32, col: u32) -> Result<(), LexError> {
        self.bump();
        self.bump();
        let mut depth = 1u32;
        while depth > 0 {
            if self.i >= self.b.len() {
                return Err(LexError { what: "block comment", line });
            }
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                self.bump();
                self.bump();
                depth += 1;
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                self.bump();
                self.bump();
                depth -= 1;
            } else {
                self.bump();
            }
        }
        self.push(TokenKind::BlockComment, start, line, col);
        Ok(())
    }

    /// Cooked string; a leading `b` prefix, if any, was consumed by the
    /// caller and `self.i` sits on the opening quote.
    fn string(&mut self, start: usize, line: u32, col: u32) -> Result<(), LexError> {
        self.bump();
        loop {
            if self.i >= self.b.len() {
                return Err(LexError { what: "string", line });
            }
            match self.peek(0) {
                b'\\' => {
                    self.bump();
                    self.bump();
                }
                b'"' => {
                    self.bump();
                    break;
                }
                _ => self.bump(),
            }
        }
        self.push(TokenKind::Str, start, line, col);
        Ok(())
    }

    /// Raw string starting at the `r`/`br` prefix.
    fn raw_string(&mut self, start: usize, line: u32, col: u32) -> Result<(), LexError> {
        while matches!(self.peek(0), b'r' | b'b') {
            self.bump();
        }
        let mut hashes = 0usize;
        while self.peek(0) == b'#' {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        loop {
            if self.i >= self.b.len() {
                return Err(LexError { what: "raw string", line });
            }
            if self.peek(0) == b'"' {
                self.bump();
                let mut seen = 0usize;
                while seen < hashes && self.peek(0) == b'#' {
                    self.bump();
                    seen += 1;
                }
                if seen == hashes {
                    break;
                }
            } else {
                self.bump();
            }
        }
        self.push(TokenKind::Str, start, line, col);
        Ok(())
    }

    /// `self.i` sits on a `'`: either a char literal or a lifetime.
    fn char_or_lifetime(&mut self, start: usize, line: u32, col: u32) -> Result<(), LexError> {
        self.bump(); // opening quote
        let c = self.peek(0);
        if is_ident_start(c) && c != b'\\' && !char_closes(self.b, self.i) {
            self.bump();
            while is_ident_continue(self.peek(0)) {
                self.bump();
            }
            self.push(TokenKind::Lifetime, start, line, col);
            return Ok(());
        }
        if c == b'\\' {
            self.bump();
            self.bump();
        }
        while self.i < self.b.len() && self.peek(0) != b'\'' {
            if self.peek(0) == b'\n' {
                return Err(LexError { what: "char literal", line });
            }
            self.bump();
        }
        if self.i >= self.b.len() {
            return Err(LexError { what: "char literal", line });
        }
        self.bump(); // closing quote
        self.push(TokenKind::Char, start, line, col);
        Ok(())
    }

    fn number(&mut self, start: usize, line: u32, col: u32) {
        let mut kind = TokenKind::Int;
        if self.peek(0) == b'0' && matches!(self.peek(1), b'x' | b'o' | b'b') {
            self.bump();
            self.bump();
            while self.peek(0).is_ascii_hexdigit() || self.peek(0) == b'_' {
                self.bump();
            }
        } else {
            while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                self.bump();
            }
            if self.peek(0) == b'.' && self.peek(1).is_ascii_digit() {
                kind = TokenKind::Float;
                self.bump();
                while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                    self.bump();
                }
            } else if self.peek(0) == b'.' && self.peek(1) != b'.' && !is_ident_start(self.peek(1))
            {
                // trailing-dot float like `1.`
                kind = TokenKind::Float;
                self.bump();
            }
            let exp_digits = self.peek(1).is_ascii_digit()
                || (matches!(self.peek(1), b'+' | b'-') && self.peek(2).is_ascii_digit());
            if matches!(self.peek(0), b'e' | b'E') && exp_digits {
                kind = TokenKind::Float;
                self.bump();
                self.bump();
                while self.peek(0).is_ascii_digit() || self.peek(0) == b'_' {
                    self.bump();
                }
            }
        }
        // type suffix (`f64`, `u32`, …): an `f` prefix forces float
        if is_ident_start(self.peek(0)) {
            let sfx = self.i;
            while is_ident_continue(self.peek(0)) {
                self.bump();
            }
            if self.b.get(sfx) == Some(&b'f') {
                kind = TokenKind::Float;
            }
        }
        self.push(kind, start, line, col);
    }

    fn punct(&mut self, start: usize, line: u32, col: u32) {
        let rest = &self.b[self.i..];
        let mut n = 1usize;
        if THREE_BYTE_OPS.iter().any(|op| rest.starts_with(op)) {
            n = 3;
        } else if TWO_BYTE_OPS.iter().any(|op| rest.starts_with(op)) {
            n = 2;
        }
        for _ in 0..n {
            self.bump();
        }
        self.push(TokenKind::Punct, start, line, col);
    }
}

/// Tokenize `src`. Comments are kept as tokens (the rule engine reads region
/// markers and waivers out of them); whitespace is dropped.
pub fn lex(src: &str) -> Result<Vec<Token>, LexError> {
    let mut lx = Lexer { b: src.as_bytes(), i: 0, line: 1, col: 1, toks: Vec::new() };
    while lx.i < lx.b.len() {
        let (start, line, col) = (lx.i, lx.line, lx.col);
        let c = lx.peek(0);
        if c.is_ascii_whitespace() {
            lx.bump();
        } else if c == b'/' && lx.peek(1) == b'/' {
            while lx.i < lx.b.len() && lx.peek(0) != b'\n' {
                lx.bump();
            }
            lx.push(TokenKind::LineComment, start, line, col);
        } else if c == b'/' && lx.peek(1) == b'*' {
            lx.block_comment(start, line, col)?;
        } else if c == b'r'
            && (lx.peek(1) == b'"' || (lx.peek(1) == b'#' && raw_follows(lx.b, lx.i + 1)))
        {
            lx.raw_string(start, line, col)?;
        } else if c == b'b'
            && lx.peek(1) == b'r'
            && (lx.peek(2) == b'"' || (lx.peek(2) == b'#' && raw_follows(lx.b, lx.i + 2)))
        {
            lx.raw_string(start, line, col)?;
        } else if c == b'b' && lx.peek(1) == b'"' {
            lx.bump();
            lx.string(start, line, col)?;
        } else if c == b'b' && lx.peek(1) == b'\'' {
            lx.bump();
            lx.char_or_lifetime(start, line, col)?;
        } else if is_ident_start(c) {
            while is_ident_continue(lx.peek(0)) {
                lx.bump();
            }
            lx.push(TokenKind::Ident, start, line, col);
        } else if c.is_ascii_digit() {
            lx.number(start, line, col);
        } else if c == b'"' {
            lx.string(start, line, col)?;
        } else if c == b'\'' {
            lx.char_or_lifetime(start, line, col)?;
        } else {
            lx.punct(start, line, col);
        }
    }
    Ok(lx.toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds_and_texts(src: &str) -> Vec<(TokenKind, String)> {
        lex(src)
            .unwrap()
            .into_iter()
            .map(|t| (t.kind, t.text(src).to_string()))
            .collect()
    }

    #[test]
    fn operators_lex_as_single_tokens() {
        let got = kinds_and_texts("a == b != c -> d :: e");
        let texts: Vec<&str> = got.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(texts, ["a", "==", "b", "!=", "c", "->", "d", "::", "e"]);
    }

    #[test]
    fn float_vs_int_vs_range() {
        let got = kinds_and_texts("0..10 1.5 2. 3e4 5f64 6u32 0xAF 1_000");
        let expect = [
            (TokenKind::Int, "0"),
            (TokenKind::Punct, ".."),
            (TokenKind::Int, "10"),
            (TokenKind::Float, "1.5"),
            (TokenKind::Float, "2."),
            (TokenKind::Float, "3e4"),
            (TokenKind::Float, "5f64"),
            (TokenKind::Int, "6u32"),
            (TokenKind::Int, "0xAF"),
            (TokenKind::Int, "1_000"),
        ];
        assert_eq!(got.len(), expect.len());
        for ((gk, gs), (ek, es)) in got.iter().zip(expect.iter()) {
            assert_eq!((gk, gs.as_str()), (ek, *es));
        }
    }

    #[test]
    fn method_call_on_int_is_not_a_float() {
        let got = kinds_and_texts("1.max(2)");
        assert_eq!(got[0], (TokenKind::Int, "1".to_string()));
        assert_eq!(got[2], (TokenKind::Ident, "max".to_string()));
    }

    #[test]
    fn lifetime_vs_char_literal() {
        let got = kinds_and_texts("'a 'static '_ 'x' '\\n' b'z'");
        let expect = [
            (TokenKind::Lifetime, "'a"),
            (TokenKind::Lifetime, "'static"),
            (TokenKind::Lifetime, "'_"),
            (TokenKind::Char, "'x'"),
            (TokenKind::Char, "'\\n'"),
            (TokenKind::Char, "b'z'"),
        ];
        assert_eq!(got.len(), expect.len());
        for ((gk, gs), (ek, es)) in got.iter().zip(expect.iter()) {
            assert_eq!((gk, gs.as_str()), (ek, *es));
        }
    }

    #[test]
    fn strings_raw_strings_and_escapes() {
        let got = kinds_and_texts(r####"  "a \" b"  r"raw"  r#"has "quotes""#  b"bytes"  "####);
        assert_eq!(got.len(), 4);
        assert!(got.iter().all(|(k, _)| *k == TokenKind::Str));
        assert_eq!(got[2].1, r###"r#"has "quotes""#"###);
    }

    #[test]
    fn nested_block_comments() {
        let got = kinds_and_texts("/* outer /* inner */ still */ x");
        assert_eq!(got[0].0, TokenKind::BlockComment);
        assert_eq!(got[1], (TokenKind::Ident, "x".to_string()));
    }

    #[test]
    fn line_and_column_tracking() {
        let toks = lex("ab\n  cd\n").unwrap();
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
    }

    #[test]
    fn unterminated_string_is_an_error_not_a_panic() {
        assert!(lex("let s = \"oops").is_err());
        assert!(lex("/* never closed").is_err());
        assert!(lex("let c = '").is_err());
    }

    #[test]
    fn raw_identifier_is_not_a_raw_string() {
        let got = kinds_and_texts("r#fn");
        // lexes as `r`, `#`, `fn` — good enough for the rules, and crucially
        // not swallowed as an unterminated raw string
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], (TokenKind::Ident, "r".to_string()));
    }
}
