//! Per-file analysis layered on the token stream: which tokens are test-only
//! code (`#[cfg(test)]` items, `#[test]` fns, `mod tests` blocks), which sit
//! inside a hot-path region marker, which lines carry waivers, and which
//! functions in the file declare a bare `f64`/`f32` return type (the FL003
//! float-call registry).

use super::lexer::{lex, LexError, Token, TokenKind};
use std::collections::{BTreeMap, BTreeSet};

/// Region marker comment: a line comment containing this needle opens a
/// hot-path region; the same needle followed by `end` closes it.
pub const HOT_MARKER: &str = "lint: hot-path";
/// Region marker for readiness-driven event-loop code (FL006): inside,
/// blocking I/O calls would stall every connection the loop owns.
pub const EVENT_LOOP_MARKER: &str = "lint: event-loop";
/// Waiver comments start with this needle (anywhere in a line comment).
pub const WAIVER_MARKER: &str = "finger-lint";

/// Everything the rules need to know about one source file.
pub struct FileModel {
    /// Normalized path label (forward slashes) used in diagnostics.
    pub path: String,
    pub src: String,
    /// All tokens, comments included.
    pub tokens: Vec<Token>,
    /// Indices into `tokens` of non-comment tokens (the "code view").
    pub code: Vec<usize>,
    /// Per code-view position: token is inside test-only code.
    pub is_test: Vec<bool>,
    /// Per code-view position: token is inside a hot-path region.
    pub in_hot: Vec<bool>,
    /// Per code-view position: token is inside an event-loop region.
    pub in_event_loop: Vec<bool>,
    /// line number -> rule ids waived on that line (a waiver covers its own
    /// line and the next, so it works trailing or standalone-above).
    pub waivers: BTreeMap<u32, BTreeSet<String>>,
    /// Waiver comments that failed to parse: (line, problem).
    pub malformed: Vec<(u32, String)>,
    /// Functions declared in this file returning a bare `f64` / `f32`.
    pub float_fns: BTreeSet<String>,
}

/// A borrowed, index-safe view over the code tokens. Out-of-range lookups
/// (including `k.wrapping_sub(1)` at position 0) return `""` / `None` so
/// rule code never needs bounds arithmetic.
pub struct CodeView<'a> {
    pub src: &'a str,
    pub tokens: &'a [Token],
    pub code: &'a [usize],
}

impl CodeView<'_> {
    pub fn len(&self) -> usize {
        self.code.len()
    }

    pub fn is_empty(&self) -> bool {
        self.code.is_empty()
    }

    pub fn tok(&self, k: usize) -> Option<&Token> {
        self.code.get(k).and_then(|&i| self.tokens.get(i))
    }

    pub fn text(&self, k: usize) -> &str {
        self.tok(k).map(|t| t.text(self.src)).unwrap_or("")
    }

    pub fn kind(&self, k: usize) -> Option<TokenKind> {
        self.tok(k).map(|t| t.kind)
    }
}

impl FileModel {
    pub fn build(path: &str, src: String) -> Result<FileModel, LexError> {
        let tokens = lex(&src)?;
        let code: Vec<usize> = tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                !matches!(t.kind, TokenKind::LineComment | TokenKind::BlockComment)
            })
            .map(|(i, _)| i)
            .collect();
        let (in_hot, in_event_loop, waivers, malformed) = analyze_comments(&src, &tokens);
        let view = CodeView { src: &src, tokens: &tokens, code: &code };
        let is_test = analyze_test_regions(&view);
        let float_fns = analyze_float_fns(&view);
        Ok(FileModel {
            path: path.replace('\\', "/"),
            src,
            tokens,
            code,
            is_test,
            in_hot,
            in_event_loop,
            waivers,
            malformed,
            float_fns,
        })
    }

    pub fn view(&self) -> CodeView<'_> {
        CodeView { src: &self.src, tokens: &self.tokens, code: &self.code }
    }

    /// Is `rule` waived on `line`?
    pub fn waived(&self, line: u32, rule: &str) -> bool {
        self.waivers.get(&line).is_some_and(|s| s.contains(rule))
    }
}

type CommentAnalysis =
    (Vec<bool>, Vec<bool>, BTreeMap<u32, BTreeSet<String>>, Vec<(u32, String)>);

/// Single pass over all tokens: hot-path and event-loop region tracking
/// (per code-view position) plus waiver extraction from line comments.
fn analyze_comments(src: &str, tokens: &[Token]) -> CommentAnalysis {
    let mut hot = false;
    let mut event_loop = false;
    let mut in_hot = Vec::new();
    let mut in_event_loop = Vec::new();
    let mut waivers: BTreeMap<u32, BTreeSet<String>> = BTreeMap::new();
    let mut malformed = Vec::new();
    for t in tokens {
        match t.kind {
            TokenKind::LineComment => {
                let text = t.text(src);
                if let Some(p) = text.find(HOT_MARKER) {
                    hot = !text[p + HOT_MARKER.len()..].contains("end");
                }
                if let Some(p) = text.find(EVENT_LOOP_MARKER) {
                    event_loop = !text[p + EVENT_LOOP_MARKER.len()..].contains("end");
                }
                if let Some(p) = text.find(WAIVER_MARKER) {
                    match parse_waiver(&text[p..]) {
                        Ok(rules) => {
                            for r in rules {
                                waivers.entry(t.line).or_default().insert(r.clone());
                                waivers.entry(t.line + 1).or_default().insert(r);
                            }
                        }
                        Err(msg) => malformed.push((t.line, msg)),
                    }
                }
            }
            TokenKind::BlockComment => {}
            _ => {
                in_hot.push(hot);
                in_event_loop.push(event_loop);
            }
        }
    }
    (in_hot, in_event_loop, waivers, malformed)
}

/// Parse a waiver starting at the marker needle. The grammar after the
/// marker is `: allow(<rule>[, <rule>…]): <non-empty reason>`, where each
/// rule id is two letters + three digits (FL001, FL002, …).
fn parse_waiver(s: &str) -> Result<Vec<String>, String> {
    let s = s.strip_prefix(WAIVER_MARKER).unwrap_or(s);
    let s = s
        .trim_start()
        .strip_prefix(':')
        .ok_or_else(|| "expected `:` after `finger-lint`".to_string())?;
    let s = s
        .trim_start()
        .strip_prefix("allow")
        .ok_or_else(|| "expected `allow(...)`".to_string())?;
    let s = s
        .trim_start()
        .strip_prefix('(')
        .ok_or_else(|| "expected `(` after `allow`".to_string())?;
    let (ids, rest) = s.split_once(')').ok_or_else(|| "unclosed `allow(`".to_string())?;
    let rest = rest
        .trim_start()
        .strip_prefix(':')
        .ok_or_else(|| "waiver missing `: reason`".to_string())?;
    if rest.trim().is_empty() {
        return Err("waiver missing reason".to_string());
    }
    let mut rules = Vec::new();
    for id in ids.split(',') {
        let id = id.trim();
        let b = id.as_bytes();
        let well_formed = b.len() == 5
            && b[0] == b'F'
            && b[1] == b'L'
            && b[2..].iter().all(u8::is_ascii_digit);
        if !well_formed {
            return Err(format!("malformed rule id `{id}`"));
        }
        rules.push(id.to_string());
    }
    if rules.is_empty() {
        return Err("empty rule list".to_string());
    }
    Ok(rules)
}

/// Mark code-view tokens that live inside test-only regions: items carrying
/// `#[test]` / `#[cfg(test)]` / `#[cfg_attr(…, test, …)]` attributes and
/// `mod tests`-style inline modules. Regions attach to the next `{ … }`
/// block; a `;` at bracket depth 0 before any `{` cancels the attachment
/// (attributed `use` items, out-of-line mods).
fn analyze_test_regions(v: &CodeView) -> Vec<bool> {
    let n = v.len();
    let mut is_test = vec![false; n];
    let mut depth: u32 = 0;
    let mut pdepth: u32 = 0;
    let mut close_at: Vec<u32> = Vec::new();
    let mut pending = false;
    let mut k = 0;
    while k < n {
        let active = !close_at.is_empty();
        let tx = v.text(k);
        if tx == "#" && v.text(k + 1) == "[" {
            // scan the attribute, collecting idents
            let mut j = k + 2;
            let mut bdepth = 1i32;
            let mut first: Option<&str> = None;
            let mut has_test = false;
            while j < n && bdepth > 0 {
                let tj = v.text(j);
                match tj {
                    "[" => bdepth += 1,
                    "]" => bdepth -= 1,
                    _ => {
                        if v.kind(j) == Some(TokenKind::Ident) {
                            if first.is_none() {
                                first = Some(tj);
                            }
                            if tj == "test" {
                                has_test = true;
                            }
                        }
                    }
                }
                j += 1;
            }
            let is_test_attr = match first {
                Some("test") => true,
                Some("cfg") | Some("cfg_attr") => has_test,
                _ => false,
            };
            if is_test_attr {
                pending = true;
            }
            for slot in is_test.iter_mut().take(j.min(n)).skip(k) {
                *slot = active;
            }
            k = j;
            continue;
        }
        if tx == "mod" && v.kind(k + 1) == Some(TokenKind::Ident) {
            let name = v.text(k + 1);
            if name == "tests"
                || name == "test"
                || name.ends_with("_tests")
                || name.ends_with("_test")
            {
                pending = true;
            }
        }
        match tx {
            "{" => {
                depth += 1;
                if pending {
                    close_at.push(depth);
                    pending = false;
                }
            }
            "}" => {
                if close_at.last() == Some(&depth) {
                    close_at.pop();
                }
                depth = depth.saturating_sub(1);
            }
            "(" | "[" => pdepth += 1,
            ")" | "]" => pdepth = pdepth.saturating_sub(1),
            ";" if pdepth == 0 => pending = false,
            _ => {}
        }
        is_test[k] = active || !close_at.is_empty();
        k += 1;
    }
    is_test
}

/// Collect the names of `fn` items whose declared return type is exactly
/// `f64` or `f32`. Used by FL003 to catch float comparisons routed through
/// same-file helper calls (e.g. `assert_eq!(score(a), score(b))`).
fn analyze_float_fns(v: &CodeView) -> BTreeSet<String> {
    let n = v.len();
    let mut out = BTreeSet::new();
    let mut k = 0;
    while k < n {
        if v.text(k) == "fn" && v.kind(k + 1) == Some(TokenKind::Ident) {
            let name = v.text(k + 1).to_string();
            let mut j = k + 2;
            let mut pd = 0i32;
            let mut ret: Vec<&str> = Vec::new();
            let mut in_ret = false;
            while j < n {
                let tx = v.text(j);
                if tx == "(" || tx == "[" {
                    pd += 1;
                } else if tx == ")" || tx == "]" {
                    pd = (pd - 1).max(0);
                } else if pd == 0 && (tx == "{" || tx == ";") {
                    break;
                } else if pd == 0 && tx == "->" {
                    in_ret = true;
                    j += 1;
                    continue;
                } else if pd == 0 && tx == "where" {
                    in_ret = false;
                }
                if in_ret {
                    ret.push(tx);
                }
                j += 1;
            }
            if ret == ["f64"] || ret == ["f32"] {
                out.insert(name);
            }
            k = j;
            continue;
        }
        k += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(src: &str) -> FileModel {
        FileModel::build("virtual/test.rs", src.to_string()).unwrap()
    }

    #[test]
    fn cfg_test_mod_marks_tokens() {
        let m = model("fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\n");
        let v = m.view();
        let idx_live = (0..v.len()).find(|&k| v.text(k) == "live").unwrap();
        let idx_t = (0..v.len()).find(|&k| v.text(k) == "t").unwrap();
        assert!(!m.is_test[idx_live]);
        assert!(m.is_test[idx_t]);
    }

    #[test]
    fn test_attr_fn_marks_body_only() {
        let m = model("#[test]\nfn check() { body(); }\nfn live() { other(); }\n");
        let v = m.view();
        let idx_body = (0..v.len()).find(|&k| v.text(k) == "body").unwrap();
        let idx_other = (0..v.len()).find(|&k| v.text(k) == "other").unwrap();
        assert!(m.is_test[idx_body]);
        assert!(!m.is_test[idx_other]);
    }

    #[test]
    fn cfg_test_use_does_not_leak() {
        let m = model("#[cfg(test)]\nuse std::fmt;\nfn live() { body(); }\n");
        let v = m.view();
        let idx_body = (0..v.len()).find(|&k| v.text(k) == "body").unwrap();
        assert!(!m.is_test[idx_body]);
    }

    #[test]
    fn hot_region_markers() {
        let src = "fn a() { x(); }\n\
                   // lint: hot-path\n\
                   fn b() { y(); }\n\
                   // lint: hot-path end\n\
                   fn c() { z(); }\n";
        let m = model(src);
        let v = m.view();
        let at = |name: &str| (0..v.len()).find(|&k| v.text(k) == name).unwrap();
        assert!(!m.in_hot[at("x")]);
        assert!(m.in_hot[at("y")]);
        assert!(!m.in_hot[at("z")]);
    }

    #[test]
    fn event_loop_region_markers_track_independently_of_hot_path() {
        let src = "fn a() { x(); }\n\
                   // lint: event-loop\n\
                   fn b() { y(); }\n\
                   // lint: hot-path\n\
                   fn c() { z(); }\n\
                   // lint: hot-path end\n\
                   // lint: event-loop end\n\
                   fn d() { w(); }\n";
        let m = model(src);
        let v = m.view();
        let at = |name: &str| (0..v.len()).find(|&k| v.text(k) == name).unwrap();
        assert!(!m.in_event_loop[at("x")]);
        assert!(m.in_event_loop[at("y")] && !m.in_hot[at("y")]);
        assert!(m.in_event_loop[at("z")] && m.in_hot[at("z")]);
        assert!(!m.in_event_loop[at("w")] && !m.in_hot[at("w")]);
    }

    #[test]
    fn waiver_parses_and_covers_next_line() {
        let src = "// finger-lint: allow(FL001): guarded by loop bound\nfn f() {}\n";
        let m = model(src);
        assert!(m.waived(1, "FL001"));
        assert!(m.waived(2, "FL001"));
        assert!(!m.waived(3, "FL001"));
        assert!(!m.waived(2, "FL002"));
        assert!(m.malformed.is_empty());
    }

    #[test]
    fn waiver_without_reason_is_malformed() {
        let m = model("// finger-lint: allow(FL001):\nfn f() {}\n");
        assert_eq!(m.malformed.len(), 1);
        assert!(m.waivers.is_empty());
    }

    #[test]
    fn float_fn_registry() {
        let src = "pub fn score(a: &G) -> f64 { 0.0 }\n\
                   fn count() -> usize { 0 }\n\
                   fn pair() -> (f64, f64) { (0.0, 0.0) }\n";
        let m = model(src);
        assert!(m.float_fns.contains("score"));
        assert!(!m.float_fns.contains("count"));
        assert!(!m.float_fns.contains("pair"));
    }
}
