//! The FL001–FL007 rule set, evaluated over a [`FileModel`]'s code-token
//! view. Each rule is a token-pattern check — deliberately syntactic (no type
//! inference), tuned to this repo's invariants with waivers/baseline as the
//! escape hatch for the boundary cases a lexer cannot judge.

use super::model::{CodeView, FileModel};
use crate::lint::lexer::TokenKind;

/// A raw rule hit, before waivers/baseline are applied.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

/// Stable rule ids with the one-line invariant each guards (mirrored in
/// `docs/LINTS.md`).
pub const RULES: &[(&str, &str)] = &[
    ("FL001", "no panic paths (unwrap/expect/panic!/indexing) in service/, net/, stream/, obs/"),
    ("FL002", "no allocating calls inside `// lint: hot-path` regions"),
    ("FL003", "no `==`/`!=` (or assert_eq!) on float-typed expressions; compare bits"),
    ("FL004", "no unbounded mpsc::channel() where sync_channel preserves backpressure"),
    ("FL005", "no `.lock().unwrap()`; use `.lock().expect(\"context\")` or a policy helper"),
    ("FL006", "no blocking I/O calls inside `// lint: event-loop` regions"),
    ("FL007", "no raw `thread::sleep` in service/ or net/ code; route waits through net/backoff"),
];

/// Rust keywords that can legally precede `[` without it being an indexing
/// expression (`let [a, b] = ..`, `return [x]`, `in [..]`, …).
const KEYWORDS: &[&str] = &[
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move", "mut",
    "pub", "ref", "return", "self", "Self", "static", "struct", "super", "trait", "type",
    "unsafe", "use", "where", "while", "yield",
];

/// Method calls that allocate (FL002), matched as `.name(`.
const ALLOC_METHODS: &[&str] = &["clone", "collect", "to_owned", "to_string", "to_vec"];

/// Macros that allocate (FL002), matched as `name!`.
const ALLOC_MACROS: &[&str] = &["format", "vec"];

/// Container types whose `::new`/`::from`/`::with_capacity` constructors
/// count as allocating calls in a hot-path region (FL002).
const ALLOC_TYPES: &[&str] =
    &["Box", "BTreeMap", "BTreeSet", "HashMap", "HashSet", "String", "Vec", "VecDeque"];

/// Macros whose invocation panics (FL001).
const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented", "unreachable"];

/// Method calls that block the calling thread until the peer produces or
/// drains bytes (FL006), matched as `.name(`. A readiness-driven loop must
/// use buffered nonblocking reads (`ReadBuf::fill_from` + `Codec::decode`)
/// instead — one slow peer must never stall the loop. `set_read_timeout`
/// is in the list because needing a timeout implies a blocking read.
const BLOCKING_IO_METHODS: &[&str] = &[
    "read_exact",
    "read_line",
    "read_to_end",
    "read_to_string",
    "read_until",
    "set_read_timeout",
    "set_write_timeout",
];

/// Float-comparing assertion macros (FL003).
const FLOAT_ASSERT_MACROS: &[&str] =
    &["assert_eq", "assert_ne", "debug_assert_eq", "debug_assert_ne"];

/// True when `path` (normalized, repo-relative) is inside the panic-free
/// zone FL001 guards: a shard worker or connection thread panic takes every
/// session it carries down with it. `obs/` is in the zone because its
/// recorders run inside those same workers — metrics must never take a
/// request down.
fn in_panic_free_zone(path: &str) -> bool {
    path.starts_with("rust/src/service/")
        || path.starts_with("rust/src/net/")
        || path.starts_with("rust/src/stream/")
        || path.starts_with("rust/src/obs/")
        || path.starts_with("rust/src/durability/")
}

/// True when `path` is inside FL007's no-raw-sleep zone: retry cadences and
/// interval waits in serving code must route through `net/backoff` so every
/// wall-clock park is enumerable and chaos-deterministic. `backoff.rs`
/// itself is the one sanctioned seam.
fn in_sleep_free_zone(path: &str) -> bool {
    (path.starts_with("rust/src/service/") || path.starts_with("rust/src/net/"))
        && !path.ends_with("net/backoff.rs")
}

/// Whole files that are test/bench-only code: integration tests and benches
/// are fail-fast by design, so the panic- and channel-hygiene rules skip
/// them (FL003 still applies — score identity is asserted *in* tests).
fn is_test_file(path: &str) -> bool {
    path.starts_with("rust/tests/") || path.starts_with("rust/benches/")
}

/// Run every rule over one file. Waivers and the baseline are applied by the
/// runner, not here.
pub fn check_file(model: &FileModel) -> Vec<Finding> {
    let v = model.view();
    let test_file = is_test_file(&model.path);
    let panic_zone = in_panic_free_zone(&model.path);
    let sleep_zone = in_sleep_free_zone(&model.path);
    let mut out = Vec::new();
    for k in 0..v.len() {
        let in_test = test_file || model.is_test.get(k).copied().unwrap_or(false);
        if panic_zone && !in_test {
            fl001(&v, k, &mut out);
        }
        if sleep_zone && !in_test {
            fl007(&v, k, &mut out);
        }
        if model.in_hot.get(k).copied().unwrap_or(false) {
            fl002(&v, k, &mut out);
        }
        fl003(&v, k, &model.float_fns, &mut out);
        if !in_test {
            fl004(&v, k, &mut out);
            fl005(&v, k, &mut out);
        }
        if model.in_event_loop.get(k).copied().unwrap_or(false) {
            fl006(&v, k, &mut out);
        }
    }
    out
}

fn finding(v: &CodeView, k: usize, rule: &'static str, message: String) -> Finding {
    let (line, col) = v.tok(k).map(|t| (t.line, t.col)).unwrap_or((0, 0));
    Finding { rule, line, col, message }
}

fn fl001(v: &CodeView, k: usize, out: &mut Vec<Finding>) {
    let tx = v.text(k);
    let prev = v.text(k.wrapping_sub(1));
    if (tx == "unwrap" || tx == "expect") && prev == "." && v.text(k + 1) == "(" {
        out.push(finding(
            v,
            k,
            "FL001",
            format!("`.{tx}()` on a request path can kill a shared worker; propagate an error"),
        ));
        return;
    }
    if PANIC_MACROS.contains(&tx) && v.text(k + 1) == "!" && prev != "." {
        out.push(finding(
            v,
            k,
            "FL001",
            format!("`{tx}!` on a request path can kill a shared worker; return an error instead"),
        ));
        return;
    }
    if tx == "[" {
        let is_index = match v.kind(k.wrapping_sub(1)) {
            Some(TokenKind::Ident) => !KEYWORDS.contains(&prev),
            Some(TokenKind::Punct) => prev == ")" || prev == "]",
            _ => false,
        };
        if is_index {
            out.push(finding(
                v,
                k,
                "FL001",
                "indexing can panic on a request path; use `.get(..)` or waive bounds".to_string(),
            ));
        }
    }
}

fn fl002(v: &CodeView, k: usize, out: &mut Vec<Finding>) {
    let tx = v.text(k);
    let prev = v.text(k.wrapping_sub(1));
    if ALLOC_METHODS.contains(&tx) && prev == "." && v.text(k + 1) == "(" {
        out.push(finding(
            v,
            k,
            "FL002",
            format!("allocating call `.{tx}()` inside a `lint: hot-path` region"),
        ));
    } else if ALLOC_MACROS.contains(&tx) && v.text(k + 1) == "!" {
        out.push(finding(
            v,
            k,
            "FL002",
            format!("allocating macro `{tx}!` inside a `lint: hot-path` region"),
        ));
    } else if ALLOC_TYPES.contains(&tx)
        && v.text(k + 1) == "::"
        && matches!(v.text(k + 2), "new" | "from" | "with_capacity")
    {
        out.push(finding(
            v,
            k,
            "FL002",
            format!("allocating constructor `{tx}::{}` in a hot-path region", v.text(k + 2)),
        ));
    }
}

/// Does the operand *ending* at token `k` look float-typed? Either a float
/// literal, or `ident(..)` where `ident` is a registered `-> f64` fn.
fn float_operand_ends_at(
    v: &CodeView,
    k: usize,
    float_fns: &std::collections::BTreeSet<String>,
) -> bool {
    if v.kind(k) == Some(TokenKind::Float) {
        return true;
    }
    if v.text(k) == ")" {
        // walk back to the matching `(` and inspect the callee ident
        let mut depth = 0i32;
        let mut j = k;
        loop {
            match v.text(j) {
                ")" => depth += 1,
                "(" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            if j == 0 {
                return false;
            }
            j -= 1;
        }
        let callee = v.text(j.wrapping_sub(1));
        return v.kind(j.wrapping_sub(1)) == Some(TokenKind::Ident) && float_fns.contains(callee);
    }
    false
}

/// Does the operand *starting* at token `k` look float-typed?
fn float_operand_starts_at(
    v: &CodeView,
    k: usize,
    float_fns: &std::collections::BTreeSet<String>,
) -> bool {
    if v.kind(k) == Some(TokenKind::Float) {
        return true;
    }
    if v.text(k) == "-" && v.kind(k + 1) == Some(TokenKind::Float) {
        return true;
    }
    v.kind(k) == Some(TokenKind::Ident) && float_fns.contains(v.text(k)) && v.text(k + 1) == "("
}

fn fl003(
    v: &CodeView,
    k: usize,
    float_fns: &std::collections::BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    let tx = v.text(k);
    if tx == "==" || tx == "!=" {
        if float_operand_ends_at(v, k.wrapping_sub(1), float_fns)
            || float_operand_starts_at(v, k + 1, float_fns)
        {
            out.push(finding(
                v,
                k,
                "FL003",
                format!("float `{tx}` breaks bit-exactness; compare `.to_bits()` instead"),
            ));
        }
        return;
    }
    if FLOAT_ASSERT_MACROS.contains(&tx) && v.text(k + 1) == "!" && v.text(k + 2) == "(" {
        // scan the macro arguments for float evidence / a to_bits() escape
        let mut depth = 1i32;
        let mut j = k + 3;
        let mut evidence = false;
        let mut bits = false;
        while j < v.len() && depth > 0 {
            match v.text(j) {
                "(" => depth += 1,
                ")" => depth -= 1,
                "to_bits" => bits = true,
                t => {
                    if v.kind(j) == Some(TokenKind::Float)
                        || (v.kind(j) == Some(TokenKind::Ident)
                            && float_fns.contains(t)
                            && v.text(j + 1) == "(")
                    {
                        evidence = true;
                    }
                }
            }
            j += 1;
        }
        if evidence && !bits {
            out.push(finding(
                v,
                k,
                "FL003",
                format!("`{tx}!` on float args; use `assert_bits_eq!` for bit-exact comparison"),
            ));
        }
    }
}

fn fl004(v: &CodeView, k: usize, out: &mut Vec<Finding>) {
    let prev = v.text(k.wrapping_sub(1));
    // `channel()` or turbofish `channel::<T>()`; a bare `channel` in a `use`
    // list or a `fn channel` definition is not a call
    let called = v.text(k + 1) == "(" || (v.text(k + 1) == "::" && v.text(k + 2) == "<");
    if v.text(k) == "channel" && called && prev != "." && prev != "fn" {
        out.push(finding(
            v,
            k,
            "FL004",
            "unbounded `mpsc::channel()`; use `sync_channel` or waive rendezvous".to_string(),
        ));
    }
}

fn fl005(v: &CodeView, k: usize, out: &mut Vec<Finding>) {
    if v.text(k) == "."
        && v.text(k + 1) == "lock"
        && v.text(k + 2) == "("
        && v.text(k + 3) == ")"
        && v.text(k + 4) == "."
        && v.text(k + 5) == "unwrap"
    {
        out.push(finding(
            v,
            k + 1,
            "FL005",
            "`.lock().unwrap()` hides the poisoning policy; spell `.lock().expect(..)`".to_string(),
        ));
    }
}

fn fl006(v: &CodeView, k: usize, out: &mut Vec<Finding>) {
    let tx = v.text(k);
    let prev = v.text(k.wrapping_sub(1));
    if BLOCKING_IO_METHODS.contains(&tx) && prev == "." && v.text(k + 1) == "(" {
        out.push(finding(
            v,
            k,
            "FL006",
            format!("blocking `.{tx}()` in a `lint: event-loop` region stalls every connection"),
        ));
    }
}

fn fl007(v: &CodeView, k: usize, out: &mut Vec<Finding>) {
    // `thread::sleep(` with any path prefix (std::thread, module alias); the
    // sanctioned wrappers live in net/backoff.rs, which the zone exempts
    if v.text(k) == "thread"
        && v.text(k + 1) == "::"
        && v.text(k + 2) == "sleep"
        && v.text(k + 3) == "("
    {
        out.push(finding(
            v,
            k + 2,
            "FL007",
            "raw `thread::sleep` hides a wall-clock wait; use `net::backoff` helpers".to_string(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::model::FileModel;

    fn findings(path: &str, src: &str) -> Vec<(String, u32)> {
        let model = FileModel::build(path, src.to_string()).unwrap();
        check_file(&model).into_iter().map(|f| (f.rule.to_string(), f.line)).collect()
    }

    #[test]
    fn fl001_flags_unwrap_and_macros_in_zone_only() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\nfn g() { panic!(\"no\"); }\n";
        let got = findings("rust/src/service/x.rs", src);
        assert_eq!(got, vec![("FL001".to_string(), 1), ("FL001".to_string(), 2)]);
        assert!(findings("rust/src/graph/x.rs", src).is_empty(), "outside the zone");
    }

    #[test]
    fn fl001_indexing_but_not_attributes_or_array_types() {
        let src = "#[derive(Debug)]\n\
                   struct S { a: [u8; 4] }\n\
                   fn f(v: &[u32], k: usize) -> u32 { v[k] }\n\
                   fn g() -> [u8; 2] { [1, 2] }\n";
        let got = findings("rust/src/net/x.rs", src);
        assert_eq!(got, vec![("FL001".to_string(), 3)]);
    }

    #[test]
    fn fl001_skips_test_code() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) { x.unwrap(); }\n}\n";
        assert!(findings("rust/src/stream/x.rs", src).is_empty());
    }

    #[test]
    fn fl002_only_inside_hot_region() {
        let src = "fn cold() { let _ = vec![1]; }\n\
                   // lint: hot-path\n\
                   fn hot(v: &[u32]) -> Vec<u32> { v.to_vec() }\n\
                   // lint: hot-path end\n\
                   fn cold2() -> String { format!(\"x\") }\n";
        let got = findings("rust/src/entropy/x.rs", src);
        assert_eq!(got, vec![("FL002".to_string(), 3)]);
    }

    #[test]
    fn fl002_constructors() {
        let src = "// lint: hot-path\n\
                   fn hot() { let v = Vec::with_capacity(4); let b = Box::new(v); }\n\
                   // lint: hot-path end\n";
        let got = findings("rust/src/entropy/x.rs", src);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|(r, _)| r == "FL002"));
    }

    #[test]
    fn fl003_operator_on_float_literal_or_registered_fn() {
        let src = "fn score(x: u32) -> f64 { x as f64 }\n\
                   fn a(w: f64) -> bool { w == 0.0 }\n\
                   fn b(x: u32, y: u32) -> bool { score(x) == score(y) }\n\
                   fn c(x: u32, y: u32) -> bool { x == y }\n\
                   fn d(x: f64, y: f64) -> bool { x.to_bits() == y.to_bits() }\n";
        let got = findings("rust/src/distance/x.rs", src);
        assert_eq!(got, vec![("FL003".to_string(), 2), ("FL003".to_string(), 3)]);
    }

    #[test]
    fn fl003_assert_eq_with_float_args() {
        let src = "fn score() -> f64 { 1.0 }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() {\n\
                           assert_eq!(super::score(), 1.0);\n\
                           assert_eq!(super::score().to_bits(), 1.0f64.to_bits());\n\
                           assert_eq!(1 + 1, 2);\n\
                       }\n\
                   }\n";
        let got = findings("rust/src/distance/x.rs", src);
        // only the raw float assert_eq! on line 6 (the score() == 1.0 literal
        // inside it is part of the same macro; to_bits and int asserts pass)
        assert_eq!(got, vec![("FL003".to_string(), 6)]);
    }

    #[test]
    fn fl004_unbounded_channel_but_not_sync_channel() {
        let src = "use std::sync::mpsc::{channel, sync_channel};\n\
                   fn f() { let (_a, _b) = channel::<u32>(); }\n\
                   fn g() { let (_a, _b) = sync_channel::<u32>(1); }\n";
        let got = findings("rust/src/service/y.rs", src);
        assert_eq!(got.iter().filter(|(r, _)| r == "FL004").count(), 1);
    }

    #[test]
    fn fl005_lock_unwrap_anywhere_non_test() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n\
                   fn g(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().expect(\"poisoned\") }\n";
        let got = findings("rust/src/runtime/x.rs", src);
        assert_eq!(got, vec![("FL005".to_string(), 1)]);
    }

    #[test]
    fn fl006_blocking_io_only_inside_event_loop_region() {
        let src = "use std::io::{BufRead, Read};\n\
                   fn setup(s: &std::net::TcpStream) { s.set_read_timeout(None).ok(); }\n\
                   // lint: event-loop\n\
                   fn tick(r: &mut dyn BufRead, s: &mut String) { r.read_line(s).ok(); }\n\
                   // lint: event-loop end\n\
                   fn drain(r: &mut dyn Read, b: &mut [u8]) { r.read_exact(b).ok(); }\n";
        let got = findings("rust/src/net/server.rs", src);
        assert_eq!(got, vec![("FL006".to_string(), 4)]);
    }

    #[test]
    fn fl007_raw_sleep_in_zone_but_not_backoff_or_tests() {
        let src = "use std::time::Duration;\n\
                   fn wait() { std::thread::sleep(Duration::from_millis(5)); }\n\
                   fn ok() { crate::net::backoff::sleep_ms(5); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { std::thread::sleep(std::time::Duration::ZERO); }\n\
                   }\n";
        let got = findings("rust/src/net/server.rs", src);
        assert_eq!(got, vec![("FL007".to_string(), 2)]);
        assert_eq!(findings("rust/src/service/engine.rs", src).len(), 1);
        assert!(findings("rust/src/net/backoff.rs", src).is_empty(), "sanctioned seam");
        assert!(findings("rust/src/util/timer.rs", src).is_empty(), "outside the zone");
    }

    #[test]
    fn waivers_are_not_applied_here() {
        // check_file reports raw findings; the runner subtracts waivers
        let src = "// finger-lint: allow(FL004): rendezvous\n\
                   fn f() { let _ = channel::<u32>(); }\n";
        let got = findings("rust/src/service/z.rs", src);
        assert_eq!(got.iter().filter(|(r, _)| r == "FL004").count(), 1);
    }
}
