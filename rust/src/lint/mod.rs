//! `finger lint` — a first-party, dependency-free invariant lint over the
//! crate's own source (see `docs/LINTS.md` for the rule catalogue).
//!
//! The repo's load-bearing guarantees — bit-for-bit score identity across
//! layers, zero allocations per steady-state window, bounded-channel
//! backpressure, panic-free shard workers — were previously enforced only
//! dynamically (the bench's counting allocator, the tests that happen to
//! exercise a path). This pass makes them static and blocking: a hand-rolled
//! lexer ([`lexer`]) feeds a per-file model ([`model`]) and a rule engine
//! ([`rules`], FL001–FL005) emitting rustc-style `file:line:col` diagnostics.
//!
//! Escape hatches, in preference order: fix the code; an inline waiver
//! comment naming the rule and a written reason on (or the line above) the
//! offending line (see `docs/LINTS.md` for the grammar — spelling it out
//! here would itself parse as a waiver); or an entry in the shrink-only
//! baseline file ([`baseline`]).

pub mod baseline;
pub mod lexer;
pub mod model;
pub mod rules;

pub use baseline::Baseline;
pub use model::FileModel;
pub use rules::RULES;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Directories scanned, relative to the repo root. The vendored crate under
/// `rust/vendor/` is third-party code and deliberately out of scope, as are
/// test fixture files (seeded violations live under a `fixtures/` dir).
pub const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// A finding that survived waivers and the baseline (or an `FL000` meta
/// problem: lexer failure / malformed waiver — those have no escape hatch).
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{}: {}: {}", self.path, self.line, self.col, self.rule, self.message)
    }
}

#[derive(Debug, Clone)]
pub struct LintOptions {
    /// Repo root the scan roots hang off.
    pub root: PathBuf,
    /// Baseline file; relative paths resolve against `root`.
    pub baseline: Option<PathBuf>,
    /// Exit non-zero on surviving findings (CI mode).
    pub deny: bool,
}

impl LintOptions {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        LintOptions {
            root: root.into(),
            baseline: Some(PathBuf::from("lint-baseline.txt")),
            deny: false,
        }
    }

    /// Read the `[lint]` config section (`baseline`, `deny`).
    pub fn from_config(config: &crate::cli::Config) -> Self {
        let mut opts = LintOptions::new(".");
        if let Some(p) = config.get("lint.baseline") {
            opts.baseline = Some(PathBuf::from(p));
        }
        opts.deny = config.get_bool("lint.deny", false);
        opts
    }
}

pub struct LintReport {
    /// Surviving diagnostics, in (file, line, col) order.
    pub diagnostics: Vec<Diagnostic>,
    /// Findings suppressed by inline waivers.
    pub waived: usize,
    /// Findings suppressed by the baseline.
    pub baselined: usize,
    /// Baseline entries that matched nothing — stale, remove them.
    pub stale_baseline: Vec<String>,
    /// Files scanned.
    pub files: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn summary(&self) -> String {
        format!(
            "finger lint: {} finding(s), {} waived, {} baselined, {} file(s) scanned",
            self.diagnostics.len(),
            self.waived,
            self.baselined,
            self.files
        )
    }
}

/// Recursively collect `.rs` files under the scan roots, sorted for stable
/// diagnostic order. Directories named `fixtures` are skipped (seeded lint
/// violations for the golden tests live there).
pub fn collect_files(root: &Path) -> Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("read dir {}", dir.display()))?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                if p.file_name().is_some_and(|n| n == "fixtures") {
                    continue;
                }
                walk(&p, out)?;
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, &mut out)?;
        }
    }
    Ok(out)
}

/// Lint one source string under a path label (the label drives the
/// directory-scoped rules, so fixture tests can pretend to live anywhere).
/// Returns surviving diagnostics plus the count of waived findings. Never
/// fails: lexer errors and malformed waivers surface as `FL000` diagnostics.
pub fn lint_source(path_label: &str, src: String) -> (Vec<Diagnostic>, usize) {
    let model = match FileModel::build(path_label, src) {
        Ok(m) => m,
        Err(e) => {
            let d = Diagnostic {
                rule: "FL000".to_string(),
                path: path_label.replace('\\', "/"),
                line: e.line,
                col: 1,
                message: format!("lexer: {e}"),
            };
            return (vec![d], 0);
        }
    };
    let mut out = Vec::new();
    for (line, msg) in &model.malformed {
        out.push(Diagnostic {
            rule: "FL000".to_string(),
            path: model.path.clone(),
            line: *line,
            col: 1,
            message: format!("malformed waiver: {msg}"),
        });
    }
    let mut waived = 0usize;
    for f in rules::check_file(&model) {
        if model.waived(f.line, f.rule) {
            waived += 1;
            continue;
        }
        out.push(Diagnostic {
            rule: f.rule.to_string(),
            path: model.path.clone(),
            line: f.line,
            col: f.col,
            message: f.message,
        });
    }
    out.sort_by(|a, b| (a.line, a.col, &a.rule).cmp(&(b.line, b.col, &b.rule)));
    (out, waived)
}

fn resolve_baseline(opts: &LintOptions) -> Option<PathBuf> {
    opts.baseline.as_ref().map(|p| {
        if p.is_absolute() {
            p.clone()
        } else {
            opts.root.join(p)
        }
    })
}

/// Run the full pass over the repo at `opts.root`.
pub fn run(opts: &LintOptions) -> Result<LintReport> {
    let files = collect_files(&opts.root)?;
    let base = match resolve_baseline(opts) {
        Some(p) => Baseline::load(&p)?,
        None => Baseline::default(),
    };
    let mut used = vec![false; base.entries.len()];
    let mut diagnostics = Vec::new();
    let mut waived = 0usize;
    let mut baselined = 0usize;
    for path in &files {
        let rel = path.strip_prefix(&opts.root).unwrap_or(path);
        let label = rel.to_string_lossy().replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let (diags, w) = lint_source(&label, src);
        waived += w;
        for d in diags {
            if d.rule != "FL000" {
                if let Some(i) = base.find(&d.rule, &d.path, d.line) {
                    used[i] = true;
                    baselined += 1;
                    continue;
                }
            }
            diagnostics.push(d);
        }
    }
    let stale_baseline = base
        .entries
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(e, _)| format!("{} {}:{} {}", e.rule, e.path, e.line, e.reason))
        .collect();
    Ok(LintReport { diagnostics, waived, baselined, stale_baseline, files: files.len() })
}

/// Render surviving diagnostics as a baseline file (for `--write-baseline`
/// when first adopting the lint on a branch with pre-existing findings).
pub fn render_as_baseline(diags: &[Diagnostic]) -> String {
    let entries = diags
        .iter()
        .filter(|d| d.rule != "FL000")
        .map(|d| baseline::BaselineEntry {
            rule: d.rule.clone(),
            path: d.path.clone(),
            line: d.line,
            reason: "carried over at lint introduction; fix or justify".to_string(),
        })
        .collect();
    Baseline { entries }.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_source_applies_waivers() {
        let src = "// finger-lint: allow(FL004): rendezvous reply, one message\n\
                   fn f() { let _ = channel::<u32>(); }\n\
                   fn g() { let _ = channel::<u32>(); }\n";
        let (diags, waived) = lint_source("rust/src/service/x.rs", src.to_string());
        assert_eq!(waived, 1, "line-2 use is covered by the waiver");
        assert_eq!(diags.len(), 1, "line-3 use is not");
        assert_eq!((diags[0].rule.as_str(), diags[0].line), ("FL004", 3));
    }

    #[test]
    fn malformed_waiver_is_fl000() {
        let src = "// finger-lint: allow(FL001)\nfn f() {}\n";
        let (diags, _) = lint_source("rust/src/a.rs", src.to_string());
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "FL000");
    }

    #[test]
    fn lexer_error_is_fl000_not_a_crash() {
        let (diags, _) = lint_source("rust/src/a.rs", "let s = \"oops".to_string());
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "FL000");
        assert!(diags[0].message.contains("unterminated"));
    }

    #[test]
    fn diagnostic_display_is_rustc_style() {
        let d = Diagnostic {
            rule: "FL001".to_string(),
            path: "rust/src/net/server.rs".to_string(),
            line: 12,
            col: 9,
            message: "boom".to_string(),
        };
        assert_eq!(d.to_string(), "rust/src/net/server.rs:12:9: FL001: boom");
    }
}
