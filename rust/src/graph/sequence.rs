//! Graph sequences {G_t} — either materialized snapshots or an initial graph
//! plus a delta stream {ΔG_t} (the two presentations the paper's Algorithms 1
//! and 2 consume).

use super::{DeltaGraph, Graph};

/// A sequence of graph snapshots with known node correspondence.
#[derive(Debug, Clone, Default)]
pub struct GraphSequence {
    snapshots: Vec<Graph>,
}

impl GraphSequence {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_snapshots(snapshots: Vec<Graph>) -> Self {
        Self { snapshots }
    }

    /// Materialize from an initial graph and deltas: G_{t+1} = G_t ⊕ ΔG_t.
    pub fn from_deltas(initial: Graph, deltas: &[DeltaGraph]) -> Self {
        let mut snapshots = Vec::with_capacity(deltas.len() + 1);
        let mut g = initial;
        snapshots.push(g.clone());
        for d in deltas {
            d.apply_to(&mut g);
            snapshots.push(g.clone());
        }
        Self { snapshots }
    }

    pub fn push(&mut self, g: Graph) {
        self.snapshots.push(g);
    }

    pub fn len(&self) -> usize {
        self.snapshots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.snapshots.is_empty()
    }

    pub fn get(&self, t: usize) -> &Graph {
        &self.snapshots[t]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Graph> {
        self.snapshots.iter()
    }

    /// Consecutive pairs (G_t, G_{t+1}).
    pub fn pairs(&self) -> impl Iterator<Item = (&Graph, &Graph)> {
        self.snapshots.windows(2).map(|w| (&w[0], &w[1]))
    }

    /// Recover the delta stream between consecutive snapshots.
    pub fn to_deltas(&self) -> Vec<DeltaGraph> {
        self.pairs().map(|(a, b)| DeltaGraph::diff(a, b)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn from_deltas_materializes() {
        let g0 = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let mut d1 = DeltaGraph::new();
        d1.add(1, 2, 2.0);
        let mut d2 = DeltaGraph::new();
        d2.add(0, 1, -1.0);
        let seq = GraphSequence::from_deltas(g0, &[d1, d2]);
        assert_eq!(seq.len(), 3);
        assert_bits_eq!(seq.get(1).weight(1, 2), 2.0);
        assert_eq!(seq.get(2).num_edges(), 1);
    }

    #[test]
    fn pairs_count() {
        let seq = GraphSequence::from_snapshots(vec![Graph::new(2), Graph::new(2), Graph::new(2)]);
        assert_eq!(seq.pairs().count(), 2);
    }

    #[test]
    fn to_deltas_roundtrip() {
        let a = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let b = Graph::from_edges(3, &[(0, 1, 3.0), (1, 2, 1.0)]);
        let c = Graph::from_edges(3, &[(1, 2, 1.0)]);
        let seq = GraphSequence::from_snapshots(vec![a.clone(), b, c]);
        let deltas = seq.to_deltas();
        let rebuilt = GraphSequence::from_deltas(a, &deltas);
        for t in 0..3 {
            let (x, y) = (seq.get(t), rebuilt.get(t));
            assert_eq!(x.num_edges(), y.num_edges(), "t={t}");
            for (i, j, w) in x.edges() {
                assert!((y.weight(i, j) - w).abs() < 1e-12);
            }
        }
    }
}
