//! Compressed sparse row view of a graph's weight matrix W (symmetric), used
//! by the spectral kernels (power iteration, Lanczos): one flat contiguous
//! array instead of per-node rows, so repeated mat-vecs stream the cache.

use super::Graph;

/// CSR of the symmetric weight matrix; `strengths[i]` carries the diagonal
/// of S so L·x = S·x − W·x needs no extra storage.
#[derive(Debug, Clone)]
pub struct Csr {
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f64>,
    pub strengths: Vec<f64>,
    pub total_weight: f64,
}

impl Csr {
    /// Build from a graph. O(n + m): the graph's compact adjacency rows are
    /// already sorted by neighbor id, so rows copy over verbatim.
    pub fn from_graph(g: &Graph) -> Self {
        let n = g.num_nodes();
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::with_capacity(2 * g.num_edges());
        let mut values = Vec::with_capacity(2 * g.num_edges());
        row_ptr.push(0);
        for i in 0..n {
            for &(j, w) in g.neighbor_entries(i as u32) {
                col_idx.push(j);
                values.push(w);
            }
            row_ptr.push(col_idx.len());
        }
        Self {
            row_ptr,
            col_idx,
            values,
            strengths: g.strengths().to_vec(),
            total_weight: g.total_weight(),
        }
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.strengths.len()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// y = W·x (symmetric weight matrix).
    pub fn matvec_w(&self, x: &[f64], y: &mut [f64]) {
        let n = self.num_nodes();
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);
        for i in 0..n {
            let mut acc = 0.0;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.values[k] * x[self.col_idx[k] as usize];
            }
            y[i] = acc;
        }
    }

    /// y = L·x where L = S − W (combinatorial Laplacian).
    pub fn matvec_laplacian(&self, x: &[f64], y: &mut [f64]) {
        let n = self.num_nodes();
        debug_assert_eq!(x.len(), n);
        for i in 0..n {
            let mut acc = self.strengths[i] * x[i];
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc -= self.values[k] * x[self.col_idx[k] as usize];
            }
            y[i] = acc;
        }
    }

    /// y = L_N·x where L_N = L / trace(L). No-op scaling for empty graphs.
    pub fn matvec_laplacian_normalized(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_laplacian(x, y);
        if self.total_weight > 0.0 {
            let c = 1.0 / self.total_weight;
            for v in y.iter_mut() {
                *v *= c;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        // 0 -1- 1 -2- 2 ; strengths [1, 3, 2]
        Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)])
    }

    #[test]
    fn csr_structure() {
        let c = Csr::from_graph(&path3());
        assert_eq!(c.row_ptr, vec![0, 1, 3, 4]);
        assert_eq!(c.col_idx, vec![1, 0, 2, 1]);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(c.values, vec![1.0, 1.0, 2.0, 2.0]);
        assert_eq!(c.nnz(), 4);
    }

    #[test]
    fn matvec_w_matches_dense() {
        let g = path3();
        let c = Csr::from_graph(&g);
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        c.matvec_w(&x, &mut y);
        // W = [[0,1,0],[1,0,2],[0,2,0]]
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(y, [2.0, 7.0, 4.0]);
    }

    #[test]
    fn matvec_laplacian_annihilates_ones() {
        let g = path3();
        let c = Csr::from_graph(&g);
        let x = [1.0; 3];
        let mut y = [0.0; 3];
        c.matvec_laplacian(&x, &mut y);
        for v in y {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_laplacian_known() {
        let c = Csr::from_graph(&path3());
        let x = [1.0, 0.0, 0.0];
        let mut y = [0.0; 3];
        c.matvec_laplacian(&x, &mut y);
        // L = [[1,-1,0],[-1,3,-2],[0,-2,2]], first column
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(y, [1.0, -1.0, 0.0]);
    }

    #[test]
    fn normalized_scales_by_trace() {
        let g = path3();
        let c = Csr::from_graph(&g);
        let x = [1.0, 0.0, 0.0];
        let mut y1 = [0.0; 3];
        let mut y2 = [0.0; 3];
        c.matvec_laplacian(&x, &mut y1);
        c.matvec_laplacian_normalized(&x, &mut y2);
        let tr = g.total_weight();
        for i in 0..3 {
            assert!((y2[i] - y1[i] / tr).abs() < 1e-15);
        }
    }

    #[test]
    fn empty_graph_matvec() {
        let c = Csr::from_graph(&Graph::new(2));
        let x = [1.0, 2.0];
        let mut y = [9.0, 9.0];
        c.matvec_laplacian_normalized(&x, &mut y);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(y, [0.0, 0.0]);
    }
}
