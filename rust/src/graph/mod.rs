//! Graph substrate: undirected weighted simple graphs (the paper's class 𝒢),
//! delta graphs (ΔG) for incremental updates, graph sequences, a CSR view for
//! spectral kernels, composition operators (⊕, averaged graph), and text I/O.

pub mod csr;
pub mod delta;
pub mod io;
pub mod ops;
pub mod sequence;

pub use csr::Csr;
pub use delta::{CoalesceBuf, DeltaGraph};
pub use sequence::GraphSequence;

/// Undirected weighted simple graph with nonnegative edge weights.
///
/// Adjacency is stored compactly as one sorted `Vec<(neighbor, weight)>` per
/// node (ascending neighbor id): `weight`/`has_edge` are a binary search over
/// a contiguous row instead of a hash probe, mutation is an insertion-point
/// write, and traversal (`neighbors`, `edges`, CSR construction) walks the
/// rows in cache order — the scoring hot path touches no hash table.
///
/// Invariants maintained by every mutator:
/// * symmetry: `weight(i,j) == weight(j,i)`;
/// * no self-loops, no zero-weight stored edges;
/// * each adjacency row strictly ascending by neighbor id;
/// * `strength(i) == Σ_j weight(i,j)` cached;
/// * `total_weight() == Σ_i strength(i) == 2·Σ_{(i,j)∈E} w_ij` cached.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    adj: Vec<Vec<(u32, f64)>>,
    strengths: Vec<f64>,
    m: usize,
    total_weight: f64,
}

impl Graph {
    /// Empty graph on `n` nodes.
    pub fn new(n: usize) -> Self {
        Self {
            adj: vec![Vec::new(); n],
            strengths: vec![0.0; n],
            m: 0,
            total_weight: 0.0,
        }
    }

    /// Build from an undirected edge list; duplicate (i,j)/(j,i) pairs keep
    /// the last weight. Panics on out-of-range endpoints or self-loops.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> Self {
        let mut g = Self::new(n);
        for &(i, j, w) in edges {
            g.set_weight(i, j, w);
        }
        g
    }

    /// Unweighted convenience constructor (all weights 1.0).
    pub fn from_pairs(n: usize, pairs: &[(u32, u32)]) -> Self {
        let mut g = Self::new(n);
        for &(i, j) in pairs {
            g.set_weight(i, j, 1.0);
        }
        g
    }

    /// Number of nodes n = |𝒱|.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.adj.len()
    }

    /// Number of edges m = |ℰ|.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.m
    }

    /// S = trace(L) = Σ_i s_i = 2·Σ w_ij.
    #[inline]
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Nodal strength (weighted degree) s_i.
    #[inline]
    pub fn strength(&self, i: u32) -> f64 {
        self.strengths[i as usize]
    }

    /// All nodal strengths.
    #[inline]
    pub fn strengths(&self) -> &[f64] {
        &self.strengths
    }

    /// Largest nodal strength s_max (0 for empty graphs).
    pub fn s_max(&self) -> f64 {
        self.strengths.iter().cloned().fold(0.0, f64::max)
    }

    /// Edge weight, or 0.0 if absent. Binary search over the sorted row.
    #[inline]
    pub fn weight(&self, i: u32, j: u32) -> f64 {
        let row = &self.adj[i as usize];
        match row.binary_search_by_key(&j, |&(k, _)| k) {
            Ok(idx) => row[idx].1,
            Err(_) => 0.0,
        }
    }

    /// Whether edge (i,j) exists.
    #[inline]
    pub fn has_edge(&self, i: u32, j: u32) -> bool {
        self.adj[i as usize].binary_search_by_key(&j, |&(k, _)| k).is_ok()
    }

    /// Unweighted degree of node i.
    #[inline]
    pub fn degree(&self, i: u32) -> usize {
        self.adj[i as usize].len()
    }

    /// Grow the node set to at least `n` nodes.
    pub fn ensure_nodes(&mut self, n: usize) {
        if n > self.adj.len() {
            self.adj.resize_with(n, Vec::new);
            self.strengths.resize(n, 0.0);
        }
    }

    /// Insert or overwrite the directed entry i→j, keeping the row sorted
    /// (binary-search insertion point).
    #[inline]
    fn row_set(&mut self, i: u32, j: u32, w: f64) {
        let row = &mut self.adj[i as usize];
        match row.binary_search_by_key(&j, |&(k, _)| k) {
            Ok(idx) => row[idx].1 = w,
            Err(idx) => row.insert(idx, (j, w)),
        }
    }

    /// Remove the directed entry i→j if present.
    #[inline]
    fn row_remove(&mut self, i: u32, j: u32) {
        let row = &mut self.adj[i as usize];
        if let Ok(idx) = row.binary_search_by_key(&j, |&(k, _)| k) {
            row.remove(idx);
        }
    }

    /// Set edge weight (w <= 0 removes the edge). Keeps all invariants.
    pub fn set_weight(&mut self, i: u32, j: u32, w: f64) {
        assert!(i != j, "self-loops are not in the graph class 𝒢");
        let n = self.adj.len();
        assert!((i as usize) < n && (j as usize) < n, "endpoint out of range");
        let old = self.weight(i, j);
        if w <= 0.0 {
            if old > 0.0 {
                self.row_remove(i, j);
                self.row_remove(j, i);
                self.m -= 1;
                self.strengths[i as usize] -= old;
                self.strengths[j as usize] -= old;
                self.total_weight -= 2.0 * old;
            }
            return;
        }
        // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
        if old == 0.0 {
            self.m += 1;
        }
        self.row_set(i, j, w);
        self.row_set(j, i, w);
        let dw = w - old;
        self.strengths[i as usize] += dw;
        self.strengths[j as usize] += dw;
        self.total_weight += 2.0 * dw;
    }

    /// Add `dw` (possibly negative) to edge (i,j); removes the edge when the
    /// result drops to <= 0.
    pub fn add_weight(&mut self, i: u32, j: u32, dw: f64) {
        let w = self.weight(i, j) + dw;
        self.set_weight(i, j, w);
    }

    /// Remove an edge; returns its previous weight.
    pub fn remove_edge(&mut self, i: u32, j: u32) -> f64 {
        let old = self.weight(i, j);
        if old > 0.0 {
            self.set_weight(i, j, 0.0);
        }
        old
    }

    /// Neighbors (and weights) of node i, ascending by neighbor id.
    pub fn neighbors(&self, i: u32) -> impl Iterator<Item = (u32, f64)> + '_ {
        self.adj[i as usize].iter().copied()
    }

    /// Neighbors of node i as the underlying sorted slice (ascending neighbor
    /// id) — the zero-cost view CSR construction and other bulk readers use.
    #[inline]
    pub fn neighbor_entries(&self, i: u32) -> &[(u32, f64)] {
        &self.adj[i as usize]
    }

    /// Iterate each undirected edge once as (i, j, w), ascending by (i, j).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32, f64)> + '_ {
        self.adj.iter().enumerate().flat_map(|(i, nbrs)| {
            nbrs.iter().filter_map(move |&(j, w)| {
                if (i as u32) < j {
                    Some((i as u32, j, w))
                } else {
                    None
                }
            })
        })
    }

    /// Σ_i s_i² and Σ_{(i,j)∈E} w_ij² — the two reductions behind the
    /// quadratic proxy Q (Lemma 1). O(n+m).
    pub fn q_moments(&self) -> (f64, f64) {
        let s2: f64 = self.strengths.iter().map(|s| s * s).sum();
        let w2: f64 = self.edges().map(|(_, _, w)| w * w).sum();
        (s2, w2)
    }

    /// Number of connected components (BFS over the edge support).
    pub fn connected_components(&self) -> usize {
        let n = self.num_nodes();
        let mut seen = vec![false; n];
        let mut comps = 0;
        let mut queue = std::collections::VecDeque::new();
        for start in 0..n {
            if seen[start] {
                continue;
            }
            comps += 1;
            seen[start] = true;
            queue.push_back(start as u32);
            while let Some(u) = queue.pop_front() {
                for (v, _) in self.neighbors(u) {
                    if !seen[v as usize] {
                        seen[v as usize] = true;
                        queue.push_back(v);
                    }
                }
            }
        }
        comps
    }

    /// Unweighted degree histogram normalized to a distribution, padded to
    /// `max_deg + 1` bins (used by the degree-distribution baselines).
    pub fn degree_distribution(&self) -> Vec<f64> {
        let n = self.num_nodes();
        if n == 0 {
            return Vec::new();
        }
        let max_deg = (0..n).map(|i| self.degree(i as u32)).max().unwrap_or(0);
        let mut hist = vec![0.0; max_deg + 1];
        for i in 0..n {
            hist[self.degree(i as u32)] += 1.0;
        }
        for h in &mut hist {
            *h /= n as f64;
        }
        hist
    }

    /// Dense weight matrix (row-major n×n), for the XLA offload path and the
    /// exact eigensolver.
    pub fn dense_weights(&self) -> Vec<f64> {
        let n = self.num_nodes();
        let mut w = vec![0.0; n * n];
        for (i, j, wij) in self.edges() {
            w[i as usize * n + j as usize] = wij;
            w[j as usize * n + i as usize] = wij;
        }
        w
    }

    /// Validate all cached invariants from scratch (test/debug helper).
    pub fn check_invariants(&self) -> Result<(), String> {
        let n = self.num_nodes();
        let mut m = 0usize;
        let mut total = 0.0;
        for i in 0..n {
            let mut s = 0.0;
            if !self.adj[i].windows(2).all(|w| w[0].0 < w[1].0) {
                return Err(format!("adjacency row {i} not strictly sorted"));
            }
            for &(j, w) in &self.adj[i] {
                if j as usize >= n {
                    return Err(format!("neighbor {j} out of range"));
                }
                if i as u32 == j {
                    return Err(format!("self-loop at {i}"));
                }
                if w <= 0.0 {
                    return Err(format!("nonpositive stored weight at ({i},{j})"));
                }
                if (self.weight(j, i as u32) - w).abs() > 1e-12 {
                    return Err(format!("asymmetric edge ({i},{j})"));
                }
                s += w;
                if (i as u32) < j {
                    m += 1;
                }
            }
            if (s - self.strengths[i]).abs() > 1e-9 * (1.0 + s.abs()) {
                return Err(format!("strength cache stale at {i}: {} vs {s}", self.strengths[i]));
            }
            total += s;
        }
        if m != self.m {
            return Err(format!("edge count stale: {} vs {m}", self.m));
        }
        if (total - self.total_weight).abs() > 1e-9 * (1.0 + total.abs()) {
            return Err(format!("total weight stale: {} vs {total}", self.total_weight));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn empty_graph() {
        let g = Graph::new(5);
        assert_eq!(g.num_nodes(), 5);
        assert_eq!(g.num_edges(), 0);
        assert_bits_eq!(g.total_weight(), 0.0);
        assert_bits_eq!(g.s_max(), 0.0);
        g.check_invariants().unwrap();
    }

    #[test]
    fn set_weight_symmetric() {
        let mut g = Graph::new(3);
        g.set_weight(0, 1, 2.5);
        assert_bits_eq!(g.weight(0, 1), 2.5);
        assert_bits_eq!(g.weight(1, 0), 2.5);
        assert_eq!(g.num_edges(), 1);
        assert_bits_eq!(g.strength(0), 2.5);
        assert_bits_eq!(g.strength(1), 2.5);
        assert_bits_eq!(g.total_weight(), 5.0);
        g.check_invariants().unwrap();
    }

    #[test]
    fn overwrite_weight_updates_caches() {
        let mut g = Graph::new(3);
        g.set_weight(0, 1, 2.0);
        g.set_weight(0, 1, 5.0);
        assert_eq!(g.num_edges(), 1);
        assert_bits_eq!(g.strength(0), 5.0);
        assert_bits_eq!(g.total_weight(), 10.0);
        g.check_invariants().unwrap();
    }

    #[test]
    fn remove_via_zero_weight() {
        let mut g = Graph::new(3);
        g.set_weight(0, 1, 2.0);
        g.set_weight(0, 2, 3.0);
        g.set_weight(0, 1, 0.0);
        assert_eq!(g.num_edges(), 1);
        assert!(!g.has_edge(0, 1));
        assert_bits_eq!(g.strength(0), 3.0);
        g.check_invariants().unwrap();
    }

    #[test]
    fn add_weight_accumulates_and_deletes() {
        let mut g = Graph::new(2);
        g.add_weight(0, 1, 1.5);
        g.add_weight(0, 1, 0.5);
        assert_bits_eq!(g.weight(0, 1), 2.0);
        g.add_weight(0, 1, -2.0);
        assert!(!g.has_edge(0, 1));
        assert_bits_eq!(g.total_weight(), 0.0);
        g.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "self-loops")]
    fn rejects_self_loop() {
        Graph::new(2).set_weight(1, 1, 1.0);
    }

    #[test]
    fn edges_iterates_once_per_edge() {
        let g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0)]);
        let mut es: Vec<_> = g.edges().collect();
        es.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(es, vec![(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0)]);
    }

    #[test]
    fn q_moments_match_manual() {
        // path 0-1-2 with weights 1, 2: s = [1, 3, 2]
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        let (s2, w2) = g.q_moments();
        assert_bits_eq!(s2, 1.0 + 9.0 + 4.0);
        assert_bits_eq!(w2, 1.0 + 4.0);
    }

    #[test]
    fn connected_components_counts() {
        let g = Graph::from_pairs(6, &[(0, 1), (1, 2), (3, 4)]);
        assert_eq!(g.connected_components(), 3); // {0,1,2}, {3,4}, {5}
    }

    #[test]
    fn degree_distribution_sums_to_one() {
        let g = Graph::from_pairs(4, &[(0, 1), (1, 2), (2, 3)]);
        let d = g.degree_distribution();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(d.len(), 3); // max degree 2
        assert!((d[1] - 0.5).abs() < 1e-12); // nodes 0,3
        assert!((d[2] - 0.5).abs() < 1e-12); // nodes 1,2
    }

    #[test]
    fn ensure_nodes_grows() {
        let mut g = Graph::new(2);
        g.ensure_nodes(5);
        assert_eq!(g.num_nodes(), 5);
        g.set_weight(0, 4, 1.0);
        g.check_invariants().unwrap();
        g.ensure_nodes(3); // no shrink
        assert_eq!(g.num_nodes(), 5);
    }

    #[test]
    fn dense_weights_symmetric() {
        let g = Graph::from_edges(3, &[(0, 2, 1.5)]);
        let w = g.dense_weights();
        assert_bits_eq!(w[0 * 3 + 2], 1.5);
        assert_bits_eq!(w[2 * 3 + 0], 1.5);
        assert_bits_eq!(w[0 * 3 + 1], 0.0);
    }

    #[test]
    fn neighbor_entries_sorted_ascending() {
        // insertion order deliberately scrambled; rows must stay sorted
        let mut g = Graph::new(6);
        g.set_weight(3, 5, 1.0);
        g.set_weight(3, 0, 2.0);
        g.set_weight(3, 4, 3.0);
        g.set_weight(3, 1, 4.0);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(g.neighbor_entries(3), &[(0, 2.0), (1, 4.0), (4, 3.0), (5, 1.0)]);
        let nbrs: Vec<_> = g.neighbors(3).collect();
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(nbrs, vec![(0, 2.0), (1, 4.0), (4, 3.0), (5, 1.0)]);
        g.remove_edge(3, 4);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(g.neighbor_entries(3), &[(0, 2.0), (1, 4.0), (5, 1.0)]);
        g.check_invariants().unwrap();
    }

    #[test]
    fn edges_emitted_in_sorted_order() {
        let mut g = Graph::new(5);
        g.set_weight(2, 4, 1.0);
        g.set_weight(0, 3, 2.0);
        g.set_weight(0, 1, 3.0);
        let es: Vec<_> = g.edges().collect();
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(es, vec![(0, 1, 3.0), (0, 3, 2.0), (2, 4, 1.0)]);
    }

    #[test]
    fn s_max_tracks_updates() {
        let mut g = Graph::new(3);
        g.set_weight(0, 1, 4.0);
        g.set_weight(1, 2, 3.0);
        assert_bits_eq!(g.s_max(), 7.0); // node 1
        g.remove_edge(0, 1);
        assert_bits_eq!(g.s_max(), 3.0);
    }
}
