//! Graph composition operators used by the JS-distance algorithms:
//! the averaged graph Ḡ = (G ⊕ G′)/2 (Algorithm 1) and helpers.

use super::{DeltaGraph, Graph};

/// Averaged graph Ḡ with W̄ = (W + W′)/2 on the common node set 𝒱_c = 𝒱 ∪ 𝒱′.
pub fn average_graph(a: &Graph, b: &Graph) -> Graph {
    let n = a.num_nodes().max(b.num_nodes());
    let mut g = Graph::new(n);
    for (i, j, w) in a.edges() {
        g.set_weight(i, j, w / 2.0);
    }
    for (i, j, w) in b.edges() {
        g.add_weight(i, j, w / 2.0);
    }
    g
}

/// G ⊕ ΔG as a new graph (non-destructive apply).
pub fn compose(g: &Graph, delta: &DeltaGraph) -> Graph {
    let mut out = g.clone();
    delta.apply_to(&mut out);
    out
}

/// Uniformly scale all edge weights.
pub fn scale(g: &Graph, f: f64) -> Graph {
    let mut out = Graph::new(g.num_nodes());
    for (i, j, w) in g.edges() {
        out.set_weight(i, j, w * f);
    }
    out
}

/// Union of edge supports, with weights from `pick`.
pub fn union_support(a: &Graph, b: &Graph, pick: impl Fn(f64, f64) -> f64) -> Graph {
    let n = a.num_nodes().max(b.num_nodes());
    let mut g = Graph::new(n);
    for (i, j, wa) in a.edges() {
        g.set_weight(i, j, pick(wa, b_weight(b, i, j)));
    }
    for (i, j, wb) in b.edges() {
        if !g.has_edge(i, j) {
            g.set_weight(i, j, pick(0.0, wb));
        }
    }
    g
}

fn b_weight(b: &Graph, i: u32, j: u32) -> f64 {
    if (i as usize) < b.num_nodes() && (j as usize) < b.num_nodes() {
        b.weight(i, j)
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn average_graph_weights() {
        let a = Graph::from_edges(3, &[(0, 1, 2.0)]);
        let b = Graph::from_edges(3, &[(0, 1, 4.0), (1, 2, 2.0)]);
        let m = average_graph(&a, &b);
        assert_bits_eq!(m.weight(0, 1), 3.0);
        assert_bits_eq!(m.weight(1, 2), 1.0);
        assert!((m.total_weight() - (a.total_weight() + b.total_weight()) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn average_graph_handles_size_mismatch() {
        let a = Graph::from_edges(2, &[(0, 1, 2.0)]);
        let b = Graph::from_edges(4, &[(2, 3, 2.0)]);
        let m = average_graph(&a, &b);
        assert_eq!(m.num_nodes(), 4);
        assert_bits_eq!(m.weight(0, 1), 1.0);
        assert_bits_eq!(m.weight(2, 3), 1.0);
    }

    #[test]
    fn compose_is_non_destructive() {
        let g = Graph::from_edges(2, &[(0, 1, 1.0)]);
        let mut d = DeltaGraph::new();
        d.add(0, 1, 1.0);
        let g2 = compose(&g, &d);
        assert_bits_eq!(g.weight(0, 1), 1.0);
        assert_bits_eq!(g2.weight(0, 1), 2.0);
    }

    #[test]
    fn scale_preserves_support() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0)]);
        let s = scale(&g, 0.5);
        assert_eq!(s.num_edges(), 2);
        assert_bits_eq!(s.weight(1, 2), 1.0);
    }

    #[test]
    fn union_support_max() {
        let a = Graph::from_edges(3, &[(0, 1, 5.0)]);
        let b = Graph::from_edges(3, &[(0, 1, 2.0), (1, 2, 7.0)]);
        let u = union_support(&a, &b, f64::max);
        assert_bits_eq!(u.weight(0, 1), 5.0);
        assert_bits_eq!(u.weight(1, 2), 7.0);
    }

    #[test]
    fn average_identity() {
        let a = Graph::from_edges(3, &[(0, 1, 2.0), (1, 2, 4.0)]);
        let m = average_graph(&a, &a);
        for (i, j, w) in a.edges() {
            assert_eq!(m.weight(i, j), w);
        }
    }
}
