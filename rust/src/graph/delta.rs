//! ΔG — the paper's incremental graph-change object (§2.4, Theorem 2).
//!
//! A `DeltaGraph` records signed edge-weight deltas Δw_ij plus the number of
//! new nodes appended, so that `G' = G ⊕ ΔG` and the FINGER state can be
//! advanced in O(Δn + Δm).

use super::Graph;

/// Reusable workspace for the stable coalesce: entries are keyed by the
/// packed (i,j) pair plus their stream position, so duplicates merge in
/// arrival order — bit-for-bit the accumulation order `coalesced()` has
/// always used — while the buffers themselves are recycled across windows
/// (the batcher/scorer hot path allocates nothing in steady state).
#[derive(Debug, Clone, Default)]
pub struct CoalesceBuf {
    /// (packed (i,j) key, stream position, Δw)
    keyed: Vec<(u64, u32, f64)>,
}

impl CoalesceBuf {
    /// Load `entries` and sort by (key, stream position). The position
    /// tiebreak makes the unstable sort order-deterministic, i.e. equivalent
    /// to a stable sort by key.
    fn load(&mut self, entries: &[(u32, u32, f64)]) {
        self.keyed.clear();
        self.keyed.extend(
            entries
                .iter()
                .enumerate()
                .map(|(pos, &(i, j, dw))| (((i as u64) << 32) | j as u64, pos as u32, dw)),
        );
        self.keyed.sort_unstable_by_key(|&(key, pos, _)| (key, pos));
    }

    /// Merge sorted runs into `out`: duplicate (i,j) deltas summed in stream
    /// order, entries whose net delta is exactly 0.0 dropped — the normal
    /// form `DeltaGraph::coalesced()` emits (ascending, duplicate-free).
    fn merge_into(&self, out: &mut Vec<(u32, u32, f64)>) {
        out.clear();
        let mut idx = 0;
        while idx < self.keyed.len() {
            let (key, _, mut acc) = self.keyed[idx];
            let mut next = idx + 1;
            while next < self.keyed.len() && self.keyed[next].0 == key {
                acc += self.keyed[next].2;
                next += 1;
            }
            // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
            if acc != 0.0 {
                out.push(((key >> 32) as u32, key as u32, acc));
            }
            idx = next;
        }
    }

    /// Coalesce `entries` into `out` (clearing it first). Shared by
    /// `DeltaGraph::coalesced`, the in-place batcher tick, and the
    /// `FingerState` non-normal-form fallback, so every path produces the
    /// identical normal form.
    pub(crate) fn coalesce_into(
        &mut self,
        entries: &[(u32, u32, f64)],
        out: &mut Vec<(u32, u32, f64)>,
    ) {
        self.load(entries);
        self.merge_into(out);
    }
}

/// A batch of incremental changes converting G into G' = G ⊕ ΔG.
///
/// `edges[(i,j)] = Δw_ij` may be negative (weight decrease / deletion). Node
/// additions are expressed by `new_nodes` (appended ids) — deletions of nodes
/// are modeled as deletion of all their incident edges, matching the paper's
/// common-node-set convention (footnote 4: 𝒱_c = 𝒱 ∪ 𝒱').
#[derive(Debug, Clone, Default)]
pub struct DeltaGraph {
    edges: Vec<(u32, u32, f64)>,
    new_nodes: usize,
}

impl DeltaGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record Δw on edge (i,j); i != j, order-normalized to i < j.
    pub fn add(&mut self, i: u32, j: u32, dw: f64) -> &mut Self {
        assert!(i != j, "self-loops are not representable");
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        self.edges.push((a, b, dw));
        self
    }

    /// Append `k` fresh nodes to the graph.
    pub fn grow_nodes(&mut self, k: usize) -> &mut Self {
        self.new_nodes += k;
        self
    }

    pub fn edge_deltas(&self) -> &[(u32, u32, f64)] {
        &self.edges
    }

    pub fn new_nodes(&self) -> usize {
        self.new_nodes
    }

    /// Δm — number of touched edges.
    pub fn num_changes(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty() && self.new_nodes == 0
    }

    /// ΔS = 2·Σ Δw_ij (the trace change of L).
    pub fn delta_total_weight(&self) -> f64 {
        2.0 * self.edges.iter().map(|&(_, _, dw)| dw).sum::<f64>()
    }

    /// ΔG/2 — halve every weight delta (used by Algorithm 2's mid-point graph
    /// G ⊕ ΔG/2). Node growth is preserved.
    pub fn half(&self) -> Self {
        Self {
            edges: self.edges.iter().map(|&(i, j, dw)| (i, j, dw / 2.0)).collect(),
            new_nodes: self.new_nodes,
        }
    }

    /// `half()` into an existing delta, reusing its buffers (the scratch
    /// mid-point delta of the allocation-free Algorithm-2 hot path). Halving
    /// is exact in binary floating point, so this is bit-identical to
    /// `half()`.
    pub fn half_into(&self, out: &mut Self) {
        out.edges.clear();
        out.edges.extend(self.edges.iter().map(|&(i, j, dw)| (i, j, dw / 2.0)));
        out.new_nodes = self.new_nodes;
    }

    /// Reset to the empty delta, keeping allocated capacity.
    pub fn clear(&mut self) {
        self.edges.clear();
        self.new_nodes = 0;
    }

    /// Scale every weight delta by `f`.
    pub fn scaled(&self, f: f64) -> Self {
        Self {
            edges: self.edges.iter().map(|&(i, j, dw)| (i, j, dw * f)).collect(),
            new_nodes: self.new_nodes,
        }
    }

    /// Coalesce duplicate (i,j) entries into a single summed delta (keeps
    /// apply/‌incremental costs proportional to distinct touched edges).
    /// Duplicates sum in stream order; exact-zero nets are dropped.
    pub fn coalesced(&self) -> Self {
        let mut edges = Vec::with_capacity(self.edges.len());
        CoalesceBuf::default().coalesce_into(&self.edges, &mut edges);
        Self { edges, new_nodes: self.new_nodes }
    }

    /// `coalesced()` without giving up this delta's buffers: sorts and merges
    /// through `buf` and writes the normal form back into `self`. The batcher
    /// tick uses this so a steady-state window allocates nothing.
    pub fn coalesce_in_place(&mut self, buf: &mut CoalesceBuf) {
        buf.load(&self.edges);
        buf.merge_into(&mut self.edges);
    }

    /// Entries strictly ascending by (i, j) — the normal form `coalesced()`
    /// emits, which implies no duplicates. O(Δ), allocation-free; the
    /// incremental hot path uses it to skip re-coalescing entirely.
    pub fn is_sorted_unique(&self) -> bool {
        self.edges.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1))
    }

    /// Whether some (i,j) pair appears more than once. Such deltas must be
    /// coalesced before clamping-sensitive incremental math (`FingerState`
    /// routes them through a coalesced view so that over-deleting duplicates
    /// see the *net* delta, exactly like `coalesced().apply_to(..)`).
    pub fn has_duplicate_edges(&self) -> bool {
        if self.edges.len() < 2 || self.is_sorted_unique() {
            return false;
        }
        let mut pairs: Vec<(u32, u32)> = self.edges.iter().map(|&(i, j, _)| (i, j)).collect();
        pairs.sort_unstable();
        pairs.windows(2).any(|w| w[0] == w[1])
    }

    /// The largest node id referenced (for sizing), if any.
    pub fn max_node(&self) -> Option<u32> {
        self.edges.iter().map(|&(i, j, _)| i.max(j)).max()
    }

    /// Apply to a graph in place: G ← G ⊕ ΔG. Grows the node set as needed.
    /// Weight deltas that would drive a weight below zero clamp to edge
    /// removal (the class 𝒢 has nonnegative weights).
    pub fn apply_to(&self, g: &mut Graph) {
        let need = self
            .max_node()
            .map(|mx| mx as usize + 1)
            .unwrap_or(0)
            .max(g.num_nodes() + self.new_nodes);
        g.ensure_nodes(need);
        for &(i, j, dw) in &self.edges {
            g.add_weight(i, j, dw);
        }
    }

    /// Build the ΔG that converts `from` into `to` (on the common node set
    /// 𝒱_c = 𝒱 ∪ 𝒱′; either side may be larger — a node id absent from one
    /// graph simply has no incident edges there). Inverse of `apply_to` up to
    /// clamping.
    pub fn diff(from: &Graph, to: &Graph) -> Self {
        let mut d = Self::new();
        if to.num_nodes() > from.num_nodes() {
            d.grow_nodes(to.num_nodes() - from.num_nodes());
        }
        for (i, j, w) in to.edges() {
            let old = if (i as usize) < from.num_nodes() && (j as usize) < from.num_nodes() {
                from.weight(i, j)
            } else {
                0.0
            };
            if (w - old).abs() > 0.0 {
                d.add(i, j, w - old);
            }
        }
        for (i, j, w) in from.edges() {
            // Bounds first: when `to` has fewer nodes, indexing its adjacency
            // with a removed node id would panic — out-of-range means the
            // edge is simply absent from `to`.
            let absent = (i as usize) >= to.num_nodes()
                || (j as usize) >= to.num_nodes()
                || !to.has_edge(i, j);
            if absent {
                d.add(i, j, -w);
            }
        }
        d.coalesced()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn apply_adds_edges_and_nodes() {
        let mut g = Graph::new(2);
        let mut d = DeltaGraph::new();
        d.grow_nodes(1).add(0, 2, 1.5).add(0, 1, 2.0);
        d.apply_to(&mut g);
        assert_eq!(g.num_nodes(), 3);
        assert_bits_eq!(g.weight(0, 2), 1.5);
        assert_bits_eq!(g.weight(0, 1), 2.0);
        g.check_invariants().unwrap();
    }

    #[test]
    fn apply_negative_removes() {
        let mut g = Graph::from_edges(3, &[(0, 1, 2.0)]);
        let mut d = DeltaGraph::new();
        d.add(0, 1, -2.0);
        d.apply_to(&mut g);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn half_scales_deltas() {
        let mut d = DeltaGraph::new();
        d.add(0, 1, 4.0).add(1, 2, -2.0);
        let h = d.half();
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(h.edge_deltas(), &[(0, 1, 2.0), (1, 2, -1.0)]);
        assert_bits_eq!(h.delta_total_weight(), d.delta_total_weight() / 2.0);
    }

    #[test]
    fn coalesce_merges_duplicates() {
        let mut d = DeltaGraph::new();
        d.add(0, 1, 1.0).add(1, 0, 2.0).add(2, 3, 1.0).add(2, 3, -1.0);
        let c = d.coalesced();
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(c.edge_deltas(), &[(0, 1, 3.0)]);
    }

    #[test]
    fn diff_roundtrip() {
        let a = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0)]);
        let b = Graph::from_edges(5, &[(0, 1, 3.0), (2, 3, 1.0)]);
        let d = DeltaGraph::diff(&a, &b);
        let mut g = a.clone();
        d.apply_to(&mut g);
        assert_eq!(g.num_nodes(), 5);
        for (i, j, w) in b.edges() {
            assert!((g.weight(i, j) - w).abs() < 1e-12, "({i},{j})");
        }
        assert_eq!(g.num_edges(), b.num_edges());
    }

    #[test]
    fn diff_to_shrunken_graph_deletes_out_of_range_edges() {
        // Regression: `to` smaller than `from` used to index `to`'s adjacency
        // with removed node ids and panic. Removed nodes are modeled as "all
        // incident edges deleted" (the paper's common-node-set convention).
        let from = Graph::from_edges(5, &[(0, 1, 1.0), (2, 4, 2.0), (1, 3, 0.5)]);
        let to = Graph::from_edges(2, &[(0, 1, 3.0)]);
        let d = DeltaGraph::diff(&from, &to);
        let mut g = from.clone();
        d.apply_to(&mut g);
        // node count never shrinks; all edges touching removed ids are gone
        assert_eq!(g.num_nodes(), 5);
        assert_eq!(g.num_edges(), 1);
        assert_bits_eq!(g.weight(0, 1), 3.0);
        assert!(!g.has_edge(2, 4));
        assert!(!g.has_edge(1, 3));
        g.check_invariants().unwrap();
        // degenerate shrink: everything deleted
        let d2 = DeltaGraph::diff(&from, &Graph::new(0));
        let mut g2 = from.clone();
        d2.apply_to(&mut g2);
        assert_eq!(g2.num_edges(), 0);
    }

    #[test]
    fn coalesce_in_place_matches_coalesced() {
        let mut d = DeltaGraph::new();
        d.grow_nodes(2)
            .add(0, 1, 1.0)
            .add(5, 2, -0.25)
            .add(1, 0, 2.5)
            .add(2, 3, 1.0)
            .add(2, 3, -1.0)
            .add(0, 1, 0.125);
        let reference = d.coalesced();
        let mut buf = CoalesceBuf::default();
        d.coalesce_in_place(&mut buf);
        assert_eq!(d.edge_deltas(), reference.edge_deltas());
        assert_eq!(d.new_nodes(), reference.new_nodes());
        assert!(d.is_sorted_unique());
        // idempotent, and the buffers keep working across reuse
        let mut again = d.clone();
        again.coalesce_in_place(&mut buf);
        assert_eq!(again.edge_deltas(), d.edge_deltas());
    }

    #[test]
    fn half_into_and_clear_reuse_buffers() {
        let mut d = DeltaGraph::new();
        d.grow_nodes(3).add(0, 1, 4.0).add(1, 2, -2.0);
        let mut out = DeltaGraph::new();
        out.add(7, 8, 9.0); // stale content must be overwritten
        d.half_into(&mut out);
        assert_eq!(out.edge_deltas(), d.half().edge_deltas());
        assert_eq!(out.new_nodes(), 3);
        out.clear();
        assert!(out.is_empty());
        assert_eq!(out.new_nodes(), 0);
    }

    #[test]
    fn delta_total_weight_is_trace_change() {
        let a = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let b = Graph::from_edges(3, &[(0, 1, 1.0), (1, 2, 4.0)]);
        let d = DeltaGraph::diff(&a, &b);
        assert!((d.delta_total_weight() - (b.total_weight() - a.total_weight())).abs() < 1e-12);
    }

    #[test]
    fn duplicate_detection() {
        let mut d = DeltaGraph::new();
        d.add(0, 1, 1.0).add(2, 3, 1.0);
        assert!(d.is_sorted_unique());
        assert!(!d.has_duplicate_edges());
        d.add(1, 0, -0.5); // same pair, order-normalized
        assert!(!d.is_sorted_unique());
        assert!(d.has_duplicate_edges());
        assert!(d.coalesced().is_sorted_unique());
        assert!(!DeltaGraph::new().has_duplicate_edges());
        // unsorted but duplicate-free: not normal form, yet no duplicates
        let mut u = DeltaGraph::new();
        u.add(2, 3, 1.0).add(0, 1, 1.0);
        assert!(!u.is_sorted_unique());
        assert!(!u.has_duplicate_edges());
    }

    #[test]
    fn order_normalized() {
        let mut d = DeltaGraph::new();
        d.add(5, 2, 1.0);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(d.edge_deltas(), &[(2, 5, 1.0)]);
    }
}
