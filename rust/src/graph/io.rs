//! Text I/O: weighted edge lists for snapshots and a timestamped delta-stream
//! format for incremental workloads (mirrors how the Wikipedia datasets are
//! distributed — rows of node/edge additions and deletions with timestamps).
//!
//! Edge list line:      `i j w`          (undirected, one line per edge)
//! Delta stream line:   `t i j dw`       (signed weight delta at step t)
//! Comment lines start with `#`, blank lines ignored.

use super::{DeltaGraph, Graph};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Parse an edge list from a reader. `n_hint` sizes the node set (grown as
/// needed when ids exceed it).
pub fn read_edge_list<R: std::io::Read>(r: R, n_hint: usize) -> Result<Graph> {
    let mut g = Graph::new(n_hint);
    for (lineno, line) in BufReader::new(r).lines().enumerate() {
        let line = line.context("read line")?;
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let mut it = s.split_whitespace();
        let i: u32 = parse(it.next(), lineno, "i")?;
        let j: u32 = parse(it.next(), lineno, "j")?;
        let w: f64 = match it.next() {
            Some(tok) => tok.parse().with_context(|| format!("line {}: bad weight", lineno + 1))?,
            None => 1.0,
        };
        if i == j {
            bail!("line {}: self-loop {i}", lineno + 1);
        }
        g.ensure_nodes(i.max(j) as usize + 1);
        g.set_weight(i, j, w);
    }
    Ok(g)
}

fn parse<T: std::str::FromStr>(tok: Option<&str>, lineno: usize, what: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    let tok = tok.with_context(|| format!("line {}: missing {what}", lineno + 1))?;
    tok.parse::<T>().map_err(|e| anyhow::anyhow!("line {}: bad {what}: {e}", lineno + 1))
}

/// Write a graph as an edge list (`edges()` already iterates ascending by
/// (i, j), so the output is deterministic without a sort pass).
pub fn write_edge_list<W: Write>(g: &Graph, mut w: W) -> Result<()> {
    writeln!(w, "# n={} m={}", g.num_nodes(), g.num_edges())?;
    for (i, j, wt) in g.edges() {
        writeln!(w, "{i} {j} {wt}")?;
    }
    Ok(())
}

/// Load an edge-list file.
pub fn load_graph(path: impl AsRef<Path>) -> Result<Graph> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_edge_list(f, 0)
}

/// Save an edge-list file.
pub fn save_graph(g: &Graph, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    write_edge_list(g, std::io::BufWriter::new(f))
}

/// Parse a delta stream: returns deltas grouped by consecutive step index t
/// (0-based, dense; missing steps become empty deltas).
pub fn read_delta_stream<R: std::io::Read>(r: R) -> Result<Vec<DeltaGraph>> {
    let mut by_t: Vec<DeltaGraph> = Vec::new();
    for (lineno, line) in BufReader::new(r).lines().enumerate() {
        let line = line.context("read line")?;
        let s = line.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let mut it = s.split_whitespace();
        let t: usize = parse(it.next(), lineno, "t")?;
        let i: u32 = parse(it.next(), lineno, "i")?;
        let j: u32 = parse(it.next(), lineno, "j")?;
        let dw: f64 = parse(it.next(), lineno, "dw")?;
        if t >= by_t.len() {
            by_t.resize_with(t + 1, DeltaGraph::new);
        }
        by_t[t].add(i, j, dw);
    }
    Ok(by_t)
}

/// Write a delta stream.
pub fn write_delta_stream<W: Write>(deltas: &[DeltaGraph], mut w: W) -> Result<()> {
    writeln!(w, "# steps={}", deltas.len())?;
    for (t, d) in deltas.iter().enumerate() {
        for &(i, j, dw) in d.edge_deltas() {
            writeln!(w, "{t} {i} {j} {dw}")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn edge_list_roundtrip() {
        let g = Graph::from_edges(4, &[(0, 1, 1.5), (2, 3, 2.0)]);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let g2 = read_edge_list(&buf[..], 0).unwrap();
        assert_eq!(g2.num_nodes(), 4);
        assert_bits_eq!(g2.weight(0, 1), 1.5);
        assert_bits_eq!(g2.weight(2, 3), 2.0);
    }

    #[test]
    fn edge_list_default_weight_and_comments() {
        let text = "# comment\n0 1\n\n1 2 3.5\n";
        let g = read_edge_list(text.as_bytes(), 0).unwrap();
        assert_bits_eq!(g.weight(0, 1), 1.0);
        assert_bits_eq!(g.weight(1, 2), 3.5);
    }

    #[test]
    fn edge_list_rejects_self_loop() {
        assert!(read_edge_list("3 3 1.0\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn edge_list_rejects_garbage() {
        assert!(read_edge_list("a b c\n".as_bytes(), 0).is_err());
    }

    #[test]
    fn delta_stream_roundtrip() {
        let mut d0 = DeltaGraph::new();
        d0.add(0, 1, 1.0);
        let mut d2 = DeltaGraph::new();
        d2.add(1, 2, -0.5);
        let deltas = vec![d0, DeltaGraph::new(), d2];
        let mut buf = Vec::new();
        write_delta_stream(&deltas, &mut buf).unwrap();
        let back = read_delta_stream(&buf[..]).unwrap();
        assert_eq!(back.len(), 3);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(back[0].edge_deltas(), &[(0, 1, 1.0)]);
        assert!(back[1].is_empty());
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(back[2].edge_deltas(), &[(1, 2, -0.5)]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("finger_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.edges");
        let g = Graph::from_edges(3, &[(0, 2, 4.0)]);
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_bits_eq!(g2.weight(0, 2), 4.0);
        std::fs::remove_file(path).ok();
    }
}
