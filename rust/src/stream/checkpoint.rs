//! Checkpoint/restore for the incremental scorer state: the graph is saved
//! as an edge list plus a small header (steps), and the `FingerState` is
//! rebuilt exactly on restore (Q/c/s_max are derived, so no drift can be
//! persisted).

use crate::entropy::{FingerState, SmaxPolicy};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Serialize a state checkpoint into any writer (the on-disk format of
/// [`save`], also used in-memory by the service's epoch canonicalization —
/// edge weights print in shortest-roundtrip form, so the format is
/// bit-exact either way).
pub fn write_state<W: Write>(w: &mut W, state: &FingerState) -> Result<()> {
    writeln!(w, "finger-checkpoint v1")?;
    writeln!(w, "steps {}", state.steps())?;
    writeln!(w, "nodes {}", state.graph().num_nodes())?;
    crate::graph::io::write_edge_list(state.graph(), w)?;
    Ok(())
}

/// Save a state checkpoint.
pub fn save(state: &FingerState, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = std::io::BufWriter::new(f);
    write_state(&mut w, state)
}

/// Restore a state checkpoint (default s_max policy).
pub fn load(path: impl AsRef<Path>) -> Result<FingerState> {
    load_with_policy(path, SmaxPolicy::default())
}

/// Restore a state checkpoint, rebuilding the `FingerState` under an
/// explicit s_max policy (the service restores sessions under whatever
/// policy its config selects; the checkpoint format itself is
/// policy-agnostic since Q/c/s_max are derived from the saved graph).
pub fn load_with_policy(path: impl AsRef<Path>, policy: SmaxPolicy) -> Result<FingerState> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_state(BufReader::new(f), policy)
}

/// Parse a checkpoint from any reader, rebuilding the `FingerState` under
/// `policy`. The state is rebuilt purely from the saved graph (Q/c/s_max are
/// derived, steps reset), which makes `write ∘ read` a **projection**:
/// applying the roundtrip twice produces byte-identical output to applying
/// it once — the idempotence the service's epoch canonicalization rests on.
pub fn read_state<R: BufRead>(mut r: R, policy: SmaxPolicy) -> Result<FingerState> {
    let mut line = String::new();
    r.read_line(&mut line)?;
    anyhow::ensure!(line.trim() == "finger-checkpoint v1", "bad checkpoint header: {line:?}");
    line.clear();
    r.read_line(&mut line)?;
    let _steps: u64 = line
        .trim()
        .strip_prefix("steps ")
        .context("missing steps")?
        .parse()
        .context("bad steps")?;
    line.clear();
    r.read_line(&mut line)?;
    let nodes: usize = line
        .trim()
        .strip_prefix("nodes ")
        .context("missing nodes")?
        .parse()
        .context("bad nodes")?;
    let mut g = crate::graph::io::read_edge_list(r, nodes)?;
    g.ensure_nodes(nodes);
    Ok(FingerState::with_policy(g, policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DeltaGraph;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip_preserves_state() {
        let g = crate::generators::erdos_renyi(30, 0.2, &mut Pcg64::new(1));
        let mut state = FingerState::new(g);
        let mut d = DeltaGraph::new();
        d.add(0, 5, 2.0).add(1, 6, -0.1);
        state.apply(&d);

        let dir = std::env::temp_dir().join("finger_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        save(&state, &path).unwrap();
        let restored = load(&path).unwrap();
        assert!((restored.q() - state.q()).abs() < 1e-12);
        assert!((restored.s_max() - state.s_max()).abs() < 1e-12);
        assert!((restored.htilde() - state.htilde()).abs() < 1e-12);
        assert_eq!(restored.graph().num_nodes(), state.graph().num_nodes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn restore_then_continue_matches_uninterrupted() {
        let g = crate::generators::erdos_renyi(25, 0.2, &mut Pcg64::new(2));
        let mut full = FingerState::new(g.clone());
        let mut first = FingerState::new(g);
        let mut rng = Pcg64::new(3);
        let deltas: Vec<DeltaGraph> = (0..10)
            .map(|_| {
                let mut d = DeltaGraph::new();
                let i = rng.below(25) as u32;
                let j = (i + 1 + rng.below(24) as u32) % 25;
                if i != j {
                    d.add(i, j, rng.uniform(0.1, 1.0));
                }
                d
            })
            .collect();
        for d in &deltas[..5] {
            full.apply(d);
            first.apply(d);
        }
        let dir = std::env::temp_dir().join("finger_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mid.ckpt");
        save(&first, &path).unwrap();
        let mut resumed = load(&path).unwrap();
        for d in &deltas[5..] {
            full.apply(d);
            resumed.apply(d);
        }
        assert!((full.htilde() - resumed.htilde()).abs() < 1e-12);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn in_memory_roundtrip_is_idempotent() {
        // write∘read applied twice == applied once, byte for byte: the
        // canonicalization idempotence bit-identical recovery rests on
        let g = crate::generators::erdos_renyi(40, 0.15, &mut Pcg64::new(11));
        let mut state = FingerState::new(g);
        let mut rng = Pcg64::new(12);
        for _ in 0..50 {
            let mut d = DeltaGraph::new();
            let i = rng.below(40) as u32;
            let j = (i + 1 + rng.below(39) as u32) % 40;
            if i != j {
                d.add(i, j, rng.uniform(-0.5, 1.0));
            }
            state.apply(&d.coalesced());
        }
        let roundtrip = |s: &FingerState| -> (Vec<u8>, FingerState) {
            let mut buf = Vec::new();
            write_state(&mut buf, s).unwrap();
            let re = read_state(std::io::Cursor::new(&buf), SmaxPolicy::default()).unwrap();
            (buf, re)
        };
        let (_, canon) = roundtrip(&state);
        let (bytes_once, canon2) = roundtrip(&canon);
        let (bytes_twice, _) = roundtrip(&canon2);
        assert_eq!(bytes_once, bytes_twice, "canonical form must be a fixed point");
        assert_eq!(canon.q().to_bits(), canon2.q().to_bits());
        assert_eq!(canon.htilde().to_bits(), canon2.htilde().to_bits());
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("finger_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, "not a checkpoint\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
