//! Typed events of the delta stream.

/// One event in a streaming graph workload.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// Signed edge-weight delta (addition, strengthening, weakening or
    /// deletion of edge (i, j)).
    EdgeDelta { i: u32, j: u32, dw: f64 },
    /// Append `count` fresh nodes.
    GrowNodes { count: usize },
    /// Window boundary: everything since the previous tick forms one ΔG_t.
    Tick,
}

impl StreamEvent {
    /// Parse from a text line: `e i j dw` | `n count` | `t`.
    ///
    /// Built for untrusted input (this is the wire format of the net front
    /// end), so semantically poisonous values are rejected, not just
    /// syntactic garbage: a non-finite `dw` (NaN/±inf would propagate
    /// through every Theorem-2 quantity in `FingerState` and stick there)
    /// and `i == j` self-loop deltas (undefined for the Laplacian model;
    /// downstream batchers silently skip them, but a reject at the parse
    /// boundary gives the sender an error instead of silent data loss).
    pub fn parse(line: &str) -> Option<Self> {
        let mut it = line.split_whitespace();
        let ev = match it.next()? {
            "e" => {
                let i: u32 = it.next()?.parse().ok()?;
                let j: u32 = it.next()?.parse().ok()?;
                let dw: f64 = it.next()?.parse().ok()?;
                if i == j || !dw.is_finite() {
                    return None;
                }
                StreamEvent::EdgeDelta { i, j, dw }
            }
            "n" => StreamEvent::GrowNodes { count: it.next()?.parse().ok()? },
            "t" => StreamEvent::Tick,
            _ => return None,
        };
        // strict arity: trailing tokens mean a malformed line (e.g. two
        // events fused by a sender bug) — reject rather than half-apply
        match it.next() {
            Some(_) => None,
            None => Some(ev),
        }
    }

    /// Serialize to the same text format.
    pub fn to_line(&self) -> String {
        match self {
            StreamEvent::EdgeDelta { i, j, dw } => format!("e {i} {j} {dw}"),
            StreamEvent::GrowNodes { count } => format!("n {count}"),
            StreamEvent::Tick => "t".to_string(),
        }
    }
}

/// Flatten a sequence of `DeltaGraph`s into a tick-separated event stream.
pub fn events_from_deltas(deltas: &[crate::graph::DeltaGraph]) -> Vec<StreamEvent> {
    let mut out = Vec::new();
    for d in deltas {
        if d.new_nodes() > 0 {
            out.push(StreamEvent::GrowNodes { count: d.new_nodes() });
        }
        for &(i, j, dw) in d.edge_deltas() {
            out.push(StreamEvent::EdgeDelta { i, j, dw });
        }
        out.push(StreamEvent::Tick);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for ev in [
            StreamEvent::EdgeDelta { i: 3, j: 7, dw: -1.5 },
            StreamEvent::GrowNodes { count: 4 },
            StreamEvent::Tick,
        ] {
            assert_eq!(StreamEvent::parse(&ev.to_line()), Some(ev));
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert_eq!(StreamEvent::parse("x 1 2"), None);
        assert_eq!(StreamEvent::parse("e 1"), None);
        assert_eq!(StreamEvent::parse(""), None);
    }

    #[test]
    fn parse_rejects_poisonous_wire_values() {
        // non-finite deltas would permanently corrupt FingerState entropy
        assert_eq!(StreamEvent::parse("e 1 2 NaN"), None);
        assert_eq!(StreamEvent::parse("e 1 2 nan"), None);
        assert_eq!(StreamEvent::parse("e 1 2 inf"), None);
        assert_eq!(StreamEvent::parse("e 1 2 -inf"), None);
        assert_eq!(StreamEvent::parse("e 1 2 infinity"), None);
        // self-loop deltas are undefined for the Laplacian model
        assert_eq!(StreamEvent::parse("e 7 7 1.0"), None);
        // trailing tokens (two events fused by a sender bug) are rejected
        assert_eq!(StreamEvent::parse("e 1 2 0.5 0.7"), None);
        assert_eq!(StreamEvent::parse("n 3 4"), None);
        assert_eq!(StreamEvent::parse("t t"), None);
        // ...but ordinary negative deltas (deletions) still parse
        // finger-lint: allow(FL003): round-trip equality of parsed events with literal weights
        assert_eq!(
            StreamEvent::parse("e 1 2 -0.5"),
            Some(StreamEvent::EdgeDelta { i: 1, j: 2, dw: -0.5 })
        );
    }

    #[test]
    fn events_from_deltas_tick_separated() {
        let mut d1 = crate::graph::DeltaGraph::new();
        d1.grow_nodes(2).add(0, 1, 1.0);
        let d2 = crate::graph::DeltaGraph::new();
        let evs = events_from_deltas(&[d1, d2]);
        // finger-lint: allow(FL003): round-trip equality of parsed events with literal weights
        assert_eq!(
            evs,
            vec![
                StreamEvent::GrowNodes { count: 2 },
                StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                StreamEvent::Tick,
                StreamEvent::Tick,
            ]
        );
    }
}
