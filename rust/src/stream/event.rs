//! Typed events of the delta stream.

/// One event in a streaming graph workload.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamEvent {
    /// Signed edge-weight delta (addition, strengthening, weakening or
    /// deletion of edge (i, j)).
    EdgeDelta { i: u32, j: u32, dw: f64 },
    /// Append `count` fresh nodes.
    GrowNodes { count: usize },
    /// Window boundary: everything since the previous tick forms one ΔG_t.
    Tick,
}

impl StreamEvent {
    /// Parse from a text line: `e i j dw` | `n count` | `t`.
    pub fn parse(line: &str) -> Option<Self> {
        let mut it = line.split_whitespace();
        match it.next()? {
            "e" => {
                let i = it.next()?.parse().ok()?;
                let j = it.next()?.parse().ok()?;
                let dw = it.next()?.parse().ok()?;
                Some(StreamEvent::EdgeDelta { i, j, dw })
            }
            "n" => Some(StreamEvent::GrowNodes { count: it.next()?.parse().ok()? }),
            "t" => Some(StreamEvent::Tick),
            _ => None,
        }
    }

    /// Serialize to the same text format.
    pub fn to_line(&self) -> String {
        match self {
            StreamEvent::EdgeDelta { i, j, dw } => format!("e {i} {j} {dw}"),
            StreamEvent::GrowNodes { count } => format!("n {count}"),
            StreamEvent::Tick => "t".to_string(),
        }
    }
}

/// Flatten a sequence of `DeltaGraph`s into a tick-separated event stream.
pub fn events_from_deltas(deltas: &[crate::graph::DeltaGraph]) -> Vec<StreamEvent> {
    let mut out = Vec::new();
    for d in deltas {
        if d.new_nodes() > 0 {
            out.push(StreamEvent::GrowNodes { count: d.new_nodes() });
        }
        for &(i, j, dw) in d.edge_deltas() {
            out.push(StreamEvent::EdgeDelta { i, j, dw });
        }
        out.push(StreamEvent::Tick);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for ev in [
            StreamEvent::EdgeDelta { i: 3, j: 7, dw: -1.5 },
            StreamEvent::GrowNodes { count: 4 },
            StreamEvent::Tick,
        ] {
            assert_eq!(StreamEvent::parse(&ev.to_line()), Some(ev));
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert_eq!(StreamEvent::parse("x 1 2"), None);
        assert_eq!(StreamEvent::parse("e 1"), None);
        assert_eq!(StreamEvent::parse(""), None);
    }

    #[test]
    fn events_from_deltas_tick_separated() {
        let mut d1 = crate::graph::DeltaGraph::new();
        d1.grow_nodes(2).add(0, 1, 1.0);
        let d2 = crate::graph::DeltaGraph::new();
        let evs = events_from_deltas(&[d1, d2]);
        assert_eq!(
            evs,
            vec![
                StreamEvent::GrowNodes { count: 2 },
                StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                StreamEvent::Tick,
                StreamEvent::Tick,
            ]
        );
    }
}
