//! The threaded streaming pipeline (source → batcher → scorer → sink) with
//! bounded-channel backpressure and per-stage metrics.
//!
//! The per-window logic (event batching, Algorithm-2 scoring, online anomaly
//! flagging) lives in `super::window` as reusable components shared with the
//! sharded multi-session service (`crate::service`); this module supplies
//! the single-stream threading harness around them.

use super::event::StreamEvent;
use super::window::{AnomalyDetector, ResyncPolicy, WindowBatcher, WindowScorer};
use crate::entropy::FingerState;
use crate::graph::{DeltaGraph, Graph};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

pub use super::window::ScoreRecord;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Bounded channel capacity between stages (backpressure knob).
    pub channel_capacity: usize,
    /// Online anomaly threshold: score > μ + k·σ over the trailing window.
    pub anomaly_sigma: f64,
    /// Trailing window length for the running anomaly statistics.
    pub anomaly_window: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self { channel_capacity: 64, anomaly_sigma: 3.0, anomaly_window: 24 }
    }
}

/// Aggregated pipeline outcome.
#[derive(Debug)]
pub struct PipelineResult {
    pub records: Vec<ScoreRecord>,
    pub total_events: usize,
    pub wall_secs: f64,
    /// Events per second through the whole pipeline.
    pub throughput: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub anomalies: Vec<usize>,
}

/// The pipeline itself. Construct with an initial graph, then `run` an event
/// iterator to completion.
pub struct Pipeline {
    cfg: PipelineConfig,
    initial: Graph,
}

impl Pipeline {
    pub fn new(initial: Graph, cfg: PipelineConfig) -> Self {
        Self { cfg, initial }
    }

    /// Run the pipeline over `events` (consumed on a source thread). Returns
    /// when the stream ends and all stages have drained.
    pub fn run<I>(&self, events: I) -> PipelineResult
    where
        I: IntoIterator<Item = StreamEvent> + Send + 'static,
        I::IntoIter: Send,
    {
        let start = Instant::now();
        let (ev_tx, ev_rx): (SyncSender<StreamEvent>, Receiver<StreamEvent>) =
            sync_channel(self.cfg.channel_capacity);
        let (win_tx, win_rx): (SyncSender<(DeltaGraph, usize)>, Receiver<(DeltaGraph, usize)>) =
            sync_channel(self.cfg.channel_capacity);

        // -- source --
        // the produced count crosses back through a shared atomic rather
        // than the join result, so the drain below has no panic site
        let produced = Arc::new(AtomicUsize::new(0));
        let source_count = Arc::clone(&produced);
        let source = std::thread::spawn(move || {
            let mut count = 0usize;
            for ev in events {
                count += 1;
                if ev_tx.send(ev).is_err() {
                    break; // downstream gone: stop producing
                }
            }
            source_count.store(count, Ordering::Release);
        });

        // -- batcher --
        let batcher = std::thread::spawn(move || {
            let mut batcher = WindowBatcher::new();
            for ev in ev_rx {
                if let Some(win) = batcher.push(ev) {
                    if win_tx.send(win).is_err() {
                        return;
                    }
                }
            }
            // flush a trailing partial window
            if let Some(win) = batcher.flush() {
                let _ = win_tx.send(win);
            }
        });

        // -- scorer + sink (inline on this thread) --
        // Resync disabled: the single-stream pipeline stays bit-identical to
        // the direct Algorithm-2 loop (the service enables it per session).
        let mut scorer = WindowScorer::new(
            FingerState::new(self.initial.clone()),
            AnomalyDetector::new(self.cfg.anomaly_sigma, self.cfg.anomaly_window),
            ResyncPolicy::disabled(),
        );
        let mut records: Vec<ScoreRecord> = Vec::new();
        for (delta, n_events) in win_rx {
            records.push(scorer.score(&delta, n_events));
        }
        if batcher.join().is_err() {
            eprintln!("pipeline: batcher thread panicked; records may be incomplete");
        }
        if source.join().is_err() {
            eprintln!("pipeline: source thread panicked; event count may be incomplete");
        }
        let total_events = produced.load(Ordering::Acquire);

        let wall = start.elapsed().as_secs_f64();
        let lats: Vec<f64> = records.iter().map(|r| r.latency).collect();
        PipelineResult {
            throughput: total_events as f64 / wall.max(1e-12),
            total_events,
            wall_secs: wall,
            p50_latency: crate::util::stats::percentile(&lats, 50.0),
            p99_latency: crate::util::stats::percentile(&lats, 99.0),
            anomalies: records
                .iter()
                .filter(|r| r.anomalous)
                .map(|r| r.window)
                .collect(),
            records,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::event::events_from_deltas;
    use crate::util::Pcg64;

    #[test]
    fn pipeline_scores_each_window() {
        let g = crate::generators::erdos_renyi(50, 0.1, &mut Pcg64::new(1));
        let mut deltas = Vec::new();
        let mut rng = Pcg64::new(2);
        for _ in 0..10 {
            let mut d = DeltaGraph::new();
            for _ in 0..5 {
                let i = rng.below(50) as u32;
                let j = (i + 1 + rng.below(49) as u32) % 50;
                if i != j {
                    d.add(i, j, rng.uniform(0.1, 1.0));
                }
            }
            deltas.push(d);
        }
        let events = events_from_deltas(&deltas);
        let res = Pipeline::new(g, PipelineConfig::default()).run(events);
        assert_eq!(res.records.len(), 10);
        assert!(res.records.iter().all(|r| r.jsdist.is_finite() && r.jsdist >= 0.0));
        assert!(res.throughput > 0.0);
    }

    #[test]
    fn pipeline_matches_offline_incremental() {
        // streaming result == direct Algorithm-2 loop over the same deltas
        let g = crate::generators::erdos_renyi(40, 0.1, &mut Pcg64::new(3));
        let mut deltas = Vec::new();
        let mut rng = Pcg64::new(4);
        for _ in 0..6 {
            let mut d = DeltaGraph::new();
            let i = rng.below(40) as u32;
            let j = (i + 1 + rng.below(39) as u32) % 40;
            if i != j {
                d.add(i, j, 1.0);
            }
            deltas.push(d.coalesced());
        }
        let events = events_from_deltas(&deltas);
        let res = Pipeline::new(g.clone(), PipelineConfig::default()).run(events);
        let mut state = FingerState::new(g);
        for (t, d) in deltas.iter().enumerate() {
            let js = crate::distance::jsdist_incremental(&mut state, d);
            assert!((res.records[t].jsdist - js).abs() < 1e-12, "window {t}");
        }
    }

    #[test]
    fn no_event_loss_under_tiny_channels() {
        // capacity 1 forces constant backpressure; everything still arrives
        let g = Graph::new(20);
        let mut events = Vec::new();
        for k in 0..200u32 {
            events.push(StreamEvent::EdgeDelta { i: k % 20, j: (k + 1) % 20, dw: 0.1 });
            if k % 10 == 9 {
                events.push(StreamEvent::Tick);
            }
        }
        let total = events.len();
        let cfg = PipelineConfig { channel_capacity: 1, ..Default::default() };
        let res = Pipeline::new(g, cfg).run(events);
        assert_eq!(res.total_events, total);
        assert_eq!(res.records.len(), 20);
        let ev_sum: usize = res.records.iter().map(|r| r.events).sum();
        assert_eq!(ev_sum, total);
    }

    #[test]
    fn trailing_partial_window_flushed() {
        let g = Graph::new(5);
        let events = vec![
            StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
            StreamEvent::Tick,
            StreamEvent::EdgeDelta { i: 1, j: 2, dw: 1.0 }, // no trailing tick
        ];
        let res = Pipeline::new(g, PipelineConfig::default()).run(events);
        assert_eq!(res.records.len(), 2);
        assert_eq!(res.records[1].edges, 2);
    }

    #[test]
    fn anomaly_flagging_fires_on_burst() {
        let g = crate::generators::erdos_renyi(100, 0.05, &mut Pcg64::new(5));
        let mut deltas = Vec::new();
        let mut rng = Pcg64::new(6);
        for t in 0..30 {
            let mut d = DeltaGraph::new();
            let count = if t == 25 { 400 } else { 3 }; // burst at window 25
            for _ in 0..count {
                let i = rng.below(100) as u32;
                let j = (i + 1 + rng.below(99) as u32) % 100;
                if i != j {
                    d.add(i, j, 1.0);
                }
            }
            deltas.push(d.coalesced());
        }
        let res = Pipeline::new(g, PipelineConfig::default()).run(events_from_deltas(&deltas));
        assert!(res.anomalies.contains(&25), "anomalies={:?}", res.anomalies);
    }

    #[test]
    fn empty_stream() {
        let res = Pipeline::new(Graph::new(3), PipelineConfig::default()).run(Vec::new());
        assert!(res.records.is_empty());
        assert_eq!(res.total_events, 0);
    }

    #[test]
    fn self_loop_events_ignored() {
        let g = Graph::new(4);
        let events = vec![
            StreamEvent::EdgeDelta { i: 2, j: 2, dw: 1.0 }, // ignored
            StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
            StreamEvent::Tick,
        ];
        let res = Pipeline::new(g, PipelineConfig::default()).run(events);
        assert_eq!(res.records[0].edges, 1);
    }
}
