//! The streaming coordinator — L3's system contribution.
//!
//! A multi-threaded pipeline consuming an edge-delta event stream:
//!
//! ```text
//! source ──(bounded ch)──► batcher ──(bounded ch)──► scorer ──► sink
//!              events        windows ΔG_t        Algorithm 2      records
//! ```
//!
//! * **batcher** groups events into window deltas (ΔG_t) on `Tick` events;
//! * **scorer** owns the incremental `FingerState` and emits the JS distance
//!   of every window in O(Δ) (Algorithm 2) plus the running H̃;
//! * **sink** flags anomalies online (score > μ + kσ over a trailing window)
//!   and aggregates per-stage metrics.
//!
//! Bounded channels give backpressure: a slow scorer stalls the source
//! instead of growing memory. Checkpoint/restore lets a stream resume.
//!
//! The per-window pieces (batching, scoring, anomaly flagging, drift-bounded
//! resync) live in [`window`] as standalone components; [`Pipeline`] wires
//! them into the single-stream thread harness above, and [`crate::service`]
//! runs one set per session across sharded workers.

pub mod checkpoint;
pub mod event;
pub mod pipeline;
pub mod window;

pub use event::StreamEvent;
pub use pipeline::{Pipeline, PipelineConfig, PipelineResult, ScoreRecord};
pub use window::{AnomalyDetector, ResyncPolicy, WindowBatcher, WindowScorer};
