//! Per-stream scoring components, factored out of the single-stream
//! `Pipeline` so the sharded multi-session service (`crate::service`) can run
//! the same batcher → scorer → anomaly logic once per session:
//!
//! * [`WindowBatcher`] folds raw [`StreamEvent`]s into window deltas ΔG_t,
//!   emitting a coalesced `DeltaGraph` on every `Tick`;
//! * [`WindowScorer`] owns the incremental `FingerState`, scores each window
//!   with Algorithm 2 (`jsdist_incremental`), flags anomalies online through
//!   an [`AnomalyDetector`], and schedules drift-bounded [`resyncs`] for
//!   long-lived streams;
//! * [`AnomalyDetector`] is the trailing-window μ + kσ rule.
//!
//! [`resyncs`]: crate::entropy::FingerState::resync

use super::event::StreamEvent;
use crate::entropy::FingerState;
use crate::graph::DeltaGraph;
use std::collections::VecDeque;
use std::time::Instant;

/// One scored window.
#[derive(Debug, Clone)]
pub struct ScoreRecord {
    pub window: usize,
    /// FINGER-JSdist (Incremental) between the pre- and post-window graphs.
    pub jsdist: f64,
    /// H̃ of the post-window graph.
    pub htilde: f64,
    pub nodes: usize,
    pub edges: usize,
    /// Events folded into this window.
    pub events: usize,
    /// Scoring latency (seconds) for this window.
    pub latency: f64,
    /// Online anomaly flag.
    pub anomalous: bool,
}

/// Folds events into window deltas: edge/node events accumulate into the
/// current `DeltaGraph`; a `Tick` closes the window and yields it coalesced.
#[derive(Debug, Default)]
pub struct WindowBatcher {
    current: DeltaGraph,
    events_in_window: usize,
}

impl WindowBatcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one event; returns the closed window `(ΔG, events)` on `Tick`
    /// (the tick itself counts as one event, matching the pipeline's
    /// historical accounting).
    pub fn push(&mut self, ev: StreamEvent) -> Option<(DeltaGraph, usize)> {
        match ev {
            StreamEvent::EdgeDelta { i, j, dw } => {
                if i != j {
                    self.current.add(i, j, dw);
                }
                self.events_in_window += 1;
                None
            }
            StreamEvent::GrowNodes { count } => {
                self.current.grow_nodes(count);
                self.events_in_window += 1;
                None
            }
            StreamEvent::Tick => {
                let d = std::mem::take(&mut self.current).coalesced();
                let n = self.events_in_window + 1;
                self.events_in_window = 0;
                Some((d, n))
            }
        }
    }

    /// Close a trailing partial window (stream ended without a final tick).
    pub fn flush(&mut self) -> Option<(DeltaGraph, usize)> {
        if self.events_in_window == 0 {
            return None;
        }
        let d = std::mem::take(&mut self.current).coalesced();
        let n = self.events_in_window;
        self.events_in_window = 0;
        Some((d, n))
    }

    /// Events accumulated in the currently-open window.
    pub fn pending_events(&self) -> usize {
        self.events_in_window
    }
}

/// Online anomaly rule: a score is anomalous when it exceeds μ + kσ of the
/// trailing window of *previous* scores (the current score is added after
/// the decision, and no decision is made until 4 scores have been seen).
#[derive(Debug, Clone)]
pub struct AnomalyDetector {
    sigma: f64,
    window: usize,
    trailing: VecDeque<f64>,
}

impl AnomalyDetector {
    /// `window` is clamped to ≥ 4: a decision needs 4 trailing samples, so a
    /// smaller window would silently disable detection forever.
    pub fn new(sigma: f64, window: usize) -> Self {
        Self { sigma, window: window.max(4), trailing: VecDeque::new() }
    }

    /// Judge `score` against the trailing statistics, then fold it in.
    pub fn observe(&mut self, score: f64) -> bool {
        let anomalous = if self.trailing.len() >= 4 {
            let xs: Vec<f64> = self.trailing.iter().copied().collect();
            let mu = crate::util::stats::mean(&xs);
            let sd = crate::util::stats::std_dev(&xs);
            score > mu + self.sigma * sd.max(1e-12)
        } else {
            false
        };
        self.trailing.push_back(score);
        if self.trailing.len() > self.window {
            self.trailing.pop_front();
        }
        anomalous
    }
}

/// Drift-bounded auto-resync schedule for long-lived streams: resync every
/// `interval` windows, halving the interval (down to `min_interval`) when the
/// measured |ΔQ| drift exceeds `drift_tolerance` and doubling it (up to
/// `max_interval`) while updates stay clean. `initial_interval == 0`
/// disables resyncing entirely (the single-stream `Pipeline` default, which
/// keeps its output bit-identical to the direct Algorithm-2 loop).
#[derive(Debug, Clone)]
pub struct ResyncPolicy {
    pub initial_interval: u64,
    pub min_interval: u64,
    pub max_interval: u64,
    pub drift_tolerance: f64,
}

impl Default for ResyncPolicy {
    fn default() -> Self {
        Self { initial_interval: 256, min_interval: 16, max_interval: 8192, drift_tolerance: 1e-9 }
    }
}

impl ResyncPolicy {
    /// Never resync (exact-replay semantics).
    pub fn disabled() -> Self {
        Self { initial_interval: 0, ..Self::default() }
    }

    /// Adaptive schedule starting at `interval` windows.
    pub fn every(interval: u64) -> Self {
        Self { initial_interval: interval, ..Self::default() }
    }
}

/// Scores window deltas against an owned incremental `FingerState`:
/// Algorithm 2 per window, online anomaly flagging, per-window latency, and
/// scheduled drift correction.
#[derive(Debug)]
pub struct WindowScorer {
    state: FingerState,
    detector: AnomalyDetector,
    resync: ResyncPolicy,
    interval: u64,
    since_resync: u64,
    window: usize,
    resyncs: u64,
    max_drift: f64,
}

impl WindowScorer {
    pub fn new(state: FingerState, detector: AnomalyDetector, resync: ResyncPolicy) -> Self {
        let interval = resync.initial_interval;
        Self {
            state,
            detector,
            resync,
            interval,
            since_resync: 0,
            window: 0,
            resyncs: 0,
            max_drift: 0.0,
        }
    }

    /// Score one window delta and advance the state (Algorithm 2 commits ΔG).
    pub fn score(&mut self, delta: &DeltaGraph, n_events: usize) -> ScoreRecord {
        let t0 = Instant::now();
        let js = crate::distance::jsdist_incremental(&mut self.state, delta);
        let latency = t0.elapsed().as_secs_f64();
        let anomalous = self.detector.observe(js);
        let record = ScoreRecord {
            window: self.window,
            jsdist: js,
            htilde: self.state.htilde(),
            nodes: self.state.graph().num_nodes(),
            edges: self.state.graph().num_edges(),
            events: n_events,
            latency,
            anomalous,
        };
        self.window += 1;
        self.maybe_resync();
        record
    }

    fn maybe_resync(&mut self) {
        if self.interval == 0 {
            return;
        }
        self.since_resync += 1;
        if self.since_resync < self.interval {
            return;
        }
        self.since_resync = 0;
        let drift = self.state.resync();
        self.resyncs += 1;
        if drift > self.max_drift {
            self.max_drift = drift;
        }
        self.interval = if drift > self.resync.drift_tolerance {
            (self.interval / 2).max(self.resync.min_interval)
        } else {
            self.interval.saturating_mul(2).min(self.resync.max_interval)
        };
    }

    pub fn state(&self) -> &FingerState {
        &self.state
    }

    pub fn into_state(self) -> FingerState {
        self.state
    }

    /// Windows scored so far.
    pub fn windows(&self) -> usize {
        self.window
    }

    /// Resyncs performed by the drift-bounded schedule.
    pub fn resyncs(&self) -> u64 {
        self.resyncs
    }

    /// Largest |ΔQ| drift any resync corrected.
    pub fn max_drift(&self) -> f64 {
        self.max_drift
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::stream::event::StreamEvent as Ev;
    use crate::util::Pcg64;

    #[test]
    fn batcher_groups_and_flushes() {
        let mut b = WindowBatcher::new();
        assert!(b.push(Ev::EdgeDelta { i: 0, j: 1, dw: 1.0 }).is_none());
        assert!(b.push(Ev::EdgeDelta { i: 2, j: 2, dw: 1.0 }).is_none()); // self-loop skipped
        let (d, n) = b.push(Ev::Tick).unwrap();
        assert_eq!(n, 3); // two edge events + the tick
        assert_eq!(d.edge_deltas(), &[(0, 1, 1.0)]);
        assert!(b.flush().is_none()); // nothing pending after a tick
        b.push(Ev::GrowNodes { count: 2 });
        let (d, n) = b.flush().unwrap();
        assert_eq!((d.new_nodes(), n), (2, 1));
    }

    #[test]
    fn detector_matches_trailing_rule() {
        let mut det = AnomalyDetector::new(3.0, 8);
        for _ in 0..6 {
            assert!(!det.observe(1.0));
        }
        assert!(det.observe(100.0)); // huge spike vs σ≈0 trailing window
        assert!(!det.observe(1.0));
    }

    #[test]
    fn detector_window_clamped_so_it_can_still_fire() {
        // window < 4 would otherwise never accumulate the 4 samples a
        // decision requires — the constructor clamps it
        let mut det = AnomalyDetector::new(3.0, 1);
        for _ in 0..5 {
            assert!(!det.observe(1.0));
        }
        assert!(det.observe(100.0));
    }

    #[test]
    fn scorer_resyncs_on_schedule_without_changing_scores() {
        let g = generators::erdos_renyi(40, 0.1, &mut Pcg64::new(9));
        let mut rng = Pcg64::new(10);
        let mut deltas = Vec::new();
        for _ in 0..24 {
            let mut d = DeltaGraph::new();
            let i = rng.below(40) as u32;
            let j = (i + 1 + rng.below(39) as u32) % 40;
            if i != j {
                d.add(i, j, rng.uniform(0.1, 1.0));
            }
            deltas.push(d.coalesced());
        }
        let mk = |resync: ResyncPolicy| {
            WindowScorer::new(
                FingerState::new(g.clone()),
                AnomalyDetector::new(3.0, 24),
                resync,
            )
        };
        let mut with = mk(ResyncPolicy::every(4));
        let mut without = mk(ResyncPolicy::disabled());
        for d in &deltas {
            let a = with.score(d, 1);
            let b = without.score(d, 1);
            // resync corrects float drift only; scores agree to tight tol
            assert!((a.jsdist - b.jsdist).abs() < 1e-9);
        }
        assert!(with.resyncs() >= 2);
        assert_eq!(without.resyncs(), 0);
        assert!(with.max_drift() < 1e-8, "drift={}", with.max_drift());
    }
}
