//! Per-stream scoring components, factored out of the single-stream
//! `Pipeline` so the sharded multi-session service (`crate::service`) can run
//! the same batcher → scorer → anomaly logic once per session:
//!
//! * [`WindowBatcher`] folds raw [`StreamEvent`]s into window deltas ΔG_t,
//!   emitting a coalesced `DeltaGraph` on every `Tick`;
//! * [`WindowScorer`] owns the incremental `FingerState`, scores each window
//!   with Algorithm 2 (`jsdist_incremental`), flags anomalies online through
//!   an [`AnomalyDetector`], and schedules drift-bounded [`resyncs`] for
//!   long-lived streams;
//! * [`AnomalyDetector`] is the trailing-window μ + kσ rule.
//!
//! [`resyncs`]: crate::entropy::FingerState::resync

use super::event::StreamEvent;
use crate::entropy::{FingerState, Scratch};
use crate::graph::{CoalesceBuf, DeltaGraph};
use std::collections::VecDeque;
use std::time::Instant;

/// One scored window.
#[derive(Debug, Clone)]
pub struct ScoreRecord {
    pub window: usize,
    /// FINGER-JSdist (Incremental) between the pre- and post-window graphs.
    pub jsdist: f64,
    /// H̃ of the post-window graph.
    pub htilde: f64,
    pub nodes: usize,
    pub edges: usize,
    /// Events folded into this window.
    pub events: usize,
    /// Scoring latency (seconds) for this window.
    pub latency: f64,
    /// Online anomaly flag.
    pub anomalous: bool,
}

/// Folds events into window deltas: edge/node events accumulate into the
/// current `DeltaGraph`; a `Tick` closes the window and yields it coalesced
/// (always in `is_sorted_unique()` normal form, so the `FingerState` fast
/// path never re-coalesces).
///
/// The in-place variants ([`push_ref`]/[`flush_ref`]) coalesce into the
/// batcher's own reusable buffers and lend the window out by reference —
/// a steady-state window allocates nothing. The owning [`push`]/[`flush`]
/// wrappers clone the emitted window for callers that must send it across a
/// thread boundary (the pipeline's channels).
///
/// [`push_ref`]: WindowBatcher::push_ref
/// [`flush_ref`]: WindowBatcher::flush_ref
/// [`push`]: WindowBatcher::push
/// [`flush`]: WindowBatcher::flush
#[derive(Debug, Default)]
pub struct WindowBatcher {
    current: DeltaGraph,
    coalesce: CoalesceBuf,
    events_in_window: usize,
    /// `current` holds a window already lent out by `push_ref`/`flush_ref`;
    /// it is reset lazily on the next event so the borrow can outlive the
    /// call that produced it.
    emitted: bool,
}

impl WindowBatcher {
    pub fn new() -> Self {
        Self::default()
    }

    fn reset_if_emitted(&mut self) {
        if self.emitted {
            self.current.clear();
            self.emitted = false;
        }
    }

    /// Feed one event; on `Tick`, closes the window and returns it coalesced
    /// **by reference** into the batcher's reusable buffer (valid until the
    /// next `push_ref`/`flush_ref` call). The tick itself counts as one
    /// event, matching the pipeline's historical accounting.
    // lint: hot-path
    pub fn push_ref(&mut self, ev: StreamEvent) -> Option<(&DeltaGraph, usize)> {
        self.reset_if_emitted();
        match ev {
            StreamEvent::EdgeDelta { i, j, dw } => {
                if i != j {
                    self.current.add(i, j, dw);
                }
                self.events_in_window += 1;
                None
            }
            StreamEvent::GrowNodes { count } => {
                self.current.grow_nodes(count);
                self.events_in_window += 1;
                None
            }
            StreamEvent::Tick => {
                self.current.coalesce_in_place(&mut self.coalesce);
                let n = self.events_in_window + 1;
                self.events_in_window = 0;
                self.emitted = true;
                // coalesce ratio telemetry: events in vs deltas surviving
                crate::obs::Counter::WinEventsIn.add(n as u64);
                crate::obs::Counter::WinCoalesced.add(self.current.edge_deltas().len() as u64);
                Some((&self.current, n))
            }
        }
    }

    /// Close a trailing partial window by reference (stream ended without a
    /// final tick). Same lifetime contract as [`WindowBatcher::push_ref`].
    pub fn flush_ref(&mut self) -> Option<(&DeltaGraph, usize)> {
        self.reset_if_emitted();
        if self.events_in_window == 0 {
            return None;
        }
        self.current.coalesce_in_place(&mut self.coalesce);
        let n = self.events_in_window;
        self.events_in_window = 0;
        self.emitted = true;
        crate::obs::Counter::WinEventsIn.add(n as u64);
        crate::obs::Counter::WinCoalesced.add(self.current.edge_deltas().len() as u64);
        Some((&self.current, n))
    }
    // lint: hot-path end

    /// Owning variant of [`WindowBatcher::push_ref`] (clones the emitted
    /// window so it can cross a thread boundary).
    pub fn push(&mut self, ev: StreamEvent) -> Option<(DeltaGraph, usize)> {
        self.push_ref(ev).map(|(d, n)| (d.clone(), n))
    }

    /// Owning variant of [`WindowBatcher::flush_ref`].
    pub fn flush(&mut self) -> Option<(DeltaGraph, usize)> {
        self.flush_ref().map(|(d, n)| (d.clone(), n))
    }

    /// Events accumulated in the currently-open window.
    pub fn pending_events(&self) -> usize {
        self.events_in_window
    }
}

/// Online anomaly rule: a score is anomalous when it exceeds μ + kσ of the
/// trailing window of *previous* scores (the current score is added after
/// the decision, and no decision is made until 4 scores have been seen).
///
/// μ and σ are maintained as rolling Σx / Σx² so each decision is O(1)
/// instead of copying the trailing deque and recomputing two passes per
/// window. Decisions match the two-pass recompute rule except for scores
/// landing within float-drift distance of the μ + kσ threshold itself (the
/// rolling one-pass variance differs from the two-pass form by ulps); the
/// sums are re-derived from the retained deque every `REFRESH_EVERY`
/// observations, which bounds the drift a rolling subtract can accumulate
/// on long streams.
#[derive(Debug, Clone)]
pub struct AnomalyDetector {
    sigma: f64,
    window: usize,
    trailing: VecDeque<f64>,
    /// Rolling Σx over `trailing`.
    sum: f64,
    /// Rolling Σx² over `trailing`.
    sum_sq: f64,
    observed: u64,
}

impl AnomalyDetector {
    /// Rolling sums are refreshed from the deque after this many `observe`
    /// calls (drift bound; the refresh itself is O(window) and alloc-free).
    const REFRESH_EVERY: u64 = 1024;

    /// `window` is clamped to ≥ 4: a decision needs 4 trailing samples, so a
    /// smaller window would silently disable detection forever.
    pub fn new(sigma: f64, window: usize) -> Self {
        Self {
            sigma,
            window: window.max(4),
            trailing: VecDeque::new(),
            sum: 0.0,
            sum_sq: 0.0,
            observed: 0,
        }
    }

    /// Judge `score` against the trailing statistics, then fold it in. O(1).
    pub fn observe(&mut self, score: f64) -> bool {
        let anomalous = if self.trailing.len() >= 4 {
            let n = self.trailing.len() as f64;
            let mu = self.sum / n;
            // population variance via E[x²] − μ²; clamped at 0 because the
            // one-pass form can go fractionally negative on near-constant
            // windows where the two-pass recompute would give ~0
            let var = (self.sum_sq / n - mu * mu).max(0.0);
            score > mu + self.sigma * var.sqrt().max(1e-12)
        } else {
            false
        };
        self.trailing.push_back(score);
        self.sum += score;
        self.sum_sq += score * score;
        if self.trailing.len() > self.window {
            if let Some(old) = self.trailing.pop_front() {
                self.sum -= old;
                self.sum_sq -= old * old;
            }
        }
        self.observed += 1;
        if self.observed % Self::REFRESH_EVERY == 0 {
            self.refresh_sums();
        }
        anomalous
    }

    /// Recompute the rolling sums from the retained samples.
    fn refresh_sums(&mut self) {
        self.sum = self.trailing.iter().sum();
        self.sum_sq = self.trailing.iter().map(|x| x * x).sum();
    }

    /// Re-derive the rolling sums from the retained deque *now*. The epoch
    /// canonicalization calls this on live detectors so their sums match
    /// what [`AnomalyDetector::restore`] will recompute after a recovery —
    /// rolling drift would otherwise make marginal decisions diverge.
    pub fn canonicalize(&mut self) {
        self.refresh_sums();
    }

    /// Observations folded in so far.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// The retained trailing scores, oldest first.
    pub fn trailing_scores(&self) -> impl Iterator<Item = f64> + '_ {
        self.trailing.iter().copied()
    }

    /// Restore the detector to a checkpointed position: the retained
    /// trailing scores (oldest first) and the observation count. Sums are
    /// recomputed two-pass — identical to what [`AnomalyDetector::canonicalize`]
    /// left on the live side at the matching epoch barrier.
    pub fn restore(&mut self, trailing: &[f64], observed: u64) {
        self.trailing.clear();
        self.trailing.extend(trailing.iter().copied());
        while self.trailing.len() > self.window {
            self.trailing.pop_front();
        }
        self.observed = observed;
        self.refresh_sums();
    }
}

/// Drift-bounded auto-resync schedule for long-lived streams: resync every
/// `interval` windows, halving the interval (down to `min_interval`) when the
/// measured |ΔQ| drift exceeds `drift_tolerance` and doubling it (up to
/// `max_interval`) while updates stay clean. `initial_interval == 0`
/// disables resyncing entirely (the single-stream `Pipeline` default, which
/// keeps its output bit-identical to the direct Algorithm-2 loop).
#[derive(Debug, Clone)]
pub struct ResyncPolicy {
    pub initial_interval: u64,
    pub min_interval: u64,
    pub max_interval: u64,
    pub drift_tolerance: f64,
}

impl Default for ResyncPolicy {
    fn default() -> Self {
        Self { initial_interval: 256, min_interval: 16, max_interval: 8192, drift_tolerance: 1e-9 }
    }
}

impl ResyncPolicy {
    /// Never resync (exact-replay semantics).
    pub fn disabled() -> Self {
        Self { initial_interval: 0, ..Self::default() }
    }

    /// Adaptive schedule starting at `interval` windows.
    pub fn every(interval: u64) -> Self {
        Self { initial_interval: interval, ..Self::default() }
    }
}

/// Scores window deltas against an owned incremental `FingerState`:
/// Algorithm 2 per window, online anomaly flagging, per-window latency, and
/// scheduled drift correction. Owns a reusable [`Scratch`] workspace, so a
/// steady-state window is scored without allocating (scores stay bit-for-bit
/// identical to the allocating `jsdist_incremental`).
#[derive(Debug)]
pub struct WindowScorer {
    state: FingerState,
    detector: AnomalyDetector,
    scratch: Scratch,
    resync: ResyncPolicy,
    interval: u64,
    since_resync: u64,
    window: usize,
    resyncs: u64,
    max_drift: f64,
}

impl WindowScorer {
    pub fn new(state: FingerState, detector: AnomalyDetector, resync: ResyncPolicy) -> Self {
        let interval = resync.initial_interval;
        Self {
            state,
            detector,
            scratch: Scratch::default(),
            resync,
            interval,
            since_resync: 0,
            window: 0,
            resyncs: 0,
            max_drift: 0.0,
        }
    }

    /// Score one window delta and advance the state (Algorithm 2 commits ΔG).
    // lint: hot-path
    pub fn score(&mut self, delta: &DeltaGraph, n_events: usize) -> ScoreRecord {
        let t0 = Instant::now();
        let js =
            crate::distance::jsdist_incremental_with(&mut self.state, delta, &mut self.scratch);
        let latency = t0.elapsed().as_secs_f64();
        let anomalous = self.detector.observe(js);
        // zero-allocation registry record: latency histogram (striped by
        // window index) + window/anomaly counters
        crate::obs::score_window((latency * 1e6) as u64, anomalous, self.window);
        let record = ScoreRecord {
            window: self.window,
            jsdist: js,
            htilde: self.state.htilde(),
            nodes: self.state.graph().num_nodes(),
            edges: self.state.graph().num_edges(),
            events: n_events,
            latency,
            anomalous,
        };
        self.window += 1;
        self.maybe_resync();
        record
    }
    // lint: hot-path end

    fn maybe_resync(&mut self) {
        if self.interval == 0 {
            return;
        }
        self.since_resync += 1;
        if self.since_resync < self.interval {
            return;
        }
        self.since_resync = 0;
        let drift = self.state.resync();
        self.resyncs += 1;
        if drift > self.max_drift {
            self.max_drift = drift;
        }
        self.interval = if drift > self.resync.drift_tolerance {
            (self.interval / 2).max(self.resync.min_interval)
        } else {
            self.interval.saturating_mul(2).min(self.resync.max_interval)
        };
    }

    pub fn state(&self) -> &FingerState {
        &self.state
    }

    pub fn into_state(self) -> FingerState {
        self.state
    }

    /// Swap in a replacement `FingerState` (the epoch canonicalization
    /// substitutes the checkpoint-roundtripped state for the live one, so
    /// live-after-barrier and restored-from-checkpoint agree bit for bit).
    /// Progress counters and the detector are untouched.
    pub fn replace_state(&mut self, state: FingerState) {
        self.state = state;
    }

    /// Windows scored so far.
    pub fn windows(&self) -> usize {
        self.window
    }

    /// Resyncs performed by the drift-bounded schedule.
    pub fn resyncs(&self) -> u64 {
        self.resyncs
    }

    /// Largest |ΔQ| drift any resync corrected.
    pub fn max_drift(&self) -> f64 {
        self.max_drift
    }

    /// Current adaptive resync interval (0 when resync is disabled).
    pub fn resync_interval(&self) -> u64 {
        self.interval
    }

    /// Windows since the last resync.
    pub fn since_resync(&self) -> u64 {
        self.since_resync
    }

    /// The online anomaly detector (durable metadata reads its position).
    pub fn detector(&self) -> &AnomalyDetector {
        &self.detector
    }

    /// Re-derive the detector's rolling sums ([`AnomalyDetector::canonicalize`]).
    pub fn canonicalize_detector(&mut self) {
        self.detector.canonicalize();
    }

    /// Restore scorer progress to a checkpointed position: window count, the
    /// adaptive resync schedule's live interval/phase, and the resync stats.
    /// Restoring these verbatim (rather than re-deriving) is what keeps the
    /// post-recovery resync *schedule* — and therefore every future
    /// drift-correction point — identical to the crashed server's.
    pub fn restore_progress(
        &mut self,
        windows: usize,
        interval: u64,
        since_resync: u64,
        resyncs: u64,
        max_drift: f64,
    ) {
        self.window = windows;
        self.interval = interval;
        self.since_resync = since_resync;
        self.resyncs = resyncs;
        self.max_drift = max_drift;
    }

    /// Restore the detector ([`AnomalyDetector::restore`]).
    pub fn restore_detector(&mut self, trailing: &[f64], observed: u64) {
        self.detector.restore(trailing, observed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::stream::event::StreamEvent as Ev;
    use crate::util::Pcg64;

    #[test]
    fn batcher_groups_and_flushes() {
        let mut b = WindowBatcher::new();
        assert!(b.push(Ev::EdgeDelta { i: 0, j: 1, dw: 1.0 }).is_none());
        assert!(b.push(Ev::EdgeDelta { i: 2, j: 2, dw: 1.0 }).is_none()); // self-loop skipped
        let (d, n) = b.push(Ev::Tick).unwrap();
        assert_eq!(n, 3); // two edge events + the tick
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(d.edge_deltas(), &[(0, 1, 1.0)]);
        assert!(b.flush().is_none()); // nothing pending after a tick
        b.push(Ev::GrowNodes { count: 2 });
        let (d, n) = b.flush().unwrap();
        assert_eq!((d.new_nodes(), n), (2, 1));
    }

    #[test]
    fn push_ref_reuses_buffers_and_matches_owned_push() {
        // the in-place window must equal the owned (cloned) one, window after
        // window, including duplicate coalescing and node growth
        let mut a = WindowBatcher::new();
        let mut b = WindowBatcher::new();
        let mut rng = Pcg64::new(77);
        for w in 0..20 {
            let mut evs = Vec::new();
            for _ in 0..6 {
                let i = rng.below(10) as u32;
                let j = rng.below(10) as u32;
                evs.push(Ev::EdgeDelta { i, j, dw: rng.uniform(-1.0, 1.0) });
            }
            if w % 3 == 0 {
                evs.push(Ev::GrowNodes { count: 1 });
            }
            evs.push(Ev::Tick);
            for ev in evs {
                let ra = a.push_ref(ev.clone()).map(|(d, n)| (d.clone(), n));
                let rb = b.push(ev);
                match (ra, rb) {
                    (None, None) => {}
                    (Some((da, na)), Some((db, nb))) => {
                        assert_eq!(na, nb, "window {w}");
                        assert_eq!(da.edge_deltas(), db.edge_deltas(), "window {w}");
                        assert_eq!(da.new_nodes(), db.new_nodes(), "window {w}");
                        assert!(da.is_sorted_unique(), "window {w} not normal form");
                    }
                    other => panic!("window {w}: mismatch {other:?}"),
                }
            }
        }
        // trailing partial window via flush_ref
        a.push_ref(Ev::EdgeDelta { i: 0, j: 1, dw: 1.0 });
        b.push(Ev::EdgeDelta { i: 0, j: 1, dw: 1.0 });
        let (da, na) = a.flush_ref().map(|(d, n)| (d.clone(), n)).unwrap();
        let (db, nb) = b.flush().unwrap();
        assert_eq!((da.edge_deltas(), na), (db.edge_deltas(), nb));
    }

    #[test]
    fn detector_rolling_decisions_match_recompute_rule() {
        // The O(1) rolling μ/σ must decide like the two-pass recompute over
        // the same trailing window (the pre-optimization rule). The two
        // formulations agree only up to float drift of the threshold itself
        // (rolling subtraction + one-pass variance vs two-pass), so scores
        // landing within a tiny band around μ + kσ are legitimately
        // undetermined and excluded from the comparison; everything else —
        // the decisions that matter — must match.
        let mut rolling = AnomalyDetector::new(2.5, 16);
        let mut trailing: VecDeque<f64> = VecDeque::new();
        let mut rng = Pcg64::new(0x0B5E);
        let mut decided = 0usize;
        for step in 0..5000 {
            // mix of smooth scores and occasional spikes
            let score = if rng.below(40) == 0 {
                rng.uniform(5.0, 50.0)
            } else {
                rng.uniform(0.0, 1.0)
            };
            let got = rolling.observe(score);
            if trailing.len() >= 4 {
                let xs: Vec<f64> = trailing.iter().copied().collect();
                let mu = crate::util::stats::mean(&xs);
                let sd = crate::util::stats::std_dev(&xs);
                let threshold = mu + 2.5 * sd.max(1e-12);
                let margin = 1e-9 * (1.0 + threshold.abs());
                if (score - threshold).abs() > margin {
                    assert_eq!(got, score > threshold, "step {step} score {score}");
                    decided += 1;
                }
            }
            trailing.push_back(score);
            if trailing.len() > 16 {
                trailing.pop_front();
            }
        }
        assert!(decided > 4900, "comparison skipped too often: {decided}");
    }

    #[test]
    fn detector_matches_trailing_rule() {
        let mut det = AnomalyDetector::new(3.0, 8);
        for _ in 0..6 {
            assert!(!det.observe(1.0));
        }
        assert!(det.observe(100.0)); // huge spike vs σ≈0 trailing window
        assert!(!det.observe(1.0));
    }

    #[test]
    fn detector_window_clamped_so_it_can_still_fire() {
        // window < 4 would otherwise never accumulate the 4 samples a
        // decision requires — the constructor clamps it
        let mut det = AnomalyDetector::new(3.0, 1);
        for _ in 0..5 {
            assert!(!det.observe(1.0));
        }
        assert!(det.observe(100.0));
    }

    #[test]
    fn scorer_resyncs_on_schedule_without_changing_scores() {
        let g = generators::erdos_renyi(40, 0.1, &mut Pcg64::new(9));
        let mut rng = Pcg64::new(10);
        let mut deltas = Vec::new();
        for _ in 0..24 {
            let mut d = DeltaGraph::new();
            let i = rng.below(40) as u32;
            let j = (i + 1 + rng.below(39) as u32) % 40;
            if i != j {
                d.add(i, j, rng.uniform(0.1, 1.0));
            }
            deltas.push(d.coalesced());
        }
        let mk = |resync: ResyncPolicy| {
            WindowScorer::new(
                FingerState::new(g.clone()),
                AnomalyDetector::new(3.0, 24),
                resync,
            )
        };
        let mut with = mk(ResyncPolicy::every(4));
        let mut without = mk(ResyncPolicy::disabled());
        for d in &deltas {
            let a = with.score(d, 1);
            let b = without.score(d, 1);
            // resync corrects float drift only; scores agree to tight tol
            assert!((a.jsdist - b.jsdist).abs() < 1e-9);
        }
        assert!(with.resyncs() >= 2);
        assert_eq!(without.resyncs(), 0);
        assert!(with.max_drift() < 1e-8, "drift={}", with.max_drift());
    }
}
