//! Anomaly and bifurcation evaluation: consecutive-pair dissimilarity series,
//! the temporal difference score (TDS) with its local-minimum bifurcation
//! detector (Liu et al. 2018a), and the top-k detection-rate evaluator used
//! by the DoS experiment (Table 3).

use crate::graph::GraphSequence;

/// Dissimilarity series θ_{t,t+1} between consecutive snapshots; length T−1.
pub fn consecutive_scores(
    seq: &GraphSequence,
    mut dissim: impl FnMut(&crate::graph::Graph, &crate::graph::Graph) -> f64,
) -> Vec<f64> {
    seq.pairs().map(|(a, b)| dissim(a, b)).collect()
}

/// Temporal difference score (TDS) over a consecutive-pair series θ of
/// length T−1:
///   TDS(1)   = θ_{1,2}
///   TDS(t)   = ½(θ_{t−1,t} + θ_{t,t+1})   for 2 ≤ t ≤ T−1
///   TDS(T)   = θ_{T−1,T}
/// Returned vector has length T (1-based t maps to index t−1).
pub fn temporal_difference_score(theta: &[f64]) -> Vec<f64> {
    let t_pairs = theta.len();
    if t_pairs == 0 {
        return Vec::new();
    }
    let t_total = t_pairs + 1;
    let mut tds = Vec::with_capacity(t_total);
    tds.push(theta[0]);
    for t in 1..t_pairs {
        tds.push(0.5 * (theta[t - 1] + theta[t]));
    }
    tds.push(theta[t_pairs - 1]);
    tds
}

/// Bifurcation instances: indices (0-based) of strict local minima of the TDS
/// curve, excluding the first and last measurements (supplement §L).
pub fn detect_bifurcations(tds: &[f64]) -> Vec<usize> {
    let n = tds.len();
    if n < 3 {
        return Vec::new();
    }
    let mut out = Vec::new();
    for t in 1..n - 1 {
        if tds[t] < tds[t - 1] && tds[t] <= tds[t + 1] {
            out.push(t);
        }
    }
    out
}

/// Top-k detection: does the anomalous pair index land in the k largest
/// scores? (Table 3 uses k = 2 over the 8 consecutive-pair scores.)
pub fn detected_top_k(scores: &[f64], anomaly_idx: usize, k: usize) -> bool {
    crate::util::stats::top_k_indices(scores, k).contains(&anomaly_idx)
}

/// Detection rate over a set of trials: fraction where `detected_top_k`.
pub struct DetectionTrial {
    pub scores: Vec<f64>,
    pub anomaly_idx: usize,
}

pub fn detection_rate(trials: &[DetectionTrial], k: usize) -> f64 {
    if trials.is_empty() {
        return 0.0;
    }
    let hits = trials.iter().filter(|t| detected_top_k(&t.scores, t.anomaly_idx, k)).count();
    hits as f64 / trials.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn tds_endpoints_and_interior() {
        let theta = [1.0, 3.0, 5.0];
        let tds = temporal_difference_score(&theta);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(tds, vec![1.0, 2.0, 4.0, 5.0]);
        assert_eq!(tds.len(), theta.len() + 1);
    }

    #[test]
    fn tds_empty() {
        assert!(temporal_difference_score(&[]).is_empty());
    }

    #[test]
    fn bifurcation_local_min() {
        //                      0    1    2    3    4    5
        let tds = [3.0, 2.0, 0.5, 1.5, 1.0, 2.0];
        let b = detect_bifurcations(&tds);
        assert_eq!(b, vec![2, 4]);
    }

    #[test]
    fn bifurcation_excludes_endpoints() {
        let tds = [0.1, 5.0, 0.2]; // min at ends not counted
        assert!(detect_bifurcations(&tds).is_empty());
    }

    #[test]
    fn bifurcation_plateau_counts_left_edge() {
        let tds = [3.0, 1.0, 1.0, 3.0];
        assert_eq!(detect_bifurcations(&tds), vec![1]);
    }

    #[test]
    fn top_k_detection() {
        let scores = [0.1, 0.9, 0.3, 0.8];
        assert!(detected_top_k(&scores, 1, 2));
        assert!(detected_top_k(&scores, 3, 2));
        assert!(!detected_top_k(&scores, 0, 2));
    }

    #[test]
    fn detection_rate_counts() {
        let trials = vec![
            DetectionTrial { scores: vec![0.9, 0.1], anomaly_idx: 0 },
            DetectionTrial { scores: vec![0.1, 0.9], anomaly_idx: 0 },
        ];
        assert_bits_eq!(detection_rate(&trials, 1), 0.5);
        assert_bits_eq!(detection_rate(&[], 1), 0.0);
    }

    #[test]
    fn consecutive_scores_length() {
        use crate::graph::Graph;
        let seq = crate::graph::GraphSequence::from_snapshots(vec![
            Graph::new(3),
            Graph::new(3),
            Graph::new(3),
        ]);
        let s = consecutive_scores(&seq, |_, _| 1.0);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(s, vec![1.0, 1.0]);
    }
}
