//! Recovery planning: turn the on-disk durability state back into the exact
//! inputs the service needs to resume.
//!
//! Recovery = snapshot + replay. [`plan`] reads `CURRENT`, loads the
//! committed epoch's manifest, and lists — per shard, in sequence order —
//! the WAL segments past the manifest's covered position. The service then
//! restores each session's `FingerState` from the epoch's checkpoint files
//! and replays the listed segments through the normal `WindowScorer` path;
//! because the WAL holds the exact coalesced deltas (bit-exact floats) and
//! the EPOCH markers reproduce the live server's canonicalization points,
//! the replayed states are bit-identical to the crashed server's.
//!
//! The plan is explicit about shard topology: WAL streams are ordered *per
//! disk shard* (the shard count the state was written under), and the plan
//! keys its segment lists by that count ([`RecoveryPlan::disk_shards`]). A
//! service restarting with a *different* shard count replays the same
//! per-disk-shard streams but routes every record's session through
//! `shard_of(id, new_shards)` — per-session order is preserved because a
//! session's whole history lives in exactly one disk stream. The service
//! commits a fresh epoch immediately after a rebound recovery so the
//! old-layout segments are pruned before any new-layout WAL traffic lands.

use super::snapshot::{self, EpochManifest};
use super::{wal, DurabilityConfig};
use std::io;
use std::path::PathBuf;

/// What a restarting service must do to resume bit-identically.
#[derive(Debug)]
pub struct RecoveryPlan {
    /// The committed epoch's manifest, if any epoch ever committed.
    pub manifest: Option<EpochManifest>,
    /// Directory of per-session checkpoint files for that epoch.
    pub epoch_dir: Option<PathBuf>,
    /// Per **disk** shard (indexed `0..disk_shards`): WAL segments to
    /// replay, ascending by sequence.
    pub segments: Vec<Vec<(u64, PathBuf)>>,
    /// The shard count the on-disk state was written under — the manifest's
    /// count when an epoch committed, otherwise inferred from the highest
    /// segment shard index (falling back to the restarting service's own
    /// count for a fresh or in-range directory).
    pub disk_shards: usize,
}

impl RecoveryPlan {
    /// True when there is nothing on disk to recover (fresh directory).
    pub fn is_empty(&self) -> bool {
        self.manifest.is_none() && self.segments.iter().all(Vec::is_empty)
    }

    /// Total segments scheduled for replay.
    pub fn segment_count(&self) -> usize {
        self.segments.iter().map(Vec::len).sum()
    }
}

/// Build the recovery plan for a service configured with `shards` shards.
/// The plan's segment lists are keyed by *disk* shard; a `disk_shards !=
/// shards` plan is a rebind and the caller must route replayed records
/// through `shard_of(id, shards)` itself.
pub fn plan(cfg: &DurabilityConfig, shards: usize) -> io::Result<RecoveryPlan> {
    let manifest = match snapshot::read_current(cfg)? {
        Some(epoch) => Some(snapshot::load_manifest(&cfg.epoch_dir(epoch))?),
        None => None,
    };
    let scanned = wal::scan_segments(&cfg.wal_dir())?;
    let max_seen = scanned.iter().map(|&(shard, _, _)| shard + 1).max().unwrap_or(0);
    // Without a manifest the true disk layout is unknown; segments beyond
    // the restarting count prove a wider one, otherwise assume the counts
    // match (a narrower old layout with no committed epoch is
    // indistinguishable from shards that simply saw no traffic).
    let disk_shards =
        manifest.as_ref().map_or_else(|| max_seen.max(shards), |m| m.shards).max(1);

    let mut segments: Vec<Vec<(u64, PathBuf)>> = vec![Vec::new(); disk_shards];
    for (shard, seq, path) in scanned {
        let Some(slot) = segments.get_mut(shard) else {
            // a segment beyond the manifest's own shard count is a
            // pre-snapshot leftover prune will collect; skip it
            continue;
        };
        let covered = manifest
            .as_ref()
            .and_then(|m| m.next_seq.get(shard))
            .is_some_and(|&next| seq < next);
        if !covered {
            slot.push((seq, path));
        }
    }
    for slot in &mut segments {
        slot.sort_by_key(|&(seq, _)| seq);
    }

    let epoch_dir = manifest.as_ref().map(|m| cfg.epoch_dir(m.epoch));
    Ok(RecoveryPlan { manifest, epoch_dir, segments, disk_shards })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::durability::snapshot::{commit_epoch, prepare_epoch_tmp, EpochCut};
    use std::fs;

    fn scratch(tag: &str) -> DurabilityConfig {
        let root = std::env::temp_dir()
            .join(format!("finger_recovery_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        let cfg = DurabilityConfig::new(&root);
        fs::create_dir_all(cfg.wal_dir()).unwrap();
        cfg
    }

    fn teardown(cfg: &DurabilityConfig) {
        fs::remove_dir_all(&cfg.dir).ok();
    }

    #[test]
    fn fresh_directory_plans_empty() {
        let cfg = scratch("fresh");
        let p = plan(&cfg, 4).unwrap();
        assert!(p.is_empty());
        assert_eq!(p.segments.len(), 4);
        teardown(&cfg);
    }

    #[test]
    fn without_manifest_all_segments_replay() {
        let cfg = scratch("nomanifest");
        for (shard, seq) in [(0usize, 1u64), (0, 2), (1, 1)] {
            fs::write(cfg.wal_dir().join(wal::segment_name(shard, seq)), b"").unwrap();
        }
        let p = plan(&cfg, 2).unwrap();
        assert!(p.manifest.is_none());
        assert_eq!(p.segments[0].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(p.segments[1].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1]);
        teardown(&cfg);
    }

    #[test]
    fn manifest_skips_covered_segments() {
        let cfg = scratch("covered");
        for seq in 1..=4u64 {
            fs::write(cfg.wal_dir().join(wal::segment_name(0, seq)), b"").unwrap();
        }
        prepare_epoch_tmp(&cfg, 1).unwrap();
        commit_epoch(
            &cfg,
            1,
            &[EpochCut { shard: 0, next_seq: 3, sessions: Vec::new() }],
        )
        .unwrap();
        let p = plan(&cfg, 1).unwrap();
        assert_eq!(p.manifest.as_ref().unwrap().epoch, 1);
        assert_eq!(p.epoch_dir.as_deref(), Some(cfg.epoch_dir(1).as_path()));
        // commit pruned 1..=2; the plan replays 3..=4
        assert_eq!(p.segments[0].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![3, 4]);
        teardown(&cfg);
    }

    #[test]
    fn shard_count_mismatch_plans_a_rebind() {
        let cfg = scratch("mismatch");
        for (shard, seq) in [(0usize, 2u64), (1, 2), (1, 3)] {
            fs::write(cfg.wal_dir().join(wal::segment_name(shard, seq)), b"").unwrap();
        }
        prepare_epoch_tmp(&cfg, 1).unwrap();
        commit_epoch(
            &cfg,
            1,
            &[
                EpochCut { shard: 0, next_seq: 2, sessions: Vec::new() },
                EpochCut { shard: 1, next_seq: 2, sessions: Vec::new() },
            ],
        )
        .unwrap();
        // the 2-shard directory restarts on 3 shards: segment lists stay
        // keyed by the recorded disk layout, covered segments still skipped
        let p = plan(&cfg, 3).unwrap();
        assert_eq!(p.disk_shards, 2);
        assert_eq!(p.segments.len(), 2);
        assert_eq!(p.segments[0].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![2]);
        assert_eq!(p.segments[1].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![2, 3]);

        // without a manifest a high-shard segment widens the inferred layout
        let cfg2 = scratch("mismatch2");
        fs::write(cfg2.wal_dir().join(wal::segment_name(5, 1)), b"").unwrap();
        let p2 = plan(&cfg2, 2).unwrap();
        assert_eq!(p2.disk_shards, 6);
        assert_eq!(p2.segments[5].len(), 1);
        teardown(&cfg);
        teardown(&cfg2);
    }
}
