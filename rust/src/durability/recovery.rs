//! Recovery planning: turn the on-disk durability state back into the exact
//! inputs the service needs to resume.
//!
//! Recovery = snapshot + replay. [`plan`] reads `CURRENT`, loads the
//! committed epoch's manifest, and lists — per shard, in sequence order —
//! the WAL segments past the manifest's covered position. The service then
//! restores each session's `FingerState` from the epoch's checkpoint files
//! and replays the listed segments through the normal `WindowScorer` path;
//! because the WAL holds the exact coalesced deltas (bit-exact floats) and
//! the EPOCH markers reproduce the live server's canonicalization points,
//! the replayed states are bit-identical to the crashed server's.
//!
//! The plan is strict about shard topology: WAL streams are ordered *per
//! shard*, so replaying them under a different shard count would interleave
//! a session's windows incorrectly. A mismatch is a hard error with a clear
//! message (restart with the recorded shard count, or move the directory
//! aside to start fresh).

use super::snapshot::{self, EpochManifest};
use super::{wal, DurabilityConfig};
use std::io;
use std::path::PathBuf;

/// What a restarting service must do to resume bit-identically.
#[derive(Debug)]
pub struct RecoveryPlan {
    /// The committed epoch's manifest, if any epoch ever committed.
    pub manifest: Option<EpochManifest>,
    /// Directory of per-session checkpoint files for that epoch.
    pub epoch_dir: Option<PathBuf>,
    /// Per shard (indexed 0..shards): WAL segments to replay, ascending.
    pub segments: Vec<Vec<(u64, PathBuf)>>,
}

impl RecoveryPlan {
    /// True when there is nothing on disk to recover (fresh directory).
    pub fn is_empty(&self) -> bool {
        self.manifest.is_none() && self.segments.iter().all(Vec::is_empty)
    }

    /// Total segments scheduled for replay.
    pub fn segment_count(&self) -> usize {
        self.segments.iter().map(Vec::len).sum()
    }
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Build the recovery plan for a service configured with `shards` shards.
pub fn plan(cfg: &DurabilityConfig, shards: usize) -> io::Result<RecoveryPlan> {
    let manifest = match snapshot::read_current(cfg)? {
        Some(epoch) => Some(snapshot::load_manifest(&cfg.epoch_dir(epoch))?),
        None => None,
    };
    if let Some(m) = &manifest {
        if m.shards != shards {
            return Err(bad(format!(
                "durability state at {} was written by a {}-shard service but this one has \
                 {shards}; restart with shards={} (or move the directory aside to start fresh)",
                cfg.dir.display(),
                m.shards,
                m.shards,
            )));
        }
    }

    let mut segments: Vec<Vec<(u64, PathBuf)>> = vec![Vec::new(); shards];
    for (shard, seq, path) in wal::scan_segments(&cfg.wal_dir())? {
        let Some(slot) = segments.get_mut(shard) else {
            if manifest.is_some() {
                // the manifest's shard count matched, so this segment is a
                // pre-snapshot leftover prune will collect; skip it
                continue;
            }
            return Err(bad(format!(
                "WAL at {} has segments for shard {shard} but this service has {shards} \
                 shards; restart with the original shard count (or move the directory aside)",
                cfg.wal_dir().display(),
            )));
        };
        let covered = manifest
            .as_ref()
            .and_then(|m| m.next_seq.get(shard))
            .is_some_and(|&next| seq < next);
        if !covered {
            slot.push((seq, path));
        }
    }
    for slot in &mut segments {
        slot.sort_by_key(|&(seq, _)| seq);
    }

    let epoch_dir = manifest.as_ref().map(|m| cfg.epoch_dir(m.epoch));
    Ok(RecoveryPlan { manifest, epoch_dir, segments })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::durability::snapshot::{commit_epoch, prepare_epoch_tmp, EpochCut};
    use std::fs;

    fn scratch(tag: &str) -> DurabilityConfig {
        let root = std::env::temp_dir()
            .join(format!("finger_recovery_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        let cfg = DurabilityConfig::new(&root);
        fs::create_dir_all(cfg.wal_dir()).unwrap();
        cfg
    }

    fn teardown(cfg: &DurabilityConfig) {
        fs::remove_dir_all(&cfg.dir).ok();
    }

    #[test]
    fn fresh_directory_plans_empty() {
        let cfg = scratch("fresh");
        let p = plan(&cfg, 4).unwrap();
        assert!(p.is_empty());
        assert_eq!(p.segments.len(), 4);
        teardown(&cfg);
    }

    #[test]
    fn without_manifest_all_segments_replay() {
        let cfg = scratch("nomanifest");
        for (shard, seq) in [(0usize, 1u64), (0, 2), (1, 1)] {
            fs::write(cfg.wal_dir().join(wal::segment_name(shard, seq)), b"").unwrap();
        }
        let p = plan(&cfg, 2).unwrap();
        assert!(p.manifest.is_none());
        assert_eq!(p.segments[0].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(p.segments[1].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1]);
        teardown(&cfg);
    }

    #[test]
    fn manifest_skips_covered_segments() {
        let cfg = scratch("covered");
        for seq in 1..=4u64 {
            fs::write(cfg.wal_dir().join(wal::segment_name(0, seq)), b"").unwrap();
        }
        prepare_epoch_tmp(&cfg, 1).unwrap();
        commit_epoch(
            &cfg,
            1,
            &[EpochCut { shard: 0, next_seq: 3, sessions: Vec::new() }],
        )
        .unwrap();
        let p = plan(&cfg, 1).unwrap();
        assert_eq!(p.manifest.as_ref().unwrap().epoch, 1);
        assert_eq!(p.epoch_dir.as_deref(), Some(cfg.epoch_dir(1).as_path()));
        // commit pruned 1..=2; the plan replays 3..=4
        assert_eq!(p.segments[0].iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![3, 4]);
        teardown(&cfg);
    }

    #[test]
    fn shard_count_mismatch_is_a_hard_error() {
        let cfg = scratch("mismatch");
        prepare_epoch_tmp(&cfg, 1).unwrap();
        commit_epoch(
            &cfg,
            1,
            &[
                EpochCut { shard: 0, next_seq: 2, sessions: Vec::new() },
                EpochCut { shard: 1, next_seq: 2, sessions: Vec::new() },
            ],
        )
        .unwrap();
        let err = plan(&cfg, 3).unwrap_err();
        assert!(err.to_string().contains("2-shard"), "{err}");

        // same without a manifest: a stray high-shard segment must refuse too
        let cfg2 = scratch("mismatch2");
        fs::write(cfg2.wal_dir().join(wal::segment_name(5, 1)), b"").unwrap();
        assert!(plan(&cfg2, 2).is_err());
        teardown(&cfg);
        teardown(&cfg2);
    }
}
