//! Per-shard append-only write-ahead log.
//!
//! Each shard worker owns a sequence of segment files,
//! `wal/shard-SSSS-NNNNNNNNNN.wal`, and appends one record per *committed
//! window* — the coalesced [`DeltaGraph`] exactly as handed to the scorer —
//! **before** scoring it, so that replaying the log through the normal
//! `WindowScorer` path reproduces bit-identical scores. Session opens and
//! closes are logged too, making the log self-contained between snapshots.
//!
//! ## Record framing
//!
//! ```text
//! [u32 LE body_len] [body: body_len bytes] [u32 LE crc32(body)]
//! ```
//!
//! The body starts with a record-type byte:
//!
//! | type | record | payload |
//! |------|--------|---------|
//! | 1    | OPEN   | id, varint nodes, varint m, m × edge |
//! | 2    | WINDOW | id, varint window_seq, varint n_events, varint new_nodes, varint m, m × edge |
//! | 3    | CLOSE  | id |
//! | 4    | EPOCH  | varint epoch |
//!
//! where `id` is `varint len` + raw bytes and `edge` is
//! `varint i, varint j, 8-byte LE f64 weight bits` — the same strict LEB128
//! varints and raw-bits floats as the v2 wire codec, so a decoded delta is
//! bit-exact by construction.
//!
//! An EPOCH record is always the *first* record of a fresh segment (the
//! epoch barrier rotates segments). On replay it marks the exact stream
//! position where the live server canonicalized its in-memory states, and
//! recovery re-canonicalizes there — that is what keeps replay bit-identical
//! even when the crash lands between a barrier and its manifest commit.
//!
//! ## Torn tails
//!
//! Writers never append to a pre-existing segment — each process start (and
//! each epoch) begins a fresh one — so a crash can only tear the tail of a
//! shard's last segment. [`WalReader`] stops at the first short, oversized,
//! checksum-failing, or semantically invalid record and reports the length
//! of the valid prefix; everything before it is intact by CRC.

use super::{crc32, FsyncPolicy};
use crate::fault::{self, Failpoint};
use crate::graph::{DeltaGraph, Graph};
use crate::obs::Counter;
use std::fs::{self, File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

const REC_OPEN: u8 = 1;
const REC_WINDOW: u8 = 2;
const REC_CLOSE: u8 = 3;
const REC_EPOCH: u8 = 4;

/// Upper bound on a single record body; anything larger is treated as
/// corruption by the reader (a window delta of this size would be ~4M edges).
pub const MAX_RECORD_LEN: u32 = 64 * 1024 * 1024;

/// One decoded WAL record.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Session opened with an initial graph.
    Open { id: String, nodes: usize, edges: Vec<(u32, u32, f64)> },
    /// One committed (coalesced, tick-terminated) window.
    Window { id: String, window_seq: u64, n_events: usize, delta: DeltaGraph },
    /// Session closed.
    Close { id: String },
    /// Epoch barrier: the live server canonicalized every session state at
    /// exactly this stream position.
    Epoch { epoch: u64 },
}

// ---------------------------------------------------------------------------
// encoding primitives (shared with the reader's strict decoders)
// ---------------------------------------------------------------------------

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            return;
        }
        buf.push(b | 0x80);
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_edge(buf: &mut Vec<u8>, i: u32, j: u32, w: f64) {
    put_varint(buf, i as u64);
    put_varint(buf, j as u64);
    buf.extend_from_slice(&w.to_bits().to_le_bytes());
}

/// Strict LEB128: at most 10 bytes, final byte must not overflow 64 bits.
fn get_varint(b: &[u8], pos: &mut usize) -> Option<u64> {
    let mut out = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *b.get(*pos)?;
        *pos += 1;
        if shift == 63 && byte > 1 {
            return None; // would overflow u64
        }
        out |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Some(out);
        }
        shift += 7;
        if shift > 63 {
            return None;
        }
    }
}

fn get_str(b: &[u8], pos: &mut usize) -> Option<String> {
    let len = get_varint(b, pos)? as usize;
    if len > MAX_RECORD_LEN as usize {
        return None;
    }
    let bytes = b.get(*pos..*pos + len)?;
    *pos += len;
    String::from_utf8(bytes.to_vec()).ok()
}

fn get_f64(b: &[u8], pos: &mut usize) -> Option<f64> {
    let bytes = b.get(*pos..*pos + 8)?;
    *pos += 8;
    let mut raw = [0u8; 8];
    raw.copy_from_slice(bytes);
    Some(f64::from_bits(u64::from_le_bytes(raw)))
}

/// Decode the edge list shared by OPEN and WINDOW bodies. Rejects edges a
/// `DeltaGraph` could not have produced (self-loop, unordered endpoints,
/// non-finite weight) — those mean corruption, and in the panic-free zone a
/// corrupt record must truncate the log, never reach `DeltaGraph::add`.
fn get_edges(b: &[u8], pos: &mut usize) -> Option<Vec<(u32, u32, f64)>> {
    let m = get_varint(b, pos)? as usize;
    // 10 bytes minimum per edge; bounds the allocation before trusting `m`
    if m > b.len().saturating_sub(*pos) / 10 {
        return None;
    }
    let mut edges = Vec::with_capacity(m);
    let mut prev: Option<(u32, u32)> = None;
    for _ in 0..m {
        let i = get_varint(b, pos)?;
        let j = get_varint(b, pos)?;
        let w = get_f64(b, pos)?;
        if i >= j || j > u32::MAX as u64 || !w.is_finite() {
            return None;
        }
        let (i, j) = (i as u32, j as u32);
        if let Some(p) = prev {
            if (i, j) <= p {
                return None; // writer emits sorted-unique edges
            }
        }
        prev = Some((i, j));
        edges.push((i, j, w));
    }
    Some(edges)
}

fn decode_body(body: &[u8]) -> Option<WalRecord> {
    let mut pos = 0usize;
    let tag = *body.get(pos)?;
    pos += 1;
    let rec = match tag {
        REC_OPEN => {
            let id = get_str(body, &mut pos)?;
            let nodes = get_varint(body, &mut pos)? as usize;
            let edges = get_edges(body, &mut pos)?;
            WalRecord::Open { id, nodes, edges }
        }
        REC_WINDOW => {
            let id = get_str(body, &mut pos)?;
            let window_seq = get_varint(body, &mut pos)?;
            let n_events = get_varint(body, &mut pos)? as usize;
            let new_nodes = get_varint(body, &mut pos)? as usize;
            let edges = get_edges(body, &mut pos)?;
            let mut delta = DeltaGraph::new();
            delta.grow_nodes(new_nodes);
            for (i, j, w) in edges {
                // i < j guaranteed by get_edges, so add() cannot assert
                delta.add(i, j, w);
            }
            WalRecord::Window { id, window_seq, n_events, delta }
        }
        REC_CLOSE => WalRecord::Close { id: get_str(body, &mut pos)? },
        REC_EPOCH => WalRecord::Epoch { epoch: get_varint(body, &mut pos)? },
        _ => return None,
    };
    if pos != body.len() {
        return None; // trailing garbage inside a framed body
    }
    Some(rec)
}

// ---------------------------------------------------------------------------
// segment naming
// ---------------------------------------------------------------------------

/// File name of segment `seq` for `shard`.
pub fn segment_name(shard: usize, seq: u64) -> String {
    format!("shard-{shard:04}-{seq:010}.wal")
}

/// Parse `shard-SSSS-NNNNNNNNNN.wal` back into `(shard, seq)`.
pub fn parse_segment_name(name: &str) -> Option<(usize, u64)> {
    let rest = name.strip_prefix("shard-")?.strip_suffix(".wal")?;
    let (shard, seq) = rest.split_once('-')?;
    Some((shard.parse().ok()?, seq.parse().ok()?))
}

/// All WAL segments under `wal_dir`, as `(shard, seq, path)` sorted by
/// `(shard, seq)`. Missing directory reads as empty (fresh start).
pub fn scan_segments(wal_dir: &Path) -> io::Result<Vec<(usize, u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match fs::read_dir(wal_dir) {
        Ok(e) => e,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        if let Some((shard, seq)) = name.to_str().and_then(parse_segment_name) {
            out.push((shard, seq, entry.path()));
        }
    }
    out.sort_by_key(|&(shard, seq, _)| (shard, seq));
    Ok(out)
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

/// Append side of one shard's WAL.
///
/// IO failures never panic and never surface into the scoring path: the
/// writer reports the error once on stderr and latches itself disabled until
/// the next epoch barrier, whose [`WalWriter::rotate_epoch`] re-opens a fresh
/// segment (safe, because the snapshot cut at that barrier supersedes
/// everything the dead writer failed to log).
pub struct WalWriter {
    dir: PathBuf,
    shard: usize,
    seq: u64,
    file: Option<File>,
    buf: Vec<u8>,
    fsync: FsyncPolicy,
    segment_bytes: u64,
    bytes_in_segment: u64,
    windows_since_sync: u64,
    last_sync: Instant,
}

impl WalWriter {
    /// Open the writer for `shard`, starting a fresh segment numbered one
    /// past the highest already on disk (writers never append to an existing
    /// segment, so torn tails stay confined to pre-crash segments).
    pub fn open(
        wal_dir: &Path,
        shard: usize,
        fsync: FsyncPolicy,
        segment_bytes: u64,
    ) -> io::Result<Self> {
        fs::create_dir_all(wal_dir)?;
        let last = scan_segments(wal_dir)?
            .into_iter()
            .filter(|&(s, _, _)| s == shard)
            .map(|(_, seq, _)| seq)
            .max()
            .unwrap_or(0);
        let mut w = Self {
            dir: wal_dir.to_path_buf(),
            shard,
            seq: last, // open_segment bumps to last + 1
            file: None,
            buf: Vec::with_capacity(4096),
            fsync,
            segment_bytes: segment_bytes.max(4096),
            bytes_in_segment: 0,
            windows_since_sync: 0,
            last_sync: Instant::now(),
        };
        w.open_segment()?;
        Ok(w)
    }

    fn open_segment(&mut self) -> io::Result<()> {
        if fault::fire(Failpoint::WalRotate) {
            return Err(fault::injected_err(Failpoint::WalRotate));
        }
        self.seq += 1;
        let path = self.dir.join(segment_name(self.shard, self.seq));
        let file = OpenOptions::new().create_new(true).write(true).open(path)?;
        self.file = Some(file);
        self.bytes_in_segment = 0;
        Ok(())
    }

    /// Sequence number of the segment currently being written.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// False once an IO error latched the writer off.
    pub fn healthy(&self) -> bool {
        self.file.is_some()
    }

    fn latch(&mut self, what: &str, e: &io::Error) {
        eprintln!(
            "wal[shard {}]: {what}: {e}; WAL disabled until the next epoch barrier",
            self.shard
        );
        self.file = None;
    }

    /// Frame `self.buf` as a record and append it; applies the fsync policy
    /// and size-based rotation. `is_window` feeds the every-N-windows policy.
    fn commit_frame(&mut self, is_window: bool) {
        if self.file.is_some() && fault::fire(Failpoint::WalAppend) {
            self.latch("append", &fault::injected_err(Failpoint::WalAppend));
            return;
        }
        let Some(file) = self.file.as_mut() else { return };
        let body_len = self.buf.len() as u32;
        let crc = crc32(&self.buf);
        let write = file
            .write_all(&body_len.to_le_bytes())
            .and_then(|()| file.write_all(&self.buf))
            .and_then(|()| file.write_all(&crc.to_le_bytes()));
        if let Err(e) = write {
            self.latch("append", &e);
            return;
        }
        let framed = self.buf.len() as u64 + 8;
        self.bytes_in_segment += framed;
        Counter::WalAppends.inc();
        Counter::WalBytes.add(framed);
        if is_window {
            self.windows_since_sync += 1;
        }
        let due = match self.fsync {
            FsyncPolicy::Always => true,
            FsyncPolicy::EveryNWindows(n) => self.windows_since_sync >= n,
            FsyncPolicy::EveryMs(ms) => self.last_sync.elapsed().as_millis() as u64 >= ms,
        };
        if due {
            self.sync();
        }
        if self.bytes_in_segment >= self.segment_bytes {
            self.sync();
            if let Err(e) = self.open_segment() {
                self.latch("rotate segment", &e);
            }
        }
    }

    /// Flush appended records to stable storage now.
    pub fn sync(&mut self) {
        if self.file.is_some() && fault::fire(Failpoint::WalFsync) {
            self.latch("fsync", &fault::injected_err(Failpoint::WalFsync));
            return;
        }
        let Some(file) = self.file.as_mut() else { return };
        if let Err(e) = file.sync_data() {
            self.latch("fsync", &e);
            return;
        }
        Counter::WalFsyncs.inc();
        self.windows_since_sync = 0;
        self.last_sync = Instant::now();
    }

    /// Log a session open with its initial graph.
    pub fn append_open(&mut self, id: &str, graph: &Graph) {
        if self.file.is_none() {
            return;
        }
        self.buf.clear();
        self.buf.push(REC_OPEN);
        put_str(&mut self.buf, id);
        put_varint(&mut self.buf, graph.num_nodes() as u64);
        put_varint(&mut self.buf, graph.num_edges() as u64);
        for (i, j, w) in graph.edges() {
            put_edge(&mut self.buf, i, j, w);
        }
        self.commit_frame(false);
    }

    /// Log one committed window, exactly as handed to the scorer. Called in
    /// the shard commit path *before* scoring — write-ahead, and with the
    /// `always` policy the sync happens before the window is acknowledged.
    pub fn append_window(&mut self, id: &str, window_seq: u64, n_events: usize, delta: &DeltaGraph) {
        if self.file.is_none() {
            return;
        }
        self.buf.clear();
        self.buf.push(REC_WINDOW);
        put_str(&mut self.buf, id);
        put_varint(&mut self.buf, window_seq);
        put_varint(&mut self.buf, n_events as u64);
        put_varint(&mut self.buf, delta.new_nodes() as u64);
        put_varint(&mut self.buf, delta.num_changes() as u64);
        for &(i, j, w) in delta.edge_deltas() {
            put_edge(&mut self.buf, i, j, w);
        }
        self.commit_frame(true);
    }

    /// Log a session close.
    pub fn append_close(&mut self, id: &str) {
        if self.file.is_none() {
            return;
        }
        self.buf.clear();
        self.buf.push(REC_CLOSE);
        put_str(&mut self.buf, id);
        self.commit_frame(false);
    }

    /// Epoch barrier: sync and retire the current segment, then start a
    /// fresh one whose first record is the EPOCH marker (synced before this
    /// returns). Re-opens a latched writer — the snapshot cut at this
    /// barrier covers whatever the dead writer missed. Returns the new
    /// segment's sequence number: the manifest's `next` position for this
    /// shard, and the first segment recovery will replay.
    pub fn rotate_epoch(&mut self, epoch: u64) -> io::Result<u64> {
        if self.file.is_some() {
            self.sync();
        }
        self.open_segment()?;
        self.buf.clear();
        self.buf.push(REC_EPOCH);
        put_varint(&mut self.buf, epoch);
        self.commit_frame(false);
        self.sync();
        if self.file.is_none() {
            return Err(io::Error::other("wal writer latched while writing epoch marker"));
        }
        Ok(self.seq)
    }
}

// ---------------------------------------------------------------------------
// reader
// ---------------------------------------------------------------------------

/// Torn-tail-tolerant reader over one segment's bytes.
///
/// Yields records until the first corrupt one (short frame, oversized
/// length, CRC mismatch, or a body the writer could not have produced) and
/// then stops for good; [`WalReader::valid_len`] reports how many bytes of
/// valid prefix were consumed.
pub struct WalReader {
    bytes: Vec<u8>,
    pos: usize,
    valid: usize,
    stopped: bool,
}

impl WalReader {
    pub fn open(path: &Path) -> io::Result<Self> {
        let mut bytes = Vec::new();
        File::open(path)?.read_to_end(&mut bytes)?;
        Ok(Self::from_bytes(bytes))
    }

    pub fn from_bytes(bytes: Vec<u8>) -> Self {
        Self { bytes, pos: 0, valid: 0, stopped: false }
    }

    /// Bytes of intact prefix consumed so far (the truncation point once
    /// iteration stops).
    pub fn valid_len(&self) -> usize {
        self.valid
    }

    fn try_next(&mut self) -> Option<WalRecord> {
        let len_bytes = self.bytes.get(self.pos..self.pos + 4)?;
        let mut raw = [0u8; 4];
        raw.copy_from_slice(len_bytes);
        let body_len = u32::from_le_bytes(raw);
        if body_len > MAX_RECORD_LEN {
            return None;
        }
        let body_start = self.pos + 4;
        let body_end = body_start + body_len as usize;
        let body = self.bytes.get(body_start..body_end)?;
        let crc_bytes = self.bytes.get(body_end..body_end + 4)?;
        let mut raw = [0u8; 4];
        raw.copy_from_slice(crc_bytes);
        if crc32(body) != u32::from_le_bytes(raw) {
            return None;
        }
        let rec = decode_body(body)?;
        self.pos = body_end + 4;
        self.valid = self.pos;
        Some(rec)
    }
}

impl Iterator for WalReader {
    type Item = WalRecord;

    fn next(&mut self) -> Option<WalRecord> {
        if self.stopped {
            return None;
        }
        match self.try_next() {
            Some(rec) => Some(rec),
            None => {
                self.stopped = true;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("finger_wal_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_delta() -> DeltaGraph {
        let mut d = DeltaGraph::new();
        d.grow_nodes(2);
        d.add(0, 1, 0.5).add(0, 3, -0.25).add(2, 5, 1.0 / 3.0);
        d
    }

    fn write_sample(dir: &Path) -> Vec<WalRecord> {
        let mut w = WalWriter::open(dir, 0, FsyncPolicy::Always, 1 << 20).unwrap();
        let mut g = Graph::new(4);
        g.add_edge(0, 1, 1.0);
        g.add_edge(1, 2, 0.125);
        w.append_open("sess-a", &g);
        w.append_window("sess-a", 0, 7, &sample_delta());
        w.append_window("sess-a", 1, 3, &DeltaGraph::new());
        w.append_close("sess-a");
        vec![
            WalRecord::Open {
                id: "sess-a".into(),
                nodes: 4,
                edges: vec![(0, 1, 1.0), (1, 2, 0.125)],
            },
            WalRecord::Window { id: "sess-a".into(), window_seq: 0, n_events: 7, delta: sample_delta() },
            WalRecord::Window { id: "sess-a".into(), window_seq: 1, n_events: 3, delta: DeltaGraph::new() },
            WalRecord::Close { id: "sess-a".into() },
        ]
    }

    #[test]
    fn records_roundtrip_bit_exact() {
        let dir = tmpdir("roundtrip");
        let want = write_sample(&dir);
        let segs = scan_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1);
        let got: Vec<_> = WalReader::open(&segs[0].2).unwrap().collect();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            match (g, w) {
                (
                    WalRecord::Window { id: gi, window_seq: gs, n_events: ge, delta: gd },
                    WalRecord::Window { id: wi, window_seq: ws, n_events: we, delta: wd },
                ) => {
                    assert_eq!((gi, gs, ge), (wi, ws, we));
                    assert_eq!(gd.new_nodes(), wd.new_nodes());
                    for (a, b) in gd.edge_deltas().iter().zip(wd.edge_deltas()) {
                        assert_eq!(a.0, b.0);
                        assert_eq!(a.1, b.1);
                        assert_eq!(a.2.to_bits(), b.2.to_bits(), "delta weights bit-exact");
                    }
                }
                _ => assert_eq!(g, w),
            }
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_never_reuses_segments_and_epoch_rotates() {
        let dir = tmpdir("seq");
        let mut w = WalWriter::open(&dir, 2, FsyncPolicy::EveryMs(0), 1 << 20).unwrap();
        assert_eq!(w.seq(), 1);
        let next = w.rotate_epoch(5).unwrap();
        assert_eq!(next, 2);
        w.append_close("x");
        drop(w);
        // a restart starts after the highest on-disk segment
        let w2 = WalWriter::open(&dir, 2, FsyncPolicy::Always, 1 << 20).unwrap();
        assert_eq!(w2.seq(), 3);
        // the epoch segment leads with its marker
        let recs: Vec<_> =
            WalReader::open(&dir.join(segment_name(2, 2))).unwrap().collect();
        assert_eq!(recs.first(), Some(&WalRecord::Epoch { epoch: 5 }));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_rotation_splits_segments() {
        let dir = tmpdir("rotate");
        let mut w = WalWriter::open(&dir, 0, FsyncPolicy::EveryNWindows(1000), 4096).unwrap();
        for s in 0..200u64 {
            w.append_window("session-with-a-longish-id", s, 5, &sample_delta());
        }
        drop(w);
        let segs = scan_segments(&dir).unwrap();
        assert!(segs.len() > 1, "200 windows must overflow a 4 KiB segment");
        let total: usize =
            segs.iter().map(|(_, _, p)| WalReader::open(p).unwrap().count()).sum();
        assert_eq!(total, 200, "no records lost across rotations");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_truncates_to_valid_prefix() {
        let dir = tmpdir("torn");
        write_sample(&dir);
        let segs = scan_segments(&dir).unwrap();
        let bytes = fs::read(&segs[0].2).unwrap();
        let full: Vec<_> = WalReader::from_bytes(bytes.clone()).collect();

        // Property: EVERY truncation point recovers a valid record prefix.
        for cut in 0..bytes.len() {
            let mut r = WalReader::from_bytes(bytes[..cut].to_vec());
            let recs: Vec<_> = r.by_ref().collect();
            assert!(recs.len() <= full.len());
            assert_eq!(recs.as_slice(), &full[..recs.len()], "cut at {cut}");
            assert!(r.valid_len() <= cut);
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_byte_truncates_and_never_panics() {
        let dir = tmpdir("flip");
        write_sample(&dir);
        let segs = scan_segments(&dir).unwrap();
        let bytes = fs::read(&segs[0].2).unwrap();
        let full: Vec<_> = WalReader::from_bytes(bytes.clone()).collect();
        // xorshift PRNG; no external deps, deterministic
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for _ in 0..500 {
            let mut mutated = bytes.clone();
            let at = (rng() % mutated.len() as u64) as usize;
            let bit = 1u8 << (rng() % 8);
            mutated[at] ^= bit;
            let recs: Vec<_> = WalReader::from_bytes(mutated).collect();
            // a flipped bit may truncate the log or (if it lands in dead
            // space) leave it intact — but every surviving record must be a
            // prefix-aligned original
            assert!(recs.len() <= full.len());
            for (g, w) in recs.iter().zip(&full) {
                if g != w {
                    // the flip landed inside this record AND defeated the
                    // CRC — with one bit flip that is impossible
                    panic!("bit flip at {at} produced a corrupt record that passed CRC");
                }
            }
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_names_roundtrip() {
        assert_eq!(parse_segment_name(&segment_name(3, 42)), Some((3, 42)));
        assert_eq!(parse_segment_name("shard-0003-0000000042.wal"), Some((3, 42)));
        assert_eq!(parse_segment_name("shard-3.wal"), None);
        assert_eq!(parse_segment_name("other-0003-0000000042.wal"), None);
        assert_eq!(parse_segment_name("shard-0003-0000000042.tmp"), None);
    }

    #[test]
    fn varints_reject_overlong_encodings() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            buf.clear();
            put_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos), Some(v));
            assert_eq!(pos, buf.len());
        }
        // 11-byte encoding: too long
        let long = [0x80u8; 10];
        let mut with_tail = long.to_vec();
        with_tail.push(0x01);
        let mut pos = 0;
        assert_eq!(get_varint(&with_tail, &mut pos), None);
        // 10th byte carrying more than the top bit of a u64
        let mut overflow = [0x80u8; 9].to_vec();
        overflow.push(0x02);
        let mut pos = 0;
        assert_eq!(get_varint(&overflow, &mut pos), None);
    }
}
