//! Durability subsystem: per-shard write-ahead logging, epoch-based online
//! snapshots, and the recovery planning that turns both back into live
//! sessions after a crash.
//!
//! The service's standing invariant — bit-for-bit score identity across the
//! entropy, pipeline, service, and both wire layers — is what makes recovery
//! here *provable* rather than approximate: a restarted `finger serve` must
//! reproduce byte-identical per-session scores, and the pieces in this module
//! are designed around that bar.
//!
//! * [`wal`] — one append-only segmented log per shard worker. Every
//!   *committed window* (the coalesced `DeltaGraph` handed to the scorer,
//!   plus session id, window sequence and event count) is appended as a
//!   length-prefixed CRC-checked binary record **before** it is scored, using
//!   the same varint / raw-f64-bits primitives as the v2 wire codec so a
//!   replayed delta is bit-exact. A torn tail (crash mid-append) is detected
//!   by the reader and the valid prefix recovered.
//! * [`snapshot`] — epoch manifests. An epoch barrier flows through every
//!   shard channel, cutting one consistent checkpoint per session (the
//!   existing `stream::checkpoint` text format) plus the WAL position it
//!   covers; the manifest + `CURRENT` pointer commit via atomic rename, after
//!   which covered WAL segments are pruned.
//! * [`recovery`] — reads `CURRENT`, the committed manifest and the
//!   surviving WAL segments into a [`recovery::RecoveryPlan`] the service
//!   replays through the normal `WindowScorer` path
//!   (`ScoringService::recover`).
//!
//! Everything is dependency-free and — like the rest of the service stack —
//! inside the FL001 panic-free zone: a corrupt log or a full disk degrades
//! durability, never the scoring service.

pub mod recovery;
pub mod snapshot;
pub mod wal;

pub use recovery::RecoveryPlan;
pub use snapshot::{EpochCut, EpochManifest, SessionDurableMeta};
pub use wal::{WalReader, WalRecord, WalWriter};

use std::path::PathBuf;

/// When appended WAL records are flushed to stable storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fsync` after every appended window — every acknowledged window is
    /// durable, at a syscall per window.
    Always,
    /// `fsync` once per `n` appended windows.
    EveryNWindows(u64),
    /// `fsync` when more than `ms` milliseconds passed since the last sync
    /// (checked at append time). The default: bounded data loss at near-zero
    /// steady-state cost.
    EveryMs(u64),
}

impl Default for FsyncPolicy {
    fn default() -> Self {
        FsyncPolicy::EveryMs(50)
    }
}

/// What the service does when WAL IO fails (a real disk error or an
/// injected `wal.*` fault): the `[durability] on_error` knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OnError {
    /// Refuse further mutating commands (`ERR durability-failed`) until an
    /// epoch cut re-establishes a healthy WAL. Nothing is ever acknowledged
    /// without the durability it promised. The default.
    #[default]
    FailStop,
    /// Drop the WAL and keep scoring: availability over durability. The
    /// server flags `durability=degraded` in `STATS` and `METRICS`.
    Degrade,
}

impl OnError {
    /// Parse the `[durability] on_error` value.
    pub fn parse(spec: &str) -> Option<Self> {
        match spec.trim() {
            "fail_stop" => Some(OnError::FailStop),
            "degrade" => Some(OnError::Degrade),
            _ => None,
        }
    }

    /// Canonical spec string (round-trips through [`OnError::parse`]).
    pub fn spec(&self) -> &'static str {
        match self {
            OnError::FailStop => "fail_stop",
            OnError::Degrade => "degrade",
        }
    }
}

impl FsyncPolicy {
    /// Parse a policy spec: `always`, `every_ms[=N]` or `every_n[=N]`
    /// (`--fsync` on the CLI, `fsync`/`fsync_ms`/`fsync_windows` in the
    /// `[durability]` config section).
    pub fn parse(spec: &str) -> Option<Self> {
        let (name, arg) = match spec.split_once('=') {
            Some((n, a)) => (n.trim(), Some(a.trim())),
            None => (spec.trim(), None),
        };
        match name {
            "always" => Some(FsyncPolicy::Always),
            "every_ms" => {
                let ms = match arg {
                    Some(a) => a.parse().ok()?,
                    None => 50,
                };
                Some(FsyncPolicy::EveryMs(ms))
            }
            "every_n" | "every_n_windows" => {
                let n = match arg {
                    Some(a) => a.parse().ok()?,
                    None => 64,
                };
                Some(FsyncPolicy::EveryNWindows(n.max(1)))
            }
            _ => None,
        }
    }

    /// Canonical spec string (round-trips through [`FsyncPolicy::parse`]).
    pub fn spec(&self) -> String {
        match self {
            FsyncPolicy::Always => "always".to_string(),
            FsyncPolicy::EveryNWindows(n) => format!("every_n={n}"),
            FsyncPolicy::EveryMs(ms) => format!("every_ms={ms}"),
        }
    }
}

/// Durability knobs, normally read from the `[durability]` config section
/// (or `finger serve --durability-dir/--fsync`). Presence of this config on
/// a `ServiceConfig` is what turns the subsystem on.
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// Root directory: WAL segments under `wal/`, committed epochs under
    /// `epoch-<n>/`, and the `CURRENT` pointer file.
    pub dir: PathBuf,
    /// When appended records reach stable storage.
    pub fsync: FsyncPolicy,
    /// Rotate a shard's segment once it grows past this (epoch cuts also
    /// rotate, regardless of size).
    pub segment_bytes: u64,
    /// Cut an epoch snapshot roughly this often while serving (0 disables
    /// the timer; the `EPOCH` wire verb and drain-time cut still work).
    pub snapshot_interval_ms: u64,
    /// What WAL IO failure does to the service (`fail_stop` | `degrade`).
    pub on_error: OnError,
}

impl DurabilityConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            fsync: FsyncPolicy::default(),
            segment_bytes: 8 * 1024 * 1024,
            snapshot_interval_ms: 0,
            on_error: OnError::default(),
        }
    }

    /// Directory holding the per-shard WAL segments.
    pub fn wal_dir(&self) -> PathBuf {
        self.dir.join("wal")
    }

    /// The `CURRENT` pointer file naming the latest committed epoch.
    pub fn current_path(&self) -> PathBuf {
        self.dir.join("CURRENT")
    }

    /// Directory of a committed epoch.
    pub fn epoch_dir(&self, epoch: u64) -> PathBuf {
        self.dir.join(format!("epoch-{epoch:010}"))
    }

    /// Staging directory an epoch is assembled in before its atomic rename.
    pub fn epoch_tmp_dir(&self, epoch: u64) -> PathBuf {
        self.dir.join(format!("epoch-{epoch:010}.tmp"))
    }
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        // finger-lint: allow(FL001): i < 256 loop bound over a 256-entry table
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE 802.3 polynomial, the zlib/`cksum -o 3` variant) — the WAL
/// record checksum. Table-driven, dependency-free.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        // finger-lint: allow(FL001): index masked to the 256-entry table
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // reference values from the zlib crc32() implementation
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn fsync_policy_specs_roundtrip() {
        for spec in ["always", "every_ms=50", "every_ms=7", "every_n=64", "every_n=3"] {
            let p = FsyncPolicy::parse(spec).expect(spec);
            assert_eq!(FsyncPolicy::parse(&p.spec()), Some(p));
        }
        assert_eq!(FsyncPolicy::parse("every_ms"), Some(FsyncPolicy::EveryMs(50)));
        assert_eq!(FsyncPolicy::parse("every_n"), Some(FsyncPolicy::EveryNWindows(64)));
        assert_eq!(FsyncPolicy::parse("every_n=0"), Some(FsyncPolicy::EveryNWindows(1)));
        assert_eq!(FsyncPolicy::parse("sometimes"), None);
        assert_eq!(FsyncPolicy::parse("every_ms=x"), None);
    }

    #[test]
    fn on_error_specs_roundtrip() {
        for spec in ["fail_stop", "degrade"] {
            let p = OnError::parse(spec).expect(spec);
            assert_eq!(OnError::parse(p.spec()), Some(p));
        }
        assert_eq!(OnError::default(), OnError::FailStop);
        assert_eq!(OnError::parse("panic"), None);
    }

    #[test]
    fn layout_paths_are_stable() {
        let d = DurabilityConfig::new("/tmp/dur");
        assert_eq!(d.wal_dir(), PathBuf::from("/tmp/dur/wal"));
        assert_eq!(d.current_path(), PathBuf::from("/tmp/dur/CURRENT"));
        assert_eq!(d.epoch_dir(3), PathBuf::from("/tmp/dur/epoch-0000000003"));
        assert_eq!(d.epoch_tmp_dir(3), PathBuf::from("/tmp/dur/epoch-0000000003.tmp"));
    }
}
