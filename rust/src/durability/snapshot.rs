//! Epoch manifests: the snapshot half of the durability story.
//!
//! An epoch is one consistent cut across every shard, taken online (no
//! drain): the coordinator injects a barrier into each shard channel; each
//! worker rotates its WAL (writing the EPOCH marker), canonicalizes its live
//! session states, writes one `stream::checkpoint` file per session into the
//! epoch's staging directory, and reports back an [`EpochCut`] — the WAL
//! segment the new epoch starts at plus the durable metadata of every live
//! session. The coordinator then writes the `MANIFEST`, fsyncs, and commits
//! the whole directory with one atomic rename (the `obs/snapshot.rs`
//! tmp-then-rename idiom), repoints `CURRENT`, and prunes the WAL segments
//! and epoch directories the new epoch supersedes.
//!
//! The `MANIFEST` is a whitespace-tokenized text file (session ids are
//! `%`-escaped and hence token-safe; floats are raw `f64::to_bits` hex, so
//! the restore is bit-exact):
//!
//! ```text
//! finger-epoch v1
//! epoch 3
//! shards 2
//! next 0 7
//! next 1 9
//! session wiki-00001 shard 0 windows 12 events 240 anomalies 1 \
//!         interval 512 since 4 resyncs 2 maxdrift 3cb0000000000000 \
//!         last 3f50624dd2f1a9fc lastanom 0 obs 12 trail 3f5062...,3f51...
//! ```
//!
//! (one `session` line per live session, shown wrapped here for width).

use super::DurabilityConfig;
use crate::service::session::{decode_session_id, encode_session_id};
use std::fs::{self, File};
use std::io::{self, BufRead, BufReader, Write};
use std::path::{Path, PathBuf};

/// Everything beyond the checkpointed `FingerState` that a session needs to
/// resume *bit-identically*: scorer progress (window count and the adaptive
/// resync schedule), detector history (trailing window, observation count),
/// and the report-level tallies surfaced by `QUERY`.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionDurableMeta {
    pub id: String,
    pub shard: usize,
    /// Windows scored so far (`WindowScorer::windows`).
    pub windows: u64,
    /// Events accepted so far (pre-coalesce).
    pub events: usize,
    /// Anomalous windows so far.
    pub anomalies: usize,
    /// Current adaptive resync interval.
    pub interval: u64,
    /// Windows since the last resync.
    pub since_resync: u64,
    /// Resyncs performed.
    pub resyncs: u64,
    /// Largest drift any resync corrected.
    pub max_drift: f64,
    /// Last window's (jsdist, anomalous), if any window was scored.
    pub last: Option<(f64, bool)>,
    /// Detector observations so far.
    pub observed: u64,
    /// Detector trailing scores, oldest first.
    pub trailing: Vec<f64>,
}

/// One shard's reply to the epoch barrier.
#[derive(Debug)]
pub struct EpochCut {
    pub shard: usize,
    /// First WAL segment NOT covered by this epoch (the segment opened by
    /// the barrier's rotation, leading with the EPOCH marker).
    pub next_seq: u64,
    pub sessions: Vec<SessionDurableMeta>,
}

/// The committed, crash-consistent description of one epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochManifest {
    pub epoch: u64,
    pub shards: usize,
    /// Per shard: first WAL segment to replay on recovery.
    pub next_seq: Vec<u64>,
    pub sessions: Vec<SessionDurableMeta>,
}

fn hex64(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn parse_hex64(tok: &str) -> Option<f64> {
    if tok.len() != 16 {
        return None;
    }
    u64::from_str_radix(tok, 16).ok().map(f64::from_bits)
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn write_manifest<W: Write>(w: &mut W, m: &EpochManifest) -> io::Result<()> {
    writeln!(w, "finger-epoch v1")?;
    writeln!(w, "epoch {}", m.epoch)?;
    writeln!(w, "shards {}", m.shards)?;
    for (shard, next) in m.next_seq.iter().enumerate() {
        writeln!(w, "next {shard} {next}")?;
    }
    for s in &m.sessions {
        let last = match s.last {
            Some((v, _)) => hex64(v),
            None => "-".to_string(),
        };
        let lastanom = match s.last {
            Some((_, true)) => "1",
            Some((_, false)) => "0",
            None => "-",
        };
        let trail = if s.trailing.is_empty() {
            "-".to_string()
        } else {
            s.trailing.iter().map(|&v| hex64(v)).collect::<Vec<_>>().join(",")
        };
        writeln!(
            w,
            "session {} shard {} windows {} events {} anomalies {} interval {} since {} \
             resyncs {} maxdrift {} last {} lastanom {} obs {} trail {}",
            encode_session_id(&s.id),
            s.shard,
            s.windows,
            s.events,
            s.anomalies,
            s.interval,
            s.since_resync,
            s.resyncs,
            hex64(s.max_drift),
            last,
            lastanom,
            s.observed,
            trail,
        )?;
    }
    Ok(())
}

fn parse_session_line(tokens: &[&str]) -> Option<SessionDurableMeta> {
    // session <id> + 12 labelled fields = 25 tokens
    if tokens.len() != 25 {
        return None;
    }
    let id = decode_session_id(tokens.get(1)?)?;
    let mut field = |idx: usize, label: &str| -> Option<&str> {
        if *tokens.get(idx)? != label {
            return None;
        }
        tokens.get(idx + 1).copied()
    };
    let shard = field(2, "shard")?.parse().ok()?;
    let windows = field(4, "windows")?.parse().ok()?;
    let events = field(6, "events")?.parse().ok()?;
    let anomalies = field(8, "anomalies")?.parse().ok()?;
    let interval = field(10, "interval")?.parse().ok()?;
    let since_resync = field(12, "since")?.parse().ok()?;
    let resyncs = field(14, "resyncs")?.parse().ok()?;
    let max_drift = parse_hex64(field(16, "maxdrift")?)?;
    let last_tok = field(18, "last")?;
    let lastanom_tok = field(20, "lastanom")?;
    let last = match (last_tok, lastanom_tok) {
        ("-", "-") => None,
        (v, "0") => Some((parse_hex64(v)?, false)),
        (v, "1") => Some((parse_hex64(v)?, true)),
        _ => return None,
    };
    let observed = field(22, "obs")?.parse().ok()?;
    let trail_tok = field(24, "trail")?;
    let trailing = if trail_tok == "-" {
        Vec::new()
    } else {
        let mut vals = Vec::new();
        for part in trail_tok.split(',') {
            vals.push(parse_hex64(part)?);
        }
        vals
    };
    Some(SessionDurableMeta {
        id,
        shard,
        windows,
        events,
        anomalies,
        interval,
        since_resync,
        resyncs,
        max_drift,
        last,
        observed,
        trailing,
    })
}

fn read_manifest<R: BufRead>(r: R) -> io::Result<EpochManifest> {
    let mut lines = r.lines();
    let header = lines.next().ok_or_else(|| bad("empty manifest"))??;
    if header.trim() != "finger-epoch v1" {
        return Err(bad(format!("bad manifest header: {header:?}")));
    }
    let mut epoch: Option<u64> = None;
    let mut shards: Option<usize> = None;
    let mut next: Vec<(usize, u64)> = Vec::new();
    let mut sessions = Vec::new();
    for line in lines {
        let line = line?;
        let tokens: Vec<&str> = line.split_whitespace().collect();
        match tokens.first().copied() {
            None => continue,
            Some("epoch") => {
                epoch = tokens.get(1).and_then(|t| t.parse().ok());
                if epoch.is_none() {
                    return Err(bad(format!("bad epoch line: {line:?}")));
                }
            }
            Some("shards") => {
                shards = tokens.get(1).and_then(|t| t.parse().ok());
                if shards.is_none() {
                    return Err(bad(format!("bad shards line: {line:?}")));
                }
            }
            Some("next") => {
                let shard: Option<usize> = tokens.get(1).and_then(|t| t.parse().ok());
                let seq: Option<u64> = tokens.get(2).and_then(|t| t.parse().ok());
                match (shard, seq, tokens.len()) {
                    (Some(s), Some(q), 3) => next.push((s, q)),
                    _ => return Err(bad(format!("bad next line: {line:?}"))),
                }
            }
            Some("session") => match parse_session_line(&tokens) {
                Some(s) => sessions.push(s),
                None => return Err(bad(format!("bad session line: {line:?}"))),
            },
            Some(other) => return Err(bad(format!("unknown manifest line {other:?}"))),
        }
    }
    let epoch = epoch.ok_or_else(|| bad("manifest missing epoch"))?;
    let shards = shards.ok_or_else(|| bad("manifest missing shards"))?;
    let mut next_seq = vec![1u64; shards];
    if next.len() != shards {
        return Err(bad(format!("{} next lines for {shards} shards", next.len())));
    }
    for (shard, seq) in next {
        match next_seq.get_mut(shard) {
            Some(slot) => *slot = seq,
            None => return Err(bad(format!("next line for out-of-range shard {shard}"))),
        }
    }
    Ok(EpochManifest { epoch, shards, next_seq, sessions })
}

/// Read the manifest of a committed epoch directory.
pub fn load_manifest(epoch_dir: &Path) -> io::Result<EpochManifest> {
    let f = File::open(epoch_dir.join("MANIFEST"))?;
    read_manifest(BufReader::new(f))
}

/// The latest committed epoch per `CURRENT`, or `None` on a fresh directory.
pub fn read_current(cfg: &DurabilityConfig) -> io::Result<Option<u64>> {
    let text = match fs::read_to_string(cfg.current_path()) {
        Ok(t) => t,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    };
    let name = text.trim();
    let epoch = name
        .strip_prefix("epoch-")
        .and_then(|n| n.parse().ok())
        .ok_or_else(|| bad(format!("bad CURRENT content: {name:?}")))?;
    Ok(Some(epoch))
}

/// Create (after clearing any stale leftover) the staging directory the
/// barrier's checkpoint files are written into.
pub fn prepare_epoch_tmp(cfg: &DurabilityConfig, epoch: u64) -> io::Result<PathBuf> {
    let tmp = cfg.epoch_tmp_dir(epoch);
    if tmp.exists() {
        fs::remove_dir_all(&tmp)?;
    }
    fs::create_dir_all(&tmp)?;
    Ok(tmp)
}

/// Commit an epoch whose per-session checkpoints already sit in the staging
/// directory: write + fsync `MANIFEST`, atomically rename the directory into
/// place, repoint `CURRENT`, then prune superseded epochs and WAL segments.
pub fn commit_epoch(
    cfg: &DurabilityConfig,
    epoch: u64,
    cuts: &[EpochCut],
) -> io::Result<EpochManifest> {
    let shards = cuts.len();
    let mut next_seq = vec![1u64; shards];
    let mut sessions = Vec::new();
    for cut in cuts {
        match next_seq.get_mut(cut.shard) {
            Some(slot) => *slot = cut.next_seq,
            None => return Err(bad(format!("epoch cut for out-of-range shard {}", cut.shard))),
        }
        sessions.extend(cut.sessions.iter().cloned());
    }
    sessions.sort_by(|a, b| a.id.cmp(&b.id));
    let manifest = EpochManifest { epoch, shards, next_seq, sessions };

    let tmp = cfg.epoch_tmp_dir(epoch);
    {
        if crate::fault::fire(crate::fault::Failpoint::SnapWrite) {
            return Err(crate::fault::injected_err(crate::fault::Failpoint::SnapWrite));
        }
        let mut f = File::create(tmp.join("MANIFEST"))?;
        write_manifest(&mut f, &manifest)?;
        f.sync_all()?;
    }
    // fsync the staging directory so the checkpoint files' names are durable
    // before the rename publishes them
    File::open(&tmp)?.sync_all()?;
    let final_dir = cfg.epoch_dir(epoch);
    if final_dir.exists() {
        fs::remove_dir_all(&final_dir)?;
    }
    if crate::fault::fire(crate::fault::Failpoint::SnapRename) {
        return Err(crate::fault::injected_err(crate::fault::Failpoint::SnapRename));
    }
    fs::rename(&tmp, &final_dir)?;

    // repoint CURRENT with the same tmp-then-rename idiom as obs snapshots
    let current_tmp = cfg.dir.join("CURRENT.tmp");
    {
        let mut f = File::create(&current_tmp)?;
        writeln!(f, "epoch-{epoch:010}")?;
        f.sync_all()?;
    }
    fs::rename(&current_tmp, cfg.current_path())?;
    File::open(&cfg.dir)?.sync_all()?;

    prune(cfg, &manifest);
    Ok(manifest)
}

/// Best-effort removal of everything the committed `manifest` supersedes:
/// older (and stale `.tmp`) epoch directories and every WAL segment below
/// the manifest's per-shard `next` position. Failures here cost disk space,
/// never correctness, so they are ignored.
fn prune(cfg: &DurabilityConfig, manifest: &EpochManifest) {
    let keep = cfg.epoch_dir(manifest.epoch);
    if let Ok(entries) = fs::read_dir(&cfg.dir) {
        for entry in entries.flatten() {
            let path = entry.path();
            let Some(name) = entry.file_name().to_str().map(str::to_string) else { continue };
            if path == keep || !name.starts_with("epoch-") {
                continue;
            }
            let _ = fs::remove_dir_all(&path);
        }
    }
    if let Ok(segments) = super::wal::scan_segments(&cfg.wal_dir()) {
        for (shard, seq, path) in segments {
            let covered = match manifest.next_seq.get(shard) {
                Some(&next) => seq < next,
                // a segment for a shard the manifest does not know cannot be
                // replayed consistently; the snapshot supersedes it
                None => true,
            };
            if covered {
                let _ = fs::remove_file(path);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: &str, shard: usize) -> SessionDurableMeta {
        SessionDurableMeta {
            id: id.to_string(),
            shard,
            windows: 12,
            events: 240,
            anomalies: 1,
            interval: 512,
            since_resync: 4,
            resyncs: 2,
            max_drift: 1e-15,
            last: Some((0.001_234_5, false)),
            observed: 12,
            trailing: vec![0.25, 1.0 / 3.0, f64::MIN_POSITIVE],
        }
    }

    #[test]
    fn manifest_roundtrips_bit_exact() {
        let m = EpochManifest {
            epoch: 7,
            shards: 2,
            next_seq: vec![4, 9],
            sessions: vec![
                meta("wiki 00001", 0), // id with a space: %-escaped on disk
                SessionDurableMeta {
                    last: None,
                    trailing: Vec::new(),
                    observed: 0,
                    ..meta("dos-00002", 1)
                },
            ],
        };
        let mut buf = Vec::new();
        write_manifest(&mut buf, &m).unwrap();
        let got = read_manifest(io::Cursor::new(&buf)).unwrap();
        assert_eq!(got, m);
        // floats survive as exact bits
        assert_eq!(got.sessions[0].max_drift.to_bits(), m.sessions[0].max_drift.to_bits());
        assert_eq!(got.sessions[0].trailing[2].to_bits(), f64::MIN_POSITIVE.to_bits());
    }

    #[test]
    fn manifest_rejects_malformed_lines() {
        for text in [
            "",
            "not-a-manifest\n",
            "finger-epoch v1\nepoch 1\n", // missing shards
            "finger-epoch v1\nepoch 1\nshards 2\nnext 0 1\n", // one next line short
            "finger-epoch v1\nepoch 1\nshards 1\nnext 0 1\nsession broken shard 0\n",
            "finger-epoch v1\nepoch 1\nshards 1\nnext 5 1\n", // out-of-range shard
        ] {
            assert!(read_manifest(io::Cursor::new(text.as_bytes())).is_err(), "{text:?}");
        }
    }

    #[test]
    fn commit_epoch_publishes_current_and_prunes() {
        let root =
            std::env::temp_dir().join(format!("finger_epoch_{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        let cfg = crate::durability::DurabilityConfig::new(&root);
        fs::create_dir_all(cfg.wal_dir()).unwrap();
        // two stale segments for shard 0, one live
        for seq in 1..=3u64 {
            fs::write(cfg.wal_dir().join(super::super::wal::segment_name(0, seq)), b"x")
                .unwrap();
        }
        prepare_epoch_tmp(&cfg, 1).unwrap();
        let cuts =
            vec![EpochCut { shard: 0, next_seq: 3, sessions: vec![meta("session-00000", 0)] }];
        let m = commit_epoch(&cfg, 1, &cuts).unwrap();
        assert_eq!(read_current(&cfg).unwrap(), Some(1));
        assert_eq!(load_manifest(&cfg.epoch_dir(1)).unwrap(), m);
        assert!(!cfg.epoch_tmp_dir(1).exists());
        // segments 1 and 2 pruned, 3 (the epoch's own start) kept
        let left = super::super::wal::scan_segments(&cfg.wal_dir()).unwrap();
        assert_eq!(left.iter().map(|&(_, s, _)| s).collect::<Vec<_>>(), vec![3]);
        fs::remove_dir_all(&root).ok();
    }
}
