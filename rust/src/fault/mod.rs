//! Deterministic failpoint registry — first-party fault injection.
//!
//! A *failpoint* is a named site on a production code path (WAL append, epoch
//! rename, socket read, shard submit) that can be armed to fail on a
//! deterministic schedule. The registry is compiled in only under the
//! `fault-inject` cargo feature; the default build inlines every
//! [`fire`] call to `false`, so the injection points cost nothing in
//! production binaries (pinned by the BENCH trajectory).
//!
//! ## Schedules
//!
//! Every spec is a pure function of the failpoint's hit counter, so a given
//! `(spec, workload)` pair fails at exactly the same points on every run —
//! chaos tests are reproducible bit for bit:
//!
//! | spec      | fires                                        |
//! |-----------|----------------------------------------------|
//! | `off`     | never (and resets the hit counter)           |
//! | `once`    | on the next hit only                         |
//! | `at=N`    | on exactly the Nth hit (1-based)             |
//! | `every=N` | on every Nth hit                             |
//! | `after=N` | on every hit past the Nth (persistent: disk-full style) |
//!
//! ## Arming
//!
//! * Config: a `[fault]` section maps failpoint names to specs
//!   (`wal.fsync = "at=3"`), applied at server start via
//!   [`arm_from_config`].
//! * Wire: the `FAULT <name> <spec>` admin verb (both codecs) arms a point
//!   on a live server, so integration tests can script fault schedules
//!   mid-load. On a default build the verb answers `ERR` — see
//!   `docs/PROTOCOL.md`.
//!
//! Names use dots (`wal.append`), specs never contain whitespace, and the
//! catalogue lives in [`Failpoint::ALL`] (documented in
//! `docs/ROBUSTNESS.md`).

use std::io;

/// Every injection point compiled into the crate. The name is the wire /
/// config identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Failpoint {
    /// WAL record append (`WalWriter::commit_frame` write path).
    WalAppend,
    /// WAL fsync (`WalWriter::sync`).
    WalFsync,
    /// WAL segment rotation / fresh-segment open.
    WalRotate,
    /// Epoch snapshot write path (manifest create/write/fsync).
    SnapWrite,
    /// Epoch snapshot atomic rename (staging dir → final dir).
    SnapRename,
    /// Server-side socket read (fires as a connection reset).
    NetRead,
    /// Server-side socket write (fires as a connection reset).
    NetWrite,
    /// Shard queue submit (fires as `WouldBlock` backpressure).
    ShardSubmit,
}

impl Failpoint {
    /// The full catalogue, in stable render order.
    pub const ALL: [Failpoint; 8] = [
        Failpoint::WalAppend,
        Failpoint::WalFsync,
        Failpoint::WalRotate,
        Failpoint::SnapWrite,
        Failpoint::SnapRename,
        Failpoint::NetRead,
        Failpoint::NetWrite,
        Failpoint::ShardSubmit,
    ];

    /// Wire / config name of this failpoint.
    pub fn name(self) -> &'static str {
        match self {
            Failpoint::WalAppend => "wal.append",
            Failpoint::WalFsync => "wal.fsync",
            Failpoint::WalRotate => "wal.rotate",
            Failpoint::SnapWrite => "snap.write",
            Failpoint::SnapRename => "snap.rename",
            Failpoint::NetRead => "net.read",
            Failpoint::NetWrite => "net.write",
            Failpoint::ShardSubmit => "shard.submit",
        }
    }

    /// Look a failpoint up by its wire / config name.
    pub fn parse(name: &str) -> Option<Failpoint> {
        Failpoint::ALL.iter().copied().find(|f| f.name() == name)
    }

    fn index(self) -> usize {
        match self {
            Failpoint::WalAppend => 0,
            Failpoint::WalFsync => 1,
            Failpoint::WalRotate => 2,
            Failpoint::SnapWrite => 3,
            Failpoint::SnapRename => 4,
            Failpoint::NetRead => 5,
            Failpoint::NetWrite => 6,
            Failpoint::ShardSubmit => 7,
        }
    }
}

/// A deterministic fault schedule (see module docs for the grammar).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    Off,
    Once,
    At(u64),
    Every(u64),
    After(u64),
}

impl FaultSpec {
    /// Parse the wire / config spec grammar: `off | once | at=N | every=N |
    /// after=N` with `N >= 1`.
    pub fn parse(s: &str) -> Option<FaultSpec> {
        match s {
            "off" => return Some(FaultSpec::Off),
            "once" => return Some(FaultSpec::Once),
            _ => {}
        }
        let (kind, n) = s.split_once('=')?;
        let n: u64 = n.parse().ok()?;
        if n == 0 {
            return None;
        }
        match kind {
            "at" => Some(FaultSpec::At(n)),
            "every" => Some(FaultSpec::Every(n)),
            "after" => Some(FaultSpec::After(n)),
            _ => None,
        }
    }

    /// Render back to the spec grammar (inverse of [`FaultSpec::parse`]).
    pub fn render(self) -> String {
        match self {
            FaultSpec::Off => "off".to_string(),
            FaultSpec::Once => "once".to_string(),
            FaultSpec::At(n) => format!("at={n}"),
            FaultSpec::Every(n) => format!("every={n}"),
            FaultSpec::After(n) => format!("after={n}"),
        }
    }
}

/// `true` when the crate was built with `--features fault-inject` — the
/// server's `FAULT` verb reports this to callers.
pub fn compiled_in() -> bool {
    cfg!(feature = "fault-inject")
}

/// The injected failure for `fp`, as an `io::Error` (the shape every
/// instrumented path already propagates).
pub fn injected_err(fp: Failpoint) -> io::Error {
    io::Error::other(format!("injected fault: {}", fp.name()))
}

/// Evaluate `fp` against its armed schedule and bump its hit counter.
/// Returns `true` when the site must fail now. Feature-off builds inline
/// this to `false`.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn fire(_fp: Failpoint) -> bool {
    false
}

/// Arm `fp` with `spec`, resetting its hit counter. Feature-off builds
/// ignore the call (the wire verb reports `ERR` before reaching here).
#[cfg(not(feature = "fault-inject"))]
pub fn set(_fp: Failpoint, _spec: FaultSpec) {}

/// Spec currently armed on `fp`. Always `Off` on feature-off builds.
#[cfg(not(feature = "fault-inject"))]
pub fn spec_of(_fp: Failpoint) -> FaultSpec {
    FaultSpec::Off
}

#[cfg(feature = "fault-inject")]
mod registry {
    use super::{FaultSpec, Failpoint};
    use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

    const KIND_OFF: u8 = 0;
    const KIND_ONCE: u8 = 1;
    const KIND_AT: u8 = 2;
    const KIND_EVERY: u8 = 3;
    const KIND_AFTER: u8 = 4;

    struct Cell {
        kind: AtomicU8,
        param: AtomicU64,
        hits: AtomicU64,
    }

    #[allow(clippy::declare_interior_mutable_const)]
    const CELL_INIT: Cell =
        Cell { kind: AtomicU8::new(KIND_OFF), param: AtomicU64::new(0), hits: AtomicU64::new(0) };
    static CELLS: [Cell; 8] = [CELL_INIT; 8];

    pub fn fire(fp: Failpoint) -> bool {
        let cell = &CELLS[fp.index()];
        let kind = cell.kind.load(Ordering::Acquire);
        if kind == KIND_OFF {
            return false;
        }
        let hit = cell.hits.fetch_add(1, Ordering::AcqRel) + 1;
        let param = cell.param.load(Ordering::Acquire);
        let fired = match kind {
            KIND_ONCE => cell
                .kind
                .compare_exchange(KIND_ONCE, KIND_OFF, Ordering::AcqRel, Ordering::Acquire)
                .is_ok(),
            KIND_AT => hit == param,
            KIND_EVERY => param > 0 && hit % param == 0,
            KIND_AFTER => hit > param,
            _ => false,
        };
        if fired {
            crate::obs::Counter::FaultInjected.inc();
        }
        fired
    }

    pub fn set(fp: Failpoint, spec: FaultSpec) {
        let cell = &CELLS[fp.index()];
        let (kind, param) = match spec {
            FaultSpec::Off => (KIND_OFF, 0),
            FaultSpec::Once => (KIND_ONCE, 0),
            FaultSpec::At(n) => (KIND_AT, n),
            FaultSpec::Every(n) => (KIND_EVERY, n),
            FaultSpec::After(n) => (KIND_AFTER, n),
        };
        cell.param.store(param, Ordering::Release);
        cell.hits.store(0, Ordering::Release);
        cell.kind.store(kind, Ordering::Release);
    }

    pub fn spec_of(fp: Failpoint) -> FaultSpec {
        let cell = &CELLS[fp.index()];
        let param = cell.param.load(Ordering::Acquire);
        match cell.kind.load(Ordering::Acquire) {
            KIND_ONCE => FaultSpec::Once,
            KIND_AT => FaultSpec::At(param),
            KIND_EVERY => FaultSpec::Every(param),
            KIND_AFTER => FaultSpec::After(param),
            _ => FaultSpec::Off,
        }
    }
}

#[cfg(feature = "fault-inject")]
pub use registry::{fire, set, spec_of};

/// Arm every failpoint named in the `[fault]` config section. Returns the
/// names armed, or an error naming the first bad key / spec. On feature-off
/// builds a non-empty `[fault]` section is an error — silently ignoring a
/// chaos schedule would make a green run meaningless.
pub fn arm_from_config(cfg: &crate::cli::Config) -> Result<Vec<&'static str>, String> {
    let mut armed = Vec::new();
    for fp in Failpoint::ALL {
        let key = format!("fault.{}", fp.name());
        let Some(raw) = cfg.get(&key) else { continue };
        let spec = FaultSpec::parse(raw)
            .ok_or_else(|| format!("[fault] {}: bad spec {raw:?}", fp.name()))?;
        if !compiled_in() {
            return Err(format!(
                "[fault] {} armed but this build lacks the fault-inject feature",
                fp.name()
            ));
        }
        set(fp, spec);
        if spec != FaultSpec::Off {
            armed.push(fp.name());
        }
    }
    Ok(armed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_roundtrips() {
        for (s, want) in [
            ("off", FaultSpec::Off),
            ("once", FaultSpec::Once),
            ("at=3", FaultSpec::At(3)),
            ("every=10", FaultSpec::Every(10)),
            ("after=7", FaultSpec::After(7)),
        ] {
            assert_eq!(FaultSpec::parse(s), Some(want));
            assert_eq!(want.render(), s);
        }
        for bad in ["", "at=0", "every=", "never", "at=x", "once=1"] {
            assert_eq!(FaultSpec::parse(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn names_roundtrip_and_cover_the_catalogue() {
        for fp in Failpoint::ALL {
            assert_eq!(Failpoint::parse(fp.name()), Some(fp));
            assert!(!fp.name().contains(char::is_whitespace));
        }
        assert_eq!(Failpoint::parse("wal.nope"), None);
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn schedules_are_deterministic() {
        // ShardSubmit is unused by other unit tests, so the global cell is
        // safe to own here
        let fp = Failpoint::ShardSubmit;
        set(fp, FaultSpec::At(3));
        let hits: Vec<bool> = (0..5).map(|_| fire(fp)).collect();
        assert_eq!(hits, [false, false, true, false, false]);

        set(fp, FaultSpec::Every(2));
        let hits: Vec<bool> = (0..6).map(|_| fire(fp)).collect();
        assert_eq!(hits, [false, true, false, true, false, true]);

        set(fp, FaultSpec::After(2));
        let hits: Vec<bool> = (0..5).map(|_| fire(fp)).collect();
        assert_eq!(hits, [false, false, true, true, true]);

        set(fp, FaultSpec::Once);
        let hits: Vec<bool> = (0..3).map(|_| fire(fp)).collect();
        assert_eq!(hits, [true, false, false]);
        assert_eq!(spec_of(fp), FaultSpec::Off, "once disarms itself");

        set(fp, FaultSpec::Off);
        assert!((0..4).all(|_| !fire(fp)));
    }

    #[cfg(not(feature = "fault-inject"))]
    #[test]
    fn feature_off_is_inert() {
        set(Failpoint::WalAppend, FaultSpec::Once);
        assert!(!fire(Failpoint::WalAppend));
        assert_eq!(spec_of(Failpoint::WalAppend), FaultSpec::Off);
        assert!(!compiled_in());
    }
}
