//! Dense symmetric eigenvalue solver: Householder tridiagonalization followed
//! by the implicit-shift QL algorithm (EISPACK tred1/tql1 lineage, eigenvalue
//! only). O(n³), numerically robust, validated against closed-form spectra.

use crate::graph::Graph;

/// Dense symmetric matrix, row-major full storage.
#[derive(Debug, Clone)]
pub struct SymMatrix {
    n: usize,
    a: Vec<f64>,
}

impl SymMatrix {
    pub fn zeros(n: usize) -> Self {
        Self { n, a: vec![0.0; n * n] }
    }

    pub fn from_rows(n: usize, a: Vec<f64>) -> Self {
        assert_eq!(a.len(), n * n);
        Self { n, a }
    }

    /// Combinatorial Laplacian L = S − W of a graph.
    pub fn laplacian(g: &Graph) -> Self {
        let n = g.num_nodes();
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.set(i, i, g.strength(i as u32));
        }
        for (i, j, w) in g.edges() {
            m.set(i as usize, j as usize, -w);
            m.set(j as usize, i as usize, -w);
        }
        m
    }

    /// Trace-normalized Laplacian L_N = L / trace(L) (the paper's density
    /// matrix). Zero matrix when the graph has no edges.
    pub fn laplacian_normalized(g: &Graph) -> Self {
        let mut m = Self::laplacian(g);
        let tr = g.total_weight();
        if tr > 0.0 {
            for v in &mut m.a {
                *v /= tr;
            }
        }
        m
    }

    /// Symmetric normalized Laplacian 𝓛 = I − S^{-1/2} W S^{-1/2}
    /// (Shi–Malik), used by the VNGE-NL baseline. Isolated nodes get a zero
    /// row/column.
    pub fn laplacian_sym_normalized(g: &Graph) -> Self {
        let n = g.num_nodes();
        let mut m = Self::zeros(n);
        for i in 0..n {
            if g.strength(i as u32) > 0.0 {
                m.set(i, i, 1.0);
            }
        }
        for (i, j, w) in g.edges() {
            let si = g.strength(i);
            let sj = g.strength(j);
            let v = -w / (si * sj).sqrt();
            m.set(i as usize, j as usize, v);
            m.set(j as usize, i as usize, v);
        }
        m
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.get(i, i)).sum()
    }

    /// All eigenvalues, ascending. Consumes a working copy; O(n³).
    pub fn eigenvalues(&self) -> Vec<f64> {
        let n = self.n;
        if n == 0 {
            return Vec::new();
        }
        let mut a = self.a.clone();
        let mut d = vec![0.0; n];
        let mut e = vec![0.0; n];
        tridiagonalize(&mut a, n, &mut d, &mut e);
        tql(&mut d, &mut e).expect("QL iteration failed to converge");
        d.sort_by(|x, y| x.partial_cmp(y).unwrap());
        d
    }
}

/// Householder reduction of a symmetric matrix (row-major `a`, n×n) to
/// tridiagonal form: diagonal in `d`, sub-diagonal in `e[1..]` (e[0]=0).
/// Eigenvalue-only variant (no eigenvector accumulation).
fn tridiagonalize(a: &mut [f64], n: usize, d: &mut [f64], e: &mut [f64]) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += a[i * n + k].abs();
            }
            // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
            if scale == 0.0 {
                e[i] = a[i * n + l];
            } else {
                for k in 0..=l {
                    a[i * n + k] /= scale;
                    h += a[i * n + k] * a[i * n + k];
                }
                let f = a[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a[i * n + l] = f - g;
                let mut f_acc = 0.0;
                for j in 0..=l {
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a[j * n + k] * a[i * n + k];
                    }
                    for k in (j + 1)..=l {
                        g += a[k * n + j] * a[i * n + k];
                    }
                    e[j] = g / h;
                    f_acc += e[j] * a[i * n + j];
                }
                let hh = f_acc / (h + h);
                for j in 0..=l {
                    let f = a[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        a[j * n + k] -= f * e[k] + g * a[i * n + k];
                    }
                }
            }
        } else {
            e[i] = a[i * n + l];
        }
        d[i] = h;
    }
    e[0] = 0.0;
    for i in 0..n {
        d[i] = a[i * n + i];
    }
}

/// Implicit-shift QL on a tridiagonal matrix (d diagonal, e sub-diagonal with
/// e[0] unused). Eigenvalues land in `d` (unsorted). Errors if any eigenvalue
/// needs more than 50 QL sweeps.
fn tql(d: &mut [f64], e: &mut [f64]) -> Result<(), String> {
    let n = d.len();
    if n == 0 {
        return Ok(());
    }
    // shift sub-diagonal down for 0-based convenience: e[i] couples d[i], d[i+1]
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find the first decoupled block boundary m >= l
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(format!("tql: no convergence at eigenvalue {l}"));
            }
            // form implicit shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = hypot(g, 1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r } else { -r });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut i = m;
            let mut underflow = false;
            while i > l {
                i -= 1;
                let f = s * e[i];
                let b = c * e[i];
                r = hypot(f, g);
                e[i + 1] = r;
                // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

#[inline]
fn hypot(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;

    fn assert_spectrum(actual: &[f64], expected: &mut Vec<f64>, tol: f64) {
        expected.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(actual.len(), expected.len());
        for (a, e) in actual.iter().zip(expected.iter()) {
            assert!((a - e).abs() < tol, "eig {a} vs expected {e}");
        }
    }

    #[test]
    fn diag_matrix_spectrum() {
        let mut m = SymMatrix::zeros(4);
        for (i, v) in [3.0, -1.0, 7.0, 0.5].iter().enumerate() {
            m.set(i, i, *v);
        }
        let eig = m.eigenvalues();
        assert_spectrum(&eig, &mut vec![3.0, -1.0, 7.0, 0.5], 1e-12);
    }

    #[test]
    fn two_by_two_known() {
        // [[2,1],[1,2]] -> {1, 3}
        let m = SymMatrix::from_rows(2, vec![2.0, 1.0, 1.0, 2.0]);
        assert_spectrum(&m.eigenvalues(), &mut vec![1.0, 3.0], 1e-12);
    }

    #[test]
    fn complete_graph_laplacian_spectrum() {
        // K_n: eigenvalues {0, n×(n−1 times)}
        let n = 8;
        let g = generators::complete(n, 1.0);
        let eig = SymMatrix::laplacian(&g).eigenvalues();
        let mut expected = vec![n as f64; n - 1];
        expected.push(0.0);
        assert_spectrum(&eig, &mut expected, 1e-9);
    }

    #[test]
    fn star_graph_laplacian_spectrum() {
        // S_n: {0, 1 (n−2 times), n}
        let n = 10;
        let g = generators::star(n);
        let eig = SymMatrix::laplacian(&g).eigenvalues();
        let mut expected = vec![1.0; n - 2];
        expected.push(0.0);
        expected.push(n as f64);
        assert_spectrum(&eig, &mut expected, 1e-9);
    }

    #[test]
    fn ring_graph_laplacian_spectrum() {
        // C_n: 2 − 2cos(2πk/n)
        let n = 12;
        let g = generators::ring(n);
        let eig = SymMatrix::laplacian(&g).eigenvalues();
        let mut expected: Vec<f64> = (0..n)
            .map(|k| 2.0 - 2.0 * (2.0 * std::f64::consts::PI * k as f64 / n as f64).cos())
            .collect();
        assert_spectrum(&eig, &mut expected, 1e-9);
    }

    #[test]
    fn path_graph_laplacian_spectrum() {
        // P_n: 2 − 2cos(πk/n), k = 0..n−1
        let n = 9;
        let g = generators::path(n);
        let eig = SymMatrix::laplacian(&g).eigenvalues();
        let mut expected: Vec<f64> =
            (0..n).map(|k| 2.0 - 2.0 * (std::f64::consts::PI * k as f64 / n as f64).cos()).collect();
        assert_spectrum(&eig, &mut expected, 1e-9);
    }

    #[test]
    fn normalized_laplacian_trace_one() {
        let mut rng = crate::util::Pcg64::new(42);
        let g = generators::erdos_renyi(60, 0.1, &mut rng);
        let eig = SymMatrix::laplacian_normalized(&g).eigenvalues();
        let sum: f64 = eig.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum={sum}");
        assert!(eig.iter().all(|&l| l > -1e-9), "PSD violated");
    }

    #[test]
    fn eigenvalue_sum_equals_trace_random() {
        let mut rng = crate::util::Pcg64::new(7);
        let n = 30;
        let mut m = SymMatrix::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let eig = m.eigenvalues();
        let sum: f64 = eig.iter().sum();
        assert!((sum - m.trace()).abs() < 1e-8 * (1.0 + m.trace().abs()), "{sum} vs {}", m.trace());
    }

    #[test]
    fn eigenvalue_sumsq_equals_frobenius_random() {
        let mut rng = crate::util::Pcg64::new(8);
        let n = 25;
        let mut m = SymMatrix::zeros(n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.uniform(-1.0, 1.0);
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let eig = m.eigenvalues();
        let sumsq: f64 = eig.iter().map(|l| l * l).sum();
        let frob: f64 = (0..n).flat_map(|i| (0..n).map(move |j| (i, j))).map(|(i, j)| m.get(i, j) * m.get(i, j)).sum();
        assert!((sumsq - frob).abs() < 1e-8 * (1.0 + frob), "{sumsq} vs {frob}");
    }

    #[test]
    fn sym_normalized_laplacian_in_zero_two() {
        let mut rng = crate::util::Pcg64::new(9);
        let g = generators::erdos_renyi(40, 0.15, &mut rng);
        let eig = SymMatrix::laplacian_sym_normalized(&g).eigenvalues();
        assert!(eig.iter().all(|&l| (-1e-9..=2.0 + 1e-9).contains(&l)), "{eig:?}");
    }

    #[test]
    fn empty_and_single() {
        assert!(SymMatrix::zeros(0).eigenvalues().is_empty());
        let mut m = SymMatrix::zeros(1);
        m.set(0, 0, 5.0);
        // finger-lint: allow(FL003): exact-constant slice; assert_bits_eq! has no slice form
        assert_eq!(m.eigenvalues(), vec![5.0]);
    }
}
