//! Lanczos iteration with full reorthogonalization for the top-k eigenvalues
//! of a symmetric operator — powers the λ-distance baseline (top-6 spectra of
//! W and L) without densifying large graphs.

use crate::util::Pcg64;

/// Top-k eigenvalues (descending) of the symmetric operator `matvec`
/// (y = A·x) of dimension n. Uses m = min(n, max(2k+16, 40)) Lanczos steps
/// with full reorthogonalization, then solves the small tridiagonal system
/// with the dense QL solver.
pub fn lanczos_top_k(
    n: usize,
    k: usize,
    seed: u64,
    mut matvec: impl FnMut(&[f64], &mut [f64]),
) -> Vec<f64> {
    if n == 0 || k == 0 {
        return Vec::new();
    }
    let m = n.min((2 * k + 16).max(40));
    let mut rng = Pcg64::new(seed);
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut alpha = Vec::with_capacity(m);
    let mut beta = Vec::with_capacity(m); // beta[j] couples q[j], q[j+1]

    let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    normalize(&mut v);
    let mut w = vec![0.0; n];
    for j in 0..m {
        matvec(&v, &mut w);
        let a: f64 = dot(&v, &w);
        alpha.push(a);
        // w ← w − a·v − β_{j−1}·q_{j−1}
        for i in 0..n {
            w[i] -= a * v[i];
        }
        if j > 0 {
            let b = beta[j - 1];
            let prev = &q[j - 1];
            for i in 0..n {
                w[i] -= b * prev[i];
            }
        }
        // full reorthogonalization (twice is enough)
        for _ in 0..2 {
            for qv in &q {
                let c = dot(qv, &w);
                for i in 0..n {
                    w[i] -= c * qv[i];
                }
            }
            let c = dot(&v, &w);
            for i in 0..n {
                w[i] -= c * v[i];
            }
        }
        let b = norm(&w);
        q.push(std::mem::replace(&mut v, vec![0.0; n]));
        if b < 1e-13 || j + 1 == m {
            beta.push(0.0);
            break;
        }
        beta.push(b);
        for i in 0..n {
            v[i] = w[i] / b;
        }
    }

    // eigenvalues of the tridiagonal via the dense path (cheap: m ≤ ~40+2k)
    let t = alpha.len();
    let mut mat = crate::linalg::SymMatrix::zeros(t);
    for i in 0..t {
        mat.set(i, i, alpha[i]);
        // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
        if i + 1 < t && beta[i] != 0.0 {
            mat.set(i, i + 1, beta[i]);
            mat.set(i + 1, i, beta[i]);
        }
    }
    let mut eig = mat.eigenvalues();
    eig.reverse(); // descending
    eig.truncate(k.min(eig.len()));
    eig
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn normalize(a: &mut [f64]) {
    let nm = norm(a);
    if nm > 0.0 {
        for v in a {
            *v /= nm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::Csr;
    use crate::linalg::SymMatrix;

    #[test]
    fn diagonal_operator_top_k() {
        let diag = [9.0, 7.0, 5.0, 3.0, 1.0, 0.5, 0.2, 0.1];
        let n = diag.len();
        let top = lanczos_top_k(n, 3, 1, |x, y| {
            for i in 0..n {
                y[i] = diag[i] * x[i];
            }
        });
        assert!((top[0] - 9.0).abs() < 1e-8);
        assert!((top[1] - 7.0).abs() < 1e-8);
        assert!((top[2] - 5.0).abs() < 1e-8);
    }

    #[test]
    fn laplacian_top_k_matches_dense() {
        let mut rng = Pcg64::new(3);
        let g = generators::erdos_renyi(70, 0.1, &mut rng);
        let csr = Csr::from_graph(&g);
        let top = lanczos_top_k(70, 6, 5, |x, y| csr.matvec_laplacian(x, y));
        let mut dense = SymMatrix::laplacian(&g).eigenvalues();
        dense.reverse();
        for i in 0..6 {
            assert!((top[i] - dense[i]).abs() < 1e-6 * (1.0 + dense[i]), "i={i}: {} vs {}", top[i], dense[i]);
        }
    }

    #[test]
    fn weight_matrix_top_k_matches_dense() {
        let mut rng = Pcg64::new(4);
        let g = generators::barabasi_albert(60, 2, &mut rng);
        let csr = Csr::from_graph(&g);
        let top = lanczos_top_k(60, 4, 6, |x, y| csr.matvec_w(x, y));
        // dense W spectrum
        let n = 60;
        let w = g.dense_weights();
        let dense_m = SymMatrix::from_rows(n, w);
        let mut dense = dense_m.eigenvalues();
        dense.reverse();
        for i in 0..4 {
            assert!((top[i] - dense[i]).abs() < 1e-6 * (1.0 + dense[i].abs()), "i={i}");
        }
    }

    #[test]
    fn k_larger_than_n() {
        let top = lanczos_top_k(3, 10, 2, |x, y| {
            y.copy_from_slice(x); // identity
        });
        assert!(top.len() <= 10);
        assert!(top.iter().all(|&l| (l - 1.0).abs() < 1e-9 || l.abs() < 1e-9));
    }

    #[test]
    fn zero_dim() {
        assert!(lanczos_top_k(0, 3, 1, |_, _| {}).is_empty());
    }
}
