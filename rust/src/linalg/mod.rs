//! Dense and sparse symmetric eigen-solvers built from scratch (no LAPACK in
//! this environment): Householder tridiagonalization + implicit-shift QL for
//! the full spectrum (the exact-VNGE baseline the paper times against), power
//! iteration for λ_max (FINGER-Ĥ's O(n+m) path), and Lanczos for the top-k
//! eigenvalues (the λ-distance baseline).

pub mod dense;
pub mod lanczos;
pub mod power;

pub use dense::SymMatrix;
pub use lanczos::lanczos_top_k;
pub use power::{power_iteration, PowerOpts};
