//! Power iteration for λ_max of the trace-normalized Laplacian — the O(n+m)
//! eigen-path behind FINGER-Ĥ (Eq. 1). L_N is PSD so plain power iteration
//! converges to the largest eigenvalue; we stop on Rayleigh-quotient
//! stagnation.

use crate::graph::Csr;
use crate::util::Pcg64;

/// Options for power iteration.
#[derive(Debug, Clone)]
pub struct PowerOpts {
    pub max_iters: usize,
    /// Relative Rayleigh-quotient change threshold.
    pub tol: f64,
    pub seed: u64,
}

impl Default for PowerOpts {
    fn default() -> Self {
        // 1e-8 relative Rayleigh stagnation: Ĥ consumes ln(λ_max), whose
        // sensitivity to a 1e-8 λ error is far below the approximation error
        // of Ĥ itself; tightening to 1e-10 costs ~25% more iterations for no
        // observable change in any experiment (EXPERIMENTS.md §Perf).
        Self { max_iters: 300, tol: 1e-8, seed: 0x9d0f_00d5 }
    }
}

/// λ_max of L_N = L/trace(L) via power iteration on the CSR view.
/// Returns 0.0 for edgeless graphs. O((n+m)·iters).
pub fn power_iteration(csr: &Csr, opts: &PowerOpts) -> f64 {
    let n = csr.num_nodes();
    if n == 0 || csr.total_weight <= 0.0 {
        return 0.0;
    }
    let mut rng = Pcg64::new(opts.seed);
    // random start, deterministic per seed; orthogonal to nothing in particular
    let mut x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    normalize(&mut x);
    let mut y = vec![0.0; n];
    let mut lambda_prev = 0.0;
    for it in 0..opts.max_iters {
        csr.matvec_laplacian_normalized(&x, &mut y);
        // Rayleigh quotient x'·L_N·x (x normalized)
        let lambda: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let norm = normalize(&mut y);
        // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
        if norm == 0.0 {
            return 0.0; // x in the kernel; restart from another random vector
        }
        std::mem::swap(&mut x, &mut y);
        if it > 0 && (lambda - lambda_prev).abs() <= opts.tol * lambda.abs().max(1e-300) {
            return lambda.max(0.0);
        }
        lambda_prev = lambda;
    }
    lambda_prev.max(0.0)
}

fn normalize(x: &mut [f64]) -> f64 {
    let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    if norm > 0.0 {
        for v in x.iter_mut() {
            *v /= norm;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;
    use crate::generators;
    use crate::graph::{Csr, Graph};
    use crate::linalg::SymMatrix;

    fn lambda_max_exact(g: &Graph) -> f64 {
        *SymMatrix::laplacian_normalized(g)
            .eigenvalues()
            .last()
            .unwrap()
    }

    #[test]
    fn complete_graph_lambda_max() {
        // K_n: λ_max(L) = n, trace = n(n−1) ⇒ λ_max(L_N) = 1/(n−1)
        let n = 10;
        let g = generators::complete(n, 1.0);
        let lam = power_iteration(&Csr::from_graph(&g), &PowerOpts::default());
        assert!((lam - 1.0 / (n as f64 - 1.0)).abs() < 1e-8, "lam={lam}");
    }

    #[test]
    fn star_graph_lambda_max() {
        // S_n: λ_max(L)=n, trace=2(n−1) ⇒ λ_max(L_N)=n/(2(n−1))
        let n = 16;
        let g = generators::star(n);
        let lam = power_iteration(&Csr::from_graph(&g), &PowerOpts::default());
        let expected = n as f64 / (2.0 * (n as f64 - 1.0));
        assert!((lam - expected).abs() < 1e-8, "lam={lam} expected={expected}");
    }

    #[test]
    fn matches_dense_solver_on_random_graphs() {
        for seed in 0..5 {
            let mut rng = Pcg64::new(seed);
            let g = generators::erdos_renyi(80, 0.08, &mut rng);
            if g.num_edges() == 0 {
                continue;
            }
            let lam = power_iteration(&Csr::from_graph(&g), &PowerOpts::default());
            let exact = lambda_max_exact(&g);
            assert!((lam - exact).abs() < 1e-6 * (1.0 + exact), "seed={seed} {lam} vs {exact}");
        }
    }

    #[test]
    fn weighted_graph_matches_dense() {
        let mut rng = Pcg64::new(11);
        let mut g = generators::erdos_renyi(50, 0.1, &mut rng);
        let edges: Vec<_> = g.edges().collect();
        for (k, (i, j, _)) in edges.into_iter().enumerate() {
            g.set_weight(i, j, 0.5 + (k % 7) as f64);
        }
        let lam = power_iteration(&Csr::from_graph(&g), &PowerOpts::default());
        let exact = lambda_max_exact(&g);
        assert!((lam - exact).abs() < 1e-6, "{lam} vs {exact}");
    }

    #[test]
    fn empty_graph_returns_zero() {
        let g = Graph::new(5);
        assert_bits_eq!(power_iteration(&Csr::from_graph(&g), &PowerOpts::default()), 0.0);
    }

    #[test]
    fn lambda_bounded_by_anderson_morley() {
        // λ_max(L) ≤ 2·s_max ⇒ λ_max(L_N) ≤ 2c·s_max (the H̃ ≤ Ĥ ordering)
        let mut rng = Pcg64::new(13);
        let g = generators::barabasi_albert(100, 3, &mut rng);
        let lam = power_iteration(&Csr::from_graph(&g), &PowerOpts::default());
        let bound = 2.0 * g.s_max() / g.total_weight();
        assert!(lam <= bound + 1e-9, "{lam} > {bound}");
    }
}
