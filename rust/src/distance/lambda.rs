//! λ-distance (Bunke et al. 2007; Wilson & Zhu 2008): Euclidean distance
//! between the top-k eigenvalues of a matrix representation of each graph.
//! The paper uses k = 6 on the weight matrix W ("Adj.") and the combinatorial
//! Laplacian L ("Lap."). Top-k spectra come from Lanczos, so large sparse
//! graphs never densify.

use crate::graph::{Csr, Graph};
use crate::linalg::lanczos_top_k;

/// Which matrix the spectrum is taken from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LambdaMatrix {
    /// Weight (adjacency) matrix W.
    Adjacency,
    /// Combinatorial Laplacian L = S − W.
    Laplacian,
}

/// λ-distance with top-k eigenvalues (k = 6 in the paper).
pub fn lambda_distance(a: &Graph, b: &Graph, k: usize, which: LambdaMatrix) -> f64 {
    let ta = top_spectrum(a, k, which);
    let tb = top_spectrum(b, k, which);
    let mut d2 = 0.0;
    for i in 0..k {
        let x = ta.get(i).copied().unwrap_or(0.0);
        let y = tb.get(i).copied().unwrap_or(0.0);
        d2 += (x - y) * (x - y);
    }
    d2.sqrt()
}

/// Below this size the dense QL solver is cheap and — unlike single-vector
/// Lanczos — resolves eigenvalue *multiplicities* (K_n's (n−1)-fold n, say).
/// Above it, random graphs essentially never carry exact multiplicities and
/// Lanczos extremal convergence is accurate.
const DENSE_CUTOFF: usize = 512;

fn top_spectrum(g: &Graph, k: usize, which: LambdaMatrix) -> Vec<f64> {
    let n = g.num_nodes();
    if n == 0 {
        return Vec::new();
    }
    if n <= DENSE_CUTOFF {
        let m = match which {
            LambdaMatrix::Adjacency => {
                crate::linalg::SymMatrix::from_rows(n, g.dense_weights())
            }
            LambdaMatrix::Laplacian => crate::linalg::SymMatrix::laplacian(g),
        };
        let mut eig = m.eigenvalues();
        eig.reverse();
        eig.truncate(k);
        return eig;
    }
    let csr = Csr::from_graph(g);
    match which {
        LambdaMatrix::Adjacency => lanczos_top_k(n, k, 0x7A3B, |x, y| csr.matvec_w(x, y)),
        LambdaMatrix::Laplacian => lanczos_top_k(n, k, 0x7A3C, |x, y| csr.matvec_laplacian(x, y)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::util::Pcg64;

    #[test]
    fn identical_zero() {
        let mut rng = Pcg64::new(1);
        let g = generators::erdos_renyi(50, 0.1, &mut rng);
        assert!(lambda_distance(&g, &g, 6, LambdaMatrix::Adjacency) < 1e-8);
        assert!(lambda_distance(&g, &g, 6, LambdaMatrix::Laplacian) < 1e-8);
    }

    #[test]
    fn symmetry() {
        let mut rng = Pcg64::new(2);
        let a = generators::barabasi_albert(40, 2, &mut rng);
        let b = generators::barabasi_albert(40, 3, &mut rng);
        let d1 = lambda_distance(&a, &b, 6, LambdaMatrix::Laplacian);
        let d2 = lambda_distance(&b, &a, 6, LambdaMatrix::Laplacian);
        assert!((d1 - d2).abs() < 1e-9);
    }

    #[test]
    fn known_spectra_star_vs_complete() {
        // top Laplacian eigenvalues: star S_8 -> {8,1,...}, K_8 -> {8,8,...}
        let s = generators::star(8);
        let k = generators::complete(8, 1.0);
        let d = lambda_distance(&s, &k, 3, LambdaMatrix::Laplacian);
        // expected sqrt((8-8)² + (1-8)² + (1-8)²) = 7√2
        assert!((d - 7.0 * 2f64.sqrt()).abs() < 1e-6, "d={d}");
    }

    #[test]
    fn detects_heavy_edge_change() {
        let mut rng = Pcg64::new(3);
        let g = generators::erdos_renyi(40, 0.15, &mut rng);
        let mut h = g.clone();
        let (i, j, _) = g.edges().next().unwrap();
        h.set_weight(i, j, 50.0); // large spectral perturbation
        assert!(lambda_distance(&g, &h, 6, LambdaMatrix::Laplacian) > 1.0);
    }

    #[test]
    fn size_mismatch_padded() {
        let a = generators::ring(10);
        let b = generators::ring(20);
        let d = lambda_distance(&a, &b, 6, LambdaMatrix::Adjacency);
        assert!(d.is_finite());
    }
}
