//! Degree-distribution distances (supplement §N): cosine, Bhattacharyya and
//! Hellinger distances on the two graphs' (unweighted) degree distributions.
//! KL is excluded for the paper's reason — supports rarely coincide.

use crate::graph::Graph;

fn padded_dists(a: &Graph, b: &Graph) -> (Vec<f64>, Vec<f64>) {
    let mut p = a.degree_distribution();
    let mut q = b.degree_distribution();
    let len = p.len().max(q.len());
    p.resize(len, 0.0);
    q.resize(len, 0.0);
    (p, q)
}

/// Cosine distance = 1 − p·q / (‖p‖‖q‖). 0 when either is degenerate-empty.
pub fn cosine_distance(a: &Graph, b: &Graph) -> f64 {
    let (p, q) = padded_dists(a, b);
    let dot: f64 = p.iter().zip(&q).map(|(x, y)| x * y).sum();
    let np: f64 = p.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nq: f64 = q.iter().map(|x| x * x).sum::<f64>().sqrt();
    // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
    if np == 0.0 || nq == 0.0 {
        return 0.0;
    }
    (1.0 - dot / (np * nq)).max(0.0)
}

/// Bhattacharyya coefficient BC = Σ √(pᵢqᵢ).
fn bc(a: &Graph, b: &Graph) -> f64 {
    let (p, q) = padded_dists(a, b);
    p.iter().zip(&q).map(|(x, y)| (x * y).sqrt()).sum()
}

/// Bhattacharyya distance = −ln BC (∞-safe: returns a large finite value for
/// disjoint supports).
pub fn bhattacharyya_distance(a: &Graph, b: &Graph) -> f64 {
    let c = bc(a, b);
    if c <= 1e-300 {
        700.0 // −ln of smallest positive double; finite sentinel
    } else {
        (-c.ln()).max(0.0)
    }
}

/// Hellinger distance = √(1 − BC) ∈ [0, 1].
pub fn hellinger_distance(a: &Graph, b: &Graph) -> f64 {
    (1.0 - bc(a, b)).max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;

    #[test]
    fn identical_zero() {
        let g = generators::ring(10);
        assert!(cosine_distance(&g, &g) < 1e-12);
        assert!(bhattacharyya_distance(&g, &g) < 1e-12);
        assert!(hellinger_distance(&g, &g) < 1e-9);
    }

    #[test]
    fn hellinger_in_unit_interval() {
        let a = generators::ring(10);
        let b = generators::star(10);
        let h = hellinger_distance(&a, &b);
        assert!((0.0..=1.0).contains(&h));
        assert!(h > 0.0);
    }

    #[test]
    fn disjoint_supports() {
        // ring: all degree 2; complete K5: all degree 4 — disjoint histograms
        let a = generators::ring(5);
        let b = generators::complete(5, 1.0);
        assert!((hellinger_distance(&a, &b) - 1.0).abs() < 1e-9);
        assert!(bhattacharyya_distance(&a, &b) > 100.0);
        assert!((cosine_distance(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn symmetry() {
        let a = generators::star(8);
        let b = generators::path(8);
        assert!((cosine_distance(&a, &b) - cosine_distance(&b, &a)).abs() < 1e-12);
        assert!((hellinger_distance(&a, &b) - hellinger_distance(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_perturbation() {
        let mut rng = crate::util::Pcg64::new(1);
        let g = generators::erdos_renyi_avg_degree(200, 10.0, &mut rng);
        let edges: Vec<_> = g.edges().collect();
        let mut small = g.clone();
        let mut big = g.clone();
        for &(i, j, _) in edges.iter().take(5) {
            small.remove_edge(i, j);
        }
        for &(i, j, _) in edges.iter().take(200) {
            big.remove_edge(i, j);
        }
        assert!(hellinger_distance(&g, &big) > hellinger_distance(&g, &small));
    }
}
