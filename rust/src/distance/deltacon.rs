//! DeltaCon (Koutra et al. 2016) and its Matusita-distance variant RMD.
//!
//! Node affinities come from fast belief propagation:
//! S = [I + ε²D − εA]⁻¹, approximated by the convergent power series
//! S ≈ Σ_k (εA − ε²D)^k with ε = 1/(1 + s_max). The scalable variant
//! propagates g random node groups instead of all n unit vectors
//! (DeltaCon's group trick), giving O(g·K·m) per graph.
//! Distance is the root Euclidean (Matusita) distance between affinity
//! matrices; similarity = 1/(1 + d); RMD = d itself (= 1/sim − 1).

use crate::graph::{Csr, Graph};
use crate::util::Pcg64;

/// Options for the FaBP affinity computation.
#[derive(Debug, Clone)]
pub struct DeltaConOpts {
    /// Number of node groups g (≤ n). More groups → better fidelity.
    pub groups: usize,
    /// Power-series terms K.
    pub terms: usize,
    pub seed: u64,
}

impl Default for DeltaConOpts {
    fn default() -> Self {
        Self { groups: 16, terms: 10, seed: 0xDE17A }
    }
}

/// Affinity sketch: n×g column-major matrix of group affinities.
fn affinities(g: &Graph, opts: &DeltaConOpts, assignment: &[usize]) -> Vec<f64> {
    let n = g.num_nodes();
    let ng = opts.groups.min(n).max(1);
    let csr = Csr::from_graph(g);
    let eps = 1.0 / (1.0 + g.s_max());
    // X0 = group indicator matrix; acc accumulates Σ M^k X0
    let mut x = vec![0.0; n * ng];
    for (i, &grp) in assignment.iter().enumerate() {
        x[grp * n + i] = 1.0;
    }
    let mut acc = x.clone();
    let mut y = vec![0.0; n];
    let mut wx = vec![0.0; n];
    for _ in 0..opts.terms {
        for col in 0..ng {
            let xc = &x[col * n..(col + 1) * n];
            // y = εA·x − ε²D·x
            csr.matvec_w(xc, &mut wx);
            for i in 0..n {
                y[i] = eps * wx[i] - eps * eps * csr.strengths[i] * xc[i];
            }
            x[col * n..(col + 1) * n].copy_from_slice(&y);
            for i in 0..n {
                acc[col * n + i] += y[i];
            }
        }
    }
    acc
}

/// Root Euclidean (Matusita) distance between the two graphs' affinity
/// sketches. Both graphs share the group assignment so columns align.
pub fn rmd_distance(a: &Graph, b: &Graph, opts: &DeltaConOpts) -> f64 {
    let n = a.num_nodes().max(b.num_nodes());
    let mut a = a.clone();
    let mut b = b.clone();
    a.ensure_nodes(n);
    b.ensure_nodes(n);
    let ng = opts.groups.min(n).max(1);
    let mut rng = Pcg64::new(opts.seed);
    let assignment: Vec<usize> = (0..n).map(|_| rng.below(ng)).collect();
    let sa = affinities(&a, opts, &assignment);
    let sb = affinities(&b, opts, &assignment);
    let mut d2 = 0.0;
    for (x, y) in sa.iter().zip(&sb) {
        // truncation noise can leave tiny negatives; clamp before sqrt
        let sx = x.max(0.0).sqrt();
        let sy = y.max(0.0).sqrt();
        d2 += (sx - sy) * (sx - sy);
    }
    d2.sqrt()
}

/// DeltaCon similarity ∈ (0, 1]: 1/(1 + rootED).
pub fn deltacon_similarity(a: &Graph, b: &Graph, opts: &DeltaConOpts) -> f64 {
    1.0 / (1.0 + rmd_distance(a, b, opts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;
    use crate::generators;

    #[test]
    fn identical_graphs_similarity_one() {
        let mut rng = Pcg64::new(1);
        let g = generators::erdos_renyi(50, 0.1, &mut rng);
        let s = deltacon_similarity(&g, &g, &DeltaConOpts::default());
        assert!((s - 1.0).abs() < 1e-12, "s={s}");
        assert!(rmd_distance(&g, &g, &DeltaConOpts::default()) < 1e-12);
    }

    #[test]
    fn symmetry() {
        let mut rng = Pcg64::new(2);
        let a = generators::erdos_renyi(40, 0.1, &mut rng);
        let b = generators::erdos_renyi(40, 0.12, &mut rng);
        let o = DeltaConOpts::default();
        assert!((rmd_distance(&a, &b, &o) - rmd_distance(&b, &a, &o)).abs() < 1e-12);
    }

    #[test]
    fn similarity_decreases_with_perturbation() {
        let mut rng = Pcg64::new(3);
        let g = generators::erdos_renyi_avg_degree(80, 8.0, &mut rng);
        let edges: Vec<_> = g.edges().collect();
        let mut small = g.clone();
        let mut big = g.clone();
        for &(i, j, _) in edges.iter().take(2) {
            small.remove_edge(i, j);
        }
        for &(i, j, _) in edges.iter().take(30) {
            big.remove_edge(i, j);
        }
        let o = DeltaConOpts::default();
        let s_small = deltacon_similarity(&g, &small, &o);
        let s_big = deltacon_similarity(&g, &big, &o);
        assert!(s_small > s_big, "{s_small} !> {s_big}");
        assert!((0.0..=1.0).contains(&s_small));
    }

    #[test]
    fn rmd_is_one_over_sim_minus_one() {
        let mut rng = Pcg64::new(4);
        let a = generators::barabasi_albert(40, 2, &mut rng);
        let b = generators::barabasi_albert(40, 2, &mut rng);
        let o = DeltaConOpts::default();
        let d = rmd_distance(&a, &b, &o);
        let s = deltacon_similarity(&a, &b, &o);
        assert!((d - (1.0 / s - 1.0)).abs() < 1e-9);
    }

    #[test]
    fn handles_size_mismatch() {
        let a = generators::star(10);
        let b = generators::star(15);
        let d = rmd_distance(&a, &b, &DeltaConOpts::default());
        assert!(d > 0.0 && d.is_finite());
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Pcg64::new(5);
        let a = generators::erdos_renyi(30, 0.2, &mut rng);
        let b = generators::erdos_renyi(30, 0.2, &mut rng);
        let o = DeltaConOpts::default();
        assert_bits_eq!(rmd_distance(&a, &b, &o), rmd_distance(&a, &b, &o));
    }
}
