//! Graph edit distance for graphs with known node correspondence (Bunke et
//! al. 2007): the number of node/edge additions and removals converting G_t
//! into G_{t+1}. With aligned ids this is |n − n′| plus the size of the edge
//! symmetric difference (unweighted — GED is support-only, which is exactly
//! why it misses weight-borne signal in the genome experiment).

use crate::graph::Graph;

/// GED(G, G′) = |n − n′| + |E Δ E′| (edge symmetric difference on supports).
pub fn graph_edit_distance(a: &Graph, b: &Graph) -> f64 {
    let node_edits = a.num_nodes().abs_diff(b.num_nodes());
    let mut edge_edits = 0usize;
    for (i, j, _) in a.edges() {
        let present =
            (i as usize) < b.num_nodes() && (j as usize) < b.num_nodes() && b.has_edge(i, j);
        if !present {
            edge_edits += 1;
        }
    }
    for (i, j, _) in b.edges() {
        let present =
            (i as usize) < a.num_nodes() && (j as usize) < a.num_nodes() && a.has_edge(i, j);
        if !present {
            edge_edits += 1;
        }
    }
    (node_edits + edge_edits) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn identical_zero() {
        let g = Graph::from_pairs(4, &[(0, 1), (2, 3)]);
        assert_bits_eq!(graph_edit_distance(&g, &g), 0.0);
    }

    #[test]
    fn counts_edge_edits() {
        let a = Graph::from_pairs(4, &[(0, 1), (1, 2)]);
        let b = Graph::from_pairs(4, &[(0, 1), (2, 3)]);
        // (1,2) removed + (2,3) added = 2
        assert_bits_eq!(graph_edit_distance(&a, &b), 2.0);
    }

    #[test]
    fn counts_node_edits() {
        let a = Graph::from_pairs(3, &[(0, 1)]);
        let b = Graph::from_pairs(5, &[(0, 1)]);
        assert_bits_eq!(graph_edit_distance(&a, &b), 2.0);
    }

    #[test]
    fn weight_changes_invisible() {
        // GED is support-only — the genome experiment's failure mode
        let a = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let b = Graph::from_edges(3, &[(0, 1, 100.0)]);
        assert_bits_eq!(graph_edit_distance(&a, &b), 0.0);
    }

    #[test]
    fn symmetry() {
        let a = Graph::from_pairs(4, &[(0, 1), (1, 2)]);
        let b = Graph::from_pairs(6, &[(0, 3), (4, 5)]);
        assert_bits_eq!(graph_edit_distance(&a, &b), graph_edit_distance(&b, &a));
    }
}
