//! Vertex/edge overlap (VEO) score (Papadimitriou et al. 2010) — the paper's
//! *anomaly proxy* for the Wikipedia experiments:
//!
//!   VEO = 1 − 2(|V∩V′| + |E∩E′|) / (|V| + |V′| + |E| + |E′|)
//!
//! ∈ [0,1], related to the Sørensen–Dice coefficient. Support-only: edge
//! weight changes are invisible (why it is *not* used in the genome case).

use crate::graph::Graph;

/// VEO dissimilarity between two snapshots with aligned node ids.
pub fn veo_score(a: &Graph, b: &Graph) -> f64 {
    let va = a.num_nodes();
    let vb = b.num_nodes();
    let v_common = va.min(vb);
    let mut e_common = 0usize;
    for (i, j, _) in a.edges() {
        if (i as usize) < vb && (j as usize) < vb && b.has_edge(i, j) {
            e_common += 1;
        }
    }
    let denom = (va + vb + a.num_edges() + b.num_edges()) as f64;
    // finger-lint: allow(FL003): exact zero sentinel, not a computed comparison
    if denom == 0.0 {
        return 0.0;
    }
    1.0 - 2.0 * (v_common + e_common) as f64 / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn identical_zero() {
        let g = Graph::from_pairs(5, &[(0, 1), (2, 3)]);
        assert!(veo_score(&g, &g).abs() < 1e-12);
    }

    #[test]
    fn disjoint_edges_positive() {
        let a = Graph::from_pairs(4, &[(0, 1)]);
        let b = Graph::from_pairs(4, &[(2, 3)]);
        // common: 4 nodes, 0 edges; denom = 4+4+1+1 = 10 -> 1 - 8/10
        assert!((veo_score(&a, &b) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn in_unit_interval() {
        let a = Graph::from_pairs(3, &[(0, 1), (1, 2)]);
        let b = Graph::from_pairs(6, &[(3, 4), (4, 5)]);
        let v = veo_score(&a, &b);
        assert!((0.0..=1.0).contains(&v), "v={v}");
    }

    #[test]
    fn weight_changes_invisible() {
        let a = Graph::from_edges(3, &[(0, 1, 1.0)]);
        let b = Graph::from_edges(3, &[(0, 1, 9.0)]);
        assert!(veo_score(&a, &b).abs() < 1e-12);
    }

    #[test]
    fn empty_graphs() {
        assert_bits_eq!(veo_score(&Graph::new(0), &Graph::new(0)), 0.0);
    }

    #[test]
    fn symmetry() {
        let a = Graph::from_pairs(4, &[(0, 1), (1, 2)]);
        let b = Graph::from_pairs(5, &[(0, 1), (3, 4)]);
        assert_bits_eq!(veo_score(&a, &b), veo_score(&b, &a));
    }
}
