//! Jensen–Shannon distance between graphs (§2.5).
//!
//! JSdiv(G,G′) = H(Ḡ) − ½[H(G) + H(G′)] with Ḡ = (G ⊕ G′)/2;
//! JSdist = √JSdiv — a valid metric for the exact entropy
//! (Endres–Schindelin). FINGER substitutes Ĥ (Algorithm 1, fast) or H̃
//! (Algorithm 2, incremental); approximation error can push the divergence
//! slightly negative, so it is clamped at 0 before the square root.

use crate::entropy::{exact_vnge, finger_hhat, FingerState, Scratch};
use crate::graph::{ops, DeltaGraph, Graph};

/// JS distance with an arbitrary entropy functional (the common core of
/// Algorithm 1 and the exact computation).
pub fn jsdist_with(a: &Graph, b: &Graph, entropy: impl Fn(&Graph) -> f64) -> f64 {
    let avg = ops::average_graph(a, b);
    let div = entropy(&avg) - 0.5 * (entropy(a) + entropy(b));
    div.max(0.0).sqrt()
}

/// FINGER-JSdist (Fast) — Algorithm 1: JS distance via Ĥ. O(n+m).
pub fn jsdist_fast(a: &Graph, b: &Graph) -> f64 {
    jsdist_with(a, b, finger_hhat)
}

/// Exact JS distance via the O(n³) VNGE (test/reference path).
pub fn jsdist_exact(a: &Graph, b: &Graph) -> f64 {
    jsdist_with(a, b, exact_vnge)
}

/// FINGER-JSdist (Incremental) — Algorithm 2: JSdist(G, G ⊕ ΔG) from a live
/// `FingerState`, advancing the state to G ⊕ ΔG. O(Δn + Δm).
///
/// Line 1 computes H̃(G ⊕ ΔG/2) and H̃(G ⊕ ΔG) by Theorem 2 previews;
/// line 2 combines them with the state's current H̃(G).
///
/// Allocates the mid-point delta and preview buffers per call; the scoring
/// hot path uses [`jsdist_incremental_with`], which reuses a caller-owned
/// [`Scratch`] and returns bit-identical scores.
pub fn jsdist_incremental(state: &mut FingerState, delta: &DeltaGraph) -> f64 {
    let h_g = state.htilde();
    let h_mid = state.htilde_after(&delta.half());
    let p_next = state.preview(delta);
    let h_next = p_next.htilde();
    state.apply_previewed(delta, p_next); // reuse the ΔG preview for commit
    let div = h_mid - 0.5 * (h_g + h_next);
    div.max(0.0).sqrt()
}

/// [`jsdist_incremental`] with a reusable [`Scratch`] workspace: the ΔG/2
/// mid-point delta and every preview/commit buffer live in `scratch`, so a
/// steady-state window scores with zero allocations. Identical arithmetic in
/// identical order — the score and the advanced state are bit-for-bit the
/// same as the allocating variant.
// lint: hot-path
pub fn jsdist_incremental_with(
    state: &mut FingerState,
    delta: &DeltaGraph,
    scratch: &mut Scratch,
) -> f64 {
    let h_g = state.htilde();
    let (half, bufs) = scratch.split();
    delta.half_into(half);
    let h_mid = state.preview_bufs(half, true, bufs).htilde();
    let p_next = state.preview_bufs(delta, true, bufs);
    let h_next = p_next.htilde();
    state.apply_previewed_bufs(delta, p_next, bufs); // reuse the ΔG preview
    let div = h_mid - 0.5 * (h_g + h_next);
    div.max(0.0).sqrt()
}
// lint: hot-path end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::util::Pcg64;

    #[test]
    fn identical_graphs_zero_distance() {
        let mut rng = Pcg64::new(1);
        let g = generators::erdos_renyi(50, 0.1, &mut rng);
        assert!(jsdist_fast(&g, &g) < 1e-9);
        assert!(jsdist_exact(&g, &g) < 1e-9);
    }

    #[test]
    fn symmetry() {
        let mut rng = Pcg64::new(2);
        let a = generators::erdos_renyi(40, 0.1, &mut rng);
        let b = generators::erdos_renyi(40, 0.15, &mut rng);
        assert!((jsdist_fast(&a, &b) - jsdist_fast(&b, &a)).abs() < 1e-12);
        assert!((jsdist_exact(&a, &b) - jsdist_exact(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn exact_satisfies_triangle_inequality_samples() {
        let mut rng = Pcg64::new(3);
        for _ in 0..3 {
            let a = generators::erdos_renyi(25, 0.15, &mut rng);
            let b = generators::erdos_renyi(25, 0.2, &mut rng);
            let c = generators::erdos_renyi(25, 0.25, &mut rng);
            let ab = jsdist_exact(&a, &b);
            let bc = jsdist_exact(&b, &c);
            let ac = jsdist_exact(&a, &c);
            assert!(ac <= ab + bc + 1e-9, "{ac} > {ab}+{bc}");
        }
    }

    #[test]
    fn fast_tracks_exact() {
        // on dense-ish ER graphs the approximation should be close in shape
        let mut rng = Pcg64::new(4);
        let a = generators::erdos_renyi_avg_degree(100, 30.0, &mut rng);
        let b = generators::erdos_renyi_avg_degree(100, 30.0, &mut rng);
        let fast = jsdist_fast(&a, &b);
        let exact = jsdist_exact(&a, &b);
        assert!((fast - exact).abs() < 0.2, "fast={fast} exact={exact}");
    }

    #[test]
    fn incremental_matches_batch_htilde_distance() {
        // Algorithm 2 == Algorithm-1-with-H̃ on the same pair
        let mut rng = Pcg64::new(5);
        let g = generators::erdos_renyi(60, 0.08, &mut rng);
        let mut delta = DeltaGraph::new();
        for _ in 0..20 {
            let i = rng.below(60) as u32;
            let j = (i + 1 + rng.below(59) as u32) % 60;
            if i != j {
                delta.add(i, j, rng.uniform(0.2, 1.0));
            }
        }
        let delta = delta.coalesced();
        let g_next = ops::compose(&g, &delta);
        let batch = jsdist_with(&g, &g_next, crate::entropy::finger_htilde);
        let mut state = FingerState::new(g);
        let inc = jsdist_incremental(&mut state, &delta);
        assert!((inc - batch).abs() < 1e-9, "inc={inc} batch={batch}");
        // state advanced to G ⊕ ΔG
        assert_eq!(state.graph().num_edges(), g_next.num_edges());
    }

    #[test]
    fn incremental_with_scratch_bit_identical() {
        let mut rng = Pcg64::new(11);
        let g = generators::erdos_renyi(50, 0.1, &mut rng);
        let mut a = FingerState::new(g.clone());
        let mut b = FingerState::new(g);
        let mut scratch = crate::entropy::Scratch::default();
        for step in 0..40 {
            let mut d = DeltaGraph::new();
            for _ in 0..8 {
                let i = rng.below(50) as u32;
                let j = (i + 1 + rng.below(49) as u32) % 50;
                if i != j {
                    d.add(i, j, rng.uniform(-0.8, 1.0));
                }
            }
            // alternate normal-form and raw (possibly duplicated) deltas
            let d = if step % 2 == 0 { d.coalesced() } else { d };
            let js_alloc = jsdist_incremental(&mut a, &d);
            let js_scratch = jsdist_incremental_with(&mut b, &d, &mut scratch);
            assert_eq!(js_alloc.to_bits(), js_scratch.to_bits(), "step {step}");
            assert_eq!(a.htilde().to_bits(), b.htilde().to_bits(), "step {step}");
            assert_eq!(a.q().to_bits(), b.q().to_bits(), "step {step}");
        }
    }

    #[test]
    fn incremental_empty_delta_zero() {
        let mut rng = Pcg64::new(6);
        let g = generators::erdos_renyi(30, 0.2, &mut rng);
        let mut state = FingerState::new(g);
        let d = DeltaGraph::new();
        assert!(jsdist_incremental(&mut state, &d) < 1e-12);
    }

    #[test]
    fn bigger_change_bigger_distance() {
        let mut rng = Pcg64::new(7);
        let g = generators::erdos_renyi_avg_degree(80, 10.0, &mut rng);
        let mut small = g.clone();
        let mut big = g.clone();
        // perturb 2 edges vs 40 edges
        let edges: Vec<_> = g.edges().collect();
        for &(i, j, _) in edges.iter().take(2) {
            small.remove_edge(i, j);
        }
        for &(i, j, _) in edges.iter().take(40) {
            big.remove_edge(i, j);
        }
        assert!(jsdist_fast(&g, &big) > jsdist_fast(&g, &small));
        assert!(jsdist_exact(&g, &big) > jsdist_exact(&g, &small));
    }
}
