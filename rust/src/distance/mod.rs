//! Graph dissimilarity methods: the paper's FINGER Jensen–Shannon distances
//! (Algorithms 1 & 2) and every baseline it compares against — DeltaCon, RMD,
//! λ-distance (Adj./Lap.), GED, VEO, and degree-distribution distances.

pub mod deltacon;
pub mod degree;
pub mod ged;
pub mod jsdist;
pub mod lambda;
pub mod veo;

pub use deltacon::{deltacon_similarity, rmd_distance, DeltaConOpts};
pub use degree::{bhattacharyya_distance, cosine_distance, hellinger_distance};
pub use ged::graph_edit_distance;
pub use jsdist::{
    jsdist_exact, jsdist_fast, jsdist_incremental, jsdist_incremental_with, jsdist_with,
};
pub use lambda::{lambda_distance, LambdaMatrix};
pub use veo::veo_score;
