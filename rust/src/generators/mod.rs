//! Random and deterministic graph generators used across the paper's
//! experiments: Erdős–Rényi (ER), Barabási–Albert (BA), Watts–Strogatz (WS),
//! plus closed-form families (complete, ring, star, path) used as eigensolver
//! ground truth.

use crate::graph::Graph;
use crate::util::rng::Pcg64;

/// Erdős–Rényi G(n, p): every node pair connected independently with
/// probability p. Uses geometric skipping, O(n + m) expected, so sparse
/// graphs at n ≥ 10⁵ are fine.
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Pcg64) -> Graph {
    assert!((0.0..=1.0).contains(&p), "p out of range");
    let mut g = Graph::new(n);
    if p <= 0.0 || n < 2 {
        return g;
    }
    if p >= 1.0 {
        return complete(n, 1.0);
    }
    // Batagelj–Brandes geometric skipping over lower-triangular pairs
    // (v, w) with w < v: O(n + m) expected.
    let lq = (1.0 - p).ln();
    let mut v: usize = 1;
    let mut w: i64 = -1;
    while v < n {
        let r = 1.0 - rng.f64();
        w += 1 + (r.ln() / lq).floor() as i64;
        while w >= v as i64 && v < n {
            w -= v as i64;
            v += 1;
        }
        if v < n {
            g.set_weight(v as u32, w as u32, 1.0);
        }
    }
    g
}

/// ER with a target average degree d̄ (p = d̄/(n−1)).
pub fn erdos_renyi_avg_degree(n: usize, avg_degree: f64, rng: &mut Pcg64) -> Graph {
    let p = (avg_degree / (n.max(2) - 1) as f64).clamp(0.0, 1.0);
    erdos_renyi(n, p, rng)
}

/// Barabási–Albert preferential attachment: start from a small clique of
/// `m0 = m_attach` nodes, each new node attaches to `m_attach` distinct
/// existing nodes with probability ∝ degree. Degree distribution is
/// power-law; eigenspectrum imbalanced (the paper's SAE-growth case).
pub fn barabasi_albert(n: usize, m_attach: usize, rng: &mut Pcg64) -> Graph {
    assert!(m_attach >= 1 && n > m_attach, "need n > m_attach >= 1");
    let mut g = Graph::new(n);
    // Repeated-node list trick: sampling uniformly from `targets` is
    // sampling proportional to degree.
    let mut targets: Vec<u32> = Vec::with_capacity(2 * n * m_attach);
    // seed clique
    for i in 0..m_attach as u32 {
        for j in (i + 1)..m_attach as u32 {
            g.set_weight(i, j, 1.0);
            targets.push(i);
            targets.push(j);
        }
    }
    if m_attach == 1 {
        targets.push(0); // lone seed node must be attachable
    }
    for v in m_attach..n {
        // small Vec instead of HashSet: m_attach is tiny and std HashSet's
        // salted iteration order would break cross-run determinism
        let mut chosen: Vec<u32> = Vec::with_capacity(m_attach);
        while chosen.len() < m_attach.min(v) {
            let t = targets[rng.below(targets.len())];
            if !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            g.set_weight(v as u32, t, 1.0);
            targets.push(v as u32);
            targets.push(t);
        }
    }
    g
}

/// Watts–Strogatz small world: ring lattice with k nearest neighbors per
/// side... (k even, k/2 per side), then each edge rewired with probability
/// p_ws to a uniform non-duplicate target. Smaller p_ws → more regular graph.
pub fn watts_strogatz(n: usize, k: usize, p_ws: f64, rng: &mut Pcg64) -> Graph {
    assert!(k % 2 == 0 && k < n, "WS needs even k < n");
    assert!((0.0..=1.0).contains(&p_ws));
    let mut g = Graph::new(n);
    for i in 0..n {
        for d in 1..=(k / 2) {
            let j = (i + d) % n;
            g.set_weight(i as u32, j as u32, 1.0);
        }
    }
    // Rewire each original lattice edge (i, i+d) with probability p_ws.
    for i in 0..n {
        for d in 1..=(k / 2) {
            let j = (i + d) % n;
            if !g.has_edge(i as u32, j as u32) {
                continue; // already rewired away
            }
            if rng.bernoulli(p_ws) {
                // pick a new target avoiding self and duplicates
                let mut tries = 0;
                loop {
                    let t = rng.below(n) as u32;
                    if t != i as u32 && !g.has_edge(i as u32, t) {
                        g.remove_edge(i as u32, j as u32);
                        g.set_weight(i as u32, t, 1.0);
                        break;
                    }
                    tries += 1;
                    if tries > 64 {
                        break; // node saturated; keep lattice edge
                    }
                }
            }
        }
    }
    g
}

/// Complete graph K_n with identical edge weight (VNGE ground truth:
/// H = ln(n−1), Theorem 1 equality case).
pub fn complete(n: usize, weight: f64) -> Graph {
    let mut g = Graph::new(n);
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            g.set_weight(i, j, weight);
        }
    }
    g
}

/// Ring (cycle) C_n — Laplacian eigenvalues 2−2cos(2πk/n).
pub fn ring(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for i in 0..n {
        g.set_weight(i as u32, ((i + 1) % n) as u32, 1.0);
    }
    g
}

/// Star S_n (one hub, n−1 leaves) — Laplacian eigenvalues {0, 1×(n−2), n}.
pub fn star(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for i in 1..n {
        g.set_weight(0, i as u32, 1.0);
    }
    g
}

/// Path P_n — Laplacian eigenvalues 2−2cos(πk/n).
pub fn path(n: usize) -> Graph {
    let mut g = Graph::new(n);
    for i in 0..n.saturating_sub(1) {
        g.set_weight(i as u32, (i + 1) as u32, 1.0);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_bits_eq;

    #[test]
    fn er_edge_count_near_expectation() {
        let mut rng = Pcg64::new(1);
        let (n, p) = (500, 0.02);
        let g = erdos_renyi(n, p, &mut rng);
        let expected = p * (n * (n - 1) / 2) as f64;
        let m = g.num_edges() as f64;
        assert!((m - expected).abs() < 4.0 * expected.sqrt() + 10.0, "m={m} expected={expected}");
        g.check_invariants().unwrap();
    }

    #[test]
    fn er_p_zero_and_one() {
        let mut rng = Pcg64::new(2);
        // finger-lint: allow(FL003): integer edge counts; the floats are literal parameters
        assert_eq!(erdos_renyi(50, 0.0, &mut rng).num_edges(), 0);
        // finger-lint: allow(FL003): integer edge counts; the floats are literal parameters
        assert_eq!(erdos_renyi(10, 1.0, &mut rng).num_edges(), 45);
    }

    #[test]
    fn er_avg_degree_matches() {
        let mut rng = Pcg64::new(3);
        let g = erdos_renyi_avg_degree(1000, 10.0, &mut rng);
        let avg = 2.0 * g.num_edges() as f64 / 1000.0;
        assert!((avg - 10.0).abs() < 1.5, "avg={avg}");
    }

    #[test]
    fn ba_edge_count_exact() {
        let mut rng = Pcg64::new(4);
        let (n, m) = (200, 3);
        let g = barabasi_albert(n, m, &mut rng);
        // clique(3)=3 edges + (n-3)*3
        assert_eq!(g.num_edges(), 3 + (n - m) * m);
        g.check_invariants().unwrap();
    }

    #[test]
    fn ba_is_connected_and_heavy_tailed() {
        let mut rng = Pcg64::new(5);
        let g = barabasi_albert(500, 2, &mut rng);
        assert_eq!(g.connected_components(), 1);
        let max_deg = (0..500).map(|i| g.degree(i)).max().unwrap();
        assert!(max_deg > 20, "max_deg={max_deg}"); // hubs exist
    }

    #[test]
    fn ws_p_zero_is_regular_lattice() {
        let mut rng = Pcg64::new(6);
        let g = watts_strogatz(100, 6, 0.0, &mut rng);
        for i in 0..100 {
            assert_eq!(g.degree(i), 6);
        }
        assert_eq!(g.num_edges(), 300);
    }

    #[test]
    fn ws_rewiring_preserves_edge_count() {
        let mut rng = Pcg64::new(7);
        let g = watts_strogatz(200, 8, 0.5, &mut rng);
        assert_eq!(g.num_edges(), 800);
        g.check_invariants().unwrap();
    }

    #[test]
    fn ws_high_p_breaks_regularity() {
        let mut rng = Pcg64::new(8);
        let g = watts_strogatz(200, 6, 0.9, &mut rng);
        let degs: Vec<usize> = (0..200).map(|i| g.degree(i)).collect();
        assert!(degs.iter().any(|&d| d != 6));
    }

    #[test]
    fn complete_structure() {
        let g = complete(5, 2.0);
        assert_eq!(g.num_edges(), 10);
        assert_bits_eq!(g.strength(0), 8.0);
    }

    #[test]
    fn ring_star_path_degrees() {
        // finger-lint: allow(FL003): ring strengths are exact small integers
        assert!(ring(6).strengths().iter().all(|&s| s == 2.0));
        let s = star(6);
        assert_bits_eq!(s.strength(0), 5.0);
        assert_bits_eq!(s.strength(3), 1.0);
        let p = path(5);
        assert_bits_eq!(p.strength(0), 1.0);
        assert_bits_eq!(p.strength(2), 2.0);
    }

    #[test]
    fn generators_are_deterministic() {
        let g1 = erdos_renyi(100, 0.05, &mut Pcg64::new(9));
        let g2 = erdos_renyi(100, 0.05, &mut Pcg64::new(9));
        assert_eq!(g1.num_edges(), g2.num_edges());
        for (i, j, w) in g1.edges() {
            assert_eq!(g2.weight(i, j), w);
        }
    }
}
