//! Network front end — the scoring service on a socket.
//!
//! FINGER's per-update cheapness (Theorem 2 / Algorithm 2) is what makes a
//! *per-event network service* viable: each arriving delta costs O(|ΔG|),
//! so events can be scored as they arrive from outside the process instead
//! of in post-hoc batch jobs. This module turns the in-process sharded
//! [`ScoringService`](crate::service::ScoringService) into exactly that — a
//! line-protocol TCP server plus the client and load-driver tooling around
//! it. Everything is `std::net` + threads: no async runtime dependency.
//!
//! # Architecture
//!
//! ```text
//!            TCP (line protocol, one reply per request)
//!  client ──────────────┐
//!  client ────────────┐ │        ┌────────────────────────────────────┐
//!  finger load ─────┐ │ │        │              NetServer             │
//!   (N connections) │ │ │        │                                    │
//!                   ▼ ▼ ▼        │  accept loop ──► conn thread 0 ──┐ │
//!               OPEN/EV/BATCH ──►│                  conn thread 1 ──┤ │
//!               QUERY/STATS      │                  conn thread k ──┤ │
//!               QUIT/SHUTDOWN    │   parse → try_submit (backoff)   │ │
//!                                └──────────────────────────────────┼─┘
//!                                                                   ▼
//!                                   ScoringService  hash(id) % shards
//!                                   shard 0 │ shard 1 │ … │ shard N-1
//!                                   (bounded queues, SessionRegistry,
//!                                    batcher → scorer → anomaly)
//! ```
//!
//! * [`proto`] — the session-scoped wire protocol: `OPEN`/`EV`/`BATCH`/
//!   `QUERY`/`STATS`/`QUIT`/`SHUTDOWN`, one-line `OK`/`ERR` replies, event
//!   payloads in the [`StreamEvent`](crate::stream::StreamEvent) text
//!   format. Spec: `docs/PROTOCOL.md`.
//! * [`server`] — [`NetServer`]: thread-per-connection readers feeding the
//!   shared service through the non-blocking submit API, per-connection
//!   error isolation, graceful drain returning the final
//!   [`ServiceReport`](crate::service::ServiceReport).
//! * [`client`] — [`NetClient`]: small blocking client (tests, tooling).
//! * [`traffic`] — the load driver: replays multi-tenant workloads
//!   (including wiki/DoS/Hi-C dataset presets) over N concurrent
//!   connections and reports end-to-end events/s.

pub mod client;
pub mod proto;
pub mod server;
pub mod traffic;

pub use client::{NetClient, NetStats};
pub use proto::{
    parse_wire_event, Request, Response, DEFAULT_ADDR, MAX_BATCH, MAX_LINE, MAX_OPEN_NODES,
};
pub use server::{NetConfig, NetServer, ShutdownHandle};
pub use traffic::{replay, run_load, TrafficConfig, TrafficReport};
