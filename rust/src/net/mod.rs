//! Network front end — the scoring service on a socket.
//!
//! FINGER's per-update cheapness (Theorem 2 / Algorithm 2) is what makes a
//! *per-event network service* viable: each arriving delta costs O(|ΔG|),
//! so events can be scored as they arrive from outside the process instead
//! of in post-hoc batch jobs. This module turns the in-process sharded
//! [`ScoringService`](crate::service::ScoringService) into exactly that — a
//! TCP server plus the client and load-driver tooling around it. Everything
//! is `std::net` + a fixed pool of event-loop threads multiplexing
//! nonblocking sockets over `poll(2)`: no async runtime dependency, and no
//! thread per connection — tens of thousands of concurrent connections ride
//! on a handful of threads.
//!
//! The API is split into a transport-independent command core and pluggable
//! wire codecs:
//!
//! * [`command`] — typed [`Command`] / [`Reply`] enums and the shared
//!   semantic validation (resource bounds, poisonous events). Nothing here
//!   knows about bytes.
//! * [`codec`] — the [`Codec`] trait plus both implementations:
//!   [`TextCodec`] (the v1 newline-delimited line protocol, `nc`-friendly
//!   and byte-identical to the original wire) and [`BinaryCodec`] (the v2
//!   length-prefixed framing: opcode byte, varint lengths, f64 scores and
//!   weights as raw bits). Both share one port — a binary connection opens
//!   with a magic-byte preamble and the server negotiates per connection.
//!   Both codecs decode *incrementally* from a per-connection [`ReadBuf`]:
//!   partial frames park in the buffer and in-progress multi-part state
//!   stays in the codec, which is what lets one thread serve many sockets.
//!   Spec for both wires: `docs/PROTOCOL.md`.
//! * [`poll`] — the crate's one FFI point: a dependency-free `poll(2)`
//!   wrapper the event loops park in.
//!
//! # Architecture
//!
//! ```text
//!        TCP (one reply frame per command frame, wire negotiated)
//!  client (text) ────────┐
//!  client (binary) ────┐ │        ┌─────────────────────────────────────┐
//!  finger load ──────┐ │ │        │              NetServer              │
//!   (N conns, either │ │ │        │  accept ─► deal round-robin         │
//!    wire)           ▼ ▼ ▼        │  event loop × T: poll(2) over the   │
//!            OPEN/EV/BATCH ──────►│    poll set; per-conn state machine │
//!            QUERY/CLOSE/STATS    │    negotiate ─► decode ─► dispatch ─┼─┐
//!            QUIT/SHUTDOWN        │    → Reply into write queue         │ │
//!                                 │    (WouldBlock parks the command,   │ │
//!                                 │     read interest withdrawn)        │ │
//!                                 └─────────────────────────────────────┘ │
//!                                                                        ▼
//!                                   ScoringService  hash(id) % shards
//!                                   shard 0 │ shard 1 │ … │ shard N-1
//!                                   (bounded queues, SessionRegistry,
//!                                    batcher → scorer → anomaly)
//! ```
//!
//! * [`server`] — [`NetServer`]: the accept loop dealing connections to a
//!   fixed pool of event-loop threads, each driving per-connection state
//!   machines (incremental decode, bounded write queue with partial-write
//!   handling, lifecycle negotiate → active → drain) and mapping service
//!   backpressure to socket readiness. Graceful drain returns the final
//!   [`ServiceReport`](crate::service::ServiceReport). Dispatch is pure
//!   `Command → Reply` — no formatting knowledge.
//! * [`client`] — [`NetClient`]: small blocking client (tests, tooling),
//!   generic over codec, with a configurable reply-read timeout.
//! * [`traffic`] — the load driver: replays multi-tenant workloads
//!   (including wiki/DoS/Hi-C dataset presets) over N concurrent
//!   connections on either wire and reports end-to-end events/s plus
//!   per-request latency percentiles.

pub mod backoff;
pub mod client;
pub mod codec;
pub mod command;
pub mod poll;
pub mod retry;
pub mod server;
pub mod traffic;

pub use client::{NetClient, NetStats};
pub use codec::{
    negotiate_buf, BinaryCodec, Codec, CommandRead, Decode, NegotiatedBuf, ReadBuf,
    TextCodec, Wire, WireMode, BINARY_MAGIC, BINARY_VERSION,
};
pub use command::{
    parse_wire_event, validate_wire_event, Command, Reply, DEFAULT_ADDR, MAX_BATCH,
    MAX_LINE, MAX_OPEN_NODES,
};
pub use retry::{ErrKind, ErrorCounts, RetryClient, RetryPolicy};
pub use server::{NetConfig, NetServer, ShutdownHandle};
pub use traffic::{replay, replay_with, run_load, TrafficConfig, TrafficReport};
