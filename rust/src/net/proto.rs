//! The session-scoped line protocol (see `docs/PROTOCOL.md` for the spec).
//!
//! Every request and every reply is exactly one `\n`-terminated UTF-8 line.
//! Event payloads reuse the [`StreamEvent`] text format (`e i j dw` |
//! `n count` | `t`), so a delta-stream file can be replayed over the wire
//! verbatim. Session ids travel in their [`encode_session_id`] form — the
//! encoding is injective and produces no whitespace, so ids containing
//! spaces or arbitrary bytes survive tokenization exactly.
//!
//! Parsing is strict: unknown verbs, arity mismatches, malformed ids and
//! semantically poisonous events (non-finite `dw`, self-loops — rejected by
//! the hardened [`StreamEvent::parse`]) all yield a one-line `ERR <reason>`
//! and nothing else, so one bad line never desynchronizes the connection.

use crate::service::{decode_session_id, encode_session_id, SessionSnapshot};
use crate::stream::StreamEvent;

/// Upper bound on the `BATCH` event count: a hostile header can not make the
/// server buffer unbounded memory. Generous — the in-process driver batches
/// one window (tens to thousands of events) per message.
pub const MAX_BATCH: usize = 1 << 20;

/// Upper bound on one request line's byte length (a `BATCH` body line is a
/// plain event line, far below this).
pub const MAX_LINE: usize = 64 * 1024;

/// Upper bound on `OPEN`'s node count: a hostile header can not make the
/// server allocate an arbitrarily large initial graph.
pub const MAX_OPEN_NODES: usize = 1 << 24;

/// Default listen address of `finger serve`.
pub const DEFAULT_ADDR: &str = "127.0.0.1:7341";

/// One parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// `OPEN <id> <n>` — (re)open `id` with a fresh `n`-node empty graph.
    Open { id: String, nodes: usize },
    /// `EV <id> <event-line>` — one stream event for `id`.
    Event { id: String, ev: StreamEvent },
    /// `BATCH <id> <k>` — header announcing `k` raw event lines that follow.
    Batch { id: String, count: usize },
    /// `QUERY <id>` — point-in-time stats of a live session.
    Query { id: String },
    /// `STATS` — per-shard queue depths and service totals.
    Stats,
    /// `QUIT` — close this connection (the server keeps running).
    Quit,
    /// `SHUTDOWN` — gracefully stop the whole server: drain every shard and
    /// produce the final `ServiceReport`.
    Shutdown,
}

fn wire_id(token: Option<&str>, verb: &str) -> Result<String, String> {
    let tok = token.ok_or_else(|| format!("{verb}: missing <id>"))?;
    decode_session_id(tok).ok_or_else(|| format!("{verb}: malformed <id> encoding"))
}

fn wire_usize(token: Option<&str>, verb: &str, what: &str) -> Result<usize, String> {
    token
        .ok_or_else(|| format!("{verb}: missing <{what}>"))?
        .parse()
        .map_err(|_| format!("{verb}: invalid <{what}>"))
}

fn no_more(mut it: std::str::SplitWhitespace<'_>, verb: &str) -> Result<(), String> {
    match it.next() {
        Some(_) => Err(format!("{verb}: unexpected trailing tokens")),
        None => Ok(()),
    }
}

/// Parse one event line from untrusted wire input: syntactic validity
/// (via the hardened [`StreamEvent::parse`]) plus resource bounds — node
/// endpoints and grow counts share `OPEN`'s [`MAX_OPEN_NODES`] cap, so no
/// single valid-syntax line can make a shard worker allocate an absurd
/// graph (an `e 0 4294967295 0.5` would otherwise grow the node set to the
/// max id on the next tick). Used by the `EV` verb and `BATCH` body lines.
pub fn parse_wire_event(line: &str) -> Result<StreamEvent, &'static str> {
    let ev = StreamEvent::parse(line)
        .ok_or("bad event (want `e i j dw` | `n count` | `t`; dw finite, i != j)")?;
    match ev {
        StreamEvent::EdgeDelta { i, j, .. }
            if i as usize >= MAX_OPEN_NODES || j as usize >= MAX_OPEN_NODES =>
        {
            Err("node id exceeds maximum")
        }
        StreamEvent::GrowNodes { count } if count > MAX_OPEN_NODES => {
            Err("grow count exceeds maximum")
        }
        ev => Ok(ev),
    }
}

impl Request {
    /// Parse one request line. The error string is the `ERR` reason sent
    /// back to the client (always a single line).
    pub fn parse(line: &str) -> Result<Self, String> {
        if line.len() > MAX_LINE {
            return Err("line too long".to_string());
        }
        let mut it = line.split_whitespace();
        let verb = it.next().ok_or("empty line")?;
        match verb {
            "OPEN" => {
                let id = wire_id(it.next(), verb)?;
                let nodes = wire_usize(it.next(), verb, "n")?;
                no_more(it, verb)?;
                if nodes > MAX_OPEN_NODES {
                    return Err(format!("OPEN: n exceeds maximum {MAX_OPEN_NODES}"));
                }
                Ok(Request::Open { id, nodes })
            }
            "EV" => {
                let id = wire_id(it.next(), verb)?;
                let ev_line: Vec<&str> = it.collect();
                let ev = parse_wire_event(&ev_line.join(" "))
                    .map_err(|e| format!("EV: {e}"))?;
                Ok(Request::Event { id, ev })
            }
            "BATCH" => {
                let id = wire_id(it.next(), verb)?;
                let count = wire_usize(it.next(), verb, "k")?;
                no_more(it, verb)?;
                if count > MAX_BATCH {
                    return Err(format!("BATCH: k exceeds maximum {MAX_BATCH}"));
                }
                Ok(Request::Batch { id, count })
            }
            "QUERY" => {
                let id = wire_id(it.next(), verb)?;
                no_more(it, verb)?;
                Ok(Request::Query { id })
            }
            "STATS" => no_more(it, verb).map(|()| Request::Stats),
            "QUIT" => no_more(it, verb).map(|()| Request::Quit),
            "SHUTDOWN" => no_more(it, verb).map(|()| Request::Shutdown),
            other => Err(format!("unknown verb `{other}`")),
        }
    }

    /// Serialize to the wire line (no trailing newline). For
    /// [`Request::Batch`] this is only the header — the `count` event lines
    /// follow separately via [`StreamEvent::to_line`].
    pub fn to_line(&self) -> String {
        match self {
            Request::Open { id, nodes } => {
                format!("OPEN {} {nodes}", encode_session_id(id))
            }
            Request::Event { id, ev } => {
                format!("EV {} {}", encode_session_id(id), ev.to_line())
            }
            Request::Batch { id, count } => {
                format!("BATCH {} {count}", encode_session_id(id))
            }
            Request::Query { id } => format!("QUERY {}", encode_session_id(id)),
            Request::Stats => "STATS".to_string(),
            Request::Quit => "QUIT".to_string(),
            Request::Shutdown => "SHUTDOWN".to_string(),
        }
    }
}

/// One server reply line: `OK [key=value ...]` or `ERR <reason>`.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Success, with ordered `key=value` detail pairs (possibly none).
    Ok(Vec<(String, String)>),
    /// Failure; the reason is free text on the rest of the line.
    Err(String),
}

impl Response {
    pub fn ok() -> Self {
        Response::Ok(Vec::new())
    }

    /// Parse one reply line.
    pub fn parse(line: &str) -> Result<Self, String> {
        let line = line.trim_end_matches(['\r', '\n']);
        if let Some(rest) = line.strip_prefix("ERR") {
            return Ok(Response::Err(rest.trim().to_string()));
        }
        let rest = match line.strip_prefix("OK") {
            Some(r) => r,
            None => return Err(format!("malformed reply: {line:?}")),
        };
        let mut pairs = Vec::new();
        for tok in rest.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| format!("malformed OK pair: {tok:?}"))?;
            pairs.push((k.to_string(), v.to_string()));
        }
        Ok(Response::Ok(pairs))
    }

    /// Serialize to the wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Response::Ok(pairs) if pairs.is_empty() => "OK".to_string(),
            Response::Ok(pairs) => {
                let body: Vec<String> =
                    pairs.iter().map(|(k, v)| format!("{k}={v}")).collect();
                format!("OK {}", body.join(" "))
            }
            Response::Err(reason) => format!("ERR {reason}"),
        }
    }

    /// Value of `key` in an `OK` reply.
    pub fn get(&self, key: &str) -> Option<&str> {
        match self {
            Response::Ok(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
            }
            Response::Err(_) => None,
        }
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }
}

/// Encode a session snapshot as `QUERY`'s `OK` reply. Floats use Rust's
/// shortest-roundtrip `Display`, so the client re-parses them bit-for-bit.
pub fn snapshot_response(s: &SessionSnapshot) -> Response {
    let mut pairs = vec![
        ("windows".to_string(), s.windows.to_string()),
        ("events".to_string(), s.events.to_string()),
        ("htilde".to_string(), s.htilde.to_string()),
        ("nodes".to_string(), s.nodes.to_string()),
        ("edges".to_string(), s.edges.to_string()),
        ("anomalies".to_string(), s.anomalies.to_string()),
        ("pending".to_string(), s.pending_events.to_string()),
        ("anomalous".to_string(), (s.last_anomalous as u8).to_string()),
    ];
    if let Some(js) = s.last_jsdist {
        pairs.push(("jsdist".to_string(), js.to_string()));
    }
    Response::Ok(pairs)
}

/// Decode `QUERY`'s `OK` reply back into a snapshot (the id is supplied by
/// the caller — it does not travel in the reply).
pub fn snapshot_from_response(id: &str, r: &Response) -> Option<SessionSnapshot> {
    Some(SessionSnapshot {
        id: id.to_string(),
        windows: r.get_parsed("windows")?,
        events: r.get_parsed("events")?,
        last_jsdist: r.get_parsed::<f64>("jsdist"),
        last_anomalous: r.get_parsed::<u8>("anomalous")? != 0,
        htilde: r.get_parsed("htilde")?,
        nodes: r.get_parsed("nodes")?,
        edges: r.get_parsed("edges")?,
        anomalies: r.get_parsed("anomalies")?,
        pending_events: r.get_parsed("pending")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        for req in [
            Request::Open { id: "tenant/1 x".to_string(), nodes: 64 },
            Request::Event {
                id: "a".to_string(),
                ev: StreamEvent::EdgeDelta { i: 3, j: 7, dw: -1.25 },
            },
            Request::Event { id: "a".to_string(), ev: StreamEvent::Tick },
            Request::Batch { id: "b".to_string(), count: 12 },
            Request::Query { id: "a".to_string() },
            Request::Stats,
            Request::Quit,
            Request::Shutdown,
        ] {
            assert_eq!(Request::parse(&req.to_line()), Ok(req));
        }
    }

    #[test]
    fn request_rejects_malformed_lines() {
        for bad in [
            "",
            "NOPE",
            "OPEN",
            "OPEN a",
            "OPEN a x",
            "OPEN a 4 extra",
            "EV a",
            "EV a e 1 1 0.5",     // self-loop
            "EV a e 1 2 NaN",     // poisonous delta
            "EV a e 1 2 0.5 0.7", // fused events (trailing tokens)
            "EV a x 1 2",
            "BATCH a",
            "BATCH a -1",
            "QUERY",
            "STATS extra",
            "QUIT now",
            "OPEN bad%zz 4", // invalid id escape
        ] {
            assert!(Request::parse(bad).is_err(), "{bad:?} should not parse");
        }
        assert!(Request::parse(&format!("BATCH a {}", MAX_BATCH + 1)).is_err());
        assert!(Request::parse(&format!("OPEN a {}", MAX_OPEN_NODES + 1)).is_err());
        // resource bounds on event payloads (EV and BATCH bodies both go
        // through parse_wire_event)
        assert!(Request::parse("EV a e 0 4294967295 0.5").is_err());
        assert!(Request::parse(&format!("EV a n {}", MAX_OPEN_NODES + 1)).is_err());
        assert!(parse_wire_event("e 0 4294967295 0.5").is_err());
        assert!(parse_wire_event("e 0 1 0.5").is_ok());
        assert!(parse_wire_event(&format!("n {}", MAX_OPEN_NODES)).is_ok());
    }

    #[test]
    fn response_roundtrip() {
        for resp in [
            Response::ok(),
            Response::Ok(vec![
                ("windows".to_string(), "3".to_string()),
                ("jsdist".to_string(), "0.12345".to_string()),
            ]),
            Response::Err("unknown-session".to_string()),
        ] {
            assert_eq!(Response::parse(&resp.to_line()), Ok(resp));
        }
        assert!(Response::parse("WAT 1").is_err());
        assert!(Response::parse("OK novalue").is_err());
    }

    #[test]
    fn snapshot_roundtrips_floats_bit_for_bit() {
        let snap = crate::service::SessionSnapshot {
            id: "s/1".to_string(),
            windows: 7,
            events: 420,
            last_jsdist: Some(0.123456789012345678), // not representable; rounds
            last_anomalous: true,
            htilde: std::f64::consts::LN_2 * 3.7,
            nodes: 100,
            edges: 321,
            anomalies: 2,
            pending_events: 5,
        };
        let resp = snapshot_response(&snap);
        let line = resp.to_line();
        let back = snapshot_from_response("s/1", &Response::parse(&line).unwrap()).unwrap();
        assert_eq!(back, snap, "wire round-trip must be bit-for-bit");

        let no_window = crate::service::SessionSnapshot {
            last_jsdist: None,
            windows: 0,
            ..snap.clone()
        };
        let back =
            snapshot_from_response("s/1", &snapshot_response(&no_window)).unwrap();
        assert_eq!(back.last_jsdist, None);
    }
}
