//! Exactly-once retrying client: [`NetClient`] plus reconnect, capped
//! exponential backoff, and replay-from-last-acked.
//!
//! The reliability contract rides on three wire extensions
//! (`docs/PROTOCOL.md`, `docs/ROBUSTNESS.md`):
//!
//! * a reliable `OPEN` carries the client's known session *epoch* and the
//!   server answers with the authoritative epoch plus `acked`, the highest
//!   applied sequence number;
//! * every `EV` / `BATCH` carries a per-session sequence number, applied
//!   exactly once — the server discards `seq <= acked` as duplicates;
//! * a saturated shard answers `ERR retry-after <ms>` instead of parking the
//!   connection forever, and the client honors the hint.
//!
//! Together those make a retry loop safe: after any connection failure the
//! client reconnects, re-`OPEN`s with its stored epoch, learns `acked`, and
//! either skips the in-flight command (already applied — the ack was lost,
//! not the write) or resends it (never applied). No window is ever scored
//! twice and none is silently dropped, which the chaos suite checks
//! bit-for-bit against an unfaulted reference run.
//!
//! Every failure is classified and counted ([`ErrorCounts`]) so the load
//! driver can report *what* went wrong per kind, not just a total.

use super::backoff::{self, Backoff};
use super::client::NetClient;
use super::codec::Wire;
use super::command::{Command, Reply};
use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::time::Duration;

/// Reconnect / backoff knobs for [`RetryClient`] (`finger load --retry`).
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Attempts per logical operation before giving up.
    pub max_attempts: u32,
    /// First backoff delay in milliseconds; attempt `k` waits roughly
    /// `base * 2^k` with jitter.
    pub base_ms: u64,
    /// Upper bound on any single backoff delay.
    pub cap_ms: u64,
    /// Seed for the deterministic jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_attempts: 8, base_ms: 10, cap_ms: 1_000, seed: 0x5EED }
    }
}

/// Coarse failure classification for per-kind error accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrKind {
    /// TCP connect refused (server down or not yet listening).
    ConnectRefused,
    /// Reply read hit the configured deadline.
    ReadTimeout,
    /// Connection reset / broken pipe / EOF mid-request.
    Reset,
    /// Anything else transport-level.
    Other,
}

/// Classify a transport failure by walking the error chain for the
/// underlying [`std::io::Error`]; falls back to message matching for the
/// client's own synthesized timeout / EOF errors.
pub fn classify(err: &anyhow::Error) -> ErrKind {
    for cause in err.chain() {
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            use std::io::ErrorKind as K;
            return match io.kind() {
                K::ConnectionRefused => ErrKind::ConnectRefused,
                K::TimedOut | K::WouldBlock => ErrKind::ReadTimeout,
                K::ConnectionReset
                | K::ConnectionAborted
                | K::BrokenPipe
                | K::UnexpectedEof => ErrKind::Reset,
                _ => ErrKind::Other,
            };
        }
    }
    let msg = err.to_string();
    if msg.contains("timed out") {
        ErrKind::ReadTimeout
    } else if msg.contains("closed the connection") {
        ErrKind::Reset
    } else {
        ErrKind::Other
    }
}

/// The reason string of a server `ERR` reply, if this error is one (the
/// blocking client surfaces them as `server: <reason>`).
fn server_reason(err: &anyhow::Error) -> Option<String> {
    // Only the root context carries the `server:` prefix; io errors never do.
    err.to_string().strip_prefix("server: ").map(str::to_string)
}

/// Per-kind failure counts accumulated by a [`RetryClient`] (and merged
/// across load-driver workers into the `TrafficReport`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ErrorCounts {
    /// TCP connects refused.
    pub connect_refused: usize,
    /// Reply reads that hit the deadline.
    pub read_timeout: usize,
    /// Connections reset / broken mid-request.
    pub reset: usize,
    /// Other transport-level failures.
    pub other_io: usize,
    /// Server `ERR` replies, keyed by the reason's first token (its "code":
    /// `retry-after`, `durability-failed`, `unknown-session`, ...).
    pub server_err: BTreeMap<String, usize>,
    /// Retry attempts performed (reconnects plus shed waits).
    pub retries: usize,
}

impl ErrorCounts {
    /// Total failures observed (retries not included — they are responses
    /// to failures, not failures themselves).
    pub fn total(&self) -> usize {
        self.connect_refused
            + self.read_timeout
            + self.reset
            + self.other_io
            + self.server_err.values().sum::<usize>()
    }

    /// Record one classified transport failure.
    pub fn record_io(&mut self, kind: ErrKind) {
        match kind {
            ErrKind::ConnectRefused => self.connect_refused += 1,
            ErrKind::ReadTimeout => self.read_timeout += 1,
            ErrKind::Reset => self.reset += 1,
            ErrKind::Other => self.other_io += 1,
        }
    }

    /// Record one server `ERR` by its code (first token of the reason).
    pub fn record_server(&mut self, reason: &str) {
        let code = reason.split_whitespace().next().unwrap_or("empty");
        *self.server_err.entry(code.to_string()).or_default() += 1;
    }

    /// Fold another worker's counts into this one.
    pub fn merge(&mut self, other: &ErrorCounts) {
        self.connect_refused += other.connect_refused;
        self.read_timeout += other.read_timeout;
        self.reset += other.reset;
        self.other_io += other.other_io;
        self.retries += other.retries;
        for (code, n) in &other.server_err {
            *self.server_err.entry(code.clone()).or_default() += n;
        }
    }
}

/// What the client knows about one reliable session.
#[derive(Debug, Clone)]
struct SessionState {
    nodes: usize,
    /// Server-assigned session epoch from the last reliable `OPEN`.
    epoch: u64,
    /// Next sequence number to assign (last applied + 1).
    next_seq: u64,
    /// Connection generation this session was last (re-)opened on.
    generation: u64,
}

/// Outcome of one delivery attempt, driving the retry loop.
enum Attempt {
    /// Applied (or proven already-applied); carries the accepted count.
    Done(usize),
    /// Transport failure — reconnect, re-open, resend-or-skip.
    Transient(anyhow::Error, ErrKind),
    /// Server shedding load — wait the hinted milliseconds, resend as-is.
    RetryAfter(u64),
    /// Non-retryable (server `ERR`, protocol violation).
    Fatal(anyhow::Error),
}

/// A reconnecting, exactly-once wrapper around [`NetClient`].
///
/// Sessions must be opened through [`RetryClient::open`]; events and batches
/// then carry sequence numbers automatically. Any transport failure triggers
/// reconnect + reliable re-`OPEN` + replay-from-last-acked, bounded by the
/// policy's `max_attempts` with deterministic jittered backoff.
pub struct RetryClient {
    addr: String,
    wire: Wire,
    timeout: Option<Duration>,
    policy: RetryPolicy,
    backoff: Backoff,
    client: Option<NetClient>,
    /// Bumped on every successful (re)connect; sessions lazily re-open when
    /// their recorded generation falls behind.
    generation: u64,
    sessions: HashMap<String, SessionState>,
    counts: ErrorCounts,
}

impl RetryClient {
    /// Connect (retrying per `policy`) to `addr` speaking `wire`.
    pub fn connect(
        addr: impl Into<String>,
        wire: Wire,
        timeout: Option<Duration>,
        policy: RetryPolicy,
    ) -> Result<Self> {
        let mut me = Self {
            addr: addr.into(),
            wire,
            timeout,
            policy,
            backoff: Backoff::new(policy.seed, policy.base_ms, policy.cap_ms),
            client: None,
            generation: 0,
            sessions: HashMap::new(),
            counts: ErrorCounts::default(),
        };
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            match me.ensure_conn() {
                Ok(()) => return Ok(me),
                Err(e) if attempts >= me.policy.max_attempts => {
                    return Err(e.context(format!("connect: gave up after {attempts} attempts")));
                }
                Err(e) => {
                    me.counts.record_io(classify(&e));
                    me.counts.retries += 1;
                    me.backoff.pause();
                }
            }
        }
    }

    /// The wire this client speaks.
    pub fn wire(&self) -> Wire {
        self.wire
    }

    /// Failure counts accumulated so far.
    pub fn counts(&self) -> &ErrorCounts {
        &self.counts
    }

    /// Consume the client, yielding its failure counts.
    pub fn into_counts(self) -> ErrorCounts {
        self.counts
    }

    fn ensure_conn(&mut self) -> Result<()> {
        if self.client.is_some() {
            return Ok(());
        }
        let c = NetClient::connect_with(&self.addr, self.wire, self.timeout)?;
        self.client = Some(c);
        self.generation += 1;
        Ok(())
    }

    fn drop_conn(&mut self) {
        self.client = None;
    }

    /// Reliable open: fresh session, epoch assigned by the server.
    pub fn open(&mut self, id: &str, nodes: usize) -> Result<()> {
        self.sessions.remove(id);
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            let r = self.try_open(id, nodes, 0);
            match r {
                Ok((epoch, acked)) => {
                    self.backoff.reset();
                    self.sessions.insert(
                        id.to_string(),
                        SessionState {
                            nodes,
                            epoch,
                            next_seq: acked + 1,
                            generation: self.generation,
                        },
                    );
                    return Ok(());
                }
                Err(e) => {
                    if let Some(reason) = server_reason(&e) {
                        self.counts.record_server(&reason);
                        return Err(e);
                    }
                    let kind = classify(&e);
                    self.counts.record_io(kind);
                    if attempts >= self.policy.max_attempts {
                        return Err(e.context(format!(
                            "open {id:?}: gave up after {attempts} attempts"
                        )));
                    }
                    self.counts.retries += 1;
                    self.drop_conn();
                    self.backoff.pause();
                }
            }
        }
    }

    fn try_open(&mut self, id: &str, nodes: usize, epoch: u64) -> Result<(u64, u64)> {
        self.ensure_conn()?;
        let Some(c) = self.client.as_mut() else { bail!("not connected") };
        c.open_reliable(id, nodes, epoch)
    }

    /// Re-open a known session after a reconnect, resyncing `next_seq` from
    /// the server's `acked`. No-op when the session is current.
    fn ensure_open(&mut self, id: &str) -> Result<()> {
        let generation = self.generation;
        let (nodes, epoch) = match self.sessions.get(id) {
            Some(st) if st.generation == generation => return Ok(()),
            Some(st) => (st.nodes, st.epoch),
            None => bail!("session {id:?} was never opened through this client"),
        };
        let Some(c) = self.client.as_mut() else { bail!("not connected") };
        let (new_epoch, acked) = c.open_reliable(id, nodes, epoch)?;
        if let Some(st) = self.sessions.get_mut(id) {
            st.generation = generation;
            if new_epoch == st.epoch {
                // Resumed: the server still holds our reliable state.
                st.next_seq = st.next_seq.max(acked + 1);
            } else {
                // The server lost the reliable map (restart): it opened a
                // fresh session under a new epoch. Earlier windows survive
                // only via the server's own WAL; sequencing restarts.
                st.epoch = new_epoch;
                st.next_seq = acked + 1;
            }
        }
        Ok(())
    }

    /// Submit one event exactly once.
    pub fn send_event(&mut self, id: &str, ev: &StreamEvent) -> Result<()> {
        self.deliver(id, std::slice::from_ref(ev), true).map(|_| ())
    }

    /// Submit a whole batch exactly once; returns the accepted event count.
    pub fn send_batch(&mut self, id: &str, events: &[StreamEvent]) -> Result<usize> {
        if events.is_empty() {
            return Ok(0);
        }
        self.deliver(id, events, false)
    }

    /// The exactly-once delivery loop shared by `send_event` / `send_batch`.
    fn deliver(&mut self, id: &str, events: &[StreamEvent], single: bool) -> Result<usize> {
        // The sequence number is fixed up front: every resend of this
        // logical command carries the same seq, which is what lets the
        // server (or the post-reconnect `acked`) deduplicate it.
        let seq = match self.sessions.get(id) {
            Some(st) => st.next_seq,
            None => bail!("session {id:?} was never opened through this client"),
        };
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            match self.attempt(id, events, single, seq) {
                Attempt::Done(n) => {
                    self.backoff.reset();
                    if let Some(st) = self.sessions.get_mut(id) {
                        st.next_seq = st.next_seq.max(seq + 1);
                    }
                    return Ok(n);
                }
                Attempt::Fatal(e) => return Err(e),
                Attempt::Transient(e, kind) => {
                    self.counts.record_io(kind);
                    if attempts >= self.policy.max_attempts {
                        return Err(e.context(format!(
                            "deliver seq {seq} to {id:?}: gave up after {attempts} attempts"
                        )));
                    }
                    self.counts.retries += 1;
                    self.drop_conn();
                    self.backoff.pause();
                }
                Attempt::RetryAfter(ms) => {
                    self.counts.record_server("retry-after");
                    if attempts >= self.policy.max_attempts {
                        bail!(
                            "server shedding {id:?} (retry-after {ms}ms): \
                             gave up after {attempts} attempts"
                        );
                    }
                    self.counts.retries += 1;
                    backoff::sleep_ms(ms);
                }
            }
        }
    }

    fn attempt(&mut self, id: &str, events: &[StreamEvent], single: bool, seq: u64) -> Attempt {
        if let Err(e) = self.ensure_conn() {
            let k = classify(&e);
            return Attempt::Transient(e, k);
        }
        if let Err(e) = self.ensure_open(id) {
            if let Some(reason) = server_reason(&e) {
                self.counts.record_server(&reason);
                return Attempt::Fatal(e);
            }
            let k = classify(&e);
            return Attempt::Transient(e, k);
        }
        // The re-open may have proven this seq already applied (ack lost in
        // the failure, not the write) — skip the resend entirely.
        if let Some(st) = self.sessions.get(id) {
            if st.next_seq > seq {
                return Attempt::Done(events.len());
            }
        }
        let Some(c) = self.client.as_mut() else {
            return Attempt::Fatal(anyhow::anyhow!("not connected"));
        };
        let sent = if single {
            match events.first() {
                Some(ev) => c.roundtrip(&Command::Event {
                    id: id.to_string(),
                    ev: ev.clone(),
                    seq: Some(seq),
                }),
                None => return Attempt::Done(0),
            }
        } else {
            c.send_batch_seq(id, events, seq)
        };
        match sent {
            Ok(Reply::Err(reason)) => {
                if let Some(ms) = reason.strip_prefix("retry-after ") {
                    let ms = ms
                        .split_whitespace()
                        .next()
                        .and_then(|t| t.parse().ok())
                        .unwrap_or(self.policy.base_ms);
                    return Attempt::RetryAfter(ms);
                }
                self.counts.record_server(&reason);
                Attempt::Fatal(anyhow::anyhow!("server: {reason}"))
            }
            Ok(reply) => {
                let dup = reply.get_parsed::<u8>("dup").unwrap_or(0) != 0;
                let accepted =
                    reply.get_parsed::<usize>("accepted").unwrap_or(events.len());
                Attempt::Done(if dup { events.len() } else { accepted })
            }
            Err(e) => {
                let k = classify(&e);
                Attempt::Transient(e, k)
            }
        }
    }

    /// Point-in-time stats of `id` (idempotent — plain reconnect retry).
    pub fn query(&mut self, id: &str) -> Result<Option<SessionSnapshot>> {
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            let r = (|| {
                self.ensure_conn()?;
                self.ensure_open(id)?;
                let Some(c) = self.client.as_mut() else { bail!("not connected") };
                c.query(id)
            })();
            match r {
                Ok(snap) => {
                    self.backoff.reset();
                    return Ok(snap);
                }
                Err(e) => {
                    if let Some(reason) = server_reason(&e) {
                        self.counts.record_server(&reason);
                        return Err(e);
                    }
                    let kind = classify(&e);
                    self.counts.record_io(kind);
                    if attempts >= self.policy.max_attempts {
                        return Err(e.context(format!(
                            "query {id:?}: gave up after {attempts} attempts"
                        )));
                    }
                    self.counts.retries += 1;
                    self.drop_conn();
                    self.backoff.pause();
                }
            }
        }
    }

    /// Retire `id`, returning its final snapshot. Safe to retry: a resend
    /// after a successful-but-unacked close reads `unknown-session`, which
    /// maps to `Ok(None)` exactly like the plain client.
    pub fn close(&mut self, id: &str) -> Result<Option<SessionSnapshot>> {
        let mut attempts = 0u32;
        let mut retried = false;
        loop {
            attempts += 1;
            let r = (|| {
                self.ensure_conn()?;
                self.ensure_open(id)?;
                let Some(c) = self.client.as_mut() else { bail!("not connected") };
                c.close(id)
            })();
            match r {
                Ok(snap) => {
                    self.backoff.reset();
                    self.sessions.remove(id);
                    if snap.is_none() && retried {
                        // The first close landed; only its ack was lost.
                        return Ok(None);
                    }
                    return Ok(snap);
                }
                Err(e) => {
                    if let Some(reason) = server_reason(&e) {
                        self.counts.record_server(&reason);
                        return Err(e);
                    }
                    let kind = classify(&e);
                    self.counts.record_io(kind);
                    if attempts >= self.policy.max_attempts {
                        return Err(e.context(format!(
                            "close {id:?}: gave up after {attempts} attempts"
                        )));
                    }
                    self.counts.retries += 1;
                    retried = true;
                    self.drop_conn();
                    self.backoff.pause();
                }
            }
        }
    }

    /// Close the connection politely; connection errors here are moot.
    pub fn quit(mut self) -> Result<()> {
        if let Some(c) = self.client.take() {
            c.quit().ok();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err(kind: std::io::ErrorKind) -> anyhow::Error {
        anyhow::Error::new(std::io::Error::new(kind, "boom")).context("send")
    }

    #[test]
    fn classify_maps_io_kinds_and_messages() {
        use std::io::ErrorKind as K;
        assert_eq!(classify(&io_err(K::ConnectionRefused)), ErrKind::ConnectRefused);
        assert_eq!(classify(&io_err(K::TimedOut)), ErrKind::ReadTimeout);
        assert_eq!(classify(&io_err(K::WouldBlock)), ErrKind::ReadTimeout);
        assert_eq!(classify(&io_err(K::ConnectionReset)), ErrKind::Reset);
        assert_eq!(classify(&io_err(K::BrokenPipe)), ErrKind::Reset);
        assert_eq!(classify(&io_err(K::UnexpectedEof)), ErrKind::Reset);
        assert_eq!(classify(&io_err(K::PermissionDenied)), ErrKind::Other);
        // the blocking client synthesizes these without an io::Error cause
        assert_eq!(
            classify(&anyhow::anyhow!("read timed out after 1s: server unresponsive")),
            ErrKind::ReadTimeout
        );
        assert_eq!(
            classify(&anyhow::anyhow!("server closed the connection")),
            ErrKind::Reset
        );
        assert_eq!(classify(&anyhow::anyhow!("huh")), ErrKind::Other);
    }

    #[test]
    fn server_reasons_are_detected_and_coded() {
        assert_eq!(
            server_reason(&anyhow::anyhow!("server: durability-failed wal latched")),
            Some("durability-failed wal latched".to_string())
        );
        assert_eq!(server_reason(&io_err(std::io::ErrorKind::TimedOut)), None);

        let mut c = ErrorCounts::default();
        c.record_server("durability-failed wal latched");
        c.record_server("durability-failed again");
        c.record_server("unknown-session");
        assert_eq!(c.server_err.get("durability-failed"), Some(&2));
        assert_eq!(c.server_err.get("unknown-session"), Some(&1));
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn error_counts_merge_and_total() {
        let mut a = ErrorCounts::default();
        a.record_io(ErrKind::ConnectRefused);
        a.record_io(ErrKind::Reset);
        a.retries = 2;
        let mut b = ErrorCounts::default();
        b.record_io(ErrKind::Reset);
        b.record_io(ErrKind::ReadTimeout);
        b.record_server("retry-after 50");
        b.retries = 1;
        a.merge(&b);
        assert_eq!(a.connect_refused, 1);
        assert_eq!(a.reset, 2);
        assert_eq!(a.read_timeout, 1);
        assert_eq!(a.retries, 3);
        assert_eq!(a.server_err.get("retry-after"), Some(&1));
        assert_eq!(a.total(), 5);
    }

    #[test]
    fn default_policy_is_sane() {
        let p = RetryPolicy::default();
        assert!(p.max_attempts >= 2);
        assert!(p.base_ms > 0 && p.cap_ms >= p.base_ms);
    }
}
