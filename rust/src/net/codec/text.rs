//! [`TextCodec`] — the original newline-delimited line protocol (wire v1),
//! reimplemented over the typed [`Command`] / [`Reply`] core with a
//! byte-for-byte identical wire format (spec: `docs/PROTOCOL.md`).
//!
//! Every request and every reply is exactly one `\n`-terminated UTF-8 line;
//! a `BATCH` header is followed by its `k` raw event lines. Event payloads
//! reuse the [`StreamEvent`] text format (`e i j dw` | `n count` | `t`), so
//! a delta-stream file can be replayed over the wire verbatim. Session ids
//! travel in their [`encode_session_id`] form — the encoding is injective
//! and produces no whitespace, so ids containing spaces or arbitrary bytes
//! survive tokenization exactly.
//!
//! Parsing is strict: unknown verbs, arity mismatches, malformed ids and
//! semantically poisonous events (non-finite `dw`, self-loops — rejected by
//! the hardened [`StreamEvent::parse`]) all yield
//! [`CommandRead::Malformed`] — one `ERR <reason>` line and nothing else —
//! so one bad line never desynchronizes the connection.

use super::super::command::{
    metrics_to_kv, parse_wire_event, snapshot_to_kv, Command, Reply, MAX_BATCH, MAX_LINE,
    MAX_OPEN_NODES,
};
use super::{read_via_decode, Codec, CommandRead, Decode, ReadBuf, Wire};
use crate::service::{decode_session_id, encode_session_id};
use crate::stream::StreamEvent;
use std::io::{BufRead, ErrorKind, Write};

/// The line-protocol codec.
///
/// Carries the incremental-decode state a readiness-driven server needs:
/// a read buffer for the blocking [`Codec::read_command`] shim, the capped
/// prefix of an oversized line being drained, and an in-progress `BATCH`
/// whose body lines are still arriving.
#[derive(Debug, Default)]
pub struct TextCodec {
    line: String,
    rbuf: ReadBuf,
    discard: Option<String>,
    batch: Option<TextBatch>,
}

/// An in-progress `BATCH`: the header has been consumed and `got` of the
/// `want` body lines have arrived so far.
#[derive(Debug)]
struct TextBatch {
    id: String,
    want: usize,
    got: usize,
    seq: Option<u64>,
    events: Vec<StreamEvent>,
    bad: Option<(usize, &'static str)>,
}

impl TextCodec {
    pub fn new() -> Self {
        Self::default()
    }

    /// Serialize one command to its wire line(s), trailing newline included
    /// (a `BATCH` emits its header plus `k` body lines). Exposed for tests
    /// that want to speak raw bytes.
    pub fn command_lines(cmd: &Command) -> String {
        let mut out = match cmd {
            // The reliability extensions ride as *trailing marker tokens*
            // (`epoch=E`, `seq=N`): a `None` emits the v1 line byte-for-byte,
            // so recorded fixtures and `nc`-style clients are untouched.
            Command::Open { id, nodes, epoch } => {
                let mut s = format!("OPEN {} {nodes}", encode_session_id(id));
                if let Some(e) = epoch {
                    s.push_str(&format!(" epoch={e}"));
                }
                s
            }
            Command::Event { id, ev, seq } => {
                let mut s = format!("EV {} {}", encode_session_id(id), ev.to_line());
                if let Some(n) = seq {
                    s.push_str(&format!(" seq={n}"));
                }
                s
            }
            Command::Batch { id, events, seq } => {
                return Self::batch_lines_seq(id, events, *seq)
            }
            Command::Query { id } => format!("QUERY {}", encode_session_id(id)),
            Command::Close { id } => format!("CLOSE {}", encode_session_id(id)),
            Command::Stats => "STATS".to_string(),
            Command::Metrics => "METRICS".to_string(),
            Command::Epoch => "EPOCH".to_string(),
            Command::Quit => "QUIT".to_string(),
            Command::Shutdown => "SHUTDOWN".to_string(),
            Command::Fault { name, spec } => format!("FAULT {name} {spec}"),
        };
        out.push('\n');
        out
    }

    /// The `BATCH` header plus body lines for a borrowed event slice.
    fn batch_lines_seq(id: &str, events: &[StreamEvent], seq: Option<u64>) -> String {
        let mut s = format!("BATCH {} {}", encode_session_id(id), events.len());
        if let Some(n) = seq {
            s.push_str(&format!(" seq={n}"));
        }
        for ev in events {
            s.push('\n');
            s.push_str(&ev.to_line());
        }
        s.push('\n');
        s
    }

    /// Serialize one reply to its wire line (no trailing newline). Exposed
    /// for tests comparing exact bytes.
    pub fn reply_line(reply: &Reply) -> String {
        let kv_line = |pairs: &[(String, String)]| {
            if pairs.is_empty() {
                "OK".to_string()
            } else {
                let body: Vec<String> =
                    pairs.iter().map(|(k, v)| format!("{k}={v}")).collect();
                format!("OK {}", body.join(" "))
            }
        };
        match reply {
            Reply::Ok => "OK".to_string(),
            Reply::OkKv(pairs) => kv_line(pairs),
            Reply::Snapshot(s) => kv_line(&snapshot_to_kv(s)),
            Reply::Metrics(r) => kv_line(&metrics_to_kv(r)),
            Reply::Err(reason) => format!("ERR {reason}"),
        }
    }

    /// Parse one reply line. The text wire cannot distinguish a snapshot
    /// from any other kv reply, so snapshots come back as [`Reply::OkKv`]
    /// (callers use [`Reply::into_snapshot`]).
    pub fn parse_reply_line(line: &str) -> Result<Reply, String> {
        let line = line.trim_end_matches(['\r', '\n']);
        if let Some(rest) = line.strip_prefix("ERR") {
            return Ok(Reply::Err(rest.trim().to_string()));
        }
        let rest = match line.strip_prefix("OK") {
            Some(r) => r,
            None => return Err(format!("malformed reply: {line:?}")),
        };
        let mut pairs = Vec::new();
        for tok in rest.split_whitespace() {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| format!("malformed OK pair: {tok:?}"))?;
            pairs.push((k.to_string(), v.to_string()));
        }
        if pairs.is_empty() {
            Ok(Reply::Ok)
        } else {
            Ok(Reply::OkKv(pairs))
        }
    }

    /// Parse one request line into a command or header. `Err` carries the
    /// `ERR` reason sent back to the client (always a single line).
    fn parse_request_line(line: &str) -> Result<Parsed, String> {
        if line.len() > MAX_LINE {
            return Err("line too long".to_string());
        }
        let mut it = line.split_whitespace();
        let verb = it.next().ok_or("empty line")?;
        match verb {
            "OPEN" => {
                let id = wire_id(it.next(), verb)?;
                let nodes = wire_usize(it.next(), verb, "n")?;
                let epoch = opt_marker(&mut it, "epoch", verb)?;
                no_more(it, verb)?;
                if nodes > MAX_OPEN_NODES {
                    return Err(format!("OPEN: n exceeds maximum {MAX_OPEN_NODES}"));
                }
                Ok(Parsed::Cmd(Command::Open { id, nodes, epoch }))
            }
            "EV" => {
                let id = wire_id(it.next(), verb)?;
                // the event grammar is variable-arity, so the optional seq
                // rides as an explicit trailing `seq=N` marker token (event
                // tokens never contain `=`)
                let mut ev_line: Vec<&str> = it.collect();
                let seq = match ev_line.last().and_then(|t| t.strip_prefix("seq=")) {
                    Some(v) => {
                        let n =
                            v.parse().map_err(|_| "EV: invalid seq".to_string())?;
                        ev_line.pop();
                        Some(n)
                    }
                    None => None,
                };
                let ev = parse_wire_event(&ev_line.join(" "))
                    .map_err(|e| format!("EV: {e}"))?;
                Ok(Parsed::Cmd(Command::Event { id, ev, seq }))
            }
            "BATCH" => {
                let id = wire_id(it.next(), verb)?;
                let count = wire_usize(it.next(), verb, "k")?;
                let seq = opt_marker(&mut it, "seq", verb)?;
                no_more(it, verb)?;
                if count > MAX_BATCH {
                    return Err(format!("BATCH: k exceeds maximum {MAX_BATCH}"));
                }
                Ok(Parsed::BatchHeader { id, count, seq })
            }
            "FAULT" => {
                let name = it
                    .next()
                    .ok_or_else(|| format!("{verb}: missing <name>"))?
                    .to_string();
                let spec = it
                    .next()
                    .ok_or_else(|| format!("{verb}: missing <spec>"))?
                    .to_string();
                no_more(it, verb)?;
                Ok(Parsed::Cmd(Command::Fault { name, spec }))
            }
            "QUERY" => {
                let id = wire_id(it.next(), verb)?;
                no_more(it, verb)?;
                Ok(Parsed::Cmd(Command::Query { id }))
            }
            "CLOSE" => {
                let id = wire_id(it.next(), verb)?;
                no_more(it, verb)?;
                Ok(Parsed::Cmd(Command::Close { id }))
            }
            "STATS" => no_more(it, verb).map(|()| Parsed::Cmd(Command::Stats)),
            "METRICS" => no_more(it, verb).map(|()| Parsed::Cmd(Command::Metrics)),
            "EPOCH" => no_more(it, verb).map(|()| Parsed::Cmd(Command::Epoch)),
            "QUIT" => no_more(it, verb).map(|()| Parsed::Cmd(Command::Quit)),
            "SHUTDOWN" => no_more(it, verb).map(|()| Parsed::Cmd(Command::Shutdown)),
            other => Err(format!("unknown verb `{other}`")),
        }
    }
}

/// A parsed request line: either a complete command or a `BATCH` header
/// whose body lines are still on the wire.
enum Parsed {
    Cmd(Command),
    BatchHeader { id: String, count: usize, seq: Option<u64> },
}

fn wire_id(token: Option<&str>, verb: &str) -> Result<String, String> {
    let tok = token.ok_or_else(|| format!("{verb}: missing <id>"))?;
    decode_session_id(tok).ok_or_else(|| format!("{verb}: malformed <id> encoding"))
}

fn wire_usize(token: Option<&str>, verb: &str, what: &str) -> Result<usize, String> {
    token
        .ok_or_else(|| format!("{verb}: missing <{what}>"))?
        .parse()
        .map_err(|_| format!("{verb}: invalid <{what}>"))
}

fn no_more(mut it: std::str::SplitWhitespace<'_>, verb: &str) -> Result<(), String> {
    match it.next() {
        Some(_) => Err(format!("{verb}: unexpected trailing tokens")),
        None => Ok(()),
    }
}

/// Consume an optional trailing `<key>=<u64>` marker token. A token that is
/// not the marker is a trailing-token error (same as `no_more`), so v1
/// arity stays strict.
fn opt_marker(
    it: &mut std::str::SplitWhitespace<'_>,
    key: &str,
    verb: &str,
) -> Result<Option<u64>, String> {
    match it.next() {
        None => Ok(None),
        Some(tok) => match tok.strip_prefix(key).and_then(|r| r.strip_prefix('=')) {
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("{verb}: invalid <{key}>")),
            None => Err(format!("{verb}: unexpected trailing tokens")),
        },
    }
}

/// Outcome of one incremental line extraction.
enum NextLine {
    /// A complete line (trailing `\r`/`\n` stripped).
    Line(String),
    /// Clean end of stream at a line boundary.
    End,
    /// No complete line buffered yet.
    More,
}

fn trim_line_end(line: &mut String) {
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
}

/// Pull one `\n`-terminated line out of the buffer, if a complete one is
/// available. Bytes stay raw until a full line arrives, so a read landing
/// mid multi-byte UTF-8 character cannot discard already-received bytes —
/// invalid UTF-8 is surfaced lossily and rejected by the parser rather
/// than silently dropped.
///
/// The line is capped at just over [`MAX_LINE`] bytes: the prefix of an
/// oversized line is parked in `discard` (and later rejected by the
/// parser) while its remaining bytes are *discarded through the newline* —
/// the buffer never holds more than the cap plus one read chunk and the
/// tail is never misparsed as further requests, preserving
/// one-reply-per-request framing.
///
/// At `eof` an unterminated final line is surfaced as a line (the peer
/// sent bytes it expects to be parsed) and an empty buffer is `End`.
fn next_line(discard: &mut Option<String>, buf: &mut ReadBuf, eof: bool) -> NextLine {
    loop {
        if discard.is_some() {
            // oversized line: throw the tail away through the newline, then
            // surface the capped prefix so the parser rejects it
            let newline = buf.bytes().iter().position(|&b| b == b'\n');
            match newline {
                Some(i) => {
                    buf.consume(i + 1);
                    let mut line = discard.take().unwrap_or_default();
                    trim_line_end(&mut line);
                    return NextLine::Line(line);
                }
                None => {
                    let n = buf.len();
                    buf.consume(n);
                    if eof {
                        return NextLine::Line(discard.take().unwrap_or_default());
                    }
                    return NextLine::More;
                }
            }
        }
        let bytes = buf.bytes();
        match bytes.iter().position(|&b| b == b'\n') {
            Some(i) => {
                let mut line = String::from_utf8_lossy(bytes.get(..i).unwrap_or(&[]))
                    .into_owned();
                buf.consume(i + 1);
                trim_line_end(&mut line);
                return NextLine::Line(line);
            }
            None if bytes.len() > MAX_LINE + 2 => {
                let cap = MAX_LINE + 2;
                let prefix =
                    String::from_utf8_lossy(bytes.get(..cap).unwrap_or(bytes)).into_owned();
                buf.consume(cap);
                *discard = Some(prefix);
            }
            None => {
                if !eof {
                    return NextLine::More;
                }
                if bytes.is_empty() {
                    return NextLine::End;
                }
                let mut line = String::from_utf8_lossy(bytes).into_owned();
                let n = buf.len();
                buf.consume(n);
                trim_line_end(&mut line);
                return NextLine::Line(line);
            }
        }
    }
}

impl Codec for TextCodec {
    fn wire(&self) -> Wire {
        Wire::Text
    }

    fn read_command(
        &mut self,
        r: &mut dyn BufRead,
        stop: &dyn Fn() -> bool,
    ) -> std::io::Result<CommandRead> {
        // blocking shim over the incremental decoder: identical semantics,
        // one framing implementation
        let mut rbuf = std::mem::take(&mut self.rbuf);
        let out = read_via_decode(&mut rbuf, r, stop, |buf, eof| self.decode(buf, eof));
        self.rbuf = rbuf;
        out
    }

    fn decode(&mut self, buf: &mut ReadBuf, eof: bool) -> std::io::Result<Decode> {
        loop {
            // an in-progress BATCH consumes exactly `want` body lines. All
            // of them are read even when one is malformed — the protocol
            // stays line-synchronized and only the batch is rejected.
            while let Some(b) = self.batch.as_mut() {
                if b.got == b.want {
                    break;
                }
                match next_line(&mut self.discard, buf, eof) {
                    NextLine::More => return Ok(Decode::Incomplete),
                    NextLine::End => {
                        // peer closed mid-batch: mirror the blocking path's
                        // clean EOF (nothing useful can be replied)
                        self.batch = None;
                        return Ok(Decode::Eof);
                    }
                    NextLine::Line(line) => {
                        b.got += 1;
                        match parse_wire_event(&line) {
                            Ok(ev) => b.events.push(ev),
                            Err(reason) => {
                                b.bad.get_or_insert((b.got, reason));
                            }
                        }
                    }
                }
            }
            if let Some(b) = self.batch.take() {
                return Ok(match b.bad {
                    Some((at, reason)) => {
                        Decode::Malformed(format!("batch line {at}: {reason}"))
                    }
                    None => Decode::Cmd(Command::Batch {
                        id: b.id,
                        events: b.events,
                        seq: b.seq,
                    }),
                });
            }
            match next_line(&mut self.discard, buf, eof) {
                NextLine::More => return Ok(Decode::Incomplete),
                NextLine::End => return Ok(Decode::Eof),
                NextLine::Line(line) => {
                    if line.trim().is_empty() {
                        continue; // blank lines are keep-alive noise, not errors
                    }
                    match TextCodec::parse_request_line(&line) {
                        Err(reason) => return Ok(Decode::Malformed(reason)),
                        Ok(Parsed::Cmd(cmd)) => return Ok(Decode::Cmd(cmd)),
                        Ok(Parsed::BatchHeader { id, count, seq }) => {
                            // Cap the prealloc: the header's count is
                            // attacker-controlled, and a bare
                            // `BATCH a 1048576` must not pin ~24 MB per
                            // idle connection.
                            self.batch = Some(TextBatch {
                                id,
                                want: count,
                                got: 0,
                                seq,
                                events: Vec::with_capacity(count.min(4096)),
                                bad: None,
                            });
                        }
                    }
                }
            }
        }
    }

    fn write_reply(&mut self, w: &mut dyn Write, reply: &Reply) -> std::io::Result<()> {
        let mut out = TextCodec::reply_line(reply);
        out.push('\n');
        w.write_all(out.as_bytes())
    }

    fn write_command(&mut self, w: &mut dyn Write, cmd: &Command) -> std::io::Result<()> {
        w.write_all(TextCodec::command_lines(cmd).as_bytes())
    }

    fn write_batch_seq(
        &mut self,
        w: &mut dyn Write,
        id: &str,
        events: &[StreamEvent],
        seq: Option<u64>,
    ) -> std::io::Result<()> {
        w.write_all(TextCodec::batch_lines_seq(id, events, seq).as_bytes())
    }

    fn read_reply(&mut self, r: &mut dyn BufRead) -> std::io::Result<Option<Reply>> {
        self.line.clear();
        let n = r.read_line(&mut self.line)?;
        if n == 0 {
            return Ok(None);
        }
        TextCodec::parse_reply_line(&self.line)
            .map(Some)
            .map_err(|e| std::io::Error::new(ErrorKind::InvalidData, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read_one(payload: &[u8]) -> CommandRead {
        TextCodec::new()
            .read_command(&mut Cursor::new(payload.to_vec()), &|| false)
            .unwrap()
    }

    #[test]
    fn command_roundtrip_through_the_wire_format() {
        for cmd in [
            Command::Open { id: "tenant/1 x".to_string(), nodes: 64, epoch: None },
            Command::Open { id: "r".to_string(), nodes: 8, epoch: Some(0) },
            Command::Open { id: "r".to_string(), nodes: 8, epoch: Some(42) },
            Command::Event {
                id: "a".to_string(),
                ev: StreamEvent::EdgeDelta { i: 3, j: 7, dw: -1.25 },
                seq: None,
            },
            Command::Event { id: "a".to_string(), ev: StreamEvent::Tick, seq: None },
            Command::Event {
                id: "a".to_string(),
                ev: StreamEvent::EdgeDelta { i: 3, j: 7, dw: -1.25 },
                seq: Some(9),
            },
            Command::Event {
                id: "a".to_string(),
                ev: StreamEvent::GrowNodes { count: 3 },
                seq: Some(1),
            },
            Command::Batch {
                id: "b".to_string(),
                events: vec![
                    StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.5 },
                    StreamEvent::GrowNodes { count: 2 },
                    StreamEvent::Tick,
                ],
                seq: None,
            },
            Command::Batch {
                id: "b".to_string(),
                events: vec![StreamEvent::Tick],
                seq: Some(17),
            },
            Command::Fault { name: "wal.fsync".to_string(), spec: "at=3".to_string() },
            Command::Query { id: "a".to_string() },
            Command::Close { id: "a b/c".to_string() },
            Command::Stats,
            Command::Metrics,
            Command::Epoch,
            Command::Quit,
            Command::Shutdown,
        ] {
            let bytes = TextCodec::command_lines(&cmd);
            assert_eq!(read_one(bytes.as_bytes()), CommandRead::Cmd(cmd), "{bytes:?}");
        }
    }

    #[test]
    fn wire_lines_are_byte_identical_to_the_v1_protocol() {
        // the pre-redesign `Request::to_line` outputs, verbatim
        assert_eq!(
            TextCodec::command_lines(&Command::Open {
                id: "a".into(),
                nodes: 4,
                epoch: None
            }),
            "OPEN a 4\n"
        );
        // finger-lint: allow(FL003): compares encoded text; the float args are literals
        assert_eq!(
            TextCodec::command_lines(&Command::Event {
                id: "tenant/1".into(),
                ev: StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.5 },
                seq: None,
            }),
            "EV tenant%2F1 e 0 1 1.5\n"
        );
        assert_eq!(
            TextCodec::command_lines(&Command::Batch {
                id: "b".into(),
                events: vec![StreamEvent::Tick],
                seq: None,
            }),
            "BATCH b 1\nt\n"
        );
        assert_eq!(TextCodec::reply_line(&Reply::Ok), "OK");
        assert_eq!(
            TextCodec::reply_line(&Reply::kv("accepted", 3)),
            "OK accepted=3"
        );
        assert_eq!(
            TextCodec::reply_line(&Reply::Err("unknown-session".into())),
            "ERR unknown-session"
        );
    }

    #[test]
    fn metrics_reply_is_one_kv_line_and_recoverable() {
        let report = crate::obs::MetricsReport {
            pairs: vec![("net_accepted".to_string(), 2), ("uptime_ms".to_string(), 77)],
            hists: vec![crate::obs::WireHist {
                name: "request_us".to_string(),
                count: 3,
                buckets: vec![(5, 1), (17, 2)],
            }],
        };
        let line = TextCodec::reply_line(&Reply::Metrics(report.clone()));
        // pinned wire bytes: the hist pair packs count|idx:cnt,... with no spaces
        assert_eq!(line, "OK net_accepted=2 uptime_ms=77 hist:request_us=3|5:1,17:2");
        let back = TextCodec::parse_reply_line(&line).unwrap();
        assert_eq!(back.into_metrics(), Some(report));
    }

    #[test]
    fn rejects_malformed_lines_without_desync() {
        for bad in [
            "NOPE\n",
            "OPEN\n",
            "OPEN a\n",
            "OPEN a x\n",
            "OPEN a 4 extra\n",
            "EV a\n",
            "EV a e 1 1 0.5\n",     // self-loop
            "EV a e 1 2 NaN\n",     // poisonous delta
            "EV a e 1 2 0.5 0.7\n", // fused events (trailing tokens)
            "EV a x 1 2\n",
            "BATCH a\n",
            "BATCH a -1\n",
            "QUERY\n",
            "CLOSE\n",
            "CLOSE bad%zz\n",
            "STATS extra\n",
            "METRICS extra\n",
            "EPOCH now\n",
            "QUIT now\n",
            "OPEN bad%zz 4\n", // invalid id escape
            "EV a e 0 4294967295 0.5\n",
            "OPEN a 4 epoch=x\n",   // marker value must parse
            "OPEN a 4 extra=1\n",   // wrong marker key is a trailing token
            "OPEN a 4 epoch=1 x\n", // nothing may follow the marker
            "BATCH a 1 seq=\n",
            "BATCH a 1 seq=1 x\n",
            "EV a e 0 1 0.5 seq=nope\n",
            "FAULT\n",
            "FAULT wal.fsync\n",
            "FAULT wal.fsync once extra\n",
        ] {
            match read_one(bad.as_bytes()) {
                CommandRead::Malformed(reason) => {
                    assert!(!reason.is_empty(), "{bad:?}")
                }
                other => panic!("{bad:?} should be Malformed, got {other:?}"),
            }
        }
        assert!(matches!(
            read_one(format!("BATCH a {}\n", MAX_BATCH + 1).as_bytes()),
            CommandRead::Malformed(_)
        ));
        assert!(matches!(
            read_one(format!("OPEN a {}\n", MAX_OPEN_NODES + 1).as_bytes()),
            CommandRead::Malformed(_)
        ));
    }

    #[test]
    fn reliability_markers_parse_on_all_three_verbs() {
        assert_eq!(
            read_one(b"OPEN a 4 epoch=7\n"),
            CommandRead::Cmd(Command::Open { id: "a".into(), nodes: 4, epoch: Some(7) })
        );
        assert_eq!(
            read_one(b"EV a t seq=3\n"),
            CommandRead::Cmd(Command::Event {
                id: "a".into(),
                ev: StreamEvent::Tick,
                seq: Some(3),
            })
        );
        assert_eq!(
            read_one(b"BATCH a 1 seq=5\nt\n"),
            CommandRead::Cmd(Command::Batch {
                id: "a".into(),
                events: vec![StreamEvent::Tick],
                seq: Some(5),
            })
        );
        assert_eq!(
            read_one(b"FAULT net.read every=2\n"),
            CommandRead::Cmd(Command::Fault {
                name: "net.read".into(),
                spec: "every=2".into(),
            })
        );
    }

    #[test]
    fn blank_lines_are_skipped_and_eof_is_clean() {
        let mut codec = TextCodec::new();
        let mut r = Cursor::new(b"\n\r\n  \nSTATS\n".to_vec());
        assert_eq!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Cmd(Command::Stats)
        );
        assert_eq!(codec.read_command(&mut r, &|| false).unwrap(), CommandRead::Eof);
    }

    #[test]
    fn batch_with_bad_body_line_is_consumed_atomically() {
        let mut codec = TextCodec::new();
        let payload = b"BATCH s 3\ne 0 1 1.0\ne 2 2 1.0\nt\nSTATS\n".to_vec();
        let mut r = Cursor::new(payload);
        match codec.read_command(&mut r, &|| false).unwrap() {
            CommandRead::Malformed(reason) => {
                assert!(reason.contains("batch line 2"), "{reason:?}")
            }
            other => panic!("bad batch should be Malformed, got {other:?}"),
        }
        // the stream is still line-synchronized: the next command parses
        assert_eq!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Cmd(Command::Stats)
        );
    }

    #[test]
    fn oversized_line_is_rejected_and_framing_survives() {
        let mut payload = vec![b'X'; MAX_LINE + 100];
        payload.push(b'\n');
        payload.extend_from_slice(b"QUIT\n");
        let mut codec = TextCodec::new();
        let mut r = Cursor::new(payload);
        assert!(matches!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Malformed(_)
        ));
        assert_eq!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Cmd(Command::Quit)
        );
    }

    #[test]
    fn reply_roundtrip() {
        for reply in [
            Reply::Ok,
            Reply::OkKv(vec![
                ("windows".to_string(), "3".to_string()),
                ("jsdist".to_string(), "0.12345".to_string()),
            ]),
            Reply::Err("unknown-session".to_string()),
        ] {
            let line = TextCodec::reply_line(&reply);
            assert_eq!(TextCodec::parse_reply_line(&line), Ok(reply));
        }
        assert!(TextCodec::parse_reply_line("WAT 1").is_err());
        assert!(TextCodec::parse_reply_line("OK novalue").is_err());
    }

    #[test]
    fn snapshot_reply_is_kv_encoded_and_recoverable() {
        let snap = crate::service::SessionSnapshot {
            id: String::new(),
            windows: 2,
            events: 9,
            last_jsdist: Some(std::f64::consts::FRAC_1_PI),
            last_anomalous: false,
            htilde: 1.75,
            nodes: 8,
            edges: 3,
            anomalies: 1,
            pending_events: 0,
        };
        let line = TextCodec::reply_line(&Reply::Snapshot(snap.clone()));
        let back = TextCodec::parse_reply_line(&line).unwrap();
        let got = back.into_snapshot("s").expect("snapshot decodes");
        assert_eq!(got.last_jsdist.unwrap().to_bits(), snap.last_jsdist.unwrap().to_bits());
        assert_eq!(got.htilde.to_bits(), snap.htilde.to_bits());
        assert_eq!(got.windows, snap.windows);
    }
}
