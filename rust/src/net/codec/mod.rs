//! Pluggable wire codecs over the typed [`Command`] / [`Reply`] core.
//!
//! A [`Codec`] owns *all* framing and encoding knowledge for one wire
//! format; the server and client are generic over it. Two codecs exist:
//!
//! * [`TextCodec`] — the original newline-delimited line protocol (v1),
//!   byte-for-byte identical to the pre-split wire format, so `nc`-style
//!   clients and recorded fixtures keep working unchanged.
//! * [`BinaryCodec`] — length-prefixed binary framing (v2): one opcode byte
//!   per frame, LEB128 varint lengths, and f64 event weights / scores as
//!   raw little-endian bits so scores stay bit-for-bit across the wire.
//!
//! Both wires share one listening port: a binary connection announces
//! itself with a two-byte preamble ([`BINARY_MAGIC`], [`BINARY_VERSION`])
//! whose magic byte can never begin a text request (text verbs are ASCII),
//! so the server [`negotiate`]s the codec on the first byte it sees without
//! consuming any text data.

use super::command::{Command, Reply};
use std::io::{BufRead, ErrorKind, Read, Write};

mod binary;
mod text;

pub use binary::BinaryCodec;
pub use text::TextCodec;

/// First byte of a binary connection. Any value ≥ 0x80 is safe (text
/// requests are ASCII); 0xB2 reads as "Binary, v2".
pub const BINARY_MAGIC: u8 = 0xB2;

/// Wire-format version sent after the magic byte. The text protocol is v1;
/// this binary framing is v2.
pub const BINARY_VERSION: u8 = 2;

/// The wire formats a connection can speak.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wire {
    Text,
    Binary,
}

impl Wire {
    pub fn name(self) -> &'static str {
        match self {
            Wire::Text => "text",
            Wire::Binary => "binary",
        }
    }

    /// Parse a `--wire` / `[net] wire` value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "text" => Some(Wire::Text),
            "binary" => Some(Wire::Binary),
            _ => None,
        }
    }

    /// A fresh codec instance for this wire.
    pub fn codec(self) -> Box<dyn Codec> {
        match self {
            Wire::Text => Box::new(TextCodec::new()),
            Wire::Binary => Box::new(BinaryCodec::new()),
        }
    }
}

impl std::fmt::Display for Wire {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which wires a server accepts (`[net] wire`, `finger serve --wire`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireMode {
    /// Negotiate per connection: both wires on one port.
    #[default]
    Auto,
    /// Only the named wire; the other is refused at negotiation.
    Only(Wire),
}

impl WireMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(WireMode::Auto),
            other => Wire::parse(other).map(WireMode::Only),
        }
    }

    pub fn allows(self, wire: Wire) -> bool {
        match self {
            WireMode::Auto => true,
            WireMode::Only(w) => w == wire,
        }
    }

    /// The client-side wire this mode implies (`Auto` defaults to text).
    pub fn client_wire(self) -> Wire {
        match self {
            WireMode::Auto => Wire::Text,
            WireMode::Only(w) => w,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireMode::Auto => "auto",
            WireMode::Only(w) => w.name(),
        }
    }
}

/// Outcome of reading one command frame on the server side.
#[derive(Debug, PartialEq)]
pub enum CommandRead {
    /// A well-formed command.
    Cmd(Command),
    /// A recoverable protocol error: the frame was fully consumed (framing
    /// is intact), the server should reply `Err(reason)` and keep going.
    Malformed(String),
    /// Clean end of stream between frames.
    Eof,
    /// The `stop` poll fired during a read (server shutting down).
    Interrupted,
}

/// Outcome of one incremental [`Codec::decode`] step over a [`ReadBuf`].
///
/// The decoder is restartable: it consumes bytes from the buffer only once
/// a complete frame (or a complete recoverable error) is available, so a
/// partially-arrived frame parks in the buffer and the next `decode` call
/// resumes exactly where the wire left off.
#[derive(Debug, PartialEq)]
pub enum Decode {
    /// A complete, well-formed command was consumed from the buffer.
    Cmd(Command),
    /// A recoverable protocol error; the offending frame was fully consumed
    /// and the caller should reply `ERR` and keep decoding.
    Malformed(String),
    /// Not enough buffered bytes for a complete frame. Only returned while
    /// `eof == false`; at EOF a decoder resolves every outcome.
    Incomplete,
    /// Clean end of stream at a frame boundary (only when `eof == true`).
    Eof,
}

/// A per-connection read buffer feeding incremental [`Codec::decode`] calls.
///
/// Bytes are appended at the tail ([`ReadBuf::fill_from`] /
/// [`ReadBuf::extend`]) and consumed from the head as the decoder completes
/// frames; the consumed prefix is reclaimed lazily so steady-state decoding
/// does not shift bytes on every frame. Decoders keep the unconsumed tail
/// bounded (oversized text lines drain through a capped scratch and binary
/// batches are consumed event-by-event), so the buffer never grows past one
/// frame head plus one read chunk.
#[derive(Debug, Default)]
pub struct ReadBuf {
    buf: Vec<u8>,
    start: usize,
}

impl ReadBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reuse a pooled allocation (the event loop recycles buffers across
    /// connections, the same scratch discipline as `entropy::Scratch`).
    pub fn from_vec(mut v: Vec<u8>) -> Self {
        v.clear();
        Self { buf: v, start: 0 }
    }

    /// Surrender the backing allocation (for pooling).
    pub fn into_vec(mut self) -> Vec<u8> {
        self.buf.clear();
        self.buf
    }

    /// The unconsumed bytes.
    pub fn bytes(&self) -> &[u8] {
        // finger-lint: allow(FL001): start <= buf.len() is a struct invariant
        &self.buf[self.start..]
    }

    pub fn len(&self) -> usize {
        self.buf.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.buf.len()
    }

    /// Mark `n` unconsumed bytes as consumed.
    pub fn consume(&mut self, n: usize) {
        self.start = (self.start + n).min(self.buf.len());
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
    }

    /// Append bytes (tests and in-memory feeds).
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Reclaim the consumed prefix so appended bytes reuse the allocation.
    fn compact(&mut self) {
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }

    /// One `read` call appending at most `max` bytes. Returns the byte
    /// count straight from the reader: `Ok(0)` is EOF, `WouldBlock` means
    /// the (nonblocking) source is drained for now.
    pub fn fill_from(&mut self, r: &mut dyn Read, max: usize) -> std::io::Result<usize> {
        self.compact();
        let old = self.buf.len();
        self.buf.resize(old + max, 0);
        // finger-lint: allow(FL001): old <= buf.len() after the resize above
        let res = r.read(&mut self.buf[old..]);
        let filled = match &res {
            Ok(n) => *n,
            Err(_) => 0,
        };
        self.buf.truncate(old + filled);
        res
    }
}

/// Read chunk size for [`read_via_decode`] and the event loop's per-call
/// socket reads.
pub(crate) const READ_CHUNK: usize = 8 * 1024;

/// Drive an incremental decoder against a blocking reader, reproducing the
/// classic `read_command` semantics: reads that time out poll `stop`, EOF
/// at a frame boundary is clean, EOF inside a frame surfaces whatever the
/// decoder resolves it to (text completes the final line; binary fails with
/// `UnexpectedEof`).
pub(crate) fn read_via_decode(
    rbuf: &mut ReadBuf,
    r: &mut dyn BufRead,
    stop: &dyn Fn() -> bool,
    mut decode: impl FnMut(&mut ReadBuf, bool) -> std::io::Result<Decode>,
) -> std::io::Result<CommandRead> {
    let mut eof = false;
    loop {
        match decode(rbuf, eof)? {
            Decode::Cmd(cmd) => return Ok(CommandRead::Cmd(cmd)),
            Decode::Malformed(reason) => return Ok(CommandRead::Malformed(reason)),
            Decode::Eof => return Ok(CommandRead::Eof),
            Decode::Incomplete => {}
        }
        if eof {
            // contract violation backstop: at EOF a decoder must resolve
            return Err(std::io::Error::new(
                ErrorKind::UnexpectedEof,
                "connection closed mid-frame",
            ));
        }
        match rbuf.fill_from(r, READ_CHUNK) {
            Ok(0) => eof = true,
            Ok(_) => {}
            Err(e) => match e.kind() {
                ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted => {
                    if stop() {
                        return Ok(CommandRead::Interrupted);
                    }
                }
                _ => return Err(e),
            },
        }
    }
}

/// One wire format, both directions. `read_command` / `write_reply` are the
/// server side; `write_command` / `read_reply` mirror them on the client.
///
/// `read_command` takes a `stop` predicate polled whenever a read times out;
/// the event-driven server decodes incrementally instead, so the blocking
/// entry point now serves round-trip tests and simple embedding callers
/// (in-memory readers never time out — pass `&|| false`).
pub trait Codec: Send {
    fn wire(&self) -> Wire;

    /// Read one complete command frame (for `BATCH`, header *and* body).
    fn read_command(
        &mut self,
        r: &mut dyn BufRead,
        stop: &dyn Fn() -> bool,
    ) -> std::io::Result<CommandRead>;

    /// Incrementally decode one command frame from buffered bytes.
    ///
    /// Consumes bytes from `buf` only when a complete frame (or complete
    /// recoverable error) is available; otherwise returns
    /// [`Decode::Incomplete`] and the partial frame parks in the buffer —
    /// the readiness-driven server never blocks a thread on a slow sender.
    /// In-progress multi-part frames (a `BATCH` header whose body is still
    /// arriving, an oversized text line being drained) keep their state in
    /// the codec, so calls must always use the same buffer.
    ///
    /// `eof` means the peer closed its write side: the decoder must resolve
    /// every outcome (no `Incomplete`) — text completes an unterminated
    /// final line, binary fails a truncated frame with `UnexpectedEof`, and
    /// an empty buffer at a frame boundary is a clean [`Decode::Eof`].
    fn decode(&mut self, buf: &mut ReadBuf, eof: bool) -> std::io::Result<Decode>;

    /// Write one reply frame.
    fn write_reply(&mut self, w: &mut dyn Write, reply: &Reply) -> std::io::Result<()>;

    /// Write one complete command frame (for `BATCH`, header *and* body, so
    /// a buffering caller gets the whole message in one syscall).
    fn write_command(&mut self, w: &mut dyn Write, cmd: &Command) -> std::io::Result<()>;

    /// Write a `Batch` command frame from a borrowed event slice — the load
    /// driver's hot path sends one window per batch, and building a
    /// [`Command::Batch`] just to encode it would clone every event.
    /// Semantically identical to `write_command` on the equivalent batch.
    fn write_batch(
        &mut self,
        w: &mut dyn Write,
        id: &str,
        events: &[crate::stream::StreamEvent],
    ) -> std::io::Result<()> {
        self.write_batch_seq(w, id, events, None)
    }

    /// Like [`Codec::write_batch`] with an optional exactly-once sequence
    /// number; `None` produces the v1 frame byte-for-byte.
    fn write_batch_seq(
        &mut self,
        w: &mut dyn Write,
        id: &str,
        events: &[crate::stream::StreamEvent],
        seq: Option<u64>,
    ) -> std::io::Result<()>;

    /// Read one reply frame; `None` on clean EOF. Timeouts (a client read
    /// deadline) surface as the underlying `io::Error`.
    fn read_reply(&mut self, r: &mut dyn BufRead) -> std::io::Result<Option<Reply>>;
}

/// Write the binary connection preamble (client side, immediately after
/// connect).
pub fn write_binary_preamble(w: &mut dyn Write) -> std::io::Result<()> {
    w.write_all(&[BINARY_MAGIC, BINARY_VERSION])
}

/// Outcome of buffer-fed codec negotiation ([`negotiate_buf`]).
pub enum NegotiatedBuf {
    Codec(Box<dyn Codec>),
    /// A lone magic byte is buffered; the version byte is still on the wire.
    Incomplete,
    /// The magic byte arrived with an unsupported version; the reason should
    /// be sent as a binary `Err` frame (the peer speaks binary) and the
    /// connection closed.
    BadPreamble(String),
}

/// The event-driven server's analogue of [`negotiate`]: decide the codec
/// from the first buffered byte(s). Text consumes nothing (the first byte
/// is the start of a request line); a binary preamble consumes exactly its
/// two bytes. EOF-before-first-byte is the caller's case (empty buffer at
/// peer close).
pub fn negotiate_buf(buf: &mut ReadBuf) -> NegotiatedBuf {
    let bytes = buf.bytes();
    let first = match bytes.first() {
        Some(&b) => b,
        None => return NegotiatedBuf::Incomplete,
    };
    if first != BINARY_MAGIC {
        return NegotiatedBuf::Codec(Box::new(TextCodec::new()));
    }
    let version = match bytes.get(1) {
        Some(&v) => v,
        None => return NegotiatedBuf::Incomplete,
    };
    buf.consume(2);
    if version != BINARY_VERSION {
        return NegotiatedBuf::BadPreamble(format!(
            "unsupported binary version {version} (want {BINARY_VERSION})"
        ));
    }
    NegotiatedBuf::Codec(Box::new(BinaryCodec::new()))
}

/// Outcome of server-side codec negotiation.
pub enum Negotiated {
    Codec(Box<dyn Codec>),
    /// Connection closed before the first byte.
    Eof,
    /// Shutdown observed while waiting for the first byte.
    Interrupted,
    /// The magic byte arrived with an unsupported version; the reason should
    /// be sent as a binary `Err` frame (the peer speaks binary) and the
    /// connection closed.
    BadPreamble(String),
}

/// Decide the connection's codec from its first byte without consuming any
/// text data: [`BINARY_MAGIC`] (plus a version byte) selects the binary
/// codec, anything else — necessarily the first byte of an ASCII text
/// request — selects the text codec.
pub fn negotiate(
    r: &mut dyn BufRead,
    stop: &dyn Fn() -> bool,
) -> std::io::Result<Negotiated> {
    let first = loop {
        match r.fill_buf() {
            Ok([]) => return Ok(Negotiated::Eof),
            // finger-lint: allow(FL001): fill_buf returned a non-empty slice
            Ok(buf) => break buf[0],
            Err(e) => match e.kind() {
                ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted => {
                    if stop() {
                        return Ok(Negotiated::Interrupted);
                    }
                }
                _ => return Err(e),
            },
        }
    };
    if first != BINARY_MAGIC {
        return Ok(Negotiated::Codec(Box::new(TextCodec::new())));
    }
    let mut preamble = [0u8; 2];
    match read_exact_polled(r, &mut preamble, stop)? {
        ReadExact::Done => {}
        ReadExact::Eof => return Ok(Negotiated::Eof),
        ReadExact::Interrupted => return Ok(Negotiated::Interrupted),
    }
    // finger-lint: allow(FL001): const index into a [u8; 2] preamble
    let version = preamble[1];
    if version != BINARY_VERSION {
        return Ok(Negotiated::BadPreamble(format!(
            "unsupported binary version {version} (want {BINARY_VERSION})"
        )));
    }
    Ok(Negotiated::Codec(Box::new(BinaryCodec::new())))
}

/// Outcome of a polled exact read.
pub(crate) enum ReadExact {
    Done,
    /// EOF with zero bytes consumed (clean end between frames). EOF *inside*
    /// a frame is an `UnexpectedEof` error instead — the peer died mid-frame.
    Eof,
    Interrupted,
}

/// `read_exact` that polls `stop` across read timeouts and distinguishes a
/// clean EOF at a frame boundary from a truncated frame. Server side: the
/// socket read timeout is a poll point, never a failure.
pub(crate) fn read_exact_polled(
    r: &mut dyn BufRead,
    buf: &mut [u8],
    stop: &dyn Fn() -> bool,
) -> std::io::Result<ReadExact> {
    let mut filled = 0;
    while filled < buf.len() {
        // finger-lint: allow(FL001): filled < buf.len() keeps the range in bounds
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(ReadExact::Eof);
                }
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) => match e.kind() {
                ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted => {
                    if stop() {
                        return Ok(ReadExact::Interrupted);
                    }
                }
                _ => return Err(e),
            },
        }
    }
    Ok(ReadExact::Done)
}

/// Client-side `read_exact`: a socket read timeout IS the reply deadline
/// (`[net] client_timeout_ms`), so `WouldBlock`/`TimedOut` propagate as
/// errors instead of being polled through — a hung server must surface,
/// not wedge the caller. Only genuine `Interrupted` (EINTR) is retried.
pub(crate) fn read_exact_deadline(
    r: &mut dyn BufRead,
    buf: &mut [u8],
) -> std::io::Result<ReadExact> {
    let mut filled = 0;
    while filled < buf.len() {
        // finger-lint: allow(FL001): filled < buf.len() keeps the range in bounds
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(ReadExact::Eof);
                }
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadExact::Done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn negotiation_picks_the_codec_from_the_first_byte() {
        let mut text = Cursor::new(b"QUERY a\n".to_vec());
        match negotiate(&mut text, &|| false).unwrap() {
            Negotiated::Codec(c) => assert_eq!(c.wire(), Wire::Text),
            _ => panic!("text stream must negotiate a codec"),
        }
        // nothing consumed: the text codec reads the request in full
        assert_eq!(text.position(), 0);

        let mut bin = Cursor::new(vec![BINARY_MAGIC, BINARY_VERSION, 0x07]);
        match negotiate(&mut bin, &|| false).unwrap() {
            Negotiated::Codec(c) => assert_eq!(c.wire(), Wire::Binary),
            _ => panic!("binary preamble must negotiate a codec"),
        }
        assert_eq!(bin.position(), 2, "only the preamble is consumed");

        let mut bad = Cursor::new(vec![BINARY_MAGIC, 9]);
        match negotiate(&mut bad, &|| false).unwrap() {
            Negotiated::BadPreamble(reason) => assert!(reason.contains("version 9")),
            _ => panic!("wrong version must be refused"),
        }

        match negotiate(&mut Cursor::new(Vec::new()), &|| false).unwrap() {
            Negotiated::Eof => {}
            _ => panic!("empty stream is a clean EOF"),
        }
    }

    #[test]
    fn readbuf_consume_and_fill_keep_the_tail_intact() {
        let mut b = ReadBuf::new();
        assert!(b.is_empty());
        b.extend(b"hello world");
        assert_eq!(b.bytes(), b"hello world");
        b.consume(6);
        assert_eq!(b.bytes(), b"world");
        assert_eq!(b.len(), 5);
        let n = b
            .fill_from(&mut Cursor::new(b"!!".to_vec()), 16)
            .expect("cursor read");
        assert_eq!(n, 2);
        assert_eq!(b.bytes(), b"world!!");
        b.consume(100); // over-consume clamps and resets
        assert!(b.is_empty());
        assert_eq!(b.fill_from(&mut Cursor::new(Vec::new()), 16).expect("eof"), 0);
    }

    #[test]
    fn negotiate_buf_matches_the_blocking_negotiation() {
        let mut text = ReadBuf::new();
        text.extend(b"QUERY a\n");
        match negotiate_buf(&mut text) {
            NegotiatedBuf::Codec(c) => assert_eq!(c.wire(), Wire::Text),
            _ => panic!("text bytes must negotiate a codec"),
        }
        assert_eq!(text.bytes(), b"QUERY a\n", "text negotiation consumes nothing");

        let mut bin = ReadBuf::new();
        bin.extend(&[BINARY_MAGIC]);
        assert!(matches!(negotiate_buf(&mut bin), NegotiatedBuf::Incomplete));
        bin.extend(&[BINARY_VERSION, 0x07]);
        match negotiate_buf(&mut bin) {
            NegotiatedBuf::Codec(c) => assert_eq!(c.wire(), Wire::Binary),
            _ => panic!("binary preamble must negotiate a codec"),
        }
        assert_eq!(bin.bytes(), &[0x07], "only the preamble is consumed");

        let mut bad = ReadBuf::new();
        bad.extend(&[BINARY_MAGIC, 9]);
        match negotiate_buf(&mut bad) {
            NegotiatedBuf::BadPreamble(reason) => assert!(reason.contains("version 9")),
            _ => panic!("wrong version must be refused"),
        }

        assert!(matches!(negotiate_buf(&mut ReadBuf::new()), NegotiatedBuf::Incomplete));
    }

    /// Feeding a frame stream one byte at a time through `decode` must
    /// yield exactly the same commands as the blocking `read_command` path
    /// — on both wires.
    #[test]
    fn byte_at_a_time_decode_matches_blocking_read() {
        let cmds = vec![
            Command::Open { id: "tenant/1".into(), nodes: 16, epoch: None },
            Command::Open { id: "tenant/2".into(), nodes: 16, epoch: Some(7) },
            Command::Batch {
                id: "b".into(),
                events: vec![
                    crate::stream::StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.5 },
                    crate::stream::StreamEvent::GrowNodes { count: 2 },
                    crate::stream::StreamEvent::Tick,
                ],
                seq: None,
            },
            Command::Batch {
                id: "b".into(),
                events: vec![crate::stream::StreamEvent::Tick],
                seq: Some(3),
            },
            Command::Event {
                id: "b".into(),
                ev: crate::stream::StreamEvent::EdgeDelta { i: 2, j: 3, dw: -0.25 },
                seq: Some(4),
            },
            Command::Fault { name: "wal.fsync".into(), spec: "every=3".into() },
            Command::Query { id: "tenant/1".into() },
            Command::Stats,
            Command::Metrics,
            Command::Quit,
        ];
        for wire in [Wire::Text, Wire::Binary] {
            let mut payload = Vec::new();
            let mut enc = wire.codec();
            for cmd in &cmds {
                enc.write_command(&mut payload, cmd).expect("encode");
            }
            let mut dec = wire.codec();
            let mut buf = ReadBuf::new();
            let mut got = Vec::new();
            for (i, byte) in payload.iter().enumerate() {
                buf.extend(&[*byte]);
                let eof = i + 1 == payload.len();
                loop {
                    match dec.decode(&mut buf, eof).expect("decode") {
                        Decode::Cmd(c) => got.push(c),
                        Decode::Incomplete | Decode::Eof => break,
                        Decode::Malformed(m) => panic!("unexpected malformed: {m}"),
                    }
                }
            }
            assert_eq!(got, cmds, "{wire} wire");
            assert!(buf.is_empty(), "{wire} wire leaves no residue");
        }
    }

    #[test]
    fn wire_and_mode_parsing() {
        assert_eq!(Wire::parse("text"), Some(Wire::Text));
        assert_eq!(Wire::parse("binary"), Some(Wire::Binary));
        assert_eq!(Wire::parse("morse"), None);
        assert_eq!(WireMode::parse("auto"), Some(WireMode::Auto));
        assert_eq!(WireMode::parse("binary"), Some(WireMode::Only(Wire::Binary)));
        assert!(WireMode::Auto.allows(Wire::Text));
        assert!(WireMode::Auto.allows(Wire::Binary));
        assert!(!WireMode::Only(Wire::Text).allows(Wire::Binary));
        assert_eq!(WireMode::Auto.client_wire(), Wire::Text);
        assert_eq!(WireMode::Only(Wire::Binary).client_wire(), Wire::Binary);
    }
}
