//! Pluggable wire codecs over the typed [`Command`] / [`Reply`] core.
//!
//! A [`Codec`] owns *all* framing and encoding knowledge for one wire
//! format; the server and client are generic over it. Two codecs exist:
//!
//! * [`TextCodec`] — the original newline-delimited line protocol (v1),
//!   byte-for-byte identical to the pre-split wire format, so `nc`-style
//!   clients and recorded fixtures keep working unchanged.
//! * [`BinaryCodec`] — length-prefixed binary framing (v2): one opcode byte
//!   per frame, LEB128 varint lengths, and f64 event weights / scores as
//!   raw little-endian bits so scores stay bit-for-bit across the wire.
//!
//! Both wires share one listening port: a binary connection announces
//! itself with a two-byte preamble ([`BINARY_MAGIC`], [`BINARY_VERSION`])
//! whose magic byte can never begin a text request (text verbs are ASCII),
//! so the server [`negotiate`]s the codec on the first byte it sees without
//! consuming any text data.

use super::command::{Command, Reply};
use std::io::{BufRead, ErrorKind, Read, Write};

mod binary;
mod text;

pub use binary::BinaryCodec;
pub use text::TextCodec;

/// First byte of a binary connection. Any value ≥ 0x80 is safe (text
/// requests are ASCII); 0xB2 reads as "Binary, v2".
pub const BINARY_MAGIC: u8 = 0xB2;

/// Wire-format version sent after the magic byte. The text protocol is v1;
/// this binary framing is v2.
pub const BINARY_VERSION: u8 = 2;

/// The wire formats a connection can speak.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wire {
    Text,
    Binary,
}

impl Wire {
    pub fn name(self) -> &'static str {
        match self {
            Wire::Text => "text",
            Wire::Binary => "binary",
        }
    }

    /// Parse a `--wire` / `[net] wire` value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "text" => Some(Wire::Text),
            "binary" => Some(Wire::Binary),
            _ => None,
        }
    }

    /// A fresh codec instance for this wire.
    pub fn codec(self) -> Box<dyn Codec> {
        match self {
            Wire::Text => Box::new(TextCodec::new()),
            Wire::Binary => Box::new(BinaryCodec::new()),
        }
    }
}

impl std::fmt::Display for Wire {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Which wires a server accepts (`[net] wire`, `finger serve --wire`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireMode {
    /// Negotiate per connection: both wires on one port.
    #[default]
    Auto,
    /// Only the named wire; the other is refused at negotiation.
    Only(Wire),
}

impl WireMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(WireMode::Auto),
            other => Wire::parse(other).map(WireMode::Only),
        }
    }

    pub fn allows(self, wire: Wire) -> bool {
        match self {
            WireMode::Auto => true,
            WireMode::Only(w) => w == wire,
        }
    }

    /// The client-side wire this mode implies (`Auto` defaults to text).
    pub fn client_wire(self) -> Wire {
        match self {
            WireMode::Auto => Wire::Text,
            WireMode::Only(w) => w,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireMode::Auto => "auto",
            WireMode::Only(w) => w.name(),
        }
    }
}

/// Outcome of reading one command frame on the server side.
#[derive(Debug, PartialEq)]
pub enum CommandRead {
    /// A well-formed command.
    Cmd(Command),
    /// A recoverable protocol error: the frame was fully consumed (framing
    /// is intact), the server should reply `Err(reason)` and keep going.
    Malformed(String),
    /// Clean end of stream between frames.
    Eof,
    /// The `stop` poll fired during a read (server shutting down).
    Interrupted,
}

/// One wire format, both directions. `read_command` / `write_reply` are the
/// server side; `write_command` / `read_reply` mirror them on the client.
///
/// `read_command` takes a `stop` predicate polled whenever a read times out
/// (the server sets a socket read timeout so a drained connection can't
/// outlive a shutdown request); in-memory readers never time out, so
/// round-trip tests can pass `&|| false`.
pub trait Codec: Send {
    fn wire(&self) -> Wire;

    /// Read one complete command frame (for `BATCH`, header *and* body).
    fn read_command(
        &mut self,
        r: &mut dyn BufRead,
        stop: &dyn Fn() -> bool,
    ) -> std::io::Result<CommandRead>;

    /// Write one reply frame.
    fn write_reply(&mut self, w: &mut dyn Write, reply: &Reply) -> std::io::Result<()>;

    /// Write one complete command frame (for `BATCH`, header *and* body, so
    /// a buffering caller gets the whole message in one syscall).
    fn write_command(&mut self, w: &mut dyn Write, cmd: &Command) -> std::io::Result<()>;

    /// Write a `Batch` command frame from a borrowed event slice — the load
    /// driver's hot path sends one window per batch, and building a
    /// [`Command::Batch`] just to encode it would clone every event.
    /// Semantically identical to `write_command` on the equivalent batch.
    fn write_batch(
        &mut self,
        w: &mut dyn Write,
        id: &str,
        events: &[crate::stream::StreamEvent],
    ) -> std::io::Result<()>;

    /// Read one reply frame; `None` on clean EOF. Timeouts (a client read
    /// deadline) surface as the underlying `io::Error`.
    fn read_reply(&mut self, r: &mut dyn BufRead) -> std::io::Result<Option<Reply>>;
}

/// Write the binary connection preamble (client side, immediately after
/// connect).
pub fn write_binary_preamble(w: &mut dyn Write) -> std::io::Result<()> {
    w.write_all(&[BINARY_MAGIC, BINARY_VERSION])
}

/// Outcome of server-side codec negotiation.
pub enum Negotiated {
    Codec(Box<dyn Codec>),
    /// Connection closed before the first byte.
    Eof,
    /// Shutdown observed while waiting for the first byte.
    Interrupted,
    /// The magic byte arrived with an unsupported version; the reason should
    /// be sent as a binary `Err` frame (the peer speaks binary) and the
    /// connection closed.
    BadPreamble(String),
}

/// Decide the connection's codec from its first byte without consuming any
/// text data: [`BINARY_MAGIC`] (plus a version byte) selects the binary
/// codec, anything else — necessarily the first byte of an ASCII text
/// request — selects the text codec.
pub fn negotiate(
    r: &mut dyn BufRead,
    stop: &dyn Fn() -> bool,
) -> std::io::Result<Negotiated> {
    let first = loop {
        match r.fill_buf() {
            Ok([]) => return Ok(Negotiated::Eof),
            // finger-lint: allow(FL001): fill_buf returned a non-empty slice
            Ok(buf) => break buf[0],
            Err(e) => match e.kind() {
                ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted => {
                    if stop() {
                        return Ok(Negotiated::Interrupted);
                    }
                }
                _ => return Err(e),
            },
        }
    };
    if first != BINARY_MAGIC {
        return Ok(Negotiated::Codec(Box::new(TextCodec::new())));
    }
    let mut preamble = [0u8; 2];
    match read_exact_polled(r, &mut preamble, stop)? {
        ReadExact::Done => {}
        ReadExact::Eof => return Ok(Negotiated::Eof),
        ReadExact::Interrupted => return Ok(Negotiated::Interrupted),
    }
    // finger-lint: allow(FL001): const index into a [u8; 2] preamble
    let version = preamble[1];
    if version != BINARY_VERSION {
        return Ok(Negotiated::BadPreamble(format!(
            "unsupported binary version {version} (want {BINARY_VERSION})"
        )));
    }
    Ok(Negotiated::Codec(Box::new(BinaryCodec::new())))
}

/// Outcome of a polled exact read.
pub(crate) enum ReadExact {
    Done,
    /// EOF with zero bytes consumed (clean end between frames). EOF *inside*
    /// a frame is an `UnexpectedEof` error instead — the peer died mid-frame.
    Eof,
    Interrupted,
}

/// `read_exact` that polls `stop` across read timeouts and distinguishes a
/// clean EOF at a frame boundary from a truncated frame. Server side: the
/// socket read timeout is a poll point, never a failure.
pub(crate) fn read_exact_polled(
    r: &mut dyn BufRead,
    buf: &mut [u8],
    stop: &dyn Fn() -> bool,
) -> std::io::Result<ReadExact> {
    let mut filled = 0;
    while filled < buf.len() {
        // finger-lint: allow(FL001): filled < buf.len() keeps the range in bounds
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(ReadExact::Eof);
                }
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) => match e.kind() {
                ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted => {
                    if stop() {
                        return Ok(ReadExact::Interrupted);
                    }
                }
                _ => return Err(e),
            },
        }
    }
    Ok(ReadExact::Done)
}

/// Client-side `read_exact`: a socket read timeout IS the reply deadline
/// (`[net] client_timeout_ms`), so `WouldBlock`/`TimedOut` propagate as
/// errors instead of being polled through — a hung server must surface,
/// not wedge the caller. Only genuine `Interrupted` (EINTR) is retried.
pub(crate) fn read_exact_deadline(
    r: &mut dyn BufRead,
    buf: &mut [u8],
) -> std::io::Result<ReadExact> {
    let mut filled = 0;
    while filled < buf.len() {
        // finger-lint: allow(FL001): filled < buf.len() keeps the range in bounds
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(ReadExact::Eof);
                }
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadExact::Done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn negotiation_picks_the_codec_from_the_first_byte() {
        let mut text = Cursor::new(b"QUERY a\n".to_vec());
        match negotiate(&mut text, &|| false).unwrap() {
            Negotiated::Codec(c) => assert_eq!(c.wire(), Wire::Text),
            _ => panic!("text stream must negotiate a codec"),
        }
        // nothing consumed: the text codec reads the request in full
        assert_eq!(text.position(), 0);

        let mut bin = Cursor::new(vec![BINARY_MAGIC, BINARY_VERSION, 0x07]);
        match negotiate(&mut bin, &|| false).unwrap() {
            Negotiated::Codec(c) => assert_eq!(c.wire(), Wire::Binary),
            _ => panic!("binary preamble must negotiate a codec"),
        }
        assert_eq!(bin.position(), 2, "only the preamble is consumed");

        let mut bad = Cursor::new(vec![BINARY_MAGIC, 9]);
        match negotiate(&mut bad, &|| false).unwrap() {
            Negotiated::BadPreamble(reason) => assert!(reason.contains("version 9")),
            _ => panic!("wrong version must be refused"),
        }

        match negotiate(&mut Cursor::new(Vec::new()), &|| false).unwrap() {
            Negotiated::Eof => {}
            _ => panic!("empty stream is a clean EOF"),
        }
    }

    #[test]
    fn wire_and_mode_parsing() {
        assert_eq!(Wire::parse("text"), Some(Wire::Text));
        assert_eq!(Wire::parse("binary"), Some(Wire::Binary));
        assert_eq!(Wire::parse("morse"), None);
        assert_eq!(WireMode::parse("auto"), Some(WireMode::Auto));
        assert_eq!(WireMode::parse("binary"), Some(WireMode::Only(Wire::Binary)));
        assert!(WireMode::Auto.allows(Wire::Text));
        assert!(WireMode::Auto.allows(Wire::Binary));
        assert!(!WireMode::Only(Wire::Text).allows(Wire::Binary));
        assert_eq!(WireMode::Auto.client_wire(), Wire::Text);
        assert_eq!(WireMode::Only(Wire::Binary).client_wire(), Wire::Binary);
    }
}
