//! [`BinaryCodec`] — length-prefixed binary framing (wire v2).
//!
//! A binary connection opens with the two-byte preamble
//! ([`BINARY_MAGIC`](super::BINARY_MAGIC),
//! [`BINARY_VERSION`](super::BINARY_VERSION)); after that, every command and
//! every reply is one self-delimiting frame:
//!
//! ```text
//! frame   := opcode:u8 payload
//! varint  := LEB128 unsigned (≤ 10 bytes, strict: overflow past u64 is
//!            rejected, never truncated)
//! string  := varint byte-length, then that many UTF-8 bytes (no escaping —
//!            ids travel raw, unlike the text wire's %XX form)
//! f64     := 8 bytes, little-endian IEEE-754 bits (scores and event
//!            weights stay bit-for-bit across the wire)
//! event   := 0x00 varint(i) varint(j) f64(dw)   — edge delta
//!          | 0x01 varint(count)                 — grow nodes
//!          | 0x02                               — tick
//! ```
//!
//! Command opcodes: `0x01 OPEN(id, varint nodes)`, `0x02 EV(id, event)`,
//! `0x03 BATCH(id, varint k, k×event)`, `0x04 QUERY(id)`, `0x05 CLOSE(id)`,
//! `0x06 STATS`, `0x07 QUIT`, `0x08 SHUTDOWN`, `0x09 METRICS`,
//! `0x0A EPOCH`, `0x0B FAULT(string name, string spec)`,
//! `0x0C OPEN_E(id, varint nodes, varint epoch)`,
//! `0x0D EV_S(id, event, varint seq)`,
//! `0x0E BATCH_S(id, varint k, varint seq, k×event)`.
//! Frames are not length-prefixed as a whole, so the exactly-once fields
//! (`docs/ROBUSTNESS.md`) ride on *new opcodes* rather than optional
//! trailers; the encoder picks the reliable opcode only when the field is
//! present, keeping every v1 frame byte-identical.
//! Reply opcodes: `0x80 OK`, `0x81 OKKV(varint n, n×(string,string))`,
//! `0x82 SNAPSHOT(varint windows, varint events, varint nodes, varint
//! edges, varint anomalies, varint pending, u8 anomalous, f64 htilde, u8
//! has_jsdist [, f64 jsdist])`, `0x83 ERR(string)`, `0x84 METRICS(varint n,
//! n×(string, varint), varint h, h×(string name, varint count, varint b,
//! b×(varint idx, varint cnt)))` — all metric values are unsigned integers,
//! so the binary and text renderings decode to identical reports.
//!
//! Server-side decoding is incremental ([`Codec::decode`]): frames are
//! parsed from a [`ReadBuf`] and consumed only once complete, so a
//! partially-arrived frame parks in the buffer instead of blocking a
//! thread. A `BATCH` body is consumed event-by-event as bytes arrive —
//! a maximum-size batch (≈29 MB) never has to fit in the buffer at once.
//!
//! Error handling splits by whether framing survives: *semantic* failures
//! on a fully-read frame (self-loop, non-finite `dw`, `OPEN`/grow counts
//! over [`MAX_OPEN_NODES`]) are recoverable `Malformed` reads — the server
//! replies `ERR` and the connection continues, mirroring the text wire.
//! *Syntactic* failures (unknown opcode or tag, oversized length prefix,
//! invalid UTF-8) mean the stream position can no longer be trusted, so
//! they are fatal `InvalidData` errors and the connection closes.

use super::super::command::{
    validate_wire_event, Command, Reply, MAX_BATCH, MAX_LINE, MAX_OPEN_NODES,
};
use super::{
    read_exact_deadline, read_via_decode, Codec, CommandRead, Decode, ReadBuf, ReadExact, Wire,
};
use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;
use std::io::{BufRead, Error, ErrorKind, Result, Write};

// Command opcodes.
const OP_OPEN: u8 = 0x01;
const OP_EV: u8 = 0x02;
const OP_BATCH: u8 = 0x03;
const OP_QUERY: u8 = 0x04;
const OP_CLOSE: u8 = 0x05;
const OP_STATS: u8 = 0x06;
const OP_QUIT: u8 = 0x07;
const OP_SHUTDOWN: u8 = 0x08;
const OP_METRICS: u8 = 0x09;
const OP_EPOCH: u8 = 0x0A;
const OP_FAULT: u8 = 0x0B;
const OP_OPEN_E: u8 = 0x0C;
const OP_EV_S: u8 = 0x0D;
const OP_BATCH_S: u8 = 0x0E;

// Reply opcodes.
const OP_OK: u8 = 0x80;
const OP_OKKV: u8 = 0x81;
const OP_SNAPSHOT: u8 = 0x82;
const OP_ERR: u8 = 0x83;
const OP_METRICS_REPLY: u8 = 0x84;

// Event tags.
const EV_EDGE: u8 = 0x00;
const EV_GROW: u8 = 0x01;
const EV_TICK: u8 = 0x02;

/// Upper bound on `OKKV` pair counts — far above any real reply, low enough
/// that a corrupt length prefix can't make a client allocate unboundedly.
const MAX_KV_PAIRS: usize = 1 << 12;

/// Upper bound on histogram counts in a `METRICS` reply (the registry ships
/// three; the bound only guards against corrupt length prefixes).
const MAX_METRIC_HISTS: usize = 64;

fn bad(msg: impl Into<String>) -> Error {
    Error::new(ErrorKind::InvalidData, msg.into())
}

/// The incremental-decode verdict for "need more bytes": mid-stream it is
/// [`Decode::Incomplete`]; at EOF a partial frame means the peer died
/// mid-frame.
fn more(eof: bool) -> Result<Decode> {
    if eof {
        Err(Error::new(ErrorKind::UnexpectedEof, "connection closed mid-frame"))
    } else {
        Ok(Decode::Incomplete)
    }
}

/// Early-return `more(eof)` when a slice-reader primitive ran out of bytes.
macro_rules! need {
    ($e:expr, $eof:expr) => {
        match $e {
            Some(v) => v,
            None => return more($eof),
        }
    };
}

/// The binary codec.
///
/// Carries the incremental-decode state a readiness-driven server needs: a
/// read buffer for the blocking [`Codec::read_command`] shim, a reusable
/// write scratch, and an in-progress `BATCH` whose body events are still
/// arriving.
#[derive(Debug, Default)]
pub struct BinaryCodec {
    buf: Vec<u8>,
    rbuf: ReadBuf,
    batch: Option<BinBatch>,
}

/// An in-progress `BATCH`: the header has been consumed and `got` of the
/// `want` body events have arrived so far.
#[derive(Debug)]
struct BinBatch {
    id: String,
    want: usize,
    got: usize,
    seq: Option<u64>,
    events: Vec<StreamEvent>,
    bad: Option<(usize, &'static str)>,
}

impl BinaryCodec {
    pub fn new() -> Self {
        Self::default()
    }

    /// Encode one command frame into `out` (exposed for tests and sizing).
    pub fn encode_command(out: &mut Vec<u8>, cmd: &Command) {
        match cmd {
            Command::Open { id, nodes, epoch: None } => {
                out.push(OP_OPEN);
                put_string(out, id);
                put_varint(out, *nodes as u64);
            }
            Command::Open { id, nodes, epoch: Some(e) } => {
                out.push(OP_OPEN_E);
                put_string(out, id);
                put_varint(out, *nodes as u64);
                put_varint(out, *e);
            }
            Command::Event { id, ev, seq: None } => {
                out.push(OP_EV);
                put_string(out, id);
                put_event(out, ev);
            }
            Command::Event { id, ev, seq: Some(n) } => {
                out.push(OP_EV_S);
                put_string(out, id);
                put_event(out, ev);
                put_varint(out, *n);
            }
            Command::Batch { id, events, seq } => {
                Self::encode_batch_seq(out, id, events, *seq)
            }
            Command::Query { id } => {
                out.push(OP_QUERY);
                put_string(out, id);
            }
            Command::Close { id } => {
                out.push(OP_CLOSE);
                put_string(out, id);
            }
            Command::Stats => out.push(OP_STATS),
            Command::Metrics => out.push(OP_METRICS),
            Command::Epoch => out.push(OP_EPOCH),
            Command::Quit => out.push(OP_QUIT),
            Command::Shutdown => out.push(OP_SHUTDOWN),
            Command::Fault { name, spec } => {
                out.push(OP_FAULT);
                put_string(out, name);
                put_string(out, spec);
            }
        }
    }

    /// Encode a `BATCH` / `BATCH_S` frame from a borrowed event slice.
    fn encode_batch_seq(out: &mut Vec<u8>, id: &str, events: &[StreamEvent], seq: Option<u64>) {
        match seq {
            None => out.push(OP_BATCH),
            Some(_) => out.push(OP_BATCH_S),
        }
        put_string(out, id);
        put_varint(out, events.len() as u64);
        if let Some(n) = seq {
            put_varint(out, n);
        }
        for ev in events {
            put_event(out, ev);
        }
    }

    /// Encode one reply frame into `out`.
    pub fn encode_reply(out: &mut Vec<u8>, reply: &Reply) {
        match reply {
            Reply::Ok => out.push(OP_OK),
            Reply::OkKv(pairs) => {
                out.push(OP_OKKV);
                put_varint(out, pairs.len() as u64);
                for (k, v) in pairs {
                    put_string(out, k);
                    put_string(out, v);
                }
            }
            Reply::Snapshot(s) => {
                out.push(OP_SNAPSHOT);
                put_varint(out, s.windows as u64);
                put_varint(out, s.events as u64);
                put_varint(out, s.nodes as u64);
                put_varint(out, s.edges as u64);
                put_varint(out, s.anomalies as u64);
                put_varint(out, s.pending_events as u64);
                out.push(s.last_anomalous as u8);
                out.extend_from_slice(&s.htilde.to_bits().to_le_bytes());
                match s.last_jsdist {
                    Some(js) => {
                        out.push(1);
                        out.extend_from_slice(&js.to_bits().to_le_bytes());
                    }
                    None => out.push(0),
                }
            }
            Reply::Metrics(r) => {
                out.push(OP_METRICS_REPLY);
                put_varint(out, r.pairs.len() as u64);
                for (k, v) in &r.pairs {
                    put_string(out, k);
                    put_varint(out, *v);
                }
                put_varint(out, r.hists.len() as u64);
                for h in &r.hists {
                    put_string(out, &h.name);
                    put_varint(out, h.count);
                    put_varint(out, h.buckets.len() as u64);
                    for (i, c) in &h.buckets {
                        put_varint(out, *i as u64);
                        put_varint(out, *c);
                    }
                }
            }
            Reply::Err(reason) => {
                out.push(OP_ERR);
                put_string(out, reason);
            }
        }
    }
}

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_event(out: &mut Vec<u8>, ev: &StreamEvent) {
    match *ev {
        StreamEvent::EdgeDelta { i, j, dw } => {
            out.push(EV_EDGE);
            put_varint(out, i as u64);
            put_varint(out, j as u64);
            out.extend_from_slice(&dw.to_bits().to_le_bytes());
        }
        StreamEvent::GrowNodes { count } => {
            out.push(EV_GROW);
            put_varint(out, count as u64);
        }
        StreamEvent::Tick => out.push(EV_TICK),
    }
}

/// A restartable reader over buffered frame bytes: every primitive returns
/// `Ok(None)` when the buffer runs out mid-value (the caller retries once
/// more bytes arrive, re-parsing from the frame start — nothing is consumed
/// until the whole frame parses) and a fatal error on syntactic garbage.
struct SliceReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> SliceReader<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn u8(&mut self) -> Option<u8> {
        let v = self.b.get(self.pos).copied();
        if v.is_some() {
            self.pos += 1;
        }
        v
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let s = self.b.get(self.pos..end)?;
        self.pos = end;
        Some(s)
    }

    fn varint(&mut self) -> Result<Option<u64>> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = match self.u8() {
                Some(b) => b,
                None => return Ok(None),
            };
            // the 10th byte lands at shift 63 and may only carry one bit;
            // anything more would silently truncate — reject, or a crafted
            // length prefix decodes small and the rest of its payload gets
            // misparsed as fresh frames
            if shift == 63 && byte & 0x7E != 0 {
                return Err(bad("varint overflows u64"));
            }
            v |= u64::from(byte & 0x7F) << shift;
            if byte & 0x80 == 0 {
                return Ok(Some(v));
            }
        }
        Err(bad("varint longer than 10 bytes"))
    }

    fn usize_bounded(&mut self, max: usize, what: &str) -> Result<Option<usize>> {
        match self.varint()? {
            Some(v) if v <= max as u64 => Ok(Some(v as usize)),
            Some(v) => Err(bad(format!("{what} {v} exceeds maximum {max}"))),
            None => Ok(None),
        }
    }

    fn f64(&mut self) -> Option<f64> {
        let bytes = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(bytes);
        Some(f64::from_bits(u64::from_le_bytes(b)))
    }

    fn string(&mut self) -> Result<Option<String>> {
        let len = match self.usize_bounded(MAX_LINE, "string length")? {
            Some(v) => v,
            None => return Ok(None),
        };
        let bytes = match self.take(len) {
            Some(b) => b,
            None => return Ok(None),
        };
        String::from_utf8(bytes.to_vec())
            .map(Some)
            .map_err(|_| bad("string is not valid UTF-8"))
    }

    /// Decode one event. Syntactic only — semantic validation
    /// ([`validate_wire_event`]) runs on the completed value so the whole
    /// frame is consumed either way.
    fn event(&mut self) -> Result<Option<StreamEvent>> {
        let tag = match self.u8() {
            Some(t) => t,
            None => return Ok(None),
        };
        let ev = match tag {
            EV_EDGE => {
                let i = match self.varint()? {
                    Some(v) if v <= u32::MAX as u64 => v as u32,
                    Some(v) => return Err(bad(format!("node id {v} exceeds u32"))),
                    None => return Ok(None),
                };
                let j = match self.varint()? {
                    Some(v) if v <= u32::MAX as u64 => v as u32,
                    Some(v) => return Err(bad(format!("node id {v} exceeds u32"))),
                    None => return Ok(None),
                };
                let dw = match self.f64() {
                    Some(v) => v,
                    None => return Ok(None),
                };
                StreamEvent::EdgeDelta { i, j, dw }
            }
            EV_GROW => match self.varint()? {
                Some(v) => match usize::try_from(v) {
                    Ok(count) => StreamEvent::GrowNodes { count },
                    Err(_) => return Err(bad(format!("grow count {v} overflows"))),
                },
                None => return Ok(None),
            },
            EV_TICK => StreamEvent::Tick,
            other => return Err(bad(format!("unknown event tag {other:#04x}"))),
        };
        Ok(Some(ev))
    }
}

/// A byte reader over one client-side reply frame. The socket read timeout
/// IS the reply deadline ([`read_exact_deadline`], `[net]
/// client_timeout_ms`): a hung server surfaces as an error, never a wedge.
/// EOF inside a frame is `UnexpectedEof`; EOF before the opcode is the
/// clean kind (handled by `read_reply`).
struct FrameReader<'a> {
    r: &'a mut dyn BufRead,
}

impl FrameReader<'_> {
    fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        match read_exact_deadline(self.r, buf)? {
            ReadExact::Done => Ok(()),
            ReadExact::Eof | ReadExact::Interrupted => Err(Error::new(
                ErrorKind::UnexpectedEof,
                "connection closed mid-frame",
            )),
        }
    }

    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.read_exact(&mut b)?;
        // finger-lint: allow(FL001): read_exact filled the 1-byte buffer
        Ok(b[0])
    }

    fn varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = self.u8()?;
            if shift == 63 && byte & 0x7E != 0 {
                return Err(bad("varint overflows u64"));
            }
            v |= u64::from(byte & 0x7F) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(bad("varint longer than 10 bytes"))
    }

    fn usize_bounded(&mut self, max: usize, what: &str) -> Result<usize> {
        let v = self.varint()?;
        if v <= max as u64 {
            Ok(v as usize)
        } else {
            Err(bad(format!("{what} {v} exceeds maximum {max}")))
        }
    }

    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b)?;
        Ok(f64::from_bits(u64::from_le_bytes(b)))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.usize_bounded(MAX_LINE, "string length")?;
        let mut bytes = vec![0u8; len];
        self.read_exact(&mut bytes)?;
        String::from_utf8(bytes).map_err(|_| bad("string is not valid UTF-8"))
    }
}

impl Codec for BinaryCodec {
    fn wire(&self) -> Wire {
        Wire::Binary
    }

    fn read_command(
        &mut self,
        r: &mut dyn BufRead,
        stop: &dyn Fn() -> bool,
    ) -> Result<CommandRead> {
        // blocking shim over the incremental decoder: identical semantics,
        // one framing implementation
        let mut rbuf = std::mem::take(&mut self.rbuf);
        let out = read_via_decode(&mut rbuf, r, stop, |buf, eof| self.decode(buf, eof));
        self.rbuf = rbuf;
        out
    }

    fn decode(&mut self, buf: &mut ReadBuf, eof: bool) -> Result<Decode> {
        loop {
            // an in-progress BATCH consumes its body event-by-event as the
            // bytes arrive, decoding past a semantic error so the frame is
            // consumed and framing stays intact — the same atomic-reject
            // discipline as the text wire
            while let Some(b) = self.batch.as_mut() {
                if b.got == b.want {
                    break;
                }
                let mut sr = SliceReader::new(buf.bytes());
                let ev = need!(sr.event()?, eof);
                buf.consume(sr.pos);
                b.got += 1;
                match validate_wire_event(&ev) {
                    Ok(()) => b.events.push(ev),
                    Err(reason) => {
                        b.bad.get_or_insert((b.got, reason));
                    }
                }
            }
            if let Some(b) = self.batch.take() {
                return Ok(match b.bad {
                    Some((at, reason)) => {
                        Decode::Malformed(format!("batch event {at}: {reason}"))
                    }
                    None => Decode::Cmd(Command::Batch {
                        id: b.id,
                        events: b.events,
                        seq: b.seq,
                    }),
                });
            }
            if buf.is_empty() {
                return if eof { Ok(Decode::Eof) } else { Ok(Decode::Incomplete) };
            }
            let mut sr = SliceReader::new(buf.bytes());
            let opcode = need!(sr.u8(), eof);
            let out = match opcode {
                OP_OPEN | OP_OPEN_E => {
                    let id = need!(sr.string()?, eof);
                    let nodes = need!(sr.varint()?, eof);
                    let epoch = if opcode == OP_OPEN_E {
                        Some(need!(sr.varint()?, eof))
                    } else {
                        None
                    };
                    if nodes > MAX_OPEN_NODES as u64 {
                        Decode::Malformed(format!("OPEN: n exceeds maximum {MAX_OPEN_NODES}"))
                    } else {
                        Decode::Cmd(Command::Open { id, nodes: nodes as usize, epoch })
                    }
                }
                OP_EV | OP_EV_S => {
                    let id = need!(sr.string()?, eof);
                    let ev = need!(sr.event()?, eof);
                    let seq = if opcode == OP_EV_S {
                        Some(need!(sr.varint()?, eof))
                    } else {
                        None
                    };
                    match validate_wire_event(&ev) {
                        Ok(()) => Decode::Cmd(Command::Event { id, ev, seq }),
                        Err(reason) => Decode::Malformed(format!("EV: {reason}")),
                    }
                }
                OP_BATCH | OP_BATCH_S => {
                    let id = need!(sr.string()?, eof);
                    let count = need!(sr.usize_bounded(MAX_BATCH, "BATCH count")?, eof);
                    let seq = if opcode == OP_BATCH_S {
                        Some(need!(sr.varint()?, eof))
                    } else {
                        None
                    };
                    buf.consume(sr.pos);
                    // cap the prealloc: the header's count is
                    // attacker-controlled, and a bare `BATCH a 1048576`
                    // must not pin ~24 MB per idle connection
                    self.batch = Some(BinBatch {
                        id,
                        want: count,
                        got: 0,
                        seq,
                        events: Vec::with_capacity(count.min(4096)),
                        bad: None,
                    });
                    continue;
                }
                OP_FAULT => {
                    let name = need!(sr.string()?, eof);
                    let spec = need!(sr.string()?, eof);
                    Decode::Cmd(Command::Fault { name, spec })
                }
                OP_QUERY => Decode::Cmd(Command::Query { id: need!(sr.string()?, eof) }),
                OP_CLOSE => Decode::Cmd(Command::Close { id: need!(sr.string()?, eof) }),
                OP_STATS => Decode::Cmd(Command::Stats),
                OP_METRICS => Decode::Cmd(Command::Metrics),
                OP_EPOCH => Decode::Cmd(Command::Epoch),
                OP_QUIT => Decode::Cmd(Command::Quit),
                OP_SHUTDOWN => Decode::Cmd(Command::Shutdown),
                other => return Err(bad(format!("unknown command opcode {other:#04x}"))),
            };
            buf.consume(sr.pos);
            return Ok(out);
        }
    }

    fn write_reply(&mut self, w: &mut dyn Write, reply: &Reply) -> Result<()> {
        self.buf.clear();
        BinaryCodec::encode_reply(&mut self.buf, reply);
        w.write_all(&self.buf)
    }

    fn write_command(&mut self, w: &mut dyn Write, cmd: &Command) -> Result<()> {
        self.buf.clear();
        BinaryCodec::encode_command(&mut self.buf, cmd);
        w.write_all(&self.buf)
    }

    fn write_batch_seq(
        &mut self,
        w: &mut dyn Write,
        id: &str,
        events: &[StreamEvent],
        seq: Option<u64>,
    ) -> Result<()> {
        self.buf.clear();
        BinaryCodec::encode_batch_seq(&mut self.buf, id, events, seq);
        w.write_all(&self.buf)
    }

    fn read_reply(&mut self, r: &mut dyn BufRead) -> Result<Option<Reply>> {
        // client side: a socket read timeout is the reply deadline and must
        // surface as the error the client maps to "read timed out"
        let mut op = [0u8; 1];
        let opcode = match read_exact_deadline(r, &mut op)? {
            // finger-lint: allow(FL001): read_exact filled the 1-byte buffer
            ReadExact::Done => op[0],
            // deadline reads never interrupt; treat it as the clean EOF arm
            ReadExact::Eof | ReadExact::Interrupted => return Ok(None),
        };
        let mut fr = FrameReader { r };
        let reply = match opcode {
            OP_OK => Reply::Ok,
            OP_OKKV => {
                let n = fr.usize_bounded(MAX_KV_PAIRS, "kv pair count")?;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    let k = fr.string()?;
                    let v = fr.string()?;
                    pairs.push((k, v));
                }
                Reply::OkKv(pairs)
            }
            OP_SNAPSHOT => {
                let windows = fr.varint()? as usize;
                let events = fr.varint()? as usize;
                let nodes = fr.varint()? as usize;
                let edges = fr.varint()? as usize;
                let anomalies = fr.varint()? as usize;
                let pending_events = fr.varint()? as usize;
                let last_anomalous = fr.u8()? != 0;
                let htilde = fr.f64()?;
                let last_jsdist = match fr.u8()? {
                    0 => None,
                    1 => Some(fr.f64()?),
                    other => return Err(bad(format!("bad jsdist flag {other}"))),
                };
                Reply::Snapshot(SessionSnapshot {
                    id: String::new(),
                    windows,
                    events,
                    last_jsdist,
                    last_anomalous,
                    htilde,
                    nodes,
                    edges,
                    anomalies,
                    pending_events,
                })
            }
            OP_METRICS_REPLY => {
                let np = fr.usize_bounded(MAX_KV_PAIRS, "metrics pair count")?;
                let mut pairs = Vec::with_capacity(np);
                for _ in 0..np {
                    let k = fr.string()?;
                    let v = fr.varint()?;
                    pairs.push((k, v));
                }
                let nh = fr.usize_bounded(MAX_METRIC_HISTS, "metrics hist count")?;
                let mut hists = Vec::with_capacity(nh);
                for _ in 0..nh {
                    let name = fr.string()?;
                    let count = fr.varint()?;
                    let nb = fr.usize_bounded(
                        crate::util::stats::HIST_BUCKETS,
                        "hist bucket count",
                    )?;
                    let mut buckets = Vec::with_capacity(nb);
                    for _ in 0..nb {
                        let i = fr.usize_bounded(
                            crate::util::stats::HIST_BUCKETS,
                            "hist bucket index",
                        )?;
                        let c = fr.varint()?;
                        buckets.push((i as u32, c));
                    }
                    hists.push(crate::obs::WireHist { name, count, buckets });
                }
                Reply::Metrics(crate::obs::MetricsReport { pairs, hists })
            }
            OP_ERR => Reply::Err(fr.string()?),
            other => return Err(bad(format!("unknown reply opcode {other:#04x}"))),
        };
        Ok(Some(reply))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip_command(cmd: &Command) -> CommandRead {
        let mut buf = Vec::new();
        BinaryCodec::encode_command(&mut buf, cmd);
        BinaryCodec::new().read_command(&mut Cursor::new(buf), &|| false).unwrap()
    }

    fn roundtrip_reply(reply: &Reply) -> Reply {
        let mut buf = Vec::new();
        BinaryCodec::encode_reply(&mut buf, reply);
        BinaryCodec::new().read_reply(&mut Cursor::new(buf)).unwrap().unwrap()
    }

    #[test]
    fn commands_roundtrip_exactly() {
        for cmd in [
            Command::Open {
                id: "raw id / no escaping % needed".into(),
                nodes: 1 << 20,
                epoch: None,
            },
            Command::Open { id: "r".into(), nodes: 16, epoch: Some(0) },
            Command::Open { id: "r".into(), nodes: 16, epoch: Some(u64::MAX) },
            Command::Event {
                id: "a".into(),
                ev: StreamEvent::EdgeDelta { i: 3, j: 7, dw: -1.25e300 },
                seq: None,
            },
            Command::Event {
                id: "a".into(),
                ev: StreamEvent::EdgeDelta { i: 3, j: 7, dw: -1.25e300 },
                seq: Some(12),
            },
            Command::Batch {
                id: "b".into(),
                events: vec![
                    StreamEvent::EdgeDelta { i: 0, j: 1, dw: f64::MIN_POSITIVE },
                    StreamEvent::GrowNodes { count: 5 },
                    StreamEvent::Tick,
                ],
                seq: None,
            },
            Command::Batch {
                id: "b".into(),
                events: vec![StreamEvent::Tick, StreamEvent::GrowNodes { count: 1 }],
                seq: Some(1 << 40),
            },
            Command::Fault { name: "snap.rename".into(), spec: "after=2".into() },
            Command::Query { id: String::new() },
            Command::Close { id: "tenant/1".into() },
            Command::Stats,
            Command::Metrics,
            Command::Epoch,
            Command::Quit,
            Command::Shutdown,
        ] {
            assert_eq!(roundtrip_command(&cmd), CommandRead::Cmd(cmd));
        }
    }

    #[test]
    fn v1_frames_stay_byte_identical_without_reliability_fields() {
        let mut buf = Vec::new();
        BinaryCodec::encode_command(
            &mut buf,
            &Command::Open { id: "x".into(), nodes: 4, epoch: None },
        );
        assert_eq!(buf, vec![OP_OPEN, 1, b'x', 4]);
        buf.clear();
        BinaryCodec::encode_command(
            &mut buf,
            &Command::Event { id: "x".into(), ev: StreamEvent::Tick, seq: None },
        );
        assert_eq!(buf, vec![OP_EV, 1, b'x', EV_TICK]);
        buf.clear();
        BinaryCodec::encode_command(
            &mut buf,
            &Command::Batch { id: "x".into(), events: vec![StreamEvent::Tick], seq: None },
        );
        assert_eq!(buf, vec![OP_BATCH, 1, b'x', 1, EV_TICK]);
    }

    #[test]
    fn replies_roundtrip_with_raw_f64_bits() {
        let snap = SessionSnapshot {
            id: String::new(),
            windows: 3,
            events: 1_000_000,
            last_jsdist: Some(0.1 + 0.2), // a value decimal formatting mangles
            last_anomalous: true,
            htilde: -0.0,
            nodes: 1 << 24,
            edges: 0,
            anomalies: 2,
            pending_events: 7,
        };
        for reply in [
            Reply::Ok,
            Reply::OkKv(vec![("depths".into(), "0,1,2".into())]),
            Reply::Snapshot(snap),
            Reply::Metrics(crate::obs::MetricsReport {
                pairs: vec![
                    ("net_accepted".into(), 0),
                    ("shard0_events".into(), u64::MAX),
                ],
                hists: vec![
                    crate::obs::WireHist {
                        name: "score_latency_us".into(),
                        count: 5,
                        buckets: vec![(0, 1), (900, 4)],
                    },
                    crate::obs::WireHist {
                        name: "queue_wait_us".into(),
                        count: 0,
                        buckets: vec![],
                    },
                ],
            }),
            Reply::Err("unknown-session".into()),
        ] {
            let back = roundtrip_reply(&reply);
            assert_eq!(back, reply);
            if let (Reply::Snapshot(a), Reply::Snapshot(b)) = (&back, &reply) {
                assert_eq!(a.htilde.to_bits(), b.htilde.to_bits());
                assert_eq!(
                    a.last_jsdist.unwrap().to_bits(),
                    b.last_jsdist.unwrap().to_bits()
                );
            }
        }
    }

    #[test]
    fn semantic_errors_are_recoverable_and_consume_the_frame() {
        // self-loop event, then a valid STATS in the same stream
        let mut buf = Vec::new();
        BinaryCodec::encode_command(
            &mut buf,
            &Command::Event {
                id: "a".into(),
                ev: StreamEvent::EdgeDelta { i: 4, j: 4, dw: 1.0 },
                seq: None,
            },
        );
        BinaryCodec::encode_command(&mut buf, &Command::Stats);
        let mut codec = BinaryCodec::new();
        let mut r = Cursor::new(buf);
        assert!(matches!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Malformed(reason) if reason.contains("self-loop")
        ));
        assert_eq!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Cmd(Command::Stats)
        );

        // batch with one poisonous event is rejected atomically, framing holds
        let mut buf = Vec::new();
        BinaryCodec::encode_command(
            &mut buf,
            &Command::Batch {
                id: "b".into(),
                events: vec![
                    StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 },
                    StreamEvent::EdgeDelta { i: 1, j: 2, dw: f64::NAN },
                    StreamEvent::Tick,
                ],
                seq: None,
            },
        );
        BinaryCodec::encode_command(&mut buf, &Command::Quit);
        let mut r = Cursor::new(buf);
        assert!(matches!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Malformed(reason) if reason.contains("batch event 2")
        ));
        assert_eq!(
            codec.read_command(&mut r, &|| false).unwrap(),
            CommandRead::Cmd(Command::Quit)
        );
    }

    #[test]
    fn resource_bounds_are_enforced() {
        // OPEN over the node cap: recoverable (frame fully read)
        let mut buf = Vec::new();
        BinaryCodec::encode_command(
            &mut buf,
            &Command::Open { id: "a".into(), nodes: MAX_OPEN_NODES + 1, epoch: None },
        );
        assert!(matches!(
            BinaryCodec::new()
                .read_command(&mut Cursor::new(buf), &|| false)
                .unwrap(),
            CommandRead::Malformed(reason) if reason.contains("exceeds maximum")
        ));

        // BATCH over the count cap: fatal (cannot affordably skip the body)
        let mut buf = vec![OP_BATCH];
        put_string(&mut buf, "a");
        put_varint(&mut buf, (MAX_BATCH + 1) as u64);
        assert!(BinaryCodec::new()
            .read_command(&mut Cursor::new(buf), &|| false)
            .is_err());

        // string length over the cap: fatal
        let mut buf = vec![OP_QUERY];
        put_varint(&mut buf, (MAX_LINE + 1) as u64);
        assert!(BinaryCodec::new()
            .read_command(&mut Cursor::new(buf), &|| false)
            .is_err());
    }

    #[test]
    fn garbage_is_fatal_not_misparsed() {
        for payload in [
            vec![0x7Fu8],             // unknown opcode
            vec![OP_EV, 1, b'a', 9],  // unknown event tag
            vec![OP_OPEN, 1, 0xFF],   // invalid UTF-8 id
        ] {
            assert!(
                BinaryCodec::new()
                    .read_command(&mut Cursor::new(payload.clone()), &|| false)
                    .is_err(),
                "{payload:?}"
            );
        }
        // truncated frame: UnexpectedEof, not a clean Eof
        let mut buf = Vec::new();
        BinaryCodec::encode_command(&mut buf, &Command::Query { id: "abcdef".into() });
        buf.truncate(buf.len() - 2);
        let err = BinaryCodec::new()
            .read_command(&mut Cursor::new(buf), &|| false)
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
    }

    #[test]
    fn incremental_batch_decode_keeps_the_buffer_bounded() {
        let events: Vec<StreamEvent> = (0..10_000)
            .map(|k| StreamEvent::EdgeDelta { i: k, j: k + 1, dw: 1.0 })
            .collect();
        let want = events.len();
        let mut payload = Vec::new();
        BinaryCodec::encode_command(
            &mut payload,
            &Command::Batch { id: "big".into(), events, seq: None },
        );
        let mut codec = BinaryCodec::new();
        let mut buf = ReadBuf::new();
        let mut got = None;
        for chunk in payload.chunks(512) {
            buf.extend(chunk);
            match codec.decode(&mut buf, false).unwrap() {
                Decode::Incomplete => {
                    // the buffer holds at most one partial event plus the
                    // unconsumed tail of the current chunk, never the frame
                    assert!(buf.len() < 600, "buffer grew to {}", buf.len());
                }
                Decode::Cmd(c) => got = Some(c),
                other => panic!("unexpected decode outcome: {other:?}"),
            }
        }
        match got {
            Some(Command::Batch { id, events, seq }) => {
                assert_eq!(id, "big");
                assert_eq!(events.len(), want);
                assert_eq!(seq, None);
            }
            other => panic!("batch did not decode: {other:?}"),
        }
    }

    /// Yields its bytes, then `WouldBlock` forever — a hung server as seen
    /// through a socket with a read timeout.
    struct HungAfter(Cursor<Vec<u8>>);

    impl std::io::Read for HungAfter {
        fn read(&mut self, buf: &mut [u8]) -> Result<usize> {
            use std::io::Read;
            let n = self.0.read(buf)?;
            if n == 0 {
                return Err(Error::new(ErrorKind::WouldBlock, "read timeout"));
            }
            Ok(n)
        }
    }

    #[test]
    fn client_reads_fail_on_timeout_instead_of_spinning() {
        // a frame that promises more bytes than the server ever sends: the
        // client-side deadline read must surface the timeout as an error
        // (NetClient maps it to a clean "read timed out"), never retry
        // forever the way the server's shutdown-polling reads do
        let mut buf = vec![OP_ERR];
        put_varint(&mut buf, 5); // 5 payload bytes promised, none delivered
        let mut r = std::io::BufReader::new(HungAfter(Cursor::new(buf)));
        let err = BinaryCodec::new().read_reply(&mut r).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::WouldBlock);

        // ...and a timeout before any frame starts is surfaced the same way
        let mut r = std::io::BufReader::new(HungAfter(Cursor::new(Vec::new())));
        let err = BinaryCodec::new().read_reply(&mut r).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::WouldBlock);
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut sr = SliceReader::new(&buf);
            assert_eq!(sr.varint().unwrap(), Some(v));
            assert_eq!(sr.pos, buf.len(), "whole varint consumed");
        }
        // a truncated varint is "need more bytes", not an error
        let mut buf = Vec::new();
        put_varint(&mut buf, 16_384);
        let mut sr = SliceReader::new(&buf[..1]);
        assert_eq!(sr.varint().unwrap(), None);
        // an 11-byte continuation run is rejected
        let mut sr = SliceReader::new(&[0x80u8; 11]);
        assert!(sr.varint().is_err());
        // a 10th byte carrying bits past u64 would silently truncate (e.g.
        // 0x02<<63 wraps to 0, turning a huge length prefix into a small
        // one and desynchronizing the frame) — must be rejected, not wrapped
        let mut overflow = vec![0x80u8; 9];
        overflow.push(0x02);
        let mut sr = SliceReader::new(&overflow);
        assert!(sr.varint().is_err());
        // the client-side FrameReader enforces the same strictness
        let mut r = Cursor::new(vec![0x80u8; 11]);
        let mut fr = FrameReader { r: &mut r };
        assert!(fr.varint().is_err());
    }
}
