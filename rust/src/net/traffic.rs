//! Load driver: replays a multi-tenant workload (including wiki/DoS/Hi-C
//! dataset-preset tenants, see [`TenantPreset`]) against a running
//! `finger serve` instance over N concurrent client connections and reports
//! end-to-end events/s.
//!
//! Tenants are round-robin partitioned across connections; each connection
//! opens its tenants, then replays them window-major (one tick-delimited
//! window per `BATCH` message, interleaved across its tenants so every
//! shard stays busy — the same discipline as the in-process
//! [`workload::drive`]), and finally `QUERY`s each tenant so callers can
//! cross-check the scores against an in-process run of the same workload.
//!
//! [`workload::drive`]: crate::service::workload::drive

use super::client::NetClient;
use crate::service::workload::{
    tenant_streams, TenantPreset, TenantStream, TenantWorkloadConfig,
};
use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;
use anyhow::{Context, Result};
use std::time::Instant;

/// Shape of one load-driver run.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent client connections (clamped to the tenant count).
    pub connections: usize,
    /// The tenant workload to replay (presets included).
    pub workload: TenantWorkloadConfig,
    /// `QUERY` every tenant after its replay and collect the snapshots.
    pub query_sessions: bool,
    /// Send `SHUTDOWN` after the run (from the first connection).
    pub shutdown_after: bool,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            addr: super::proto::DEFAULT_ADDR.to_string(),
            connections: 4,
            workload: TenantWorkloadConfig::default(),
            query_sessions: true,
            shutdown_after: false,
        }
    }
}

/// Aggregate outcome of one load-driver run.
#[derive(Debug)]
pub struct TrafficReport {
    /// Connections actually used.
    pub connections: usize,
    pub sessions: usize,
    /// Events sent (and acknowledged) across all connections.
    pub events_sent: usize,
    /// Wall-clock of the replay, connect to last acknowledgment.
    pub wall_secs: f64,
    /// End-to-end acknowledged events per second, aggregated.
    pub events_per_sec: f64,
    /// Windows scored server-side, summed over `QUERY` snapshots (0 when
    /// `query_sessions` is off).
    pub windows: usize,
    /// Anomalous windows, summed over `QUERY` snapshots.
    pub anomalies: usize,
    /// One snapshot per tenant (empty when `query_sessions` is off),
    /// sorted by session id.
    pub snapshots: Vec<SessionSnapshot>,
}

/// Replay `cfg.workload` against `cfg.addr`. Builds the tenant streams,
/// drives them over `cfg.connections` concurrent connections and returns
/// the aggregate report. Fails on the first protocol or I/O error.
pub fn run_load(cfg: &TrafficConfig) -> Result<TrafficReport> {
    let streams = tenant_streams(&cfg.workload);
    let report = replay(&cfg.addr, cfg.connections, cfg.query_sessions, &streams)?;
    if cfg.shutdown_after {
        NetClient::connect(cfg.addr.as_str())?.shutdown_server()?;
    }
    Ok(report)
}

/// Replay prebuilt tenant streams over `connections` concurrent client
/// connections (exposed so tests can drive the exact same streams through
/// the wire and through the in-process service).
pub fn replay(
    addr: &str,
    connections: usize,
    query_sessions: bool,
    streams: &[TenantStream],
) -> Result<TrafficReport> {
    let connections = connections.clamp(1, streams.len().max(1));
    let start = Instant::now();
    let mut outcomes: Vec<Result<(usize, Vec<SessionSnapshot>)>> =
        Vec::with_capacity(connections);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(connections);
        for c in 0..connections {
            let chunk: Vec<&TenantStream> =
                streams.iter().skip(c).step_by(connections).collect();
            handles
                .push(scope.spawn(move || drive_connection(addr, &chunk, query_sessions)));
        }
        for h in handles {
            outcomes.push(h.join().expect("load connection thread panicked"));
        }
    });
    let mut events_sent = 0;
    let mut snapshots = Vec::new();
    for outcome in outcomes {
        let (sent, snaps) = outcome?;
        events_sent += sent;
        snapshots.extend(snaps);
    }
    let wall_secs = start.elapsed().as_secs_f64();
    snapshots.sort_by(|a, b| a.id.cmp(&b.id));
    Ok(TrafficReport {
        connections,
        sessions: streams.len(),
        events_sent,
        wall_secs,
        events_per_sec: events_sent as f64 / wall_secs.max(1e-12),
        windows: snapshots.iter().map(|s| s.windows).sum(),
        anomalies: snapshots.iter().map(|s| s.anomalies).sum(),
        snapshots,
    })
}

/// One connection's share: open every tenant, replay window-major, then
/// optionally query each tenant.
fn drive_connection(
    addr: &str,
    chunk: &[&TenantStream],
    query: bool,
) -> Result<(usize, Vec<SessionSnapshot>)> {
    let mut client = NetClient::connect(addr)?;
    let mut sent = 0;
    for (id, initial, _) in chunk {
        client
            .open(id, initial.num_nodes())
            .with_context(|| format!("open {id}"))?;
        // the wire opens an *empty* graph; replay the initial edges as a
        // window-0 batch so the server-side state matches the local graph
        let seed_events: Vec<StreamEvent> = initial
            .edges()
            .map(|(i, j, w)| StreamEvent::EdgeDelta { i, j, dw: w })
            .chain(std::iter::once(StreamEvent::Tick))
            .collect();
        sent += client
            .send_batch(id, &seed_events)
            .with_context(|| format!("seed {id}"))?;
    }
    let windows: Vec<Vec<&[StreamEvent]>> = chunk
        .iter()
        .map(|(_, _, evs)| {
            evs.split_inclusive(|e| matches!(e, StreamEvent::Tick)).collect()
        })
        .collect();
    let max_windows = windows.iter().map(|w| w.len()).max().unwrap_or(0);
    for w in 0..max_windows {
        for (k, (id, _, _)) in chunk.iter().enumerate() {
            if let Some(win) = windows[k].get(w) {
                sent += client
                    .send_batch(id, win)
                    .with_context(|| format!("batch {w} for {id}"))?;
            }
        }
    }
    let mut snaps = Vec::new();
    if query {
        for (id, _, _) in chunk {
            let snap = client
                .query(id)
                .with_context(|| format!("query {id}"))?
                .with_context(|| format!("session {id} vanished server-side"))?;
            snaps.push(snap);
        }
    }
    client.quit()?;
    Ok((sent, snaps))
}

/// Human-readable preset mix of a workload (for logs and reports).
pub fn preset_summary(workload: &TenantWorkloadConfig) -> String {
    if workload.presets.is_empty() {
        return "synthetic".to_string();
    }
    let names: Vec<&str> = workload.presets.iter().map(TenantPreset::name).collect();
    names.join(",")
}
