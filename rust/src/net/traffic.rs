//! Load driver: replays a multi-tenant workload (including wiki/DoS/Hi-C
//! dataset-preset tenants, see [`TenantPreset`]) against a running
//! `finger serve` instance over N concurrent client connections — on either
//! wire — and reports end-to-end events/s.
//!
//! Tenants are round-robin partitioned across connections; each connection
//! opens its tenants, then replays them window-major (one tick-delimited
//! window per `Batch` command, interleaved across its tenants so every
//! shard stays busy — the same discipline as the in-process
//! [`workload::drive`]), and finally `Query`s each tenant so callers can
//! cross-check the scores against an in-process run of the same workload.
//!
//! [`workload::drive`]: crate::service::workload::drive

use super::client::NetClient;
use super::codec::Wire;
use crate::service::workload::{
    tenant_streams, TenantPreset, TenantStream, TenantWorkloadConfig,
};
use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;
use anyhow::{Context, Result};
use std::time::{Duration, Instant};

/// Shape of one load-driver run.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Wire format every connection speaks (`--wire text|binary`).
    pub wire: Wire,
    /// Reply-read deadline per connection (`[net] client_timeout_ms`); a
    /// hung server surfaces as a per-connection error instead of wedging
    /// the run forever.
    pub client_timeout: Option<Duration>,
    /// Concurrent client connections (clamped to the tenant count).
    pub connections: usize,
    /// The tenant workload to replay (presets included).
    pub workload: TenantWorkloadConfig,
    /// `Query` every tenant after its replay and collect the snapshots.
    pub query_sessions: bool,
    /// Send `Shutdown` after the run (from the first connection).
    pub shutdown_after: bool,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            addr: super::command::DEFAULT_ADDR.to_string(),
            wire: Wire::Text,
            client_timeout: super::server::NetConfig::default().client_timeout(),
            connections: 4,
            workload: TenantWorkloadConfig::default(),
            query_sessions: true,
            shutdown_after: false,
        }
    }
}

/// Aggregate outcome of one load-driver run.
#[derive(Debug)]
pub struct TrafficReport {
    /// The wire the run spoke.
    pub wire: Wire,
    /// Connections actually used.
    pub connections: usize,
    pub sessions: usize,
    /// Events sent (and acknowledged) across all connections.
    pub events_sent: usize,
    /// Wall-clock of the replay, connect to last acknowledgment.
    pub wall_secs: f64,
    /// End-to-end acknowledged events per second, aggregated.
    pub events_per_sec: f64,
    /// Windows scored server-side, summed over `Query` snapshots (0 when
    /// `query_sessions` is off).
    pub windows: usize,
    /// Anomalous windows, summed over `Query` snapshots.
    pub anomalies: usize,
    /// One snapshot per tenant (empty when `query_sessions` is off),
    /// sorted by session id.
    pub snapshots: Vec<SessionSnapshot>,
}

/// Replay `cfg.workload` against `cfg.addr`. Builds the tenant streams,
/// drives them over `cfg.connections` concurrent connections on `cfg.wire`
/// and returns the aggregate report. Fails on the first protocol or I/O
/// error.
pub fn run_load(cfg: &TrafficConfig) -> Result<TrafficReport> {
    let streams = tenant_streams(&cfg.workload);
    let report = replay(
        &cfg.addr,
        cfg.connections,
        cfg.query_sessions,
        &streams,
        cfg.wire,
        cfg.client_timeout,
    )?;
    if cfg.shutdown_after {
        NetClient::connect_with(cfg.addr.as_str(), cfg.wire, cfg.client_timeout)?
            .shutdown_server()?;
    }
    Ok(report)
}

/// Replay prebuilt tenant streams over `connections` concurrent client
/// connections speaking `wire` (exposed so tests can drive the exact same
/// streams through either wire and through the in-process service).
pub fn replay(
    addr: &str,
    connections: usize,
    query_sessions: bool,
    streams: &[TenantStream],
    wire: Wire,
    client_timeout: Option<Duration>,
) -> Result<TrafficReport> {
    let connections = connections.clamp(1, streams.len().max(1));
    let start = Instant::now();
    let mut outcomes: Vec<Result<(usize, Vec<SessionSnapshot>)>> =
        Vec::with_capacity(connections);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(connections);
        for c in 0..connections {
            let chunk: Vec<&TenantStream> =
                streams.iter().skip(c).step_by(connections).collect();
            handles.push(scope.spawn(move || {
                drive_connection(addr, &chunk, query_sessions, wire, client_timeout)
                    // a timeout or protocol failure names its connection,
                    // so the load report pinpoints which link wedged
                    .with_context(|| format!("connection {c} ({wire} wire)"))
            }));
        }
        for h in handles {
            outcomes.push(h.join().expect("load connection thread panicked"));
        }
    });
    let mut events_sent = 0;
    let mut snapshots = Vec::new();
    for outcome in outcomes {
        let (sent, snaps) = outcome?;
        events_sent += sent;
        snapshots.extend(snaps);
    }
    let wall_secs = start.elapsed().as_secs_f64();
    snapshots.sort_by(|a, b| a.id.cmp(&b.id));
    Ok(TrafficReport {
        wire,
        connections,
        sessions: streams.len(),
        events_sent,
        wall_secs,
        events_per_sec: events_sent as f64 / wall_secs.max(1e-12),
        windows: snapshots.iter().map(|s| s.windows).sum(),
        anomalies: snapshots.iter().map(|s| s.anomalies).sum(),
        snapshots,
    })
}

/// One connection's share: open every tenant, replay window-major, then
/// optionally query each tenant.
fn drive_connection(
    addr: &str,
    chunk: &[&TenantStream],
    query: bool,
    wire: Wire,
    client_timeout: Option<Duration>,
) -> Result<(usize, Vec<SessionSnapshot>)> {
    let mut client = NetClient::connect_with(addr, wire, client_timeout)?;
    let mut sent = 0;
    for (id, initial, _) in chunk {
        client
            .open(id, initial.num_nodes())
            .with_context(|| format!("open {id}"))?;
        // the wire opens an *empty* graph; replay the initial edges as a
        // window-0 batch so the server-side state matches the local graph
        let seed_events: Vec<StreamEvent> = initial
            .edges()
            .map(|(i, j, w)| StreamEvent::EdgeDelta { i, j, dw: w })
            .chain(std::iter::once(StreamEvent::Tick))
            .collect();
        sent += client
            .send_batch(id, &seed_events)
            .with_context(|| format!("seed {id}"))?;
    }
    let windows: Vec<Vec<&[StreamEvent]>> = chunk
        .iter()
        .map(|(_, _, evs)| {
            evs.split_inclusive(|e| matches!(e, StreamEvent::Tick)).collect()
        })
        .collect();
    let max_windows = windows.iter().map(|w| w.len()).max().unwrap_or(0);
    for w in 0..max_windows {
        for (k, (id, _, _)) in chunk.iter().enumerate() {
            if let Some(win) = windows[k].get(w) {
                sent += client
                    .send_batch(id, win)
                    .with_context(|| format!("batch {w} for {id}"))?;
            }
        }
    }
    let mut snaps = Vec::new();
    if query {
        for (id, _, _) in chunk {
            let snap = client
                .query(id)
                .with_context(|| format!("query {id}"))?
                .with_context(|| format!("session {id} vanished server-side"))?;
            snaps.push(snap);
        }
    }
    client.quit()?;
    Ok((sent, snaps))
}

/// Human-readable preset mix of a workload (for logs and reports).
pub fn preset_summary(workload: &TenantWorkloadConfig) -> String {
    if workload.presets.is_empty() {
        return "synthetic".to_string();
    }
    let names: Vec<&str> = workload.presets.iter().map(TenantPreset::name).collect();
    names.join(",")
}
