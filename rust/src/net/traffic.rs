//! Load driver: replays a multi-tenant workload (including wiki/DoS/Hi-C
//! dataset-preset tenants, see [`TenantPreset`]) against a running
//! `finger serve` instance over N concurrent client connections — on either
//! wire — and reports end-to-end events/s plus per-request latency
//! percentiles.
//!
//! The driver separates *connections* from *threads* so it can exercise the
//! server's multiplexer at high connection counts: every one of the N
//! sockets is connected up front and stays open for the whole run, but they
//! are driven by at most [`MAX_LOAD_WORKERS`] worker threads, each
//! multiplexing its share of the sockets. Tenants are round-robin
//! partitioned across connections; each worker opens its tenants, then
//! replays them window-major (one tick-delimited window per `Batch`
//! command, interleaved across its connections so every shard stays busy —
//! the same discipline as the in-process [`workload::drive`]), and finally
//! `Query`s each tenant so callers can cross-check the scores against an
//! in-process run of the same workload. Every request round-trip (open,
//! batch, query) is timed into a shared [`Histogram`], surfacing p50/p99
//! alongside throughput.
//!
//! [`workload::drive`]: crate::service::workload::drive

use super::client::NetClient;
use super::codec::Wire;
use super::retry::{ErrorCounts, RetryClient, RetryPolicy};
use crate::service::workload::{
    tenant_streams, TenantPreset, TenantStream, TenantWorkloadConfig,
};
use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;
use crate::util::stats::Histogram;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Driver thread cap: a 10k-connection sweep opens 10k sockets but never
/// more than this many client threads — each worker round-robins its share
/// of the connections, mirroring how the server side multiplexes them.
pub const MAX_LOAD_WORKERS: usize = 64;

/// Shape of one load-driver run.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Wire format every connection speaks (`--wire text|binary`).
    pub wire: Wire,
    /// Reply-read deadline per connection (`[net] client_timeout_ms`); a
    /// hung server surfaces as a per-connection error instead of wedging
    /// the run forever.
    pub client_timeout: Option<Duration>,
    /// Concurrent client connections (clamped to the tenant count). All of
    /// them are open simultaneously for the whole run, driven by up to
    /// [`MAX_LOAD_WORKERS`] threads.
    pub connections: usize,
    /// The tenant workload to replay (presets included).
    pub workload: TenantWorkloadConfig,
    /// `Query` every tenant after its replay and collect the snapshots.
    pub query_sessions: bool,
    /// Send `Shutdown` after the run (from a fresh connection).
    pub shutdown_after: bool,
    /// Poll `STATS` from a side connection roughly once a second during the
    /// replay and print a live per-shard queue-depth imbalance line.
    pub live_stats: bool,
    /// After the replay, fetch `METRICS` on *both* wires and fail the run
    /// unless the key lists are identical (codec parity check).
    pub check_metrics: bool,
    /// Drive every connection through the exactly-once [`RetryClient`]
    /// (`finger load --retry`): reconnect + replay-from-acked on transport
    /// faults, honor `retry-after` shedding hints, and report per-kind
    /// error counts. `None` uses the plain fail-fast client.
    pub retry: Option<RetryPolicy>,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            addr: super::command::DEFAULT_ADDR.to_string(),
            wire: Wire::Text,
            client_timeout: super::server::NetConfig::default().client_timeout(),
            connections: 4,
            workload: TenantWorkloadConfig::default(),
            query_sessions: true,
            shutdown_after: false,
            live_stats: false,
            check_metrics: false,
            retry: None,
        }
    }
}

/// Aggregate outcome of one load-driver run.
#[derive(Debug)]
pub struct TrafficReport {
    /// The wire the run spoke.
    pub wire: Wire,
    /// Connections actually used.
    pub connections: usize,
    pub sessions: usize,
    /// Events sent (and acknowledged) across all connections.
    pub events_sent: usize,
    /// Wall-clock of the replay, connect to last acknowledgment.
    pub wall_secs: f64,
    /// End-to-end acknowledged events per second, aggregated.
    pub events_per_sec: f64,
    /// Windows scored server-side, summed over `Query` snapshots (0 when
    /// `query_sessions` is off).
    pub windows: usize,
    /// Anomalous windows, summed over `Query` snapshots.
    pub anomalies: usize,
    /// Median request round-trip (microseconds) over every open, batch and
    /// query command of the run; 0 when nothing was recorded.
    pub p50_us: u64,
    /// 99th-percentile request round-trip (microseconds) — the tail a
    /// C10K front end is judged on.
    pub p99_us: u64,
    /// One snapshot per tenant (empty when `query_sessions` is off),
    /// sorted by session id.
    pub snapshots: Vec<SessionSnapshot>,
    /// `Some(key count)` when the run verified METRICS key parity across
    /// both wires (`check_metrics`).
    pub metrics_keys: Option<usize>,
    /// Per-kind failure counts merged across workers — all zero on a clean
    /// run with the plain client; under `--retry` they tally what the run
    /// survived (resets, timeouts, shedding, server errors) plus retries.
    pub errors: ErrorCounts,
}

/// Replay `cfg.workload` against `cfg.addr`. Builds the tenant streams,
/// drives them over `cfg.connections` concurrent connections on `cfg.wire`
/// and returns the aggregate report. Fails on the first protocol or I/O
/// error.
pub fn run_load(cfg: &TrafficConfig) -> Result<TrafficReport> {
    let streams = tenant_streams(&cfg.workload);
    let stop = Arc::new(AtomicBool::new(false));
    let monitor = if cfg.live_stats {
        let (addr, wire, timeout) = (cfg.addr.clone(), cfg.wire, cfg.client_timeout);
        let stop = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("finger-load-mon".to_string())
            .spawn(move || monitor_stats(&addr, wire, timeout, &stop))
            .ok()
    } else {
        None
    };
    let outcome = replay_with(
        &cfg.addr,
        cfg.connections,
        cfg.query_sessions,
        &streams,
        cfg.wire,
        cfg.client_timeout,
        cfg.retry,
    );
    stop.store(true, Ordering::SeqCst);
    if let Some(h) = monitor {
        let _ = h.join();
    }
    let mut report = outcome?;
    if cfg.check_metrics {
        report.metrics_keys = Some(check_metrics_parity(&cfg.addr, cfg.client_timeout)?);
    }
    if cfg.shutdown_after {
        NetClient::connect_with(cfg.addr.as_str(), cfg.wire, cfg.client_timeout)?
            .shutdown_server()?;
    }
    Ok(report)
}

/// Poll `STATS` once a second until `stop`, printing one live line per poll:
/// per-shard queue depths plus a max/mean imbalance ratio, so a skewed
/// tenant partition shows up while the run is still going.
fn monitor_stats(addr: &str, wire: Wire, timeout: Option<Duration>, stop: &AtomicBool) {
    let mut client = match NetClient::connect_with(addr, wire, timeout) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("load: stats monitor: {e:#}");
            return;
        }
    };
    loop {
        if super::backoff::sleep_interruptible(Duration::from_secs(1), &|| {
            stop.load(Ordering::SeqCst)
        }) {
            let _ = client.quit();
            return;
        }
        match client.stats() {
            Ok(s) => {
                let depths: Vec<String> =
                    s.depths.iter().map(|d| d.to_string()).collect();
                let max = s.depths.iter().copied().max().unwrap_or(0);
                let mean = s.depths.iter().sum::<usize>() as f64
                    / s.depths.len().max(1) as f64;
                let imbalance = if mean > 0.0 { max as f64 / mean } else { 1.0 };
                eprintln!(
                    "load: depths=[{}] max={max} mean={mean:.1} imbalance={imbalance:.2} conns={} submitted={} uptime={}ms",
                    depths.join(","),
                    s.connections,
                    s.submitted,
                    s.uptime_ms,
                );
            }
            Err(e) => {
                eprintln!("load: stats monitor: {e:#}");
                return;
            }
        }
    }
}

/// Fetch `METRICS` on both wires and require the key lists to be identical
/// — every counter, gauge, slot, extra and histogram the text codec renders
/// must come back through the binary codec under the same name. Returns the
/// (common) key count.
pub fn check_metrics_parity(addr: &str, timeout: Option<Duration>) -> Result<usize> {
    let text = metric_keys(addr, Wire::Text, timeout)?;
    let binary = metric_keys(addr, Wire::Binary, timeout)?;
    if text != binary {
        anyhow::bail!(
            "METRICS key lists differ across wires: text={text:?} binary={binary:?}"
        );
    }
    Ok(text.len())
}

/// One `METRICS` round-trip on `wire`, flattened to its key list (histogram
/// keys use the text wire's `hist:` prefix so both shapes compare equal).
fn metric_keys(addr: &str, wire: Wire, timeout: Option<Duration>) -> Result<Vec<String>> {
    let mut client = NetClient::connect_with(addr, wire, timeout)
        .with_context(|| format!("connect ({wire} wire)"))?;
    let report =
        client.metrics().with_context(|| format!("METRICS on the {wire} wire"))?;
    let mut keys: Vec<String> = report.pairs.iter().map(|(k, _)| k.clone()).collect();
    keys.extend(report.hists.iter().map(|h| format!("hist:{}", h.name)));
    client.quit()?;
    Ok(keys)
}

/// Replay prebuilt tenant streams over `connections` concurrent client
/// connections speaking `wire` (exposed so tests can drive the exact same
/// streams through either wire and through the in-process service).
pub fn replay(
    addr: &str,
    connections: usize,
    query_sessions: bool,
    streams: &[TenantStream],
    wire: Wire,
    client_timeout: Option<Duration>,
) -> Result<TrafficReport> {
    replay_with(addr, connections, query_sessions, streams, wire, client_timeout, None)
}

/// [`replay`] with an optional exactly-once retry policy: `Some` drives every
/// connection through a [`RetryClient`] instead of the fail-fast
/// [`NetClient`].
pub fn replay_with(
    addr: &str,
    connections: usize,
    query_sessions: bool,
    streams: &[TenantStream],
    wire: Wire,
    client_timeout: Option<Duration>,
    retry: Option<RetryPolicy>,
) -> Result<TrafficReport> {
    let connections = connections.clamp(1, streams.len().max(1));
    let workers = connections.min(MAX_LOAD_WORKERS);
    let start = Instant::now();
    let mut outcomes: Vec<Result<WorkerOutcome>> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for worker in 0..workers {
            let plan = WorkerPlan {
                addr,
                streams,
                connections,
                worker,
                workers,
                query: query_sessions,
                wire,
                client_timeout,
                retry,
            };
            handles.push(scope.spawn(move || drive_worker(plan)));
        }
        for h in handles {
            // finger-lint: allow(FL001): load worker join; the run is lost anyway if one died
            outcomes.push(h.join().expect("load worker thread panicked"));
        }
    });
    let mut events_sent = 0;
    let mut snapshots = Vec::new();
    let mut lat = Histogram::new();
    let mut errors = ErrorCounts::default();
    for outcome in outcomes {
        let o = outcome?;
        events_sent += o.sent;
        snapshots.extend(o.snaps);
        lat.merge(&o.lat);
        errors.merge(&o.errors);
    }
    let wall_secs = start.elapsed().as_secs_f64();
    snapshots.sort_by(|a, b| a.id.cmp(&b.id));
    Ok(TrafficReport {
        wire,
        connections,
        sessions: streams.len(),
        events_sent,
        wall_secs,
        events_per_sec: events_sent as f64 / wall_secs.max(1e-12),
        windows: snapshots.iter().map(|s| s.windows).sum(),
        anomalies: snapshots.iter().map(|s| s.anomalies).sum(),
        p50_us: lat.percentile(50.0),
        p99_us: lat.percentile(99.0),
        snapshots,
        metrics_keys: None,
        errors,
    })
}

/// Everything one worker thread needs to drive its share of the run.
struct WorkerPlan<'a> {
    addr: &'a str,
    streams: &'a [TenantStream],
    /// Total connection count of the run (tenant partitioning modulus).
    connections: usize,
    /// This worker's index; it owns connections `worker, worker + workers, …`.
    worker: usize,
    workers: usize,
    query: bool,
    wire: Wire,
    client_timeout: Option<Duration>,
    retry: Option<RetryPolicy>,
}

struct WorkerOutcome {
    sent: usize,
    snaps: Vec<SessionSnapshot>,
    lat: Histogram,
    errors: ErrorCounts,
}

/// The two client disciplines a load connection can speak: fail-fast
/// ([`NetClient`]) or exactly-once with reconnect ([`RetryClient`]).
enum LoadClient {
    Plain(NetClient),
    Retry(RetryClient),
}

impl LoadClient {
    fn connect(
        addr: &str,
        wire: Wire,
        timeout: Option<Duration>,
        retry: Option<RetryPolicy>,
    ) -> Result<Self> {
        match retry {
            None => Ok(LoadClient::Plain(NetClient::connect_with(addr, wire, timeout)?)),
            Some(p) => Ok(LoadClient::Retry(RetryClient::connect(addr, wire, timeout, p)?)),
        }
    }

    fn open(&mut self, id: &str, nodes: usize) -> Result<()> {
        match self {
            LoadClient::Plain(c) => c.open(id, nodes),
            LoadClient::Retry(c) => c.open(id, nodes),
        }
    }

    fn send_batch(&mut self, id: &str, events: &[StreamEvent]) -> Result<usize> {
        match self {
            LoadClient::Plain(c) => c.send_batch(id, events),
            LoadClient::Retry(c) => c.send_batch(id, events),
        }
    }

    fn query(&mut self, id: &str) -> Result<Option<SessionSnapshot>> {
        match self {
            LoadClient::Plain(c) => c.query(id),
            LoadClient::Retry(c) => c.query(id),
        }
    }

    /// Close politely, yielding any accumulated error counts.
    fn quit(self) -> Result<ErrorCounts> {
        match self {
            LoadClient::Plain(c) => {
                c.quit()?;
                Ok(ErrorCounts::default())
            }
            LoadClient::Retry(c) => {
                let counts = c.counts().clone();
                c.quit()?;
                Ok(counts)
            }
        }
    }
}

/// One open connection and the tenants partitioned onto it.
struct LoadConn<'a> {
    /// Global connection index (names the link in error contexts).
    index: usize,
    client: LoadClient,
    tenants: Vec<&'a TenantStream>,
}

/// Time one request round-trip into the latency histogram (errors are
/// recorded too — a timed-out request is exactly the tail worth seeing).
fn timed<T>(lat: &mut Histogram, f: impl FnOnce() -> Result<T>) -> Result<T> {
    let t0 = Instant::now();
    let out = f();
    lat.record(t0.elapsed().as_micros().min(u64::MAX as u128) as u64);
    out
}

/// Drive this worker's connections: connect all of them up front (the whole
/// run's sockets are open at once), open + seed every tenant, replay
/// window-major across the worker's links, then query and quit.
fn drive_worker(plan: WorkerPlan<'_>) -> Result<WorkerOutcome> {
    let WorkerPlan {
        addr,
        streams,
        connections,
        worker,
        workers,
        query,
        wire,
        client_timeout,
        retry,
    } = plan;
    let mut lat = Histogram::new();
    let mut sent = 0usize;
    let mut conns: Vec<LoadConn<'_>> = Vec::new();
    let mut c = worker;
    while c < connections {
        let client = LoadClient::connect(addr, wire, client_timeout, retry)
            // a connect/timeout failure names its connection, so the load
            // report pinpoints which link wedged
            .with_context(|| format!("connect {c} ({wire} wire)"))?;
        let tenants: Vec<&TenantStream> =
            streams.iter().skip(c).step_by(connections).collect();
        conns.push(LoadConn { index: c, client, tenants });
        c += workers;
    }
    for conn in conns.iter_mut() {
        for (id, initial, _) in conn.tenants.iter().copied() {
            timed(&mut lat, || conn.client.open(id, initial.num_nodes()))
                .with_context(|| format!("open {id} (connection {})", conn.index))?;
            // the wire opens an *empty* graph; replay the initial edges as a
            // window-0 batch so the server-side state matches the local graph
            let seed_events: Vec<StreamEvent> = initial
                .edges()
                .map(|(i, j, w)| StreamEvent::EdgeDelta { i, j, dw: w })
                .chain(std::iter::once(StreamEvent::Tick))
                .collect();
            sent += timed(&mut lat, || conn.client.send_batch(id, &seed_events))
                .with_context(|| format!("seed {id} (connection {})", conn.index))?;
        }
    }
    // per connection, per tenant: the tick-delimited windows of its stream
    let windows: Vec<Vec<Vec<&[StreamEvent]>>> = conns
        .iter()
        .map(|conn| {
            conn.tenants
                .iter()
                .copied()
                .map(|(_, _, evs)| {
                    evs.split_inclusive(|e| matches!(e, StreamEvent::Tick)).collect()
                })
                .collect()
        })
        .collect();
    let max_windows =
        windows.iter().flatten().map(|w| w.len()).max().unwrap_or(0);
    // window-major: every tenant's window w lands before any window w+1,
    // interleaved across this worker's connections so shards stay busy
    for w in 0..max_windows {
        for (conn, per_tenant) in conns.iter_mut().zip(windows.iter()) {
            for (t, wins) in per_tenant.iter().enumerate() {
                let Some(win) = wins.get(w) else { continue };
                let Some((id, _, _)) = conn.tenants.get(t).copied() else { continue };
                sent += timed(&mut lat, || conn.client.send_batch(id, win))
                    .with_context(|| {
                        format!("batch {w} for {id} (connection {})", conn.index)
                    })?;
            }
        }
    }
    let mut snaps = Vec::new();
    if query {
        for conn in conns.iter_mut() {
            for (id, _, _) in conn.tenants.iter().copied() {
                let snap = timed(&mut lat, || conn.client.query(id))
                    .with_context(|| format!("query {id} (connection {})", conn.index))?
                    .with_context(|| format!("session {id} vanished server-side"))?;
                snaps.push(snap);
            }
        }
    }
    let mut errors = ErrorCounts::default();
    for conn in conns {
        errors.merge(&conn.client.quit()?);
    }
    Ok(WorkerOutcome { sent, snaps, lat, errors })
}

/// Human-readable preset mix of a workload (for logs and reports).
pub fn preset_summary(workload: &TenantWorkloadConfig) -> String {
    if workload.presets.is_empty() {
        return "synthetic".to_string();
    }
    let names: Vec<&str> = workload.presets.iter().map(TenantPreset::name).collect();
    names.join(",")
}
