//! Small blocking client for the line protocol — used by the load driver
//! (`net::traffic`), the integration tests and `examples/tcp_traffic.rs`.
//!
//! One request, one reply: every helper writes a line (a `BATCH` writes the
//! header plus its body in a single buffered syscall) and blocks on the
//! one-line response. Protocol-level failures surface as `anyhow` errors
//! carrying the server's `ERR` reason.

use super::proto::{snapshot_from_response, Request, Response};
use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// Per-shard queue depths and service totals from the `STATS` verb.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetStats {
    pub shards: usize,
    /// Messages in flight per shard (queued + being processed).
    pub depths: Vec<usize>,
    /// Events the service accepted so far.
    pub submitted: usize,
}

/// A blocking connection to a `finger serve` instance.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Self> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connect {addr:?}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(Self { reader, writer: stream })
    }

    /// Send raw bytes (already newline-terminated) and read one reply line.
    /// Exposed for protocol tests; normal callers use the typed helpers.
    pub fn roundtrip_raw(&mut self, payload: &str) -> Result<Response> {
        self.writer.write_all(payload.as_bytes()).context("send")?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("read reply")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Response::parse(&line).map_err(anyhow::Error::msg)
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let mut line = req.to_line();
        line.push('\n');
        self.roundtrip_raw(&line)
    }

    /// Like `roundtrip`, but converts `ERR` replies into errors.
    fn expect_ok(&mut self, req: &Request) -> Result<Response> {
        match self.roundtrip(req)? {
            Response::Err(reason) => bail!("server: {reason}"),
            ok => Ok(ok),
        }
    }

    /// (Re)open `id` with a fresh `nodes`-node empty graph.
    pub fn open(&mut self, id: &str, nodes: usize) -> Result<()> {
        self.expect_ok(&Request::Open { id: id.to_string(), nodes })?;
        Ok(())
    }

    /// Submit one event.
    pub fn send_event(&mut self, id: &str, ev: &StreamEvent) -> Result<()> {
        self.expect_ok(&Request::Event { id: id.to_string(), ev: ev.clone() })?;
        Ok(())
    }

    /// Submit a whole batch as one header + body write and one reply read.
    /// Returns the number of events the server accepted.
    pub fn send_batch(&mut self, id: &str, events: &[StreamEvent]) -> Result<usize> {
        if events.is_empty() {
            return Ok(0);
        }
        let header = Request::Batch { id: id.to_string(), count: events.len() };
        let mut payload = header.to_line();
        payload.push('\n');
        for ev in events {
            payload.push_str(&ev.to_line());
            payload.push('\n');
        }
        let resp = self.roundtrip_raw(&payload)?;
        match resp {
            Response::Err(reason) => bail!("server: {reason}"),
            ok => ok
                .get_parsed("accepted")
                .context("BATCH reply missing accepted count"),
        }
    }

    /// Point-in-time stats of `id`; `None` if the server knows no such
    /// session.
    pub fn query(&mut self, id: &str) -> Result<Option<SessionSnapshot>> {
        match self.roundtrip(&Request::Query { id: id.to_string() })? {
            Response::Err(reason) if reason == "unknown-session" => Ok(None),
            Response::Err(reason) => bail!("server: {reason}"),
            ok => Ok(Some(
                snapshot_from_response(id, &ok).context("malformed QUERY reply")?,
            )),
        }
    }

    /// Per-shard queue depths and totals.
    pub fn stats(&mut self) -> Result<NetStats> {
        let resp = self.expect_ok(&Request::Stats)?;
        let depths_raw = resp.get("depths").context("STATS reply missing depths")?;
        let depths: Vec<usize> = if depths_raw.is_empty() {
            Vec::new()
        } else {
            depths_raw
                .split(',')
                .map(|d| d.parse().map_err(|_| anyhow::anyhow!("bad depth {d:?}")))
                .collect::<Result<_>>()?
        };
        Ok(NetStats {
            shards: resp.get_parsed("shards").context("STATS reply missing shards")?,
            depths,
            submitted: resp
                .get_parsed("submitted")
                .context("STATS reply missing submitted")?,
        })
    }

    /// Close this connection politely (the server keeps running).
    pub fn quit(mut self) -> Result<()> {
        self.expect_ok(&Request::Quit)?;
        Ok(())
    }

    /// Ask the server to drain and stop. The connection is closed by the
    /// server after the `OK`.
    pub fn shutdown_server(mut self) -> Result<()> {
        self.expect_ok(&Request::Shutdown)?;
        Ok(())
    }
}
