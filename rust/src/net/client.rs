//! Small blocking client, generic over the wire codec — used by the load
//! driver (`net::traffic`), the integration tests and
//! `examples/tcp_traffic.rs`.
//!
//! One command, one reply: every helper writes one complete frame (a
//! `BATCH` is its header plus body in a single buffered syscall) and blocks
//! on the one-frame response. Protocol-level failures surface as `anyhow`
//! errors carrying the server's `Err` reason; a configured read timeout
//! turns a hung server into a clean timeout error instead of blocking
//! forever.

use super::codec::{write_binary_preamble, Codec, Wire};
use super::command::{Command, Reply};
use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Per-shard queue depths and service totals from the `Stats` command.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetStats {
    pub shards: usize,
    /// Messages in flight per shard (queued + being processed).
    pub depths: Vec<usize>,
    /// Events the service accepted so far.
    pub submitted: usize,
    /// Milliseconds since the service started.
    pub uptime_ms: u64,
    /// Live client connections across the server's event loops.
    pub connections: u64,
}

/// A blocking connection to a `finger serve` instance, speaking either wire.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    codec: Box<dyn Codec>,
    /// Reply-read deadline, for error messages.
    timeout: Option<Duration>,
    /// Write-side frame buffer: one frame, one syscall.
    wbuf: Vec<u8>,
}

impl NetClient {
    /// Connect on the text wire with no read deadline (the conservative
    /// default — matches the v1 client).
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Self> {
        Self::connect_with(addr, Wire::Text, None)
    }

    /// Connect speaking `wire`, optionally bounding every reply read by
    /// `timeout` (`[net] client_timeout_ms`). A binary connection sends its
    /// two-byte preamble immediately, so the server can negotiate on the
    /// first byte.
    pub fn connect_with(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        wire: Wire,
        timeout: Option<Duration>,
    ) -> Result<Self> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connect {addr:?}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(timeout).context("set_read_timeout")?;
        let mut writer = stream.try_clone().context("clone stream")?;
        if wire == Wire::Binary {
            write_binary_preamble(&mut writer).context("send binary preamble")?;
        }
        let reader = BufReader::new(stream);
        Ok(Self { reader, writer, codec: wire.codec(), timeout, wbuf: Vec::new() })
    }

    /// The wire this connection speaks.
    pub fn wire(&self) -> Wire {
        self.codec.wire()
    }

    /// Read one reply frame, mapping EOF and read deadlines to clean errors.
    fn read_reply(&mut self) -> Result<Reply> {
        match self.codec.read_reply(&mut self.reader as &mut dyn BufRead) {
            Ok(Some(reply)) => Ok(reply),
            Ok(None) => bail!("server closed the connection"),
            Err(e)
                if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) =>
            {
                bail!(
                    "read timed out after {:?}: server unresponsive",
                    self.timeout.unwrap_or_default()
                )
            }
            Err(e) => Err(anyhow::Error::new(e).context("read reply")),
        }
    }

    /// Send raw pre-framed bytes and read one reply. Exposed for protocol
    /// tests that speak `nc`-style text; the bytes must be one complete
    /// frame in this connection's wire format.
    pub fn roundtrip_raw(&mut self, payload: &[u8]) -> Result<Reply> {
        self.writer.write_all(payload).context("send")?;
        self.read_reply()
    }

    /// One command, one reply.
    pub fn roundtrip(&mut self, cmd: &Command) -> Result<Reply> {
        self.wbuf.clear();
        self.codec.write_command(&mut self.wbuf, cmd).context("encode command")?;
        self.writer.write_all(&self.wbuf).context("send")?;
        self.read_reply()
    }

    /// Like `roundtrip`, but converts `Err` replies into errors.
    fn expect_ok(&mut self, cmd: &Command) -> Result<Reply> {
        match self.roundtrip(cmd)? {
            Reply::Err(reason) => bail!("server: {reason}"),
            ok => Ok(ok),
        }
    }

    /// (Re)open `id` with a fresh `nodes`-node empty graph.
    pub fn open(&mut self, id: &str, nodes: usize) -> Result<()> {
        self.expect_ok(&Command::Open { id: id.to_string(), nodes, epoch: None })?;
        Ok(())
    }

    /// Reliable open: pass the client's known session `epoch` (0 for a
    /// fresh session). Returns `(epoch, acked)` from the server — a matching
    /// epoch resumes the session without resetting it and `acked` is the
    /// highest applied sequence number to replay from.
    pub fn open_reliable(&mut self, id: &str, nodes: usize, epoch: u64) -> Result<(u64, u64)> {
        let resp = self.expect_ok(&Command::Open {
            id: id.to_string(),
            nodes,
            epoch: Some(epoch),
        })?;
        Ok((
            resp.get_parsed("epoch").context("reliable OPEN reply missing epoch")?,
            resp.get_parsed("acked").context("reliable OPEN reply missing acked")?,
        ))
    }

    /// Submit one event.
    pub fn send_event(&mut self, id: &str, ev: &StreamEvent) -> Result<()> {
        self.expect_ok(&Command::Event { id: id.to_string(), ev: ev.clone(), seq: None })?;
        Ok(())
    }

    /// Submit a whole batch as one frame write and one reply read. Returns
    /// the number of events the server accepted. Encodes straight from the
    /// borrowed slice (`Codec::write_batch`) — the load driver sends one
    /// window per call and must not clone it into a `Command` first.
    pub fn send_batch(&mut self, id: &str, events: &[StreamEvent]) -> Result<usize> {
        if events.is_empty() {
            return Ok(0);
        }
        self.wbuf.clear();
        self.codec.write_batch(&mut self.wbuf, id, events).context("encode batch")?;
        self.writer.write_all(&self.wbuf).context("send")?;
        match self.read_reply()? {
            Reply::Err(reason) => bail!("server: {reason}"),
            ok => ok.get_parsed("accepted").context("BATCH reply missing accepted count"),
        }
    }

    /// Reliable batch: one frame carrying the whole batch plus its
    /// per-session sequence number. Returns the raw reply — the retry layer
    /// inspects `accepted` / `acked` / `dup` and server `ERR`s itself.
    pub fn send_batch_seq(
        &mut self,
        id: &str,
        events: &[StreamEvent],
        seq: u64,
    ) -> Result<Reply> {
        self.wbuf.clear();
        self.codec
            .write_batch_seq(&mut self.wbuf, id, events, Some(seq))
            .context("encode batch")?;
        self.writer.write_all(&self.wbuf).context("send")?;
        self.read_reply()
    }

    /// Point-in-time stats of `id`; `None` if the server knows no such
    /// session.
    pub fn query(&mut self, id: &str) -> Result<Option<SessionSnapshot>> {
        match self.roundtrip(&Command::Query { id: id.to_string() })? {
            Reply::Err(reason) if reason == "unknown-session" => Ok(None),
            Reply::Err(reason) => bail!("server: {reason}"),
            ok => Ok(Some(ok.into_snapshot(id).context("malformed QUERY reply")?)),
        }
    }

    /// Retire session `id`, returning its final snapshot (trailing partial
    /// window flushed); `None` if the server knows no such session.
    pub fn close(&mut self, id: &str) -> Result<Option<SessionSnapshot>> {
        match self.roundtrip(&Command::Close { id: id.to_string() })? {
            Reply::Err(reason) if reason == "unknown-session" => Ok(None),
            Reply::Err(reason) => bail!("server: {reason}"),
            ok => Ok(Some(ok.into_snapshot(id).context("malformed CLOSE reply")?)),
        }
    }

    /// Per-shard queue depths and totals.
    pub fn stats(&mut self) -> Result<NetStats> {
        let resp = self.expect_ok(&Command::Stats)?;
        let depths_raw = resp.get("depths").context("STATS reply missing depths")?;
        let depths: Vec<usize> = if depths_raw.is_empty() {
            Vec::new()
        } else {
            depths_raw
                .split(',')
                .map(|d| d.parse().map_err(|_| anyhow::anyhow!("bad depth {d:?}")))
                .collect::<Result<_>>()?
        };
        Ok(NetStats {
            shards: resp.get_parsed("shards").context("STATS reply missing shards")?,
            depths,
            submitted: resp
                .get_parsed("submitted")
                .context("STATS reply missing submitted")?,
            uptime_ms: resp
                .get_parsed("uptime_ms")
                .context("STATS reply missing uptime_ms")?,
            connections: resp
                .get_parsed("connections")
                .context("STATS reply missing connections")?,
        })
    }

    /// Cut one durability epoch snapshot online (all shards, no drain).
    /// Returns `(epoch, sessions)` — the committed epoch number and how many
    /// sessions it covers. Errors when the server runs without durability.
    pub fn epoch(&mut self) -> Result<(u64, usize)> {
        let resp = self.expect_ok(&Command::Epoch)?;
        Ok((
            resp.get_parsed("epoch").context("EPOCH reply missing epoch")?,
            resp.get_parsed("sessions").context("EPOCH reply missing sessions")?,
        ))
    }

    /// The full metrics registry: counters, gauges, per-shard/per-loop
    /// slots, latency histograms and service extras. Identical reports on
    /// both wires (all values are integers).
    pub fn metrics(&mut self) -> Result<crate::obs::MetricsReport> {
        self.expect_ok(&Command::Metrics)?
            .into_metrics()
            .context("malformed METRICS reply")
    }

    /// Close this connection politely (the server keeps running).
    pub fn quit(mut self) -> Result<()> {
        self.expect_ok(&Command::Quit)?;
        Ok(())
    }

    /// Ask the server to drain and stop. The connection is closed by the
    /// server after the `Ok`.
    pub fn shutdown_server(mut self) -> Result<()> {
        self.expect_ok(&Command::Shutdown)?;
        Ok(())
    }
}
