//! The transport-independent command core of the network API.
//!
//! A [`Command`] is what a client asks the service to do; a [`Reply`] is the
//! structured answer. Neither knows anything about bytes on a wire — framing
//! and encoding live entirely in the pluggable codecs
//! ([`TextCodec`](super::codec::TextCodec) /
//! [`BinaryCodec`](super::codec::BinaryCodec)), and the server dispatches
//! `Command → Reply` against the scoring service with no formatting
//! knowledge at all.
//!
//! Validation that is *semantic* rather than syntactic — resource bounds,
//! poisonous event values — also lives here ([`validate_wire_event`],
//! [`parse_wire_event`]) so both codecs enforce identical rules.

use crate::service::SessionSnapshot;
use crate::stream::StreamEvent;

/// Upper bound on a `BATCH`'s event count: a hostile header can not make the
/// server buffer unbounded memory. Generous — the load driver batches one
/// window (tens to thousands of events) per message.
pub const MAX_BATCH: usize = 1 << 20;

/// Upper bound on one text request line's byte length (a `BATCH` body line
/// is a plain event line, far below this). The binary codec reuses it as its
/// string-length bound.
pub const MAX_LINE: usize = 64 * 1024;

/// Upper bound on `OPEN`'s node count: a hostile header can not make the
/// server allocate an arbitrarily large initial graph.
pub const MAX_OPEN_NODES: usize = 1 << 24;

/// Default listen address of `finger serve`.
pub const DEFAULT_ADDR: &str = "127.0.0.1:7341";

/// One client command, independent of the wire that carried it.
///
/// Unlike the old line-protocol `Request`, a batch carries its events
/// directly: reading the `k` body frames that follow a `BATCH` header is the
/// codec's job, so the server never sees partial framing state.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// (Re)open `id` with a fresh `nodes`-node empty graph. A reliable
    /// client passes its known session `epoch` (`OPEN <id> <n> epoch=E` /
    /// binary `OPEN_E`): a matching epoch *resumes* the session (no reset,
    /// reply carries `acked`), a zero or stale epoch opens fresh and the
    /// reply carries the new epoch. `None` keeps the v1 semantics.
    Open { id: String, nodes: usize, epoch: Option<u64> },
    /// One stream event for `id`. A reliable client numbers it with a
    /// per-session sequence (`seq=N` / binary `EV_S`) so the server can
    /// discard duplicates after a retry; `None` keeps v1 semantics.
    Event { id: String, ev: StreamEvent, seq: Option<u64> },
    /// A batch of events for `id`, submitted as one shard message.
    /// `seq` numbers the whole batch as one exactly-once unit.
    Batch { id: String, events: Vec<StreamEvent>, seq: Option<u64> },
    /// Point-in-time stats of a live session.
    Query { id: String },
    /// Retire session `id`: free its shard state and return the final
    /// snapshot (trailing partial window flushed).
    Close { id: String },
    /// Per-shard queue depths and service totals.
    Stats,
    /// The full metrics registry: every counter/gauge, per-shard and
    /// per-event-loop slots, latency histograms and service-derived extras.
    Metrics,
    /// Cut one durability epoch snapshot online (all shards, no drain).
    /// `ERR` when the server runs without a `[durability]` dir.
    Epoch,
    /// Close this connection (the server keeps running).
    Quit,
    /// Gracefully stop the whole server: drain every shard and produce the
    /// final `ServiceReport`.
    Shutdown,
    /// Arm (or disarm) a named failpoint: `FAULT <name> <spec>` with spec
    /// `off | once | at=N | every=N | after=N`. `ERR` unless the server was
    /// built with the `fault-inject` feature. See `docs/ROBUSTNESS.md`.
    Fault { name: String, spec: String },
}

impl Command {
    /// The session id this command addresses, if any.
    pub fn session_id(&self) -> Option<&str> {
        match self {
            Command::Open { id, .. }
            | Command::Event { id, .. }
            | Command::Batch { id, .. }
            | Command::Query { id }
            | Command::Close { id } => Some(id),
            Command::Stats
            | Command::Metrics
            | Command::Epoch
            | Command::Quit
            | Command::Shutdown
            | Command::Fault { .. } => None,
        }
    }
}

/// One structured server reply, independent of the wire that will carry it.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// Bare success.
    Ok,
    /// Success with ordered `key=value` detail pairs (`STATS`, `BATCH`).
    OkKv(Vec<(String, String)>),
    /// A session snapshot (`QUERY` / `CLOSE`). The id does not travel on
    /// either wire — decoders leave it empty and callers re-attach it.
    Snapshot(SessionSnapshot),
    /// The metrics registry (`METRICS`): ordered name→value pairs plus
    /// encoded latency histograms. Values are integers end to end, so the
    /// text wire's decimal rendering round-trips bit-for-bit and both wires
    /// deliver identical reports.
    Metrics(crate::obs::MetricsReport),
    /// Failure; the reason is free text.
    Err(String),
}

impl Reply {
    /// Convenience constructor for a single `key=value` pair.
    pub fn kv(key: &str, value: impl ToString) -> Self {
        Reply::OkKv(vec![(key.to_string(), value.to_string())])
    }

    /// Value of `key` in an `OkKv` (or kv-encoded snapshot) reply.
    pub fn get(&self, key: &str) -> Option<&str> {
        match self {
            Reply::OkKv(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
            }
            _ => None,
        }
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// Extract a snapshot, whichever shape the codec delivered: the binary
    /// wire returns [`Reply::Snapshot`] directly, the text wire returns the
    /// kv encoding (indistinguishable from any other `OK key=value` line).
    /// The caller supplies `id` — it does not travel in the reply.
    pub fn into_snapshot(self, id: &str) -> Option<SessionSnapshot> {
        match self {
            Reply::Snapshot(mut s) => {
                s.id = id.to_string();
                Some(s)
            }
            Reply::OkKv(ref pairs) => snapshot_from_kv(id, pairs),
            _ => None,
        }
    }

    /// Extract a metrics report, whichever shape the codec delivered: the
    /// binary wire returns [`Reply::Metrics`] directly, the text wire the kv
    /// encoding ([`metrics_to_kv`]). Every value is an integer, so the two
    /// shapes decode to identical reports.
    pub fn into_metrics(self) -> Option<crate::obs::MetricsReport> {
        match self {
            Reply::Metrics(r) => Some(r),
            Reply::OkKv(ref pairs) => metrics_from_kv(pairs),
            _ => None,
        }
    }
}

/// Encode a session snapshot as ordered `key=value` pairs — the `QUERY` /
/// `CLOSE` reply body on the text wire. Floats use Rust's
/// shortest-roundtrip `Display`, so the client re-parses them bit-for-bit.
pub fn snapshot_to_kv(s: &SessionSnapshot) -> Vec<(String, String)> {
    let mut pairs = vec![
        ("windows".to_string(), s.windows.to_string()),
        ("events".to_string(), s.events.to_string()),
        ("htilde".to_string(), s.htilde.to_string()),
        ("nodes".to_string(), s.nodes.to_string()),
        ("edges".to_string(), s.edges.to_string()),
        ("anomalies".to_string(), s.anomalies.to_string()),
        ("pending".to_string(), s.pending_events.to_string()),
        ("anomalous".to_string(), (s.last_anomalous as u8).to_string()),
    ];
    if let Some(js) = s.last_jsdist {
        pairs.push(("jsdist".to_string(), js.to_string()));
    }
    pairs
}

/// Decode the kv encoding back into a snapshot (the id is supplied by the
/// caller — it does not travel in the reply).
pub fn snapshot_from_kv(id: &str, pairs: &[(String, String)]) -> Option<SessionSnapshot> {
    fn parsed<T: std::str::FromStr>(pairs: &[(String, String)], key: &str) -> Option<T> {
        pairs.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.parse().ok())
    }
    Some(SessionSnapshot {
        id: id.to_string(),
        windows: parsed(pairs, "windows")?,
        events: parsed(pairs, "events")?,
        last_jsdist: parsed::<f64>(pairs, "jsdist"),
        last_anomalous: parsed::<u8>(pairs, "anomalous")? != 0,
        htilde: parsed(pairs, "htilde")?,
        nodes: parsed(pairs, "nodes")?,
        edges: parsed(pairs, "edges")?,
        anomalies: parsed(pairs, "anomalies")?,
        pending_events: parsed(pairs, "pending")?,
    })
}

/// Encode a metrics report as ordered `key=value` pairs — the `METRICS`
/// reply body on the text wire. Registry pairs travel verbatim (values are
/// `u64`, so decimal text round-trips exactly); each histogram becomes one
/// `hist:<name>` pair whose value packs the total count and the sparse
/// bucket list without whitespace: `<count>|<idx>:<n>,<idx>:<n>,...`.
pub fn metrics_to_kv(r: &crate::obs::MetricsReport) -> Vec<(String, String)> {
    let mut pairs: Vec<(String, String)> =
        r.pairs.iter().map(|(k, v)| (k.clone(), v.to_string())).collect();
    for h in &r.hists {
        let buckets: Vec<String> =
            h.buckets.iter().map(|(i, c)| format!("{i}:{c}")).collect();
        pairs.push((format!("hist:{}", h.name), format!("{}|{}", h.count, buckets.join(","))));
    }
    pairs
}

/// Decode the kv encoding back into a metrics report. `None` on any
/// malformed value — the reply then surfaces as plain kv pairs.
pub fn metrics_from_kv(pairs: &[(String, String)]) -> Option<crate::obs::MetricsReport> {
    let mut report = crate::obs::MetricsReport::default();
    for (k, v) in pairs {
        if let Some(name) = k.strip_prefix("hist:") {
            let (count, body) = v.split_once('|')?;
            let mut buckets = Vec::new();
            for tok in body.split(',').filter(|t| !t.is_empty()) {
                let (i, c) = tok.split_once(':')?;
                buckets.push((i.parse().ok()?, c.parse().ok()?));
            }
            report.hists.push(crate::obs::WireHist {
                name: name.to_string(),
                count: count.parse().ok()?,
                buckets,
            });
        } else {
            report.pairs.push((k.clone(), v.parse().ok()?));
        }
    }
    Some(report)
}

/// Resource-bound check shared by both codecs: node endpoints and grow
/// counts share `OPEN`'s [`MAX_OPEN_NODES`] cap, so no single well-formed
/// event can make a shard worker allocate an absurd graph (an
/// `e 0 4294967295 0.5` would otherwise grow the node set to the max id on
/// the next tick). Self-loops and non-finite deltas are rejected by the
/// codecs' event decoders before this runs on the text wire; the binary
/// decoder calls [`validate_wire_event`] for both classes.
pub fn validate_wire_event(ev: &StreamEvent) -> Result<(), &'static str> {
    match *ev {
        StreamEvent::EdgeDelta { i, j, dw } => {
            if i == j {
                Err("self-loop delta")
            } else if !dw.is_finite() {
                Err("non-finite dw")
            } else if i as usize >= MAX_OPEN_NODES || j as usize >= MAX_OPEN_NODES {
                Err("node id exceeds maximum")
            } else {
                Ok(())
            }
        }
        StreamEvent::GrowNodes { count } if count > MAX_OPEN_NODES => {
            Err("grow count exceeds maximum")
        }
        _ => Ok(()),
    }
}

/// Parse one event line from untrusted wire input: syntactic validity (via
/// the hardened [`StreamEvent::parse`]) plus the [`validate_wire_event`]
/// resource bounds. Used by the text codec's `EV` verb and `BATCH` body
/// lines.
pub fn parse_wire_event(line: &str) -> Result<StreamEvent, &'static str> {
    let ev = StreamEvent::parse(line)
        .ok_or("bad event (want `e i j dw` | `n count` | `t`; dw finite, i != j)")?;
    validate_wire_event(&ev)?;
    Ok(ev)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_event_bounds_are_enforced() {
        assert!(parse_wire_event("e 0 4294967295 0.5").is_err());
        assert!(parse_wire_event("e 1 1 0.5").is_err());
        assert!(parse_wire_event("e 1 2 NaN").is_err());
        assert!(parse_wire_event("e 0 1 0.5").is_ok());
        assert!(parse_wire_event(&format!("n {MAX_OPEN_NODES}")).is_ok());
        assert!(parse_wire_event(&format!("n {}", MAX_OPEN_NODES + 1)).is_err());
        assert!(validate_wire_event(&StreamEvent::Tick).is_ok());
        assert!(validate_wire_event(&StreamEvent::EdgeDelta {
            i: 0,
            j: 1,
            dw: f64::INFINITY
        })
        .is_err());
    }

    #[test]
    fn snapshot_kv_roundtrips_floats_bit_for_bit() {
        let snap = SessionSnapshot {
            id: "s/1".to_string(),
            windows: 7,
            events: 420,
            last_jsdist: Some(0.123456789012345678), // not representable; rounds
            last_anomalous: true,
            htilde: std::f64::consts::LN_2 * 3.7,
            nodes: 100,
            edges: 321,
            anomalies: 2,
            pending_events: 5,
        };
        let back = snapshot_from_kv("s/1", &snapshot_to_kv(&snap)).unwrap();
        assert_eq!(back, snap, "kv round-trip must be bit-for-bit");

        let no_window =
            SessionSnapshot { last_jsdist: None, windows: 0, ..snap.clone() };
        let back = snapshot_from_kv("s/1", &snapshot_to_kv(&no_window)).unwrap();
        assert_eq!(back.last_jsdist, None);
    }

    #[test]
    fn reply_into_snapshot_handles_both_shapes() {
        let snap = SessionSnapshot {
            id: String::new(),
            windows: 1,
            events: 2,
            last_jsdist: Some(0.5),
            last_anomalous: false,
            htilde: 1.25,
            nodes: 4,
            edges: 1,
            anomalies: 0,
            pending_events: 0,
        };
        let direct = Reply::Snapshot(snap.clone()).into_snapshot("x").unwrap();
        let via_kv = Reply::OkKv(snapshot_to_kv(&snap)).into_snapshot("x").unwrap();
        assert_eq!(direct, via_kv);
        assert_eq!(direct.id, "x");
        assert_eq!(Reply::Ok.into_snapshot("x"), None);
        assert_eq!(Reply::Err("nope".into()).into_snapshot("x"), None);
    }

    #[test]
    fn command_session_ids() {
        assert_eq!(Command::Query { id: "a".into() }.session_id(), Some("a"));
        assert_eq!(Command::Close { id: "b".into() }.session_id(), Some("b"));
        assert_eq!(Command::Stats.session_id(), None);
        assert_eq!(Command::Metrics.session_id(), None);
        assert_eq!(
            Command::Fault { name: "wal.fsync".into(), spec: "once".into() }.session_id(),
            None
        );
    }

    #[test]
    fn metrics_kv_roundtrips_exactly() {
        let report = crate::obs::MetricsReport {
            pairs: vec![
                ("net_accepted".to_string(), 12),
                ("shard0_events".to_string(), u64::MAX),
                ("uptime_ms".to_string(), 0),
            ],
            hists: vec![
                crate::obs::WireHist {
                    name: "score_latency_us".to_string(),
                    count: 7,
                    buckets: vec![(0, 3), (64, 4)],
                },
                crate::obs::WireHist {
                    name: "queue_wait_us".to_string(),
                    count: 0,
                    buckets: vec![],
                },
            ],
        };
        let kv = metrics_to_kv(&report);
        // the hist pairs pack without whitespace, so the text wire's
        // space-tokenized OK line carries them intact
        assert!(kv.iter().all(|(k, v)| !k.contains(' ') && !v.contains(' ')));
        let back = metrics_from_kv(&kv).expect("kv decodes");
        assert_eq!(back, report, "kv round-trip must be exact");
        assert_eq!(
            Reply::Metrics(report.clone()).into_metrics(),
            Reply::OkKv(kv).into_metrics(),
            "both wire shapes decode to the same report"
        );
        // a non-metrics kv reply does not decode (non-integer value)
        assert_eq!(metrics_from_kv(&[("depths".into(), "0,1".into())]), None);
    }
}
