//! Dependency-free readiness multiplexing over `poll(2)`.
//!
//! The event-driven front end ([`crate::net::server`]) parks one thread on a
//! whole set of nonblocking sockets and wakes only when one of them has work.
//! std exposes no readiness primitive, so this module carries the crate's one
//! FFI declaration: the POSIX `poll` syscall, a single function over a
//! `#[repr(C)]` struct that has been ABI-stable since the nineties. Nothing
//! else in the crate is allowed `unsafe` (see `[lints.rust]` in Cargo.toml);
//! the two `unsafe` blocks here are the entire surface, each a direct call
//! with the pointer/length taken from one live `&mut [PollFd]`.
//!
//! On non-Unix targets there is no `poll`; [`poll_fds`] degrades to a short
//! sleep that reports every requested interest as ready, which the caller's
//! nonblocking reads/writes then sort out via `WouldBlock`. Correct, but a
//! busy loop — the readiness front end is for Unix hosts.

use std::io;
use std::net::TcpStream;

/// Interest/readiness entry, layout-identical to `struct pollfd`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

/// Data may be read without blocking.
pub const POLLIN: i16 = 0x001;
/// Data may be written without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (always polled, never requested).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (always polled, never requested).
pub const POLLHUP: i16 = 0x010;
/// The fd is not open (always polled, never requested).
pub const POLLNVAL: i16 = 0x020;

impl PollFd {
    /// An entry asking for `events` readiness on `fd`, `revents` cleared.
    pub fn interest(fd: i32, events: i16) -> Self {
        PollFd { fd, events, revents: 0 }
    }

    /// Any readable-ish condition: data, hangup, error, or a bad fd. All
    /// four resolve the same way — attempt the nonblocking read and let it
    /// report data / clean EOF / an error.
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0
    }

    /// Writable, or in an error state the write will surface.
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLHUP | POLLERR | POLLNVAL) != 0
    }
}

#[cfg(target_os = "linux")]
mod sys {
    #![allow(unsafe_code)] // the crate's single FFI point; see module docs

    use super::PollFd;
    use std::io;

    extern "C" {
        // `nfds_t` is `c_ulong`, which matches `usize` on every Linux target.
        fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
    }

    pub fn poll_raw(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        // SAFETY: the pointer and length come from one live mutable slice of
        // `#[repr(C)]` PollFd entries, exactly the array poll(2) expects; the
        // kernel writes only within `fds.len()` entries' `revents` fields.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len(), timeout_ms) };
        if rc < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(rc as usize)
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    #![allow(unsafe_code)] // the crate's single FFI point; see module docs

    use super::PollFd;
    use std::io;

    extern "C" {
        // `nfds_t` is `u32` on the BSD family (macOS included).
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }

    pub fn poll_raw(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        let nfds = u32::try_from(fds.len())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "poll set exceeds u32"))?;
        // SAFETY: pointer/length from one live mutable slice of repr(C)
        // entries; the kernel writes only the `revents` fields.
        let rc = unsafe { poll(fds.as_mut_ptr(), nfds, timeout_ms) };
        if rc < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(rc as usize)
        }
    }
}

#[cfg(not(unix))]
mod sys {
    use super::PollFd;
    use std::io;
    use std::time::Duration;

    /// Degraded portability fallback: sleep briefly, then report every
    /// requested interest as ready and let the caller's nonblocking I/O
    /// return `WouldBlock` for the fds that were not actually ready.
    pub fn poll_raw(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        if timeout_ms != 0 {
            crate::net::backoff::sleep(Duration::from_millis(1));
        }
        let mut ready = 0;
        for f in fds.iter_mut() {
            f.revents = f.events;
            if f.revents != 0 {
                ready += 1;
            }
        }
        Ok(ready)
    }
}

/// Block until at least one entry is ready, the timeout elapses, or the set
/// is empty. `timeout_ms < 0` waits indefinitely, `0` returns immediately.
/// Returns the number of entries with nonzero `revents`. `EINTR` is retried
/// internally so callers never see a spurious error from a signal.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        match sys::poll_raw(fds, timeout_ms) {
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            other => return other,
        }
    }
}

/// The raw fd backing a std TCP socket, for building a [`PollFd`] entry.
#[cfg(unix)]
pub fn raw_fd(stream: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    stream.as_raw_fd()
}

/// Non-Unix targets have no fd concept here; the fallback `poll_raw` never
/// dereferences the value, so any sentinel works.
#[cfg(not(unix))]
pub fn raw_fd(_stream: &TcpStream) -> i32 {
    -1
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::TcpListener;

    fn loopback_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr");
        let a = TcpStream::connect(addr).expect("connect");
        let (b, _) = listener.accept().expect("accept");
        (a, b)
    }

    #[test]
    fn poll_times_out_when_idle_and_wakes_on_data() {
        let (mut a, b) = loopback_pair();
        let fd = raw_fd(&b);

        let mut set = [PollFd::interest(fd, POLLIN)];
        let n = poll_fds(&mut set, 0).expect("poll immediate");
        assert_eq!(n, 0, "no data yet: {:?}", set[0]);
        assert!(!set[0].readable());

        a.write_all(&[0x2a]).expect("write wake byte");
        let n = poll_fds(&mut set, 1000).expect("poll after write");
        assert_eq!(n, 1);
        assert!(set[0].readable());
    }

    #[test]
    fn poll_reports_writable_and_hangup() {
        let (a, b) = loopback_pair();
        let mut set = [PollFd::interest(raw_fd(&b), POLLOUT)];
        let n = poll_fds(&mut set, 1000).expect("poll writable");
        assert_eq!(n, 1);
        assert!(set[0].writable());

        drop(a);
        let mut set = [PollFd::interest(raw_fd(&b), POLLIN)];
        let n = poll_fds(&mut set, 1000).expect("poll hup");
        assert_eq!(n, 1);
        assert!(set[0].readable(), "peer close must surface as readable: {:?}", set[0]);
    }

    #[test]
    fn empty_set_with_zero_timeout_is_a_noop() {
        let mut set: [PollFd; 0] = [];
        assert_eq!(poll_fds(&mut set, 0).expect("empty poll"), 0);
    }
}
