//! The crate's one sanctioned blocking-sleep seam (lint rule FL007).
//!
//! Every retry, poll-fallback, and interval wait in service/net code routes
//! through this module instead of calling `std::thread::sleep` directly.
//! That buys two things: the waits are *visible* (FL007 bans stray sleeps,
//! so a reviewer can enumerate every place a thread parks on wall-clock
//! time), and the retry delays are *deterministic* — [`Backoff`] derives its
//! jitter from a seeded [`Pcg64`], so a chaos run retries at the same
//! schedule every time.

use crate::util::Pcg64;
use std::time::Duration;

/// Sleep for `ms` milliseconds. The FL007-sanctioned primitive.
pub fn sleep_ms(ms: u64) {
    sleep(Duration::from_millis(ms));
}

/// Sleep for `d`. The FL007-sanctioned primitive.
pub fn sleep(d: Duration) {
    std::thread::sleep(d);
}

/// Sleep up to `total`, waking every `step` (≤ 100 ms) to re-check `stop`;
/// returns early — and reports `true` — the moment `stop()` turns true.
/// The idiom behind the obs-snapshot and epoch-timer interval loops: a
/// server shutdown never waits out a multi-second interval.
pub fn sleep_interruptible(total: Duration, stop: &dyn Fn() -> bool) -> bool {
    let step_cap = Duration::from_millis(100);
    let mut slept = Duration::ZERO;
    while slept < total {
        if stop() {
            return true;
        }
        let step = (total - slept).min(step_cap);
        sleep(step);
        slept += step;
    }
    stop()
}

/// Capped exponential backoff with deterministic full jitter.
///
/// Attempt `k` waits a uniform duration in `[base·2ᵏ/2, base·2ᵏ]`, capped at
/// `cap`. The jitter stream is seeded, so two runs with the same seed (and
/// the same failure schedule) retry at identical times — chaos tests stay
/// reproducible.
#[derive(Debug)]
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    attempt: u32,
    rng: Pcg64,
}

impl Backoff {
    pub fn new(seed: u64, base_ms: u64, cap_ms: u64) -> Self {
        Self { base_ms: base_ms.max(1), cap_ms: cap_ms.max(1), attempt: 0, rng: Pcg64::new(seed) }
    }

    /// Attempts taken since the last [`Backoff::reset`].
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// The next delay in the schedule (advances the attempt counter).
    pub fn next_delay(&mut self) -> Duration {
        let exp = self.attempt.min(20);
        self.attempt += 1;
        let ceiling = self.base_ms.saturating_mul(1u64 << exp).min(self.cap_ms).max(1);
        let floor = (ceiling / 2).max(1);
        let ms = floor + self.rng.below((ceiling - floor + 1) as usize) as u64;
        Duration::from_millis(ms)
    }

    /// Sleep out the next delay in the schedule.
    pub fn pause(&mut self) {
        sleep(self.next_delay());
    }

    /// Success: the next failure starts the schedule over.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_caps_and_is_deterministic() {
        let mut a = Backoff::new(7, 10, 200);
        let mut b = Backoff::new(7, 10, 200);
        let da: Vec<_> = (0..8).map(|_| a.next_delay().as_millis() as u64).collect();
        let db: Vec<_> = (0..8).map(|_| b.next_delay().as_millis() as u64).collect();
        assert_eq!(da, db, "same seed, same schedule");
        for (k, &ms) in da.iter().enumerate() {
            let ceiling = (10u64 << k.min(20)).min(200);
            assert!(ms >= (ceiling / 2).max(1) && ms <= ceiling, "attempt {k}: {ms}ms");
        }
        assert!(da[7] <= 200, "cap holds");
        a.reset();
        assert_eq!(a.attempt(), 0);
        assert!(a.next_delay().as_millis() as u64 <= 10);
    }

    #[test]
    fn interruptible_sleep_honors_stop() {
        let t0 = std::time::Instant::now();
        let stopped = sleep_interruptible(Duration::from_secs(30), &|| true);
        assert!(stopped);
        assert!(t0.elapsed() < Duration::from_secs(5), "stop short-circuits");
        let stopped = sleep_interruptible(Duration::from_millis(1), &|| false);
        assert!(!stopped);
    }
}
