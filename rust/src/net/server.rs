//! Thread-per-connection TCP server putting a [`ScoringService`] on a
//! socket. Pure `std::net` — no async runtime dependency.
//!
//! * **Connection isolation** — every accepted connection gets its own
//!   reader thread; a malformed line yields a one-line `ERR` and the
//!   connection keeps going; an I/O error or panic-free protocol failure
//!   kills only that connection, never the server.
//! * **Backpressure without wedging** — submissions go through the
//!   service's non-blocking [`ScoringService::try_submit`] /
//!   [`ScoringService::try_submit_batch`] in a bounded-sleep retry loop
//!   that also watches the shutdown flag, so one stalled shard can slow a
//!   connection but can neither wedge it past shutdown nor drop events.
//! * **Graceful shutdown** — the `SHUTDOWN` verb (or
//!   [`ShutdownHandle::signal`]) stops the accept loop, joins every
//!   connection thread, drains all shards via [`ScoringService::finish`]
//!   and returns the final [`ServiceReport`] from [`NetServer::run`].

use super::proto::{snapshot_response, Request, Response, DEFAULT_ADDR, MAX_LINE};
use crate::cli::Config;
use crate::entropy::FingerState;
use crate::graph::Graph;
use crate::service::{ScoringService, ServiceConfig, ServiceReport, SubmitError};
use crate::stream::StreamEvent;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Knobs of the network front end, readable from the `[net]` config section.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Listen address (`host:port`; port 0 binds an ephemeral port).
    pub addr: String,
    /// Sleep between non-blocking submit retries while a shard queue is
    /// full (microseconds).
    pub backoff_us: u64,
    /// Socket read timeout used to poll the shutdown flag (milliseconds);
    /// bounds how long a drained connection outlives a shutdown request.
    pub poll_ms: u64,
    /// Socket write timeout (milliseconds): a client that stops reading its
    /// replies gets its connection dropped instead of wedging the thread
    /// (and the shutdown join) in `write_all` forever.
    pub write_timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: DEFAULT_ADDR.to_string(),
            backoff_us: 200,
            poll_ms: 25,
            write_timeout_ms: 5000,
        }
    }
}

impl NetConfig {
    /// Read the `[net]` section of a parsed config file; missing keys fall
    /// back to the defaults. Recognized keys: `addr`, `backoff_us`,
    /// `poll_ms`, `write_timeout_ms`.
    pub fn from_config(c: &Config) -> Self {
        let d = Self::default();
        Self {
            addr: c.get("net.addr").unwrap_or(&d.addr).to_string(),
            backoff_us: c.get_or("net.backoff_us", d.backoff_us).max(1),
            poll_ms: c.get_or("net.poll_ms", d.poll_ms).max(1),
            write_timeout_ms: c.get_or("net.write_timeout_ms", d.write_timeout_ms).max(1),
        }
    }
}

/// Signals a running [`NetServer`] to stop from another thread (tests, a
/// CLI signal handler). Protocol clients use the `SHUTDOWN` verb instead.
#[derive(Clone)]
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ShutdownHandle {
    pub fn signal(&self) {
        self.flag.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection; a wildcard
        // bind address (0.0.0.0 / ::) is not connectable on every platform,
        // so target loopback on the bound port instead
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match self.addr {
                SocketAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
    }

    pub fn is_signaled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// The bound, not-yet-running server.
pub struct NetServer {
    listener: TcpListener,
    service: Arc<ScoringService>,
    net: NetConfig,
    shutdown: ShutdownHandle,
}

impl NetServer {
    /// Bind the listen socket and start the scoring service's shard workers.
    pub fn bind(service_cfg: ServiceConfig, net: NetConfig) -> Result<Self> {
        let listener = TcpListener::bind(&net.addr)
            .with_context(|| format!("bind {}", net.addr))?;
        let addr = listener.local_addr().context("local_addr")?;
        let shutdown = ShutdownHandle { flag: Arc::new(AtomicBool::new(false)), addr };
        Ok(Self {
            listener,
            service: Arc::new(ScoringService::start(service_cfg)),
            net,
            shutdown,
        })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.shutdown.addr
    }

    /// Handle for programmatic shutdown from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        self.shutdown.clone()
    }

    /// Accept connections until a `SHUTDOWN` request (or
    /// [`ShutdownHandle::signal`]) arrives, then join every connection
    /// thread, drain the shards and return the final report.
    pub fn run(self) -> Result<ServiceReport> {
        let Self { listener, service, net, shutdown } = self;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for (conn_id, incoming) in listener.incoming().enumerate() {
            if shutdown.is_signaled() {
                break;
            }
            let stream = match incoming {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("net: accept failed: {e}");
                    continue;
                }
            };
            let service = Arc::clone(&service);
            let net = net.clone();
            let shutdown = shutdown.clone();
            let handle = std::thread::Builder::new()
                .name(format!("finger-conn-{conn_id}"))
                .spawn(move || {
                    if let Err(e) = handle_conn(stream, &service, &net, &shutdown) {
                        // per-connection isolation: log and move on
                        eprintln!("net: connection {conn_id}: {e}");
                    }
                })
                .context("spawn connection thread")?;
            conns.push(handle);
            // opportunistically reap finished connection threads
            conns = conns
                .into_iter()
                .filter_map(|h| {
                    if h.is_finished() {
                        let _ = h.join();
                        None
                    } else {
                        Some(h)
                    }
                })
                .collect();
        }
        for h in conns {
            let _ = h.join();
        }
        let service = Arc::try_unwrap(service)
            .map_err(|_| anyhow::anyhow!("connection thread leaked a service handle"))?;
        Ok(service.finish())
    }
}

/// Outcome of one polled line read.
enum LineRead {
    /// A complete line (without the trailing newline).
    Line,
    /// Clean end of stream.
    Eof,
    /// The server is shutting down.
    Shutdown,
}

/// Read one `\n`-terminated line, polling the shutdown flag on read
/// timeouts. Bytes are accumulated with `read_until` (not `read_line`),
/// so a timeout landing mid multi-byte UTF-8 character cannot discard
/// already-received bytes — invalid UTF-8 is surfaced lossily and rejected
/// by the parser rather than silently dropped.
///
/// The line is capped at just over [`MAX_LINE`] bytes: the prefix of an
/// oversized line is returned (and rejected by `Request::parse`) while its
/// remaining bytes are *discarded through the newline* in bounded chunks —
/// the buffer never grows past the cap and the tail is never misparsed as
/// further requests, preserving one-reply-per-request framing.
fn read_line_polled(
    reader: &mut BufReader<TcpStream>,
    buf: &mut String,
    shutdown: &ShutdownHandle,
) -> std::io::Result<LineRead> {
    buf.clear();
    let mut bytes: Vec<u8> = Vec::new();
    let mut discard: Vec<u8> = Vec::new();
    let outcome = loop {
        // phase 1 accumulates into `bytes` until the cap; phase 2
        // (oversized) drains the rest of the physical line into a bounded
        // scratch so the tail is never misparsed as further requests
        let oversized = bytes.len() > MAX_LINE;
        let (target, budget) = if oversized {
            discard.clear();
            (&mut discard, MAX_LINE as u64)
        } else {
            let budget = (MAX_LINE + 2 - bytes.len()) as u64;
            (&mut bytes, budget)
        };
        let mut limited = (&mut *reader).take(budget);
        match limited.read_until(b'\n', target) {
            Ok(0) => {
                // budget is always > 0, so 0 bytes means real EOF
                break if bytes.is_empty() { LineRead::Eof } else { LineRead::Line };
            }
            Ok(n) => {
                if target.last() == Some(&b'\n') {
                    break LineRead::Line;
                }
                // no newline: the cap was hit (n == budget → keep draining)
                // or the stream ended mid-line (surface what arrived)
                if (n as u64) < budget {
                    break LineRead::Line;
                }
            }
            Err(e) => match e.kind() {
                ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted => {
                    if shutdown.is_signaled() {
                        break LineRead::Shutdown;
                    }
                }
                _ => return Err(e),
            },
        }
    };
    if matches!(outcome, LineRead::Line) {
        while matches!(bytes.last(), Some(b'\n') | Some(b'\r')) {
            bytes.pop();
        }
        buf.push_str(&String::from_utf8_lossy(&bytes));
    }
    Ok(outcome)
}

/// One attempt of a non-blocking service call inside [`retry_backoff`].
enum Backoff<T> {
    /// The call went through.
    Done(T),
    /// The shard queue was full — sleep and try again.
    Retry,
    /// Terminal failure (shard closed); the `ERR` reason.
    Fail(String),
}

/// The shared full-queue retry discipline of every service call on a
/// connection thread: retry `attempt` with `backoff_us` sleeps while the
/// target shard's queue is full, honoring a shutdown request so one
/// stalled shard can't wedge the thread past a drain. `Err` carries the
/// `ERR` response to send instead.
fn retry_backoff<T>(
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    mut attempt: impl FnMut() -> Backoff<T>,
) -> Result<T, Response> {
    loop {
        match attempt() {
            Backoff::Done(v) => return Ok(v),
            Backoff::Fail(reason) => return Err(Response::Err(reason)),
            Backoff::Retry => {
                if shutdown.is_signaled() {
                    return Err(Response::Err("shutting-down".to_string()));
                }
                std::thread::sleep(Duration::from_micros(net.backoff_us));
            }
        }
    }
}

/// Submit a batch through the non-blocking path; returns the accepted
/// event count. Rejected batches are handed back by the service, so
/// retries never clone the events.
fn submit_batch_backoff(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    id: &str,
    events: Vec<StreamEvent>,
) -> Result<usize, Response> {
    let mut pending = Some(events);
    retry_backoff(net, shutdown, || {
        match service.try_submit_batch(id, pending.take().expect("pending batch")) {
            Ok(n) => Backoff::Done(n),
            Err((back, SubmitError::WouldBlock { .. })) => {
                pending = Some(back);
                Backoff::Retry
            }
            Err((_, e)) => Backoff::Fail(e.to_string()),
        }
    })
}

/// Open a session through the non-blocking path; the initial state is
/// built once and handed back on every retry.
fn open_backoff(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    id: &str,
    nodes: usize,
) -> Result<(), Response> {
    let mut state =
        Some(FingerState::with_policy(Graph::new(nodes), service.config().policy));
    retry_backoff(net, shutdown, || {
        match service.try_open_session_state(id, state.take().expect("pending state")) {
            Ok(()) => Backoff::Done(()),
            Err((back, SubmitError::WouldBlock { .. })) => {
                state = Some(back);
                Backoff::Retry
            }
            Err((_, e)) => Backoff::Fail(e.to_string()),
        }
    })
}

/// Query through the non-blocking path.
fn query_backoff(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    id: &str,
) -> Result<Option<crate::service::SessionSnapshot>, Response> {
    retry_backoff(net, shutdown, || match service.try_query(id) {
        Ok(snap) => Backoff::Done(snap),
        Err(SubmitError::WouldBlock { .. }) => Backoff::Retry,
        Err(e) => Backoff::Fail(e.to_string()),
    })
}

fn stats_response(service: &ScoringService) -> Response {
    let depths: Vec<String> =
        service.queue_depths().iter().map(|d| d.to_string()).collect();
    Response::Ok(vec![
        ("shards".to_string(), service.shards().to_string()),
        ("depths".to_string(), depths.join(",")),
        ("submitted".to_string(), service.events_submitted().to_string()),
    ])
}

/// Serve one connection until `QUIT`, EOF, `SHUTDOWN` or an I/O error.
fn handle_conn(
    stream: TcpStream,
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
) -> Result<()> {
    stream.set_nodelay(true).ok(); // request/reply latency over throughput
    stream
        .set_read_timeout(Some(Duration::from_millis(net.poll_ms)))
        .context("set_read_timeout")?;
    // a client that stops reading replies must not wedge this thread (and
    // the shutdown join) in write_all — time the write out and drop it
    stream
        .set_write_timeout(Some(Duration::from_millis(net.write_timeout_ms)))
        .context("set_write_timeout")?;
    let mut writer = stream.try_clone().context("clone stream")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let reply = |w: &mut TcpStream, resp: &Response| -> std::io::Result<()> {
        let mut out = resp.to_line();
        out.push('\n');
        w.write_all(out.as_bytes())
    };
    loop {
        match read_line_polled(&mut reader, &mut line, shutdown)? {
            LineRead::Eof | LineRead::Shutdown => return Ok(()),
            LineRead::Line => {}
        }
        if line.trim().is_empty() {
            continue; // blank lines are keep-alive noise, not errors
        }
        let resp = match Request::parse(&line) {
            Err(reason) => Response::Err(reason),
            Ok(Request::Open { id, nodes }) => {
                match open_backoff(service, net, shutdown, &id, nodes) {
                    Ok(()) => Response::ok(),
                    Err(err) => err,
                }
            }
            Ok(Request::Event { id, ev }) => {
                match submit_batch_backoff(service, net, shutdown, &id, vec![ev]) {
                    Ok(_) => Response::ok(),
                    Err(err) => err,
                }
            }
            Ok(Request::Batch { id, count }) => {
                match read_batch(&mut reader, &mut line, shutdown, count)? {
                    BatchRead::Events(events) => {
                        match submit_batch_backoff(service, net, shutdown, &id, events) {
                            Ok(n) => Response::Ok(vec![(
                                "accepted".to_string(),
                                n.to_string(),
                            )]),
                            Err(err) => err,
                        }
                    }
                    BatchRead::Malformed { at, reason } => {
                        Response::Err(format!("batch line {at}: {reason}"))
                    }
                    BatchRead::Interrupted => return Ok(()),
                }
            }
            Ok(Request::Query { id }) => match query_backoff(service, net, shutdown, &id) {
                Ok(Some(snap)) => snapshot_response(&snap),
                Ok(None) => Response::Err("unknown-session".to_string()),
                Err(err) => err,
            },
            Ok(Request::Stats) => stats_response(service),
            Ok(Request::Quit) => {
                reply(&mut writer, &Response::ok())?;
                return Ok(());
            }
            Ok(Request::Shutdown) => {
                reply(&mut writer, &Response::ok())?;
                shutdown.signal();
                return Ok(());
            }
        };
        reply(&mut writer, &resp)?;
        // during a drain, finish the in-flight request but take no new ones:
        // a connection that never pauses must not stall the shutdown join
        if shutdown.is_signaled() {
            return Ok(());
        }
    }
}

enum BatchRead {
    Events(Vec<StreamEvent>),
    /// Some body line failed to parse (1-based index); the whole batch is
    /// consumed and rejected so the stream stays in sync.
    Malformed {
        at: usize,
        reason: &'static str,
    },
    /// EOF or shutdown arrived mid-batch.
    Interrupted,
}

/// Consume exactly `count` event lines after a `BATCH` header. All `count`
/// lines are read even when one is malformed — the protocol stays line-
/// synchronized and only the batch is rejected.
fn read_batch(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    shutdown: &ShutdownHandle,
    count: usize,
) -> std::io::Result<BatchRead> {
    // cap the prealloc: the header's count is attacker-controlled, and a
    // bare `BATCH a 1048576` must not pin ~24 MB per idle connection
    let mut events = Vec::with_capacity(count.min(4096));
    let mut bad: Option<(usize, &'static str)> = None;
    for k in 1..=count {
        match read_line_polled(reader, line, shutdown)? {
            LineRead::Line => {}
            LineRead::Eof | LineRead::Shutdown => return Ok(BatchRead::Interrupted),
        }
        match super::proto::parse_wire_event(line) {
            Ok(ev) => events.push(ev),
            Err(reason) => {
                bad.get_or_insert((k, reason));
            }
        }
    }
    Ok(match bad {
        Some((at, reason)) => BatchRead::Malformed { at, reason },
        None => BatchRead::Events(events),
    })
}
