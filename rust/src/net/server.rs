//! Thread-per-connection TCP server putting a [`ScoringService`] on a
//! socket. Pure `std::net` — no async runtime dependency.
//!
//! The server is codec-agnostic: each connection negotiates its wire format
//! on the first byte ([`negotiate`] — text line protocol or binary v2
//! framing, both on one port), and from then on the connection loop only
//! moves typed [`Command`]s in and [`Reply`]s out. All formatting knowledge
//! lives in the codec; [`dispatch`] maps `Command → Reply` against the
//! service with none.
//!
//! * **Connection isolation** — every accepted connection gets its own
//!   reader thread; a malformed frame yields a one-frame `Err` reply and
//!   the connection keeps going; an I/O error kills only that connection,
//!   never the server.
//! * **Backpressure without wedging** — submissions go through the
//!   service's non-blocking [`ScoringService::try_submit_batch`] (and
//!   friends) in a bounded-sleep retry loop that also watches the shutdown
//!   flag, so one stalled shard can slow a connection but can neither wedge
//!   it past shutdown nor drop events.
//! * **Graceful shutdown** — the `Shutdown` command (or
//!   [`ShutdownHandle::signal`]) stops the accept loop, joins every
//!   connection thread, drains all shards via [`ScoringService::finish`]
//!   and returns the final [`ServiceReport`] from [`NetServer::run`].

use super::codec::{negotiate, Codec, CommandRead, Negotiated, Wire, WireMode};
use super::command::{Command, Reply, DEFAULT_ADDR};
use crate::cli::Config;
use crate::entropy::FingerState;
use crate::graph::Graph;
use crate::service::{ScoringService, ServiceConfig, ServiceReport, SubmitError};
use crate::stream::StreamEvent;
use anyhow::{Context, Result};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Knobs of the network front end, readable from the `[net]` config section.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Listen address (`host:port`; port 0 binds an ephemeral port).
    pub addr: String,
    /// Which wires the server accepts / the client speaks by default:
    /// `auto` (negotiate per connection) or a single named wire.
    pub wire: WireMode,
    /// Sleep between non-blocking submit retries while a shard queue is
    /// full (microseconds).
    pub backoff_us: u64,
    /// Socket read timeout used to poll the shutdown flag (milliseconds);
    /// bounds how long a drained connection outlives a shutdown request.
    pub poll_ms: u64,
    /// Socket write timeout (milliseconds): a client that stops reading its
    /// replies gets its connection dropped instead of wedging the thread
    /// (and the shutdown join) in `write_all` forever.
    pub write_timeout_ms: u64,
    /// Client-side reply-read timeout (milliseconds; 0 disables): a hung or
    /// wedged server surfaces as a clean per-connection error instead of
    /// blocking `finger load` forever.
    pub client_timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: DEFAULT_ADDR.to_string(),
            wire: WireMode::Auto,
            backoff_us: 200,
            poll_ms: 25,
            write_timeout_ms: 5000,
            client_timeout_ms: 30_000,
        }
    }
}

impl NetConfig {
    /// Read the `[net]` section of a parsed config file; missing keys fall
    /// back to the defaults. Recognized keys: `addr`, `wire`
    /// (`auto` | `text` | `binary`), `backoff_us`, `poll_ms`,
    /// `write_timeout_ms`, `client_timeout_ms`.
    pub fn from_config(c: &Config) -> Self {
        let d = Self::default();
        Self {
            addr: c.get("net.addr").unwrap_or(&d.addr).to_string(),
            wire: c.get("net.wire").and_then(WireMode::parse).unwrap_or(d.wire),
            backoff_us: c.get_or("net.backoff_us", d.backoff_us).max(1),
            poll_ms: c.get_or("net.poll_ms", d.poll_ms).max(1),
            write_timeout_ms: c.get_or("net.write_timeout_ms", d.write_timeout_ms).max(1),
            client_timeout_ms: c.get_or("net.client_timeout_ms", d.client_timeout_ms),
        }
    }

    /// The client read deadline this config implies (`None` when disabled).
    pub fn client_timeout(&self) -> Option<Duration> {
        (self.client_timeout_ms > 0)
            .then(|| Duration::from_millis(self.client_timeout_ms))
    }
}

/// Signals a running [`NetServer`] to stop from another thread (tests, a
/// CLI signal handler). Protocol clients use the `Shutdown` command instead.
#[derive(Clone)]
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ShutdownHandle {
    pub fn signal(&self) {
        self.flag.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection; a wildcard
        // bind address (0.0.0.0 / ::) is not connectable on every platform,
        // so target loopback on the bound port instead
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match self.addr {
                SocketAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
    }

    pub fn is_signaled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// The bound, not-yet-running server.
pub struct NetServer {
    listener: TcpListener,
    service: Arc<ScoringService>,
    net: NetConfig,
    shutdown: ShutdownHandle,
}

impl NetServer {
    /// Bind the listen socket and start the scoring service's shard workers.
    pub fn bind(service_cfg: ServiceConfig, net: NetConfig) -> Result<Self> {
        let listener = TcpListener::bind(&net.addr)
            .with_context(|| format!("bind {}", net.addr))?;
        let addr = listener.local_addr().context("local_addr")?;
        let shutdown = ShutdownHandle { flag: Arc::new(AtomicBool::new(false)), addr };
        Ok(Self {
            listener,
            service: Arc::new(ScoringService::start(service_cfg)),
            net,
            shutdown,
        })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.shutdown.addr
    }

    /// Handle for programmatic shutdown from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        self.shutdown.clone()
    }

    /// Accept connections until a `Shutdown` command (or
    /// [`ShutdownHandle::signal`]) arrives, then join every connection
    /// thread, drain the shards and return the final report.
    pub fn run(self) -> Result<ServiceReport> {
        let Self { listener, service, net, shutdown } = self;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for (conn_id, incoming) in listener.incoming().enumerate() {
            if shutdown.is_signaled() {
                break;
            }
            let stream = match incoming {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("net: accept failed: {e}");
                    continue;
                }
            };
            let service = Arc::clone(&service);
            let net = net.clone();
            let shutdown = shutdown.clone();
            let handle = std::thread::Builder::new()
                .name(format!("finger-conn-{conn_id}"))
                .spawn(move || {
                    if let Err(e) = handle_conn(stream, &service, &net, &shutdown) {
                        // per-connection isolation: log and move on
                        eprintln!("net: connection {conn_id}: {e}");
                    }
                })
                .context("spawn connection thread")?;
            conns.push(handle);
            // opportunistically reap finished connection threads
            conns = conns
                .into_iter()
                .filter_map(|h| {
                    if h.is_finished() {
                        let _ = h.join();
                        None
                    } else {
                        Some(h)
                    }
                })
                .collect();
        }
        for h in conns {
            let _ = h.join();
        }
        let service = Arc::try_unwrap(service)
            .map_err(|_| anyhow::anyhow!("connection thread leaked a service handle"))?;
        Ok(service.finish())
    }
}

/// One attempt of a non-blocking service call inside [`retry_backoff`].
enum Backoff<T> {
    /// The call went through.
    Done(T),
    /// The shard queue was full — sleep and try again.
    Retry,
    /// Terminal failure (shard closed); the `Err` reason.
    Fail(String),
}

/// The shared full-queue retry discipline of every service call on a
/// connection thread: retry `attempt` with `backoff_us` sleeps while the
/// target shard's queue is full, honoring a shutdown request so one
/// stalled shard can't wedge the thread past a drain. `Err` carries the
/// reply to send instead.
fn retry_backoff<T>(
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    mut attempt: impl FnMut() -> Backoff<T>,
) -> Result<T, Reply> {
    loop {
        match attempt() {
            Backoff::Done(v) => return Ok(v),
            Backoff::Fail(reason) => return Err(Reply::Err(reason)),
            Backoff::Retry => {
                if shutdown.is_signaled() {
                    return Err(Reply::Err("shutting-down".to_string()));
                }
                std::thread::sleep(Duration::from_micros(net.backoff_us));
            }
        }
    }
}

/// Submit a batch through the non-blocking path; returns the accepted
/// event count. Rejected batches are handed back by the service and rebound
/// directly (no `Option` shuttle), so retries never clone the events and the
/// loop has no panic path (FL001).
fn submit_batch_backoff(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    id: &str,
    mut events: Vec<StreamEvent>,
) -> Result<usize, Reply> {
    loop {
        match service.try_submit_batch(id, events) {
            Ok(n) => return Ok(n),
            Err((back, SubmitError::WouldBlock { .. })) => {
                if shutdown.is_signaled() {
                    return Err(Reply::Err("shutting-down".to_string()));
                }
                events = back;
                std::thread::sleep(Duration::from_micros(net.backoff_us));
            }
            Err((_, e)) => return Err(Reply::Err(e.to_string())),
        }
    }
}

/// Open a session through the non-blocking path; the initial state is built
/// once and handed back by the service on every retry (same loop shape as
/// `submit_batch_backoff`, for the same FL001 reason).
fn open_backoff(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    id: &str,
    nodes: usize,
) -> Result<(), Reply> {
    let mut state = FingerState::with_policy(Graph::new(nodes), service.config().policy);
    loop {
        match service.try_open_session_state(id, state) {
            Ok(()) => return Ok(()),
            Err((back, SubmitError::WouldBlock { .. })) => {
                if shutdown.is_signaled() {
                    return Err(Reply::Err("shutting-down".to_string()));
                }
                state = back;
                std::thread::sleep(Duration::from_micros(net.backoff_us));
            }
            Err((_, e)) => return Err(Reply::Err(e.to_string())),
        }
    }
}

/// Query through the non-blocking path.
fn query_backoff(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    id: &str,
) -> Result<Option<crate::service::SessionSnapshot>, Reply> {
    retry_backoff(net, shutdown, || match service.try_query(id) {
        Ok(snap) => Backoff::Done(snap),
        Err(SubmitError::WouldBlock { .. }) => Backoff::Retry,
        Err(e) => Backoff::Fail(e.to_string()),
    })
}

/// Close through the non-blocking path.
fn close_backoff(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    id: &str,
) -> Result<Option<crate::service::SessionSnapshot>, Reply> {
    retry_backoff(net, shutdown, || match service.try_close_session(id) {
        Ok(snap) => Backoff::Done(snap),
        Err(SubmitError::WouldBlock { .. }) => Backoff::Retry,
        Err(e) => Backoff::Fail(e.to_string()),
    })
}

fn stats_reply(service: &ScoringService) -> Reply {
    let depths: Vec<String> =
        service.queue_depths().iter().map(|d| d.to_string()).collect();
    Reply::OkKv(vec![
        ("shards".to_string(), service.shards().to_string()),
        ("depths".to_string(), depths.join(",")),
        ("submitted".to_string(), service.events_submitted().to_string()),
    ])
}

/// What the connection loop does after writing the reply.
enum Flow {
    Continue,
    /// Close this connection (the server keeps running).
    Quit,
    /// Signal server shutdown and close this connection.
    Shutdown,
}

/// Map one command to its reply against the service. This is the whole
/// server-side semantics of the protocol — no wire format in sight.
fn dispatch(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    cmd: Command,
) -> (Reply, Flow) {
    let reply = match cmd {
        Command::Open { id, nodes } => {
            match open_backoff(service, net, shutdown, &id, nodes) {
                Ok(()) => Reply::Ok,
                Err(err) => err,
            }
        }
        Command::Event { id, ev } => {
            match submit_batch_backoff(service, net, shutdown, &id, vec![ev]) {
                Ok(_) => Reply::Ok,
                Err(err) => err,
            }
        }
        Command::Batch { id, events } => {
            match submit_batch_backoff(service, net, shutdown, &id, events) {
                Ok(n) => Reply::kv("accepted", n),
                Err(err) => err,
            }
        }
        Command::Query { id } => match query_backoff(service, net, shutdown, &id) {
            Ok(Some(snap)) => Reply::Snapshot(snap),
            Ok(None) => Reply::Err("unknown-session".to_string()),
            Err(err) => err,
        },
        Command::Close { id } => match close_backoff(service, net, shutdown, &id) {
            Ok(Some(snap)) => Reply::Snapshot(snap),
            Ok(None) => Reply::Err("unknown-session".to_string()),
            Err(err) => err,
        },
        Command::Stats => stats_reply(service),
        Command::Quit => return (Reply::Ok, Flow::Quit),
        Command::Shutdown => return (Reply::Ok, Flow::Shutdown),
    };
    (reply, Flow::Continue)
}

/// Serve one connection until `Quit`, EOF, `Shutdown` or an I/O error.
fn handle_conn(
    stream: TcpStream,
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
) -> Result<()> {
    stream.set_nodelay(true).ok(); // request/reply latency over throughput
    stream
        .set_read_timeout(Some(Duration::from_millis(net.poll_ms)))
        .context("set_read_timeout")?;
    // a client that stops reading replies must not wedge this thread (and
    // the shutdown join) in write_all — time the write out and drop it
    stream
        .set_write_timeout(Some(Duration::from_millis(net.write_timeout_ms)))
        .context("set_write_timeout")?;
    let mut writer = stream.try_clone().context("clone stream")?;
    let mut reader = BufReader::new(stream);
    let stop = || shutdown.is_signaled();
    // buffer each reply frame and hit the socket once, so a frame is never
    // split across a write timeout
    let mut wbuf: Vec<u8> = Vec::new();
    let mut reply = |codec: &mut dyn Codec,
                     w: &mut TcpStream,
                     r: &Reply|
     -> std::io::Result<()> {
        wbuf.clear();
        codec.write_reply(&mut wbuf, r)?;
        w.write_all(&wbuf)
    };

    // first byte picks the wire; nothing text-framed is consumed
    let mut codec = match negotiate(&mut reader, &stop)? {
        Negotiated::Codec(c) => c,
        Negotiated::Eof | Negotiated::Interrupted => return Ok(()),
        Negotiated::BadPreamble(reason) => {
            // the peer committed to binary framing; answer in kind and close
            let mut bincodec = Wire::Binary.codec();
            reply(bincodec.as_mut(), &mut writer, &Reply::Err(reason))?;
            return Ok(());
        }
    };
    if !net.wire.allows(codec.wire()) {
        let refusal =
            Reply::Err(format!("{} wire disabled on this server", codec.wire()));
        reply(codec.as_mut(), &mut writer, &refusal)?;
        return Ok(());
    }

    loop {
        let resp = match codec.read_command(&mut reader, &stop)? {
            CommandRead::Eof | CommandRead::Interrupted => return Ok(()),
            CommandRead::Malformed(reason) => Reply::Err(reason),
            CommandRead::Cmd(cmd) => {
                let (resp, flow) = dispatch(service, net, shutdown, cmd);
                match flow {
                    Flow::Continue => resp,
                    Flow::Quit => {
                        reply(codec.as_mut(), &mut writer, &resp)?;
                        return Ok(());
                    }
                    Flow::Shutdown => {
                        reply(codec.as_mut(), &mut writer, &resp)?;
                        shutdown.signal();
                        return Ok(());
                    }
                }
            }
        };
        reply(codec.as_mut(), &mut writer, &resp)?;
        // during a drain, finish the in-flight request but take no new ones:
        // a connection that never pauses must not stall the shutdown join
        if shutdown.is_signaled() {
            return Ok(());
        }
    }
}
