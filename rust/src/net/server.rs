//! Readiness-driven TCP front end: a fixed pool of event-loop threads puts
//! the [`ScoringService`] on a socket and multiplexes tens of thousands of
//! nonblocking connections over `poll(2)` ([`poll`](super::poll) — pure
//! `std::net` plus one FFI declaration, no async runtime).
//!
//! Every connection is a small state machine ([`Conn`]): a per-connection
//! read buffer feeds the codec's incremental [`Codec::decode`] (partial
//! frames park in the buffer, so a slow or stalled sender costs its own
//! connection nothing but a few buffered bytes), replies queue in a write
//! buffer with partial-write handling, and the buffers are pooled across
//! connections. Dispatch stays pure `Command → Reply` with no formatting
//! knowledge.
//!
//! * **Negotiation in the state machine** — the first buffered byte picks
//!   the codec ([`negotiate_buf`]): text consumes nothing, a binary
//!   preamble consumes exactly its two bytes, and a refused or malformed
//!   preamble answers with one `Err` frame before the connection drains.
//! * **Backpressure as readiness** — a command the service cannot take yet
//!   ([`SubmitError::WouldBlock`]) parks as [`Pending`] and the
//!   connection's read interest is withdrawn until the shard accepts it:
//!   flow control by suspending the socket, not by sleeping a thread. The
//!   parked attempt retries on a `backoff_us` cadence.
//! * **Graceful shutdown** — `SHUTDOWN` (or [`ShutdownHandle::signal`])
//!   wakes every loop through its waker socket; parked commands answer
//!   `shutting-down`, queued replies flush under the write deadline, the
//!   accept loop stops, and [`NetServer::run`] joins the loops, drains the
//!   shards and returns the final [`ServiceReport`].
//! * **No idle burn** — an idle loop parks in `poll` with no timeout; new
//!   connections and shutdown arrive as waker bytes, so a quiet server
//!   makes no periodic wakeups at all.

use super::codec::{
    negotiate_buf, Codec, Decode, NegotiatedBuf, ReadBuf, Wire, WireMode, READ_CHUNK,
};
use super::command::{Command, Reply, DEFAULT_ADDR};
use super::poll::{poll_fds, raw_fd, PollFd, POLLIN, POLLOUT};
use crate::cli::Config;
use crate::entropy::FingerState;
use crate::graph::Graph;
use crate::service::{ScoringService, ServiceConfig, ServiceReport, SubmitError};
use crate::stream::StreamEvent;
use anyhow::{Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Bounded hand-off queue from the accept loop to each event loop.
const INTAKE_CAP: usize = 1024;

/// Per-connection write-queue high-water mark: once this many reply bytes
/// are queued, the connection stops decoding (and reading) until the peer
/// drains some — a client that pipelines requests without reading replies
/// is flow-controlled instead of ballooning the server.
const WBUF_HIGH: usize = 256 * 1024;

/// Recycled buffer pool cap per event loop (two buffers per connection).
const POOL_CAP: usize = 128;

/// Knobs of the network front end, readable from the `[net]` config section.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Listen address (`host:port`; port 0 binds an ephemeral port).
    pub addr: String,
    /// Which wires the server accepts / the client speaks by default:
    /// `auto` (negotiate per connection) or a single named wire.
    pub wire: WireMode,
    /// Retry cadence for a command parked on a full shard queue
    /// (microseconds); the event loop's poll timeout while anything is
    /// parked, never slept on a thread.
    pub backoff_us: u64,
    /// Event-loop threads; each owns a poll set of nonblocking connections
    /// (accepted connections are dealt round-robin).
    pub event_threads: usize,
    /// Write-progress deadline (milliseconds): a client that stops reading
    /// its replies gets its connection dropped once its write queue makes
    /// no progress for this long, instead of wedging a drain.
    pub write_timeout_ms: u64,
    /// Client-side reply-read timeout (milliseconds; 0 disables): a hung or
    /// wedged server surfaces as a clean per-connection error instead of
    /// blocking `finger load` forever.
    pub client_timeout_ms: u64,
    /// Load shedding (milliseconds; 0 disables): a command parked on a
    /// saturated shard for this long is answered `ERR retry-after <ms>`
    /// instead of holding its connection parked forever; retrying clients
    /// honor the hint.
    pub shed_after_ms: u64,
    /// Observability knobs: the periodic JSON snapshot writer and the
    /// slow-request span ring (`[obs]` section, `finger serve
    /// --metrics-interval/--metrics-out`).
    pub obs: crate::obs::ObsConfig,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: DEFAULT_ADDR.to_string(),
            wire: WireMode::Auto,
            backoff_us: 200,
            event_threads: 2,
            write_timeout_ms: 5000,
            client_timeout_ms: 30_000,
            shed_after_ms: 0,
            obs: crate::obs::ObsConfig::default(),
        }
    }
}

impl NetConfig {
    /// Read the `[net]` and `[obs]` sections of a parsed config file;
    /// missing keys fall back to the defaults. Recognized keys: `addr`,
    /// `wire` (`auto` | `text` | `binary`), `backoff_us`, `event_threads`,
    /// `write_timeout_ms`, `client_timeout_ms`, `shed_after_ms`;
    /// `obs.snapshot_path`, `obs.interval_ms`, `obs.slow_n`,
    /// `obs.sample_every`.
    pub fn from_config(c: &Config) -> Self {
        let d = Self::default();
        let od = crate::obs::ObsConfig::default();
        Self {
            addr: c.get("net.addr").unwrap_or(&d.addr).to_string(),
            wire: c.get("net.wire").and_then(WireMode::parse).unwrap_or(d.wire),
            backoff_us: c.get_or("net.backoff_us", d.backoff_us).max(1),
            event_threads: c.get_or("net.event_threads", d.event_threads).clamp(1, 64),
            write_timeout_ms: c.get_or("net.write_timeout_ms", d.write_timeout_ms).max(1),
            client_timeout_ms: c.get_or("net.client_timeout_ms", d.client_timeout_ms),
            shed_after_ms: c.get_or("net.shed_after_ms", d.shed_after_ms),
            obs: crate::obs::ObsConfig {
                snapshot_path: c.get("obs.snapshot_path").map(str::to_string),
                interval_ms: c.get_or("obs.interval_ms", od.interval_ms).max(1),
                slow_n: c.get_or("obs.slow_n", od.slow_n),
                sample_every: c.get_or("obs.sample_every", od.sample_every),
            },
        }
    }

    /// The client read deadline this config implies (`None` when disabled).
    pub fn client_timeout(&self) -> Option<Duration> {
        (self.client_timeout_ms > 0)
            .then(|| Duration::from_millis(self.client_timeout_ms))
    }
}

/// Signals a running [`NetServer`] to stop from another thread (tests, a
/// CLI signal handler). Protocol clients use the `Shutdown` command instead.
#[derive(Clone)]
pub struct ShutdownHandle {
    flag: Arc<AtomicBool>,
    addr: SocketAddr,
    /// Write side of each event loop's waker socket; a signal nudges every
    /// loop out of its (possibly indefinite) poll.
    wakers: Arc<Mutex<Vec<TcpStream>>>,
}

impl ShutdownHandle {
    pub fn signal(&self) {
        self.flag.store(true, Ordering::SeqCst);
        // finger-lint: allow(FL001): crash-on-poison policy — the registry only holds wake handles
        let wakers = self.wakers.lock().expect("waker registry poisoned");
        for w in wakers.iter() {
            let mut w: &TcpStream = w;
            let _ = w.write_all(&[1u8]);
        }
        drop(wakers);
        // wake the blocking accept with a throwaway connection; a wildcard
        // bind address (0.0.0.0 / ::) is not connectable on every platform,
        // so target loopback on the bound port instead
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match self.addr {
                SocketAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                SocketAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
    }

    pub fn is_signaled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    fn register_waker(&self, w: TcpStream) {
        // finger-lint: allow(FL001): crash-on-poison policy — the registry only holds wake handles
        self.wakers.lock().expect("waker registry poisoned").push(w);
    }
}

/// A loopback socket pair used to interrupt a parked `poll`: the returned
/// `(write, read)` halves are connected; the read half is nonblocking and
/// sits in the loop's poll set, the write half is nudged with single bytes.
fn waker_pair() -> std::io::Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0))?;
    let tx = TcpStream::connect(listener.local_addr()?)?;
    let (rx, _) = listener.accept()?;
    rx.set_nonblocking(true)?;
    tx.set_nodelay(true).ok();
    Ok((tx, rx))
}

/// The bound, not-yet-running server.
pub struct NetServer {
    listener: TcpListener,
    service: Arc<ScoringService>,
    net: NetConfig,
    shutdown: ShutdownHandle,
}

impl NetServer {
    /// Bind the listen socket and start the scoring service's shard workers.
    /// When the config carries a `[durability]` dir this recovers the latest
    /// epoch snapshot plus WAL tail before accepting a single connection, so
    /// a restarted server answers queries with bit-identical session state.
    pub fn bind(service_cfg: ServiceConfig, net: NetConfig) -> Result<Self> {
        let listener = TcpListener::bind(&net.addr)
            .with_context(|| format!("bind {}", net.addr))?;
        let addr = listener.local_addr().context("local_addr")?;
        let shutdown = ShutdownHandle {
            flag: Arc::new(AtomicBool::new(false)),
            addr,
            wakers: Arc::new(Mutex::new(Vec::new())),
        };
        let service = ScoringService::recover(service_cfg).context("durability recovery")?;
        Ok(Self { listener, service: Arc::new(service), net, shutdown })
    }

    /// What startup recovery restored (empty outside durability mode).
    pub fn recovery(&self) -> &crate::service::RecoveryReport {
        self.service.recovery()
    }

    /// Re-open the finish-time `<id>.ckpt` sessions under the configured
    /// `checkpoint_dir`, if any. A no-op when the directory is unset or
    /// absent, and in durability mode — there the epoch snapshot + WAL
    /// replay already rebuilt every session, and double-restoring would
    /// reset them. Returns how many sessions were restored.
    pub fn restore_checkpoint_sessions(&self) -> Result<usize> {
        if self.service.config().durability.is_some() {
            return Ok(0);
        }
        match self.service.config().checkpoint_dir.clone() {
            Some(dir) if dir.is_dir() => self.service.restore_sessions(dir),
            _ => Ok(0),
        }
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.shutdown.addr
    }

    /// Handle for programmatic shutdown from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        self.shutdown.clone()
    }

    /// Accept connections until a `Shutdown` command (or
    /// [`ShutdownHandle::signal`]) arrives, dealing them round-robin to the
    /// event-loop threads; then join every loop, drain the shards and
    /// return the final report.
    pub fn run(self) -> Result<ServiceReport> {
        let Self { listener, service, net, shutdown } = self;
        let threads = net.event_threads.max(1);
        crate::obs::init_spans(net.obs.slow_n, net.obs.sample_every);
        crate::obs::note_loops(threads);
        let mut loops = Vec::with_capacity(threads);
        let mut senders: Vec<SyncSender<TcpStream>> = Vec::with_capacity(threads);
        let mut wake_txs: Vec<TcpStream> = Vec::with_capacity(threads);
        let mut boot_err: Option<anyhow::Error> = None;
        for t in 0..threads {
            let booted = waker_pair()
                .context("create event-loop waker")
                .and_then(|(wake_tx, wake_rx)| {
                    let clone = wake_tx.try_clone().context("clone waker")?;
                    Ok((wake_tx, wake_rx, clone))
                });
            let (wake_tx, wake_rx, waker_clone) = match booted {
                Ok(parts) => parts,
                Err(e) => {
                    boot_err = Some(e);
                    break;
                }
            };
            shutdown.register_waker(waker_clone);
            let (tx, rx) = sync_channel::<TcpStream>(INTAKE_CAP);
            let (service, net, shutdown) =
                (Arc::clone(&service), net.clone(), shutdown.clone());
            let spawned = std::thread::Builder::new()
                .name(format!("finger-loop-{t}"))
                .spawn(move || {
                    EventLoop::new(t, service, net, shutdown, rx, wake_rx).run()
                });
            match spawned {
                Ok(h) => {
                    loops.push(h);
                    senders.push(tx);
                    wake_txs.push(wake_tx);
                }
                Err(e) => {
                    boot_err =
                        Some(anyhow::Error::new(e).context("spawn event-loop thread"));
                    break;
                }
            }
        }
        // periodic JSON metrics snapshots while the server runs; the final
        // post-drain write below covers whatever happened after the last tick
        let mut obs_writer = None;
        if let Some(p) = net.obs.snapshot_path.clone() {
            let path = std::path::PathBuf::from(p);
            let service = Arc::clone(&service);
            let shutdown = shutdown.clone();
            let interval = Duration::from_millis(net.obs.interval_ms.max(1));
            let spawned = std::thread::Builder::new()
                .name("finger-obs".to_string())
                .spawn(move || loop {
                    super::backoff::sleep_interruptible(interval, &|| shutdown.is_signaled());
                    let extras = service_extras(&service);
                    if let Err(e) = crate::obs::write_snapshot(&path, &extras) {
                        eprintln!("net: metrics snapshot {}: {e}", path.display());
                    }
                    if shutdown.is_signaled() {
                        return;
                    }
                });
            match spawned {
                Ok(h) => obs_writer = Some(h),
                Err(e) => eprintln!("net: spawn metrics writer: {e}"),
            }
        }
        // periodic online epoch snapshots while the server runs (durability
        // mode with `snapshot_interval_ms > 0`); the drain-time cut below
        // covers whatever happened after the last tick
        let mut epoch_timer = None;
        let epoch_interval_ms =
            service.config().durability.as_ref().map_or(0, |d| d.snapshot_interval_ms);
        if epoch_interval_ms > 0 {
            let service = Arc::clone(&service);
            let shutdown = shutdown.clone();
            let interval = Duration::from_millis(epoch_interval_ms);
            let spawned = std::thread::Builder::new()
                .name("finger-epoch".to_string())
                .spawn(move || loop {
                    if super::backoff::sleep_interruptible(interval, &|| shutdown.is_signaled())
                    {
                        return;
                    }
                    if let Err(e) = service.snapshot_epoch() {
                        eprintln!("net: epoch snapshot: {e}");
                    }
                });
            match spawned {
                Ok(h) => epoch_timer = Some(h),
                Err(e) => eprintln!("net: spawn epoch timer: {e}"),
            }
        }
        if boot_err.is_none() {
            let mut next = 0usize;
            for incoming in listener.incoming() {
                if shutdown.is_signaled() {
                    break;
                }
                let stream = match incoming {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("net: accept failed: {e}");
                        continue;
                    }
                };
                let t = next % threads;
                next = next.wrapping_add(1);
                // a full intake queue briefly blocks accept — bounded
                // backpressure instead of an unbounded backlog
                let sent = senders.get(t).map(|tx| tx.send(stream).is_ok()).unwrap_or(false);
                if sent {
                    if let Some(w) = wake_txs.get_mut(t) {
                        let _ = w.write_all(&[1u8]);
                    }
                }
            }
        }
        shutdown.signal();
        drop(senders); // event loops see a disconnected intake
        for w in wake_txs.iter_mut() {
            let _ = w.write_all(&[1u8]);
        }
        for h in loops {
            let _ = h.join();
        }
        if let Some(h) = obs_writer {
            let _ = h.join();
        }
        if let Some(h) = epoch_timer {
            let _ = h.join();
        }
        // one post-drain snapshot so the file on disk reflects the quiesced
        // counters (every event loop has joined; nothing submits anymore)
        if let Some(p) = net.obs.snapshot_path.as_deref() {
            let extras = service_extras(&service);
            if let Err(e) = crate::obs::write_snapshot(std::path::Path::new(p), &extras) {
                eprintln!("net: metrics snapshot {p}: {e}");
            }
        }
        if let Some(e) = boot_err {
            return Err(e);
        }
        // one final epoch cut so a clean shutdown restarts from the snapshot
        // alone (no WAL tail to replay); every event loop has joined, so
        // nothing submits concurrently and the cut covers everything
        if service.config().durability.is_some() {
            if let Err(e) = service.snapshot_epoch() {
                eprintln!("net: final epoch snapshot: {e}");
            }
        }
        let service = Arc::try_unwrap(service)
            .map_err(|_| anyhow::anyhow!("event loop leaked a service handle"))?;
        Ok(service.finish())
    }
}

fn stats_reply(service: &ScoringService) -> Reply {
    let depths: Vec<String> =
        service.queue_depths().iter().map(|d| d.to_string()).collect();
    Reply::OkKv(vec![
        ("shards".to_string(), service.shards().to_string()),
        ("depths".to_string(), depths.join(",")),
        ("submitted".to_string(), service.events_submitted().to_string()),
        ("uptime_ms".to_string(), service.uptime_ms().to_string()),
        (
            "connections".to_string(),
            crate::obs::Gauge::NetConnections.get().to_string(),
        ),
        ("durability".to_string(), service.durability_status().to_string()),
    ])
}

/// Service-side extras merged into every metrics report and snapshot:
/// totals the registry cannot see on its own (authoritative submit count,
/// live queue depths) keyed alongside the registry's counters.
fn service_extras(service: &ScoringService) -> Vec<(String, u64)> {
    let mut extra = vec![
        ("service_shards".to_string(), service.shards() as u64),
        (
            "service_events_submitted".to_string(),
            service.events_submitted() as u64,
        ),
        ("uptime_ms".to_string(), service.uptime_ms()),
        (
            "durability_degraded".to_string(),
            u64::from(service.durability_health() == crate::service::DUR_DEGRADED),
        ),
        (
            "durability_failed".to_string(),
            u64::from(service.durability_health() == crate::service::DUR_FAILED),
        ),
    ];
    for (i, d) in service.queue_depths().iter().enumerate() {
        extra.push((format!("shard{i}_depth"), *d as u64));
    }
    extra
}

fn metrics_reply(service: &ScoringService) -> Reply {
    Reply::Metrics(crate::obs::report(&service_extras(service)))
}

/// A command the service could not take yet (shard queue full): the typed
/// retry state parked on its connection. While one of these is parked the
/// connection reads nothing — service backpressure propagates to the
/// socket, and the attempt re-runs on the `backoff_us` poll cadence.
enum Pending {
    /// `reliable` carries the `(epoch, acked)` pair a reliable OPEN must
    /// answer with once the service accepts the session.
    Open { id: String, state: Box<FingerState>, reliable: Option<(u64, u64)> },
    /// `seq` is the client sequence number to acknowledge once the batch is
    /// accepted (exactly-once writes; `None` for plain fire-and-forget).
    Batch { id: String, events: Vec<StreamEvent>, single: bool, seq: Option<u64> },
    Query { id: String },
    Close { id: String },
}

/// One non-blocking service attempt: done (with the reply) or parked again.
enum Attempt {
    Done(Reply),
    Blocked(Pending),
}

/// A parked attempt plus when it first parked — the span's queue-wait clock.
struct Parked {
    p: Pending,
    since: Instant,
}

/// Copy the span source fields out of a pending attempt before the service
/// consumes it: command kind, the session-id bytes (truncated to the span
/// ring's fixed width, so nothing allocates) and the target shard.
fn span_src(
    service: &ScoringService,
    p: &Pending,
) -> (crate::obs::SpanKind, [u8; crate::obs::SPAN_ID_BYTES], usize, usize) {
    use crate::obs::SpanKind;
    let (kind, id) = match p {
        Pending::Open { id, .. } => (SpanKind::Open, id),
        Pending::Batch { id, .. } => (SpanKind::Batch, id),
        Pending::Query { id } => (SpanKind::Query, id),
        Pending::Close { id } => (SpanKind::Close, id),
    };
    let mut buf = [0u8; crate::obs::SPAN_ID_BYTES];
    let len = id.len().min(buf.len());
    for (dst, src) in buf.iter_mut().zip(id.as_bytes()) {
        *dst = *src;
    }
    (kind, buf, len, service.shard_for(id))
}

/// Run one attempt of `p` against the service. Rejected payloads are handed
/// back by the service and rebound directly, so retries never clone events
/// or state and the path has no panic site.
fn attempt(service: &ScoringService, p: Pending) -> Attempt {
    match p {
        Pending::Open { id, state, reliable } => {
            match service.try_open_session_state(&id, *state) {
                Ok(()) => Attempt::Done(match reliable {
                    Some((epoch, acked)) => Reply::OkKv(vec![
                        ("epoch".to_string(), epoch.to_string()),
                        ("acked".to_string(), acked.to_string()),
                    ]),
                    None => Reply::Ok,
                }),
                Err((back, SubmitError::WouldBlock { .. })) => {
                    Attempt::Blocked(Pending::Open { id, state: Box::new(back), reliable })
                }
                Err((_, e)) => Attempt::Done(Reply::Err(e.to_string())),
            }
        }
        Pending::Batch { id, events, single, seq } => {
            match service.try_submit_batch(&id, events) {
                Ok(n) => Attempt::Done(match seq {
                    Some(s) => {
                        service.reliable_ack(&id, s);
                        Reply::OkKv(vec![
                            ("accepted".to_string(), n.to_string()),
                            ("acked".to_string(), s.to_string()),
                        ])
                    }
                    None if single => Reply::Ok,
                    None => Reply::kv("accepted", n),
                }),
                Err((back, SubmitError::WouldBlock { .. })) => {
                    Attempt::Blocked(Pending::Batch { id, events: back, single, seq })
                }
                Err((_, e)) => Attempt::Done(Reply::Err(e.to_string())),
            }
        }
        Pending::Query { id } => match service.try_query(&id) {
            Ok(Some(snap)) => Attempt::Done(Reply::Snapshot(snap)),
            Ok(None) => Attempt::Done(Reply::Err("unknown-session".to_string())),
            Err(SubmitError::WouldBlock { .. }) => Attempt::Blocked(Pending::Query { id }),
            Err(e) => Attempt::Done(Reply::Err(e.to_string())),
        },
        Pending::Close { id } => match service.try_close_session(&id) {
            Ok(Some(snap)) => Attempt::Done(Reply::Snapshot(snap)),
            Ok(None) => Attempt::Done(Reply::Err("unknown-session".to_string())),
            Err(SubmitError::WouldBlock { .. }) => Attempt::Blocked(Pending::Close { id }),
            Err(e) => Attempt::Done(Reply::Err(e.to_string())),
        },
    }
}

// lint: event-loop

/// Where a connection is in its life. `Draining` writes out what is queued
/// (under the write deadline) and closes; nothing further is read.
enum Lifecycle {
    /// Waiting for the first byte(s) to pick the codec.
    Negotiating,
    /// Normal request/reply service.
    Active,
    /// Flush the write queue, then close.
    Draining { since: Instant },
}

/// Per-connection state machine owned by one event loop.
struct Conn {
    stream: TcpStream,
    fd: i32,
    /// Loop-local id for log lines.
    serial: u64,
    /// `None` until the first byte(s) negotiate a wire.
    codec: Option<Box<dyn Codec>>,
    rbuf: ReadBuf,
    /// Encoded replies not yet written; `wpos` marks the written prefix.
    wbuf: Vec<u8>,
    wpos: usize,
    pending: Option<Parked>,
    life: Lifecycle,
    /// Peer closed its write side (read returned 0).
    peer_eof: bool,
    /// Set while the write queue is stuck on `WouldBlock`.
    write_stall: Option<Instant>,
    dead: bool,
}

impl Conn {
    fn queued(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    fn is_draining(&self) -> bool {
        matches!(self.life, Lifecycle::Draining { .. })
    }

    fn start_drain(&mut self) {
        if !self.is_draining() {
            self.life = Lifecycle::Draining { since: Instant::now() };
        }
    }

    /// Read interest: withdrawn while a command is parked on backpressure,
    /// while the write queue is over its high-water mark, and once the
    /// connection is draining or the peer's write side is closed.
    fn wants_read(&self) -> bool {
        !self.dead
            && !self.is_draining()
            && !self.peer_eof
            && self.pending.is_none()
            && self.queued() < WBUF_HIGH
    }

    /// Encode one reply onto the write queue with this connection's codec.
    fn reply(&mut self, r: &Reply) {
        let was = self.queued();
        let Some(codec) = self.codec.as_mut() else {
            self.dead = true;
            return;
        };
        if codec.write_reply(&mut self.wbuf, r).is_err() {
            self.dead = true;
        }
        if was < WBUF_HIGH && self.queued() >= WBUF_HIGH {
            crate::obs::Counter::NetWriteSuspensions.inc();
        }
    }

    /// Pull whatever the socket has ready into the read buffer (bounded per
    /// call: leftovers re-report readiness on the next poll, so one greedy
    /// peer cannot starve the rest of the set).
    fn fill(&mut self) {
        if crate::fault::fire(crate::fault::Failpoint::NetRead) {
            self.dead = true; // injected connection reset
            return;
        }
        let mut r: &TcpStream = &self.stream;
        for _ in 0..4 {
            match self.rbuf.fill_from(&mut r, READ_CHUNK) {
                Ok(0) => {
                    self.peer_eof = true;
                    return;
                }
                Ok(n) => crate::obs::Counter::NetBytesIn.add(n as u64),
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Write as much of the queue as the socket takes. `WouldBlock` arms the
    /// stall clock; no progress for `deadline` drops the connection instead
    /// of letting an unread reply wedge a drain.
    fn flush(&mut self, deadline: Duration) {
        if crate::fault::fire(crate::fault::Failpoint::NetWrite) {
            self.dead = true; // injected connection reset
            return;
        }
        let mut w: &TcpStream = &self.stream;
        while self.wpos < self.wbuf.len() {
            let chunk = self.wbuf.get(self.wpos..).unwrap_or(&[]);
            match w.write(chunk) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.write_stall = None;
                    crate::obs::Counter::NetBytesOut.add(n as u64);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    let since = *self.write_stall.get_or_insert_with(Instant::now);
                    if since.elapsed() >= deadline {
                        self.dead = true;
                    }
                    return;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        self.wbuf.clear();
        self.wpos = 0;
    }
}

/// Map one decoded command to its reply (or parked attempt) against the
/// service. This is the whole server-side semantics of the protocol — no
/// wire format in sight.
fn dispatch_cmd(
    service: &ScoringService,
    shutdown: &ShutdownHandle,
    conn: &mut Conn,
    cmd: Command,
) {
    match cmd {
        Command::Open { id, nodes, epoch } => {
            if let Some(r) = durability_gate(service) {
                conn.reply(&r);
                return;
            }
            let reliable = match epoch {
                None => {
                    // a plain OPEN resets any reliable-session bookkeeping:
                    // the client opted out of exactly-once semantics
                    service.reliable_forget(&id);
                    None
                }
                Some(client_epoch) => {
                    if let Some((epoch, acked)) = service.reliable_resume(&id, client_epoch) {
                        // same epoch, session already live: a reconnect, not
                        // a re-open — answer the resume point immediately
                        conn.reply(&Reply::OkKv(vec![
                            ("epoch".to_string(), epoch.to_string()),
                            ("acked".to_string(), acked.to_string()),
                        ]));
                        return;
                    }
                    Some((service.reliable_begin(&id), 0))
                }
            };
            let state = Box::new(FingerState::with_policy(
                Graph::new(nodes),
                service.config().policy,
            ));
            run_attempt(service, shutdown, conn, Pending::Open { id, state, reliable });
        }
        Command::Event { id, ev, seq } => {
            reliable_write(service, shutdown, conn, id, vec![ev], true, seq);
        }
        Command::Batch { id, events, seq } => {
            reliable_write(service, shutdown, conn, id, events, false, seq);
        }
        Command::Query { id } => run_attempt(service, shutdown, conn, Pending::Query { id }),
        Command::Close { id } => {
            service.reliable_forget(&id);
            run_attempt(service, shutdown, conn, Pending::Close { id });
        }
        Command::Fault { name, spec } => conn.reply(&fault_reply(&name, &spec)),
        Command::Stats => conn.reply(&stats_reply(service)),
        Command::Metrics => conn.reply(&metrics_reply(service)),
        Command::Epoch => {
            // admin verb: blocks this event loop for one barrier round-trip
            // across the shards (checkpoint writes included) — rare by
            // construction, and every other loop keeps serving meanwhile
            let r = match service.snapshot_epoch() {
                Ok(cut) => Reply::OkKv(vec![
                    ("epoch".to_string(), cut.epoch.to_string()),
                    ("sessions".to_string(), cut.sessions.to_string()),
                ]),
                Err(e) => Reply::Err(e.to_string()),
            };
            conn.reply(&r);
        }
        Command::Quit => {
            conn.reply(&Reply::Ok);
            conn.start_drain();
        }
        Command::Shutdown => {
            conn.reply(&Reply::Ok);
            shutdown.signal();
            conn.start_drain();
        }
    }
}

/// Refuse writes while durability is failed (`on_error = fail_stop`): the
/// WAL cannot record them, so accepting would silently break the
/// recovers-bit-identically contract. Cleared by the next successful epoch
/// cut.
fn durability_gate(service: &ScoringService) -> Option<Reply> {
    (service.durability_health() == crate::service::DUR_FAILED).then(|| {
        Reply::Err(
            "durability-failed write-ahead log unavailable (on_error=fail_stop)".to_string(),
        )
    })
}

/// One write command (EVENT or BATCH), with the exactly-once seq protocol
/// applied before the service sees it: duplicates answer without
/// re-applying, gaps refuse, fresh seqs flow to the normal attempt path and
/// acknowledge on completion.
fn reliable_write(
    service: &ScoringService,
    shutdown: &ShutdownHandle,
    conn: &mut Conn,
    id: String,
    events: Vec<StreamEvent>,
    single: bool,
    seq: Option<u64>,
) {
    if let Some(r) = durability_gate(service) {
        conn.reply(&r);
        return;
    }
    if let Some(s) = seq {
        use crate::service::SeqOutcome;
        match service.reliable_seq(&id, s) {
            SeqOutcome::Apply => {}
            SeqOutcome::Duplicate { acked } => {
                // already applied before the client's previous reply was
                // lost: acknowledge again, apply nothing
                crate::obs::Counter::DupDiscards.inc();
                conn.reply(&Reply::OkKv(vec![
                    ("accepted".to_string(), "0".to_string()),
                    ("acked".to_string(), acked.to_string()),
                    ("dup".to_string(), "1".to_string()),
                ]));
                return;
            }
            SeqOutcome::Gap { acked } => {
                conn.reply(&Reply::Err(format!("seq-gap acked={acked}")));
                return;
            }
        }
    }
    run_attempt(service, shutdown, conn, Pending::Batch { id, events, single, seq });
}

/// Answer the `FAULT <name> <spec>` admin verb: arm (or disarm) one
/// failpoint on a live server. A build without the `fault-inject` feature
/// refuses rather than silently ignoring a chaos schedule.
fn fault_reply(name: &str, spec: &str) -> Reply {
    if !crate::fault::compiled_in() {
        return Reply::Err(
            "fault-injection not compiled in (build with --features fault-inject)".to_string(),
        );
    }
    let Some(fp) = crate::fault::Failpoint::parse(name) else {
        return Reply::Err(format!("unknown-failpoint {name}"));
    };
    let Some(parsed) = crate::fault::FaultSpec::parse(spec) else {
        return Reply::Err(format!("bad-fault-spec {spec}"));
    };
    crate::fault::set(fp, parsed);
    Reply::OkKv(vec![
        ("fault".to_string(), name.to_string()),
        ("spec".to_string(), parsed.render()),
    ])
}

/// First attempt of a service command; a full shard queue parks it on the
/// connection (unless a shutdown is in progress, which answers like the
/// old retry loop did).
fn run_attempt(
    service: &ScoringService,
    shutdown: &ShutdownHandle,
    conn: &mut Conn,
    p: Pending,
) {
    let t0 = Instant::now();
    let (kind, idbuf, idlen, shard) = span_src(service, &p);
    match attempt(service, p) {
        Attempt::Done(r) => {
            let total_us = t0.elapsed().as_micros() as u64;
            crate::obs::request_us().record(conn.serial as usize, total_us);
            let id = std::str::from_utf8(idbuf.get(..idlen).unwrap_or(&[])).unwrap_or("");
            crate::obs::span_record(kind, id, shard, 0, total_us);
            conn.reply(&r);
        }
        Attempt::Blocked(p) => {
            if shutdown.is_signaled() {
                conn.reply(&Reply::Err("shutting-down".to_string()));
            } else {
                crate::obs::Counter::NetParks.inc();
                conn.pending = Some(Parked { p, since: t0 });
            }
        }
    }
}

/// Advance one connection as far as it can go without blocking: negotiate
/// the codec, retry a parked command, decode and dispatch every complete
/// buffered frame flow control allows, then opportunistically flush.
fn progress_conn(
    service: &ScoringService,
    net: &NetConfig,
    shutdown: &ShutdownHandle,
    conn: &mut Conn,
) {
    if conn.dead {
        return;
    }

    // first byte(s) pick the wire; a refused wire answers on the codec the
    // peer committed to, before any command arrives
    if conn.codec.is_none() && !conn.is_draining() {
        match negotiate_buf(&mut conn.rbuf) {
            NegotiatedBuf::Codec(c) => {
                let wire = c.wire();
                conn.codec = Some(c);
                if net.wire.allows(wire) {
                    conn.life = Lifecycle::Active;
                } else {
                    conn.reply(&Reply::Err(format!("{wire} wire disabled on this server")));
                    conn.start_drain();
                }
            }
            NegotiatedBuf::Incomplete => {
                if conn.peer_eof {
                    // closed before (or inside) the preamble: nothing to say
                    conn.dead = true;
                }
            }
            NegotiatedBuf::BadPreamble(reason) => {
                conn.codec = Some(Wire::Binary.codec());
                conn.reply(&Reply::Err(reason));
                conn.start_drain();
            }
        }
    }

    // retry the parked command before decoding anything new — replies must
    // stay in request order
    if let Some(parked) = conn.pending.take() {
        if shutdown.is_signaled() {
            conn.reply(&Reply::Err("shutting-down".to_string()));
        } else if net.shed_after_ms > 0
            && parked.since.elapsed() >= Duration::from_millis(net.shed_after_ms)
        {
            // load shedding: the shard stayed saturated past the budget, so
            // answer with a retry hint instead of parking indefinitely —
            // the client backs off and the connection resumes reading
            crate::obs::Counter::ShedRequests.inc();
            conn.reply(&Reply::Err(format!("retry-after {}", net.shed_after_ms)));
        } else {
            let since = parked.since;
            let queue_us = since.elapsed().as_micros() as u64;
            let (kind, idbuf, idlen, shard) = span_src(service, &parked.p);
            match attempt(service, parked.p) {
                Attempt::Done(r) => {
                    crate::obs::Counter::NetResumes.inc();
                    let total_us = since.elapsed().as_micros() as u64;
                    let stripe = conn.serial as usize;
                    crate::obs::request_us().record(stripe, total_us);
                    crate::obs::queue_wait_us().record(stripe, queue_us);
                    let id = std::str::from_utf8(idbuf.get(..idlen).unwrap_or(&[]))
                        .unwrap_or("");
                    crate::obs::span_record(kind, id, shard, queue_us, total_us);
                    conn.reply(&r);
                }
                Attempt::Blocked(p) => conn.pending = Some(Parked { p, since }),
            }
        }
    }

    // decode every complete buffered frame flow control allows
    loop {
        if conn.pending.is_some()
            || conn.is_draining()
            || conn.dead
            || conn.queued() >= WBUF_HIGH
        {
            break;
        }
        let outcome = match conn.codec.as_mut() {
            Some(codec) => codec.decode(&mut conn.rbuf, conn.peer_eof),
            None => break,
        };
        match outcome {
            Ok(Decode::Cmd(cmd)) => dispatch_cmd(service, shutdown, conn, cmd),
            Ok(Decode::Malformed(reason)) => {
                crate::obs::Counter::NetDecodeErrors.inc();
                conn.reply(&Reply::Err(reason));
            }
            Ok(Decode::Incomplete) => break,
            Ok(Decode::Eof) => {
                conn.start_drain();
                break;
            }
            Err(e) => {
                // fatal framing error: flush what is queued, then close
                crate::obs::Counter::NetDecodeErrors.inc();
                eprintln!("net: connection {}: {e}", conn.serial);
                conn.start_drain();
                break;
            }
        }
    }

    if conn.queued() > 0 {
        conn.flush(Duration::from_millis(net.write_timeout_ms));
    }
}

/// One event-loop thread: a poll set of nonblocking connections, the waker
/// socket, and the bounded intake from the accept loop.
struct EventLoop {
    /// Which loop this is (`finger-loop-{index}`) — its slot in the
    /// per-loop poll-set gauges.
    index: usize,
    service: Arc<ScoringService>,
    net: NetConfig,
    shutdown: ShutdownHandle,
    intake: Receiver<TcpStream>,
    waker: TcpStream,
    conns: Vec<Conn>,
    pollfds: Vec<PollFd>,
    /// Recycled read/write buffers from closed connections.
    pool: Vec<Vec<u8>>,
    next_serial: u64,
}

impl EventLoop {
    fn new(
        index: usize,
        service: Arc<ScoringService>,
        net: NetConfig,
        shutdown: ShutdownHandle,
        intake: Receiver<TcpStream>,
        waker: TcpStream,
    ) -> Self {
        Self {
            index,
            service,
            net,
            shutdown,
            intake,
            waker,
            conns: Vec::new(),
            pollfds: Vec::new(),
            pool: Vec::new(),
            next_serial: 0,
        }
    }

    fn run(mut self) {
        loop {
            self.drain_intake();
            if self.shutdown.is_signaled() {
                self.begin_shutdown_drain();
            }
            for conn in self.conns.iter_mut() {
                progress_conn(&self.service, &self.net, &self.shutdown, conn);
            }
            self.sweep();
            if self.shutdown.is_signaled() && self.conns.is_empty() {
                return;
            }
            self.poll_wait();
        }
    }

    /// Adopt connections the accept loop handed over (drop them straight
    /// away once a shutdown is in progress, like an un-accepted backlog).
    fn drain_intake(&mut self) {
        loop {
            match self.intake.try_recv() {
                Ok(stream) => {
                    if self.shutdown.is_signaled() {
                        continue;
                    }
                    if let Err(e) = self.admit(stream) {
                        eprintln!("net: connection setup failed: {e}");
                    }
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => return,
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) -> std::io::Result<()> {
        stream.set_nodelay(true).ok(); // request/reply latency over throughput
        stream.set_nonblocking(true)?;
        let fd = raw_fd(&stream);
        let rbuf = ReadBuf::from_vec(self.pool.pop().unwrap_or_default());
        let mut wbuf = self.pool.pop().unwrap_or_default();
        wbuf.clear();
        let serial = self.next_serial;
        self.next_serial = self.next_serial.wrapping_add(1);
        self.conns.push(Conn {
            stream,
            fd,
            serial,
            codec: None,
            rbuf,
            wbuf,
            wpos: 0,
            pending: None,
            life: Lifecycle::Negotiating,
            peer_eof: false,
            write_stall: None,
            dead: false,
        });
        crate::obs::Counter::NetAccepted.inc();
        crate::obs::Gauge::NetConnections.inc();
        Ok(())
    }

    /// Fail parked commands and stop taking new ones on every connection;
    /// queued replies still flush under the write deadline.
    fn begin_shutdown_drain(&mut self) {
        for conn in self.conns.iter_mut() {
            if conn.pending.take().is_some() {
                conn.reply(&Reply::Err("shutting-down".to_string()));
            }
            conn.start_drain();
        }
    }

    /// Close finished connections and recycle their buffers.
    fn sweep(&mut self) {
        let deadline = Duration::from_millis(self.net.write_timeout_ms);
        let pool = &mut self.pool;
        self.conns.retain_mut(|c| {
            if let Lifecycle::Draining { since } = c.life {
                if c.queued() == 0 || since.elapsed() >= deadline {
                    c.dead = true;
                }
            }
            if !c.dead {
                return true;
            }
            crate::obs::Gauge::NetConnections.dec();
            if pool.len() + 1 < POOL_CAP {
                pool.push(std::mem::take(&mut c.rbuf).into_vec());
                let mut w = std::mem::take(&mut c.wbuf);
                w.clear();
                pool.push(w);
            }
            false
        });
    }

    /// How long the next poll may park. Fully idle means indefinitely — new
    /// work arrives as readiness or a waker byte, never on a timer.
    fn tick_timeout_ms(&self) -> i32 {
        let mut parked = false;
        let mut busy = false;
        for c in &self.conns {
            parked |= c.pending.is_some();
            busy |= c.queued() > 0 || c.is_draining();
        }
        if parked {
            // service backpressure: retry cadence (poll still wakes earlier
            // for any socket event)
            ((self.net.backoff_us / 1000).max(1)).min(50) as i32
        } else if busy || self.shutdown.is_signaled() {
            // bounded tick to enforce write/drain deadlines
            25
        } else {
            -1
        }
    }

    /// Park in `poll(2)`, then move readiness into the connections: fill
    /// read buffers, flush write queues. Decode/dispatch happens at the top
    /// of the loop, right after this returns.
    fn poll_wait(&mut self) {
        self.pollfds.clear();
        self.pollfds.push(PollFd::interest(raw_fd(&self.waker), POLLIN));
        for c in &self.conns {
            let mut ev = 0i16;
            if c.wants_read() {
                ev |= POLLIN;
            }
            if c.queued() > 0 {
                ev |= POLLOUT;
            }
            self.pollfds.push(PollFd::interest(c.fd, ev));
        }
        crate::obs::set_loop_pollset(self.index, self.pollfds.len() as u64);
        let timeout = self.tick_timeout_ms();
        if let Err(e) = poll_fds(&mut self.pollfds, timeout) {
            eprintln!("net: poll failed: {e}");
            super::backoff::sleep_ms(1);
            return;
        }
        crate::obs::Counter::NetWakeups.inc();
        if self.pollfds.first().map(|p| p.readable()).unwrap_or(false) {
            self.drain_waker();
        }
        let deadline = Duration::from_millis(self.net.write_timeout_ms);
        for (c, p) in self.conns.iter_mut().zip(self.pollfds.iter().skip(1)) {
            if c.dead {
                continue;
            }
            if p.readable() && c.wants_read() {
                c.fill();
            }
            if p.writable() && c.queued() > 0 {
                c.flush(deadline);
            }
        }
    }

    /// Swallow queued wake bytes (their only content is "wake up").
    fn drain_waker(&mut self) {
        let mut sink = [0u8; 64];
        let mut r: &TcpStream = &self.waker;
        loop {
            match r.read(&mut sink) {
                Ok(0) => return,
                Ok(_) => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
    }
}

// lint: event-loop end
