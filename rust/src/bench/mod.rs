//! Minimal micro-benchmark harness (criterion is unavailable offline):
//! warmup + timed iterations, reporting mean/p50/p99 and throughput. Used by
//! every target in `rust/benches/`.

use crate::util::stats::percentile;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<5} mean={:<10} p50={:<10} p99={}",
            self.name,
            self.iters,
            crate::util::fmt::secs(self.mean_secs),
            crate::util::fmt::secs(self.p50_secs),
            crate::util::fmt::secs(self.p99_secs),
        )
    }
}

/// Benchmark runner with a time budget per case.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Soft time budget per case (seconds).
    pub budget_secs: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 2, min_iters: 5, max_iters: 200, budget_secs: 2.0 }
    }
}

impl Bencher {
    /// Fast settings for CI-ish runs.
    pub fn quick() -> Self {
        Self { warmup_iters: 1, min_iters: 3, max_iters: 30, budget_secs: 0.5 }
    }

    /// Time `f`, preventing dead-code elimination via the returned value.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let budget_start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && budget_start.elapsed().as_secs_f64() < self.budget_secs)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_secs: mean,
            p50_secs: percentile(&samples, 50.0),
            p99_secs: percentile(&samples, 99.0),
        }
    }
}

/// Shared CLI convention for bench targets: `--full` switches paper scale,
/// `--quick` shrinks budgets (also honored via env FINGER_BENCH=quick|full).
pub fn bench_mode() -> BenchMode {
    let args: Vec<String> = std::env::args().collect();
    let env = std::env::var("FINGER_BENCH").unwrap_or_default();
    if args.iter().any(|a| a == "--full") || env == "full" {
        BenchMode::Full
    } else if args.iter().any(|a| a == "--quick") || env == "quick" {
        BenchMode::Quick
    } else {
        BenchMode::Default
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchMode {
    Quick,
    Default,
    Full,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_min_iters() {
        let b = Bencher { warmup_iters: 0, min_iters: 7, max_iters: 10, budget_secs: 0.0 };
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.iters, 7);
        assert!(r.mean_secs >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bencher { warmup_iters: 0, min_iters: 1, max_iters: 3, budget_secs: 100.0 };
        let r = b.run("noop", || ());
        assert!(r.iters <= 3);
    }

    #[test]
    fn report_contains_name() {
        let r = Bencher::quick().run("my-case", || 42);
        assert!(r.report().contains("my-case"));
    }
}
