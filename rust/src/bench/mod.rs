//! Minimal micro-benchmark harness (criterion is unavailable offline):
//! warmup + timed iterations, reporting mean/p50/p99 and throughput. Used by
//! every target in `rust/benches/`.

use crate::util::stats::LatencySummary;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let summary = LatencySummary {
            count: self.iters as u64,
            mean: self.mean_secs,
            p50: self.p50_secs,
            p99: self.p99_secs,
        };
        format!("{:<44} iters={:<5} {}", self.name, self.iters, summary.report_secs())
    }
}

/// Benchmark runner with a time budget per case.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Soft time budget per case (seconds).
    pub budget_secs: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 2, min_iters: 5, max_iters: 200, budget_secs: 2.0 }
    }
}

impl Bencher {
    /// Fast settings for CI-ish runs.
    pub fn quick() -> Self {
        Self { warmup_iters: 1, min_iters: 3, max_iters: 30, budget_secs: 0.5 }
    }

    /// Time `f`, preventing dead-code elimination via the returned value.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let budget_start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && budget_start.elapsed().as_secs_f64() < self.budget_secs)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let summary = LatencySummary::from_samples(&samples);
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_secs: summary.mean,
            p50_secs: summary.p50,
            p99_secs: summary.p99,
        }
    }
}

/// Shared CLI convention for bench targets: `--full` switches paper scale,
/// `--quick` shrinks budgets (also honored via env FINGER_BENCH=quick|full).
pub fn bench_mode() -> BenchMode {
    let args: Vec<String> = std::env::args().collect();
    let env = std::env::var("FINGER_BENCH").unwrap_or_default();
    if args.iter().any(|a| a == "--full") || env == "full" {
        BenchMode::Full
    } else if args.iter().any(|a| a == "--quick") || env == "quick" {
        BenchMode::Quick
    } else {
        BenchMode::Default
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchMode {
    Quick,
    Default,
    Full,
}

/// One entry of a machine-readable bench report (`BENCH_*.json`): either a
/// timed case (from a [`BenchResult`]) or a free-standing metric such as an
/// aggregate throughput.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    /// Metric value (seconds for timed cases, unit given by `unit`).
    pub value: f64,
    pub unit: String,
    /// Optional p50/p99 for timed cases.
    pub p50_secs: Option<f64>,
    pub p99_secs: Option<f64>,
}

impl BenchRecord {
    /// A free-standing metric (e.g. `events_per_sec`).
    pub fn metric(name: impl Into<String>, value: f64, unit: impl Into<String>) -> Self {
        Self { name: name.into(), value, unit: unit.into(), p50_secs: None, p99_secs: None }
    }
}

impl From<&BenchResult> for BenchRecord {
    fn from(r: &BenchResult) -> Self {
        Self {
            name: r.name.clone(),
            value: r.mean_secs,
            unit: "secs_mean".to_string(),
            p50_secs: Some(r.p50_secs),
            p99_secs: Some(r.p99_secs),
        }
    }
}

/// JSON string escaping shared by [`write_json_report`] and the
/// observability snapshot writer (`crate::obs::snapshot`) — hand-rolled
/// because serde is not in the offline registry.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON number rendering (non-finite → `null`), shared with the snapshot
/// writer like [`json_escape`].
pub fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:e}")
    } else {
        "null".to_string()
    }
}

/// Write records as a `BENCH_*.json` file (hand-rolled JSON — no serde in
/// the offline registry) so the perf trajectory can be tracked across PRs.
pub fn write_json_report(
    path: impl AsRef<std::path::Path>,
    bench: &str,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"{}\",", json_escape(bench))?;
    writeln!(f, "  \"records\": [")?;
    for (k, r) in records.iter().enumerate() {
        let comma = if k + 1 < records.len() { "," } else { "" };
        let mut extra = String::new();
        if let Some(p50) = r.p50_secs {
            extra.push_str(&format!(", \"p50_secs\": {}", json_num(p50)));
        }
        if let Some(p99) = r.p99_secs {
            extra.push_str(&format!(", \"p99_secs\": {}", json_num(p99)));
        }
        writeln!(
            f,
            "    {{\"name\": \"{}\", \"value\": {}, \"unit\": \"{}\"{extra}}}{comma}",
            json_escape(&r.name),
            json_num(r.value),
            json_escape(&r.unit),
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_min_iters() {
        let b = Bencher { warmup_iters: 0, min_iters: 7, max_iters: 10, budget_secs: 0.0 };
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.iters, 7);
        assert!(r.mean_secs >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bencher { warmup_iters: 0, min_iters: 1, max_iters: 3, budget_secs: 100.0 };
        let r = b.run("noop", || ());
        assert!(r.iters <= 3);
    }

    #[test]
    fn report_contains_name() {
        let r = Bencher::quick().run("my-case", || 42);
        assert!(r.report().contains("my-case"));
    }

    #[test]
    fn json_report_roundtrips_structure() {
        let r = Bencher::quick().run("timed \"case\"", || 42);
        let records =
            vec![BenchRecord::from(&r), BenchRecord::metric("throughput", 1.5e6, "events_per_sec")];
        let path = std::env::temp_dir().join("finger_bench_report_test.json");
        write_json_report(&path, "unit-test", &records).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"unit-test\""));
        assert!(text.contains("timed \\\"case\\\""), "{text}");
        assert!(text.contains("events_per_sec"));
        assert!(text.contains("p99_secs"));
        assert_eq!(text.matches("{\"name\"").count(), 2);
        std::fs::remove_file(path).ok();
    }
}
