//! The sharded scoring engine: N shard workers, each owning a
//! `SessionRegistry` and fed by a bounded channel. `submit` hashes the
//! session id to a shard and blocks when that shard's queue is full
//! (backpressure); `finish` drains the workers and aggregates per-session
//! reports. See the module docs in `service/mod.rs` for the full model.

use super::config::ServiceConfig;
use super::registry::{shard_of, SessionRegistry};
use super::session::{SessionReport, SessionSnapshot, SessionState};
use crate::entropy::FingerState;
use crate::graph::Graph;
use crate::stream::{checkpoint, StreamEvent};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Message routed to a shard worker. Per-session ordering is guaranteed by
/// the single FIFO channel each shard consumes.
enum ShardMsg {
    /// (Re)open a session with an explicit state.
    Open { id: String, state: FingerState },
    /// One stream event for a session.
    Event { id: String, ev: StreamEvent },
    /// A batch of events for one session (amortizes the per-message routing
    /// and channel cost on the ingest path).
    Batch { id: String, events: Vec<StreamEvent> },
    /// Point-in-time read of a session's live stats. Flows through the same
    /// FIFO channel as events, so a query observes everything the caller
    /// submitted before it.
    Query { id: String, reply: Sender<Option<SessionSnapshot>> },
    /// Retire a session: flush its trailing partial window, free the shard
    /// state and reply with the final snapshot (`None` if unknown). FIFO
    /// ordering means the close observes every event submitted before it.
    Close { id: String, reply: Sender<Option<SessionSnapshot>> },
}

/// Submission failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The target shard's worker is gone (it panicked — workers otherwise
    /// outlive every sender).
    Closed { shard: usize },
    /// Non-blocking submission (`try_submit*`) found the shard's bounded
    /// queue full; the blocking `submit` path waits instead of failing.
    WouldBlock { shard: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed { shard } => {
                write!(f, "shard {shard} is no longer accepting events")
            }
            SubmitError::WouldBlock { shard } => {
                write!(f, "shard {shard}'s queue is full (would block)")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// The running service. `submit` may be called from any number of threads
/// (`&self`, channels are `Sync`); `finish` consumes the service, joins the
/// workers and returns the aggregate report.
pub struct ScoringService {
    cfg: ServiceConfig,
    senders: Vec<SyncSender<ShardMsg>>,
    workers: Vec<JoinHandle<ShardOutcome>>,
    /// Messages in flight per shard (queued + the one being processed);
    /// incremented on send, decremented by the worker as it picks each up.
    depths: Vec<Arc<AtomicUsize>>,
    submitted: AtomicUsize,
    start: Instant,
}

struct ShardOutcome {
    reports: Vec<SessionReport>,
    dropped: usize,
    closed_reports_dropped: usize,
}

/// Per-shard cap on retained reports of `Close`d sessions. Open/close churn
/// (or a hostile `OPEN`/`CLOSE` loop) must not grow server memory without
/// bound; past the cap the oldest-retired histories are dropped and only
/// counted ([`ServiceReport::closed_reports_dropped`]). Event *accounting*
/// ([`ServiceReport::total_events`]) is a counter and stays exact
/// regardless.
const MAX_RETAINED_CLOSED: usize = 4096;

impl ScoringService {
    /// Spawn the shard workers and start accepting events.
    pub fn start(cfg: ServiceConfig) -> Self {
        let shards = cfg.shards.max(1);
        crate::obs::note_shards(shards);
        let mut senders = Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        let mut depths = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = sync_channel::<ShardMsg>(cfg.channel_capacity.max(1));
            let worker_cfg = cfg.clone();
            let depth = Arc::new(AtomicUsize::new(0));
            let worker_depth = Arc::clone(&depth);
            let handle = std::thread::Builder::new()
                .name(format!("finger-shard-{shard}"))
                .spawn(move || shard_worker(rx, worker_cfg, worker_depth, shard))
                // finger-lint: allow(FL001): cold-start — no spawn, no service
                .expect("spawn shard worker");
            senders.push(tx);
            workers.push(handle);
            depths.push(depth);
        }
        Self {
            cfg,
            senders,
            workers,
            depths,
            submitted: AtomicUsize::new(0),
            start: Instant::now(),
        }
    }

    pub fn shards(&self) -> usize {
        self.senders.len()
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Deterministic shard a session's events flow through.
    pub fn shard_for(&self, session_id: &str) -> usize {
        shard_of(session_id, self.senders.len())
    }

    /// (Re)open a session with an initial graph. Ordered with respect to
    /// subsequent `submit`s for the same id (same FIFO shard channel).
    pub fn open_session(&self, id: &str, initial: Graph) -> Result<(), SubmitError> {
        self.open_session_state(id, FingerState::with_policy(initial, self.cfg.policy))
    }

    /// (Re)open a session resuming from an existing incremental state.
    pub fn open_session_state(&self, id: &str, state: FingerState) -> Result<(), SubmitError> {
        self.send(ShardMsg::Open { id: id.to_string(), state }).map(|_| ())
    }

    /// Route one event to `id`'s shard. Blocks while that shard's bounded
    /// queue is full (backpressure) — it never drops.
    pub fn submit(&self, id: &str, ev: StreamEvent) -> Result<(), SubmitError> {
        let shard = self.send(ShardMsg::Event { id: id.to_string(), ev })?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        crate::obs::shard_events_add(shard, 1);
        Ok(())
    }

    /// Route a whole event stream to one session; returns the event count.
    pub fn submit_all<I>(&self, id: &str, events: I) -> Result<usize, SubmitError>
    where
        I: IntoIterator<Item = StreamEvent>,
    {
        let mut n = 0;
        for ev in events {
            self.submit(id, ev)?;
            n += 1;
        }
        Ok(n)
    }

    /// Route a batch of events to `id`'s shard as a single message —
    /// identical semantics to submitting each event in order, at a fraction
    /// of the routing/channel overhead. Returns the batch size.
    pub fn submit_batch(&self, id: &str, events: Vec<StreamEvent>) -> Result<usize, SubmitError> {
        let n = events.len();
        if n == 0 {
            return Ok(0);
        }
        let shard = self.send(ShardMsg::Batch { id: id.to_string(), events })?;
        self.submitted.fetch_add(n, Ordering::Relaxed);
        crate::obs::shard_events_add(shard, n as u64);
        Ok(n)
    }

    /// Non-blocking [`submit`](Self::submit): fails with
    /// [`SubmitError::WouldBlock`] instead of waiting when `id`'s shard
    /// queue is full, so an ingest thread multiplexing many sessions (e.g. a
    /// network connection reader) is never wedged by one stalled shard.
    pub fn try_submit(&self, id: &str, ev: StreamEvent) -> Result<(), SubmitError> {
        let shard =
            self.try_send(ShardMsg::Event { id: id.to_string(), ev }).map_err(|(_, e)| e)?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        crate::obs::shard_events_add(shard, 1);
        Ok(())
    }

    /// Non-blocking [`submit_batch`](Self::submit_batch). On failure the
    /// events are handed back so the caller can retry without cloning.
    pub fn try_submit_batch(
        &self,
        id: &str,
        events: Vec<StreamEvent>,
    ) -> Result<usize, (Vec<StreamEvent>, SubmitError)> {
        let n = events.len();
        if n == 0 {
            return Ok(0);
        }
        match self.try_send(ShardMsg::Batch { id: id.to_string(), events }) {
            Ok(shard) => {
                self.submitted.fetch_add(n, Ordering::Relaxed);
                crate::obs::shard_events_add(shard, n as u64);
                Ok(n)
            }
            Err((ShardMsg::Batch { events, .. }, e)) => Err((events, e)),
            Err((_, e)) => Err((Vec::new(), e)), // try_send echoes the variant
        }
    }

    /// Non-blocking [`open_session_state`](Self::open_session_state): fails
    /// with [`SubmitError::WouldBlock`] when the shard's queue is full,
    /// handing the state back so the caller can retry without rebuilding it.
    pub fn try_open_session_state(
        &self,
        id: &str,
        state: FingerState,
    ) -> Result<(), (FingerState, SubmitError)> {
        match self.try_send(ShardMsg::Open { id: id.to_string(), state }) {
            Ok(_) => Ok(()),
            Err((ShardMsg::Open { state, .. }, e)) => Err((state, e)),
            // finger-lint: allow(FL001): try_send echoes the sent variant back
            Err(_) => unreachable!("try_send echoes the sent message variant"),
        }
    }

    /// Point-in-time stats for a live session (windows scored, latest
    /// JSdist, H̃, anomaly count, pending events). `Ok(None)` when the shard
    /// has no such session. The query rides the same FIFO channel as events,
    /// so it reflects every event this caller submitted before it. Blocks
    /// while the shard's queue is full, like `submit`.
    pub fn query(&self, id: &str) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.send(ShardMsg::Query { id: id.to_string(), reply: tx })?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Non-blocking [`query`](Self::query): fails with
    /// [`SubmitError::WouldBlock`] instead of waiting when the shard's queue
    /// is full. Once enqueued, the reply wait is bounded by the work already
    /// queued (shard workers never block on anything themselves).
    pub fn try_query(&self, id: &str) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.try_send(ShardMsg::Query { id: id.to_string(), reply: tx })
            .map_err(|(_, e)| e)?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Retire session `id`: flush its trailing partial window, free the
    /// shard state and return the final [`SessionSnapshot`] (`None` when the
    /// shard knows no such session — the wire maps that to
    /// `ERR unknown-session`). The close rides the same FIFO channel as
    /// events, so it observes everything this caller submitted before it.
    /// The retired session's report still counts in the final
    /// [`ServiceReport`] (its events were genuinely scored, retained up to a
    /// per-shard cap — see [`ServiceReport::closed_reports_dropped`]); it is
    /// simply no longer live, so later events for the id hit the
    /// auto-create/drop path and `finish` does not checkpoint it. Blocks
    /// while the shard's queue is full, like `submit`.
    pub fn close_session(&self, id: &str) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.send(ShardMsg::Close { id: id.to_string(), reply: tx })?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Non-blocking [`close_session`](Self::close_session): fails with
    /// [`SubmitError::WouldBlock`] instead of waiting when the shard's queue
    /// is full.
    pub fn try_close_session(
        &self,
        id: &str,
    ) -> Result<Option<SessionSnapshot>, SubmitError> {
        // finger-lint: allow(FL004): rendezvous reply; one message, then dropped
        let (tx, rx) = channel();
        self.try_send(ShardMsg::Close { id: id.to_string(), reply: tx })
            .map_err(|(_, e)| e)?;
        rx.recv().map_err(|_| SubmitError::Closed { shard: self.shard_for(id) })
    }

    /// Messages currently in flight per shard (queued plus being processed).
    /// A persistently deep shard signals a hot session set; the `STATS`
    /// protocol verb surfaces this to operators.
    pub fn queue_depths(&self) -> Vec<usize> {
        self.depths.iter().map(|d| d.load(Ordering::Relaxed)).collect()
    }

    /// Events accepted so far across all sessions.
    pub fn events_submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Milliseconds since the service started accepting events (surfaced by
    /// the `STATS`/`METRICS` protocol verbs and the obs snapshot).
    pub fn uptime_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Re-open every `<id>.ckpt` session found in `dir` (written by a prior
    /// run's `finish` with `checkpoint_dir` set). Returns how many sessions
    /// were restored.
    pub fn restore_sessions(&self, dir: impl AsRef<Path>) -> anyhow::Result<usize> {
        let mut restored = 0;
        let mut entries: Vec<_> =
            std::fs::read_dir(dir.as_ref())?.collect::<Result<Vec<_>, _>>()?;
        entries.sort_by_key(|e| e.path());
        for entry in entries {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("ckpt") {
                continue;
            }
            let id = match path
                .file_stem()
                .and_then(|s| s.to_str())
                .and_then(super::session::decode_session_id)
            {
                Some(s) => s,
                None => continue, // not written by our encoder
            };
            let state = checkpoint::load_with_policy(&path, self.cfg.policy)?;
            self.open_session_state(&id, state)
                .map_err(|e| anyhow::anyhow!("restore {id}: {e}"))?;
            restored += 1;
        }
        Ok(restored)
    }

    fn shard_of_msg(&self, msg: &ShardMsg) -> usize {
        let id = match msg {
            ShardMsg::Open { id, .. }
            | ShardMsg::Event { id, .. }
            | ShardMsg::Batch { id, .. }
            | ShardMsg::Query { id, .. }
            | ShardMsg::Close { id, .. } => id,
        };
        shard_of(id, self.senders.len())
    }

    /// Route `msg` to its shard, returning the shard index on success so
    /// callers can attribute the send in the metrics registry.
    fn send(&self, msg: ShardMsg) -> Result<usize, SubmitError> {
        let shard = self.shard_of_msg(&msg);
        // finger-lint: allow(FL001): shard_of bounds the index by senders.len()
        let (sender, depth) = (&self.senders[shard], &self.depths[shard]);
        // count before sending so a blocked send is visible as queue depth
        depth.fetch_add(1, Ordering::Relaxed);
        sender.send(msg).map(|()| shard).map_err(|_| {
            depth.fetch_sub(1, Ordering::Relaxed);
            SubmitError::Closed { shard }
        })
    }

    fn try_send(&self, msg: ShardMsg) -> Result<usize, (ShardMsg, SubmitError)> {
        let shard = self.shard_of_msg(&msg);
        // finger-lint: allow(FL001): shard_of bounds the index by senders.len()
        let (sender, depth) = (&self.senders[shard], &self.depths[shard]);
        depth.fetch_add(1, Ordering::Relaxed);
        sender.try_send(msg).map(|()| shard).map_err(|e| {
            depth.fetch_sub(1, Ordering::Relaxed);
            match e {
                TrySendError::Full(m) => {
                    crate::obs::shard_would_block(shard);
                    (m, SubmitError::WouldBlock { shard })
                }
                TrySendError::Disconnected(m) => (m, SubmitError::Closed { shard }),
            }
        })
    }

    /// Close the ingest side, drain every shard (flushing partial windows,
    /// checkpointing when configured) and aggregate the results.
    pub fn finish(self) -> ServiceReport {
        let Self { cfg, senders, workers, submitted, start, depths: _ } = self;
        drop(senders); // workers' receive loops end once the queues drain
        let mut sessions = Vec::new();
        let mut dropped_events = 0;
        let mut closed_reports_dropped = 0;
        for worker in workers {
            match worker.join() {
                Ok(outcome) => {
                    sessions.extend(outcome.reports);
                    dropped_events += outcome.dropped;
                    closed_reports_dropped += outcome.closed_reports_dropped;
                }
                // a panicked shard lost its session reports, but the drain
                // must still surface what the surviving shards scored
                Err(_) => {
                    eprintln!("finger-service: a shard worker panicked; its reports are lost");
                }
            }
        }
        sessions.sort_by(|a, b| a.id.cmp(&b.id));
        let wall_secs = start.elapsed().as_secs_f64();
        let total_events = submitted.into_inner();
        ServiceReport {
            throughput: total_events as f64 / wall_secs.max(1e-12),
            total_events,
            dropped_events,
            closed_reports_dropped,
            wall_secs,
            shards: cfg.shards.max(1),
            sessions,
        }
    }
}

fn shard_worker(
    rx: Receiver<ShardMsg>,
    cfg: ServiceConfig,
    depth: Arc<AtomicUsize>,
    shard: usize,
) -> ShardOutcome {
    let mut registry = SessionRegistry::new();
    let mut dropped = 0;
    // reports of sessions retired via Close: their events were scored, so
    // they still count in the final ServiceReport — they are just no longer
    // live (not queryable, not checkpointed at finish). Retention is capped
    // so close churn can't grow memory without bound.
    let mut closed: Vec<SessionReport> = Vec::new();
    let mut closed_reports_dropped = 0usize;
    let route = |registry: &mut SessionRegistry,
                     dropped: &mut usize,
                     id: String,
                     events: &mut dyn Iterator<Item = StreamEvent>| {
        if !registry.contains(&id) && cfg.auto_create_sessions {
            registry.insert(SessionState::new(id.clone(), Graph::new(0), &cfg));
            crate::obs::Gauge::SvcSessions.inc();
        }
        match registry.get_mut(&id) {
            Some(session) => {
                for ev in events {
                    if session.on_event(ev) {
                        crate::obs::shard_window(shard);
                    }
                }
            }
            // auto-create disabled and the id is unknown: count, don't panic
            None => *dropped += events.count(),
        }
    };
    for msg in rx {
        match msg {
            ShardMsg::Open { id, state } => {
                if !registry.contains(&id) {
                    crate::obs::Gauge::SvcSessions.inc();
                }
                registry.insert(SessionState::from_finger_state(id, state, &cfg));
            }
            ShardMsg::Event { id, ev } => {
                route(&mut registry, &mut dropped, id, &mut std::iter::once(ev));
            }
            ShardMsg::Batch { id, events } => {
                route(&mut registry, &mut dropped, id, &mut events.into_iter());
            }
            ShardMsg::Query { id, reply } => {
                // the querying side may have hung up; that's its business
                let _ = reply.send(registry.get(&id).map(SessionState::snapshot));
            }
            ShardMsg::Close { id, reply } => {
                let snapshot = registry.remove(&id).map(|mut session| {
                    crate::obs::Gauge::SvcSessions.dec();
                    if session.flush() {
                        // the final snapshot scores any open window
                        crate::obs::shard_window(shard);
                    }
                    let snap = session.snapshot();
                    if closed.len() < MAX_RETAINED_CLOSED {
                        closed.push(session.into_report());
                    } else {
                        closed_reports_dropped += 1;
                    }
                    snap
                });
                let _ = reply.send(snapshot);
            }
        }
        // decrement only after the message is fully processed, so depth
        // really is "queued + being processed": a shard grinding through a
        // huge batch must not look idle to STATS / rebalancing heuristics
        depth.fetch_sub(1, Ordering::Relaxed);
    }
    // ingest closed: flush, checkpoint, report
    let mut reports = closed;
    for mut session in registry.into_sessions() {
        crate::obs::Gauge::SvcSessions.dec();
        if session.flush() {
            crate::obs::shard_window(shard);
        }
        if let Some(dir) = &cfg.checkpoint_dir {
            if let Err(e) = session.checkpoint_into(dir) {
                eprintln!("checkpoint session {}: {e:#}", session.id());
            }
        }
        reports.push(session.into_report());
    }
    ShardOutcome { reports, dropped, closed_reports_dropped }
}

/// Aggregate outcome across all shards and sessions.
#[derive(Debug)]
pub struct ServiceReport {
    /// Per-session reports, sorted by session id.
    pub sessions: Vec<SessionReport>,
    /// Events accepted through `submit` across all sessions.
    pub total_events: usize,
    /// Events for unknown sessions dropped because `auto_create_sessions`
    /// was off.
    pub dropped_events: usize,
    /// `Close`d-session reports discarded past the per-shard retention cap
    /// (close churn must not grow memory unboundedly); their events remain
    /// counted in `total_events`.
    pub closed_reports_dropped: usize,
    pub wall_secs: f64,
    /// Accepted events per second, aggregated over the whole run.
    pub throughput: f64,
    pub shards: usize,
}

impl ServiceReport {
    pub fn session(&self, id: &str) -> Option<&SessionReport> {
        self.sessions.iter().find(|s| s.id == id)
    }

    pub fn total_windows(&self) -> usize {
        self.sessions.iter().map(|s| s.records.len()).sum()
    }

    pub fn total_anomalies(&self) -> usize {
        self.sessions.iter().map(|s| s.anomalies.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_session_basic_flow() {
        let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
        svc.open_session("a", Graph::new(4)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        let report = svc.finish();
        assert_eq!(report.total_events, 2);
        assert_eq!(report.dropped_events, 0);
        let s = report.session("a").unwrap();
        assert_eq!(s.records.len(), 1);
        assert_eq!(s.edges, 1);
    }

    #[test]
    fn auto_create_off_drops_and_counts() {
        let cfg = ServiceConfig { shards: 1, auto_create_sessions: false, ..Default::default() };
        let svc = ScoringService::start(cfg);
        svc.open_session("known", Graph::new(2)).unwrap();
        svc.submit("known", StreamEvent::Tick).unwrap();
        svc.submit("unknown", StreamEvent::Tick).unwrap();
        let report = svc.finish();
        assert_eq!(report.sessions.len(), 1);
        assert_eq!(report.dropped_events, 1);
        assert_eq!(report.total_events, 2);
    }

    #[test]
    fn try_submit_reports_would_block_and_recovers() {
        // capacity-1 queue, no consumer progress guaranteed: fill it with a
        // blocking submit, then try_submit must fail fast with WouldBlock
        // once the queue is full (never hang), and a blocking submit after
        // the worker drains must still succeed.
        let cfg = ServiceConfig { shards: 1, channel_capacity: 1, ..Default::default() };
        let svc = ScoringService::start(cfg);
        svc.open_session("a", Graph::new(4)).unwrap();
        // occupy the worker with one long batch so the queue stays full
        let busy: Vec<StreamEvent> = (0..200_000u32)
            .map(|k| StreamEvent::EdgeDelta { i: k % 4, j: (k + 1) % 4, dw: 1e-6 })
            .collect();
        svc.submit_batch("a", busy).unwrap();
        let mut saw_would_block = false;
        for _ in 0..10_000 {
            match svc.try_submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 0.01 }) {
                Ok(()) => {}
                Err(SubmitError::WouldBlock { shard }) => {
                    assert_eq!(shard, 0);
                    saw_would_block = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_would_block, "a capacity-1 queue must eventually refuse");
        // batch variant hands the events back for a clone-free retry
        let mut evs = vec![StreamEvent::Tick];
        loop {
            match svc.try_submit_batch("a", evs) {
                Ok(n) => {
                    assert_eq!(n, 1);
                    break;
                }
                Err((back, SubmitError::WouldBlock { .. })) => {
                    assert_eq!(back.len(), 1);
                    evs = back;
                    std::thread::yield_now();
                }
                Err((_, e)) => panic!("unexpected {e}"),
            }
        }
        let report = svc.finish();
        assert_eq!(report.total_events, report.session("a").unwrap().events);
    }

    #[test]
    fn queue_depths_drain_to_zero_and_query_sees_prior_events() {
        let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
        svc.open_session("a", Graph::new(4)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        // query is FIFO-ordered behind the events above
        let snap = svc.query("a").unwrap().expect("session exists");
        assert_eq!(snap.id, "a");
        assert_eq!(snap.windows, 1);
        assert_eq!(snap.events, 2);
        assert!(snap.last_jsdist.is_some());
        assert_eq!(snap.edges, 1);
        assert_eq!(snap.pending_events, 0);
        assert_eq!(svc.query("missing").unwrap(), None);
        assert_eq!(svc.queue_depths().len(), 2);
        // the query round-trip means everything queued ahead of it was
        // consumed; the query message's own depth decrement lands just
        // after the reply, so poll briefly instead of asserting instantly
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let depths = svc.queue_depths();
            if depths[svc.shard_for("a")] == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "depth never drained: {depths:?}");
            std::thread::yield_now();
        }
        assert_eq!(svc.events_submitted(), 2);
        svc.finish();
    }

    #[test]
    fn close_session_returns_final_snapshot_and_frees_state() {
        let svc = ScoringService::start(ServiceConfig { shards: 2, ..Default::default() });
        svc.open_session("a", Graph::new(4)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        // trailing partial window: flushed into the final snapshot
        svc.submit("a", StreamEvent::EdgeDelta { i: 1, j: 2, dw: 2.0 }).unwrap();
        let snap = svc.close_session("a").unwrap().expect("session was live");
        assert_eq!(snap.windows, 2, "close flushes the open window");
        assert_eq!(snap.events, 3);
        assert_eq!(snap.edges, 2);
        assert_eq!(snap.pending_events, 0);
        // the session is gone: a second close and a query both miss
        assert_eq!(svc.close_session("a").unwrap(), None);
        assert_eq!(svc.query("a").unwrap(), None);
        // ...but its scored history still reaches the final report
        let report = svc.finish();
        let s = report.session("a").expect("closed session still reported");
        assert_eq!(s.records.len(), 2);
        assert_eq!(s.events, 3);
        assert_eq!(report.total_events, 3);
    }

    #[test]
    fn reopening_a_session_resets_it() {
        let svc = ScoringService::start(ServiceConfig { shards: 1, ..Default::default() });
        svc.open_session("a", Graph::new(2)).unwrap();
        svc.submit("a", StreamEvent::EdgeDelta { i: 0, j: 1, dw: 1.0 }).unwrap();
        svc.submit("a", StreamEvent::Tick).unwrap();
        svc.open_session("a", Graph::new(2)).unwrap(); // reset
        svc.submit("a", StreamEvent::Tick).unwrap();
        let report = svc.finish();
        let s = report.session("a").unwrap();
        assert_eq!(s.records.len(), 1, "reset session only saw the final empty window");
        assert_eq!(s.edges, 0);
    }
}
